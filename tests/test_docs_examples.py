"""Every ```python block in README.md and docs/*.md must execute.

Doctest-style guard so the quickstart can never rot: blocks are extracted
verbatim and exec'd in order per document (later blocks see earlier
blocks' names, like a reader typing the document into one REPL).  Shell
blocks (```sh etc.) are not executed.  A block can opt out with a first
line of `# doctest: skip` (reserved for examples that need hardware or
network; none currently do).

`EXECUTED_EXAMPLES` scripts run end to end as subprocesses (they carry
their own assertions -- the streaming demo asserts overlays and a
delta-forced recompile both actually happened).
"""
import re
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"

_FENCE = re.compile(r"^```python[ \t]*$(.*?)^```[ \t]*$",
                    re.MULTILINE | re.DOTALL)


def python_blocks(path: Path):
    return [m.group(1).strip() for m in _FENCE.finditer(path.read_text())]


def doc_files():
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


DOCS = doc_files()


def test_docs_exist():
    names = {f.name for f in DOCS}
    assert "README.md" in names
    assert "architecture.md" in names
    assert "experiment_design.md" in names
    assert "paper_mapping.md" in names


def test_readme_has_executable_quickstart():
    assert python_blocks(REPO / "README.md"), \
        "README.md must contain at least one ```python quickstart block"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_doc_python_blocks_execute(doc, capsys):
    blocks = python_blocks(doc)
    if not blocks:
        pytest.skip(f"{doc.name}: no python blocks")
    if str(SRC) not in sys.path:
        sys.path.insert(0, str(SRC))
    ns = {"__name__": f"doc_{doc.stem}"}
    for i, block in enumerate(blocks):
        if block.startswith("# doctest: skip"):
            continue
        try:
            exec(compile(block, f"{doc.name}[block {i}]", "exec"), ns)
        except Exception as e:  # noqa: BLE001 - report which block broke
            pytest.fail(f"{doc.name} python block {i} failed: {e!r}\n"
                        f"---\n{block}\n---")


# Example scripts executed end to end (each carries its own assertions).
EXECUTED_EXAMPLES = ["examples/streaming_demo.py"]


@pytest.mark.parametrize("script", EXECUTED_EXAMPLES)
def test_example_scripts_execute(script):
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(REPO / script)], env=env, cwd=REPO,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, \
        f"{script} exited {proc.returncode}\n--- stdout\n{proc.stdout}" \
        f"\n--- stderr\n{proc.stderr}"
