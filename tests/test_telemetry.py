"""Telemetry subsystem: caches, mechanisms, topdown tree, sweeps, and
bit-exact parity of the default hierarchy with the legacy simulator."""
from collections import OrderedDict

import numpy as np
import pytest

from repro.core.cache_model import SANDY_BRIDGE, simulate_exact
from repro.core.generators import fd_matrix, rmat_matrix
from repro.telemetry import events as ev
from repro.telemetry import report, sweep, topdown
from repro.telemetry.events import EventCounters
from repro.telemetry.hierarchy import (CacheLevel, Hierarchy, HierarchySpec,
                                       MissCache, SequentialPrefetcher,
                                       SetAssocCache, StreamBuffers,
                                       VictimCache, spmv_address_trace)


# ---------------------------------------------------------------------------
# SetAssocCache
# ---------------------------------------------------------------------------

def test_fully_assoc_lru_eviction_order():
    c = SetAssocCache(2)                    # fully associative, 2 lines
    assert c.insert(1) is None
    assert c.insert(2) is None
    assert c.insert(3) == 1                 # LRU (line 1) evicted
    hit, _ = c.lookup(2)
    assert hit
    assert c.insert(4) == 3                 # 2 was refreshed; 3 is LRU now


def test_set_assoc_conflict_misses():
    # 4 lines, 1 way -> 4 direct-mapped sets; lines 0 and 4 conflict
    c = SetAssocCache(4, ways=1)
    assert c.n_sets == 4 and c.ways == 1
    c.insert(0)
    assert c.insert(4) == 0                 # same set, direct-mapped conflict
    assert not c.lookup(0)[0]
    # a fully-associative cache of the same capacity keeps both
    f = SetAssocCache(4)
    f.insert(0), f.insert(4)
    assert f.lookup(0)[0] and f.lookup(4)[0]


def test_prefetched_flag_cleared_on_first_hit():
    c = SetAssocCache(8)
    c.insert(5, prefetched=True)
    hit, was_pf = c.lookup(5)
    assert hit and was_pf
    hit, was_pf = c.lookup(5)
    assert hit and not was_pf               # only the first hit counts


# ---------------------------------------------------------------------------
# Mechanisms
# ---------------------------------------------------------------------------

def test_victim_cache_rescues_conflict_evictions():
    vc = VictimCache(4)
    c = EventCounters()
    vc.on_evict(7)
    assert vc.probe(7, c)                   # swap back
    assert c[ev.VICTIM_HIT] == 1
    assert not vc.probe(7, c)               # consumed by the swap
    assert c[ev.VICTIM_PROBE] == 2


def test_miss_cache_catches_repeat_misses():
    mc = MissCache(2)
    c = EventCounters()
    assert not mc.probe(3, c)               # first miss inserts
    assert mc.probe(3, c)                   # repeat miss is served
    assert c[ev.MISS_CACHE_HIT] == 1


def test_stream_buffers_serve_sequential_run():
    sb = StreamBuffers(n_streams=2, depth=4)
    c = EventCounters()
    assert not sb.probe(100, c)             # allocates [101..104]
    for line in (101, 102, 103, 104, 105):  # buffer keeps refilling ahead
        assert sb.probe(line, c), line
    assert c[ev.STREAM_HIT] == 5
    assert not sb.probe(500, c)             # unrelated miss: new allocation
    assert c[ev.STREAM_ALLOC] == 2


def test_stream_buffer_lru_replacement():
    sb = StreamBuffers(n_streams=1, depth=2)
    c = EventCounters()
    sb.probe(10, c)                         # tracks [11, 12]
    sb.probe(50, c)                         # replaces the only buffer
    assert not sb.probe(11, c)              # old stream is gone


# ---------------------------------------------------------------------------
# Hierarchy behavior
# ---------------------------------------------------------------------------

def _tiny_hierarchy(l2_lines=8, l3_lines=64, ways=None, mechs=(),
                    prefetch=True):
    levels = [CacheLevel("L2", l2_lines, ways, mechanisms=list(mechs)),
              CacheLevel("L3", l3_lines, ways)]
    pf = SequentialPrefetcher(4) if prefetch else None
    return Hierarchy(levels, pf)


def test_sequential_trace_is_prefetched():
    h = _tiny_hierarchy()
    c = h.replay(range(0, 64))
    assert c[ev.L2_PREFETCH_FILL] > 0
    assert c[ev.L2_PREFETCH_HIT] > 0
    # coverage: most lines arrive before demand
    assert c[ev.L2_PREFETCH_HIT] > c[ev.L2_DEMAND_MISS] / 2


def test_random_trace_misses_without_prefetch_benefit():
    rng = np.random.default_rng(0)
    trace = rng.integers(0, 4096, size=4096).tolist()
    h = _tiny_hierarchy()
    c = h.replay(trace)
    assert c[ev.L2_DEMAND_MISS] > 0.8 * c[ev.ACCESS] * (1 - 8 / 4096)
    assert c.validate() == []               # every event name is registered


def test_victim_cache_serves_direct_mapped_ping_pong():
    # two lines in the same direct-mapped set ping-pong; the victim cache
    # turns every other miss into a swap
    mech = VictimCache(4)
    h = _tiny_hierarchy(l2_lines=4, ways=1, mechs=(mech,), prefetch=False)
    trace = [0, 4, 0, 4, 0, 4, 0, 4]
    c = h.replay(trace)
    assert c[ev.VICTIM_HIT] >= 4            # all re-accesses swap back
    assert c[ev.L3_DEMAND_MISS] + c[ev.L3_DEMAND_HIT] \
        == c[ev.L2_DEMAND_MISS] - c[ev.VICTIM_HIT]


def test_counters_accounting_identity():
    h = _tiny_hierarchy()
    rng = np.random.default_rng(1)
    c = h.replay(rng.integers(0, 512, size=2048).tolist())
    assert c[ev.ACCESS] == c[ev.L2_DEMAND_HIT] + c[ev.L2_DEMAND_MISS]
    assert c[ev.L2_DEMAND_MISS] == c[ev.L3_DEMAND_HIT] + c[ev.L3_DEMAND_MISS]


# ---------------------------------------------------------------------------
# SpMV trace + legacy parity
# ---------------------------------------------------------------------------

def test_spmv_trace_shape_and_layout():
    csr = fd_matrix(256)
    t = spmv_address_trace(csr, SANDY_BRIDGE)
    assert t.shape[0] == 2 * csr.n_rows + 3 * csr.nnz
    # x region starts at line 0; row 0's x gathers (every 3rd slot from
    # position 4 within the row body) are exactly its column lines
    per_line = SANDY_BRIDGE.line_bytes // SANDY_BRIDGE.elem_bytes
    cols = np.asarray(csr.indices)[:int(np.asarray(csr.indptr)[1])]
    assert set(t[4::3][: len(cols)].tolist()) == set(
        (cols // per_line).tolist())


def _legacy_simulate(csr, machine, sweeps):
    """The pre-refactor cache_model simulator, kept verbatim as an oracle."""
    class LRU:
        def __init__(self, cap):
            self.cap, self.d = max(int(cap), 1), OrderedDict()

        def access(self, line):
            if line in self.d:
                self.d.move_to_end(line)
                return True
            self.d[line] = True
            if len(self.d) > self.cap:
                self.d.popitem(last=False)
            return False

        def insert(self, line):
            if line in self.d:
                self.d.move_to_end(line)
                return
            self.d[line] = True
            if len(self.d) > self.cap:
                self.d.popitem(last=False)

    lb = machine.line_bytes
    l2, l3 = LRU(machine.l2_bytes // lb), LRU(machine.l3_bytes // lb)
    pf = SequentialPrefetcher(machine.prefetch_streams)
    indptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices, dtype=np.int64)
    n = csr.n_rows
    eb, ib = machine.elem_bytes, machine.idx_bytes
    x_base = 0
    val_base = x_base + (-(-n * eb // lb)) + 16
    idx_base = val_base + (-(-csr.nnz * eb // lb)) + 16
    ptr_base = idx_base + (-(-csr.nnz * ib // lb)) + 16
    y_base = ptr_base + (-(-(n + 1) * ib // lb)) + 16
    stats = None
    for _ in range(sweeps):
        c = dict(l2_demand=0, l3_demand=0, pf_fills=0, accesses=0)

        def access(line, c=c):
            c["accesses"] += 1
            for pline in pf.observe(line):
                if pline not in l2.d:
                    c["pf_fills"] += 1
                    l3.insert(pline)
                    l2.insert(pline)
            if l2.access(line):
                return
            c["l2_demand"] += 1
            if l3.access(line):
                return
            c["l3_demand"] += 1

        for r in range(n):
            access(ptr_base + (r * ib) // lb)
            access(y_base + (r * eb) // lb)
            for p in range(int(indptr[r]), int(indptr[r + 1])):
                access(val_base + (p * eb) // lb)
                access(idx_base + (p * ib) // lb)
                access(x_base + (int(cols[p]) * eb) // lb)
        stats = c
    return stats


@pytest.mark.parametrize("gen,seed", [(fd_matrix, 0), (rmat_matrix, 1)])
def test_default_hierarchy_matches_legacy_exactly(gen, seed):
    """simulate_exact (now routed through telemetry.hierarchy) must agree
    counter-for-counter with the pre-refactor implementation."""
    csr = gen(2 ** 10, seed=seed)
    got = simulate_exact(csr, sweeps=2)
    want = _legacy_simulate(csr, SANDY_BRIDGE, sweeps=2)
    assert got == want


def test_headline_ordering_scaled_geometry():
    """The paper's headline (R-MAT L2 demand-miss rate >> FD) holds in the
    telemetry hierarchy at a working-set-scaled geometry."""
    spec = HierarchySpec(l2_bytes=16 * 1024, l3_bytes=256 * 1024)
    machine = SANDY_BRIDGE
    out = {}
    for kind, gen in (("fd", fd_matrix), ("rmat", rmat_matrix)):
        csr = gen(2 ** 12)
        c = spec.instantiate(machine).run_spmv(csr, machine, sweeps=2)
        out[kind] = c[ev.L2_DEMAND_MISS] / c[ev.ACCESS]
    assert out["rmat"] > 3 * out["fd"]


# ---------------------------------------------------------------------------
# Topdown
# ---------------------------------------------------------------------------

def _counters_for(kind, n=2 ** 12, spec=None):
    spec = spec or HierarchySpec(l2_bytes=16 * 1024, l3_bytes=128 * 1024)
    gen = fd_matrix if kind == "fd" else rmat_matrix
    csr = gen(n)
    c = spec.instantiate(SANDY_BRIDGE).run_spmv(csr, SANDY_BRIDGE, sweeps=2)
    return csr, c


def test_topdown_tree_fractions_consistent():
    csr, c = _counters_for("rmat")
    tree = topdown.topdown_tree(c, SANDY_BRIDGE, csr.nnz)
    flat = tree.flatten()
    mb = flat["spmv.memory_bound"]
    parts = (flat["spmv.memory_bound.l3_bound"]
             + flat["spmv.memory_bound.dram_bound"]
             + flat["spmv.memory_bound.mechanism_bound"])
    assert 0.0 <= mb <= 1.0
    assert parts == pytest.approx(mb, abs=1e-9)
    rendered = tree.render()
    assert "memory_bound" in rendered and "dram_bound" in rendered


def test_topdown_rmat_more_memory_bound_than_fd():
    csr_fd, c_fd = _counters_for("fd")
    csr_rm, c_rm = _counters_for("rmat")
    s_fd = topdown.topdown_summary(c_fd, SANDY_BRIDGE, csr_fd.nnz)
    s_rm = topdown.topdown_summary(c_rm, SANDY_BRIDGE, csr_rm.nnz)
    assert s_rm.l2_mpki > 3 * s_fd.l2_mpki
    assert s_rm.memory_bound > s_fd.memory_bound
    assert s_rm.gflops_est < s_fd.gflops_est


def test_topdown_summary_fields_complete():
    csr, c = _counters_for("fd")
    s = topdown.topdown_summary(c, SANDY_BRIDGE, csr.nnz)
    d = s.as_dict()
    assert set(d) == set(topdown.TopdownSummary.FIELDS)
    assert all(np.isfinite(v) for v in d.values())


# ---------------------------------------------------------------------------
# Sweep + report
# ---------------------------------------------------------------------------

SMALL = {
    "baseline": HierarchySpec(l2_bytes=16 * 1024, l3_bytes=128 * 1024),
    "victim-cache": HierarchySpec(l2_bytes=16 * 1024, l3_bytes=128 * 1024,
                                  victim_entries=32),
    "combined": HierarchySpec(l2_bytes=16 * 1024, l3_bytes=128 * 1024,
                              victim_entries=32, stream_buffers=4),
}


def test_run_sweep_grid_complete():
    pts = sweep.run_sweep(log2ns=(10, 11), mechanisms=SMALL, sweeps=1)
    assert len(pts) == 2 * 2 * len(SMALL)       # kinds x sizes x mechanisms
    labels = {p.mechanism for p in pts}
    assert labels == set(SMALL)
    for p in pts:
        assert p.counters[ev.ACCESS] > 0
        assert np.isfinite(p.summary.gflops_est)


def test_sweep_threads_shrinks_shared_l3():
    csr = rmat_matrix(2 ** 12)
    spec = HierarchySpec(l2_bytes=16 * 1024, l3_bytes=256 * 1024)
    c1 = sweep.run_point(csr, spec, threads=1, sweeps=1)
    c8 = sweep.run_point(csr, spec, threads=8, sweeps=1)
    # 8 threads: 1/8 of the rows replayed against 1/8 of the L3
    assert c8[ev.ACCESS] < c1[ev.ACCESS]


def test_reports_render():
    pts = sweep.run_sweep(log2ns=(10,), mechanisms=SMALL, sweeps=1)
    csv = report.to_csv(pts)
    md = report.to_markdown(pts)
    js = report.to_json(pts)
    gap = report.gap_report(pts)
    assert "l2_mpki" in csv and "baseline" in csv
    assert md.startswith("|") and "victim-cache" in md
    assert "counters" in js
    assert "gap_closed_vs_baseline" in gap


def test_geometry_sweep_labels():
    pts = sweep.geometry_sweep(log2n=10, l2_kb=(16, 32), ways=(1, None),
                               sweeps=1)
    assert {p.mechanism for p in pts} == {
        "l2-16k-1way", "l2-16k-full", "l2-32k-1way", "l2-32k-full"}
    # lower associativity can only hurt (or equal): conflict misses
    by = {(p.kind, p.mechanism): p for p in pts}
    for kind in ("fd", "rmat"):
        assert by[(kind, "l2-16k-1way")].summary.l2_mpki >= \
            by[(kind, "l2-16k-full")].summary.l2_mpki - 1e-9
