"""Checkpointing: roundtrip, commit safety, GC, elastic restore."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "b16": jnp.asarray(rng.normal(size=(4,)), dtype=jnp.bfloat16),
        "step": jnp.int32(7),
        "nested": {"scale": jnp.ones((3,), jnp.float32)},
    }


def test_save_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = _tree()
        mgr.save(3, tree, blocking=True)
        restored, step = mgr.restore(None, jax.tree.map(jnp.zeros_like, tree))
        assert step == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        assert restored["b16"].dtype == jnp.bfloat16


def test_uncommitted_checkpoints_ignored():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = _tree()
        mgr.save(1, tree, blocking=True)
        # fake a torn write: step dir without COMMITTED
        torn = os.path.join(d, "step_000000009")
        os.makedirs(torn)
        with open(os.path.join(torn, "manifest.msgpack"), "wb") as f:
            f.write(b"torn")
        assert mgr.latest_step() == 1


def test_gc_keeps_newest_k():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s), blocking=True)
        assert mgr.committed_steps() == [3, 4]


def test_restore_specific_step():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=5)
        for s in (1, 2):
            mgr.save(s, {"v": jnp.float32(s)}, blocking=True)
        restored, step = mgr.restore(1, {"v": jnp.float32(0)})
        assert step == 1 and float(restored["v"]) == 1.0


def test_restore_any_rebuilds_dict_tree_without_target():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32),
                      "c": jnp.int32(3)},
                "meta": np.arange(4, dtype=np.uint8)}
        mgr.save(2, tree, blocking=True)
        restored, step = mgr.restore_any()
        assert step == 2
        np.testing.assert_array_equal(np.asarray(restored["a"]["b"]),
                                      np.arange(6, dtype=np.float32))
        np.testing.assert_array_equal(np.asarray(restored["meta"]),
                                      np.arange(4, dtype=np.uint8))


def test_restore_any_rejects_non_dict_trees():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"lst": [jnp.zeros(2), jnp.ones(2)]}, blocking=True)
        with pytest.raises(ValueError, match="string-keyed"):
            mgr.restore_any()


def test_async_save_overlaps_then_joins():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _tree())          # non-blocking
        mgr.wait()
        assert mgr.latest_step() == 1


def test_elastic_restart_end_to_end():
    """Integration: train N steps on a '2-host' data layout, checkpoint,
    restore on a '1-host' layout (elastic rescale), continue -- the
    restored params must match and training must proceed."""
    import jax
    from repro.configs import CONFIGS
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.distributed.fault import plan_elastic_rescale
    from repro.models.registry import get_model
    from repro.optim import OptimizerConfig
    from repro.train.loop import TrainConfig, init_train_state, make_train_step

    cfg = CONFIGS["stablelm-1.6b"].reduced()
    api = get_model(cfg)
    tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                               total_steps=20))
    params, opt = init_train_state(api, tc, jax.random.PRNGKey(0))
    step = make_train_step(api, tc)

    # "2 hosts": each sees half the global batch; equivalent single-proc run
    d0 = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4,
                                host_id=0, n_hosts=2))
    d1 = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4,
                                host_id=1, n_hosts=2))
    for s in range(3):
        batch = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                             d0.batch_at(s), d1.batch_at(s))
        params, opt, _ = step(params, opt, batch)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(2, (params, opt), blocking=True)
        plan = plan_elastic_rescale({"data": 2, "model": 1}, n_devices_now=1)
        assert plan.new_mesh == (1, 1)
        # restore on the shrunken layout and take one more step
        (params2, opt2), at = mgr.restore(None, (params, opt))
        assert at == 2
        batch = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                             d0.batch_at(3), d1.batch_at(3))
        p3, _, m = step(params2, opt2, batch)
        assert np.isfinite(float(m["loss"]))


def test_elastic_restore_multishard_manifest():
    """Restore reassembles leaves from whichever shard holds them --
    simulate a 2-host save by writing two shard files by hand."""
    import msgpack

    from repro.checkpoint.manager import (DEFAULT_CODEC, compress_payload,
                                          shard_filename)

    with tempfile.TemporaryDirectory() as d:
        step_dir = os.path.join(d, "step_000000005")
        os.makedirs(step_dir)
        a = np.arange(6, dtype=np.float32).reshape(2, 3)
        b = np.arange(4, dtype=np.float32) * 2
        entries = []
        for shard_id, (key, arr) in enumerate(
                [("['a']", a), ("['b']", b)]):
            payload = arr.tobytes()
            comp = compress_payload(payload, DEFAULT_CODEC)
            with open(os.path.join(
                    step_dir, shard_filename(shard_id, DEFAULT_CODEC)),
                    "wb") as f:
                f.write(comp)
            entries.append({"key": key, "shape": list(arr.shape),
                            "dtype": "float32", "offset": 0,
                            "nbytes": len(payload), "shard": shard_id})
        with open(os.path.join(step_dir, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb({"step": 5, "n_hosts": 2,
                                   "codec": DEFAULT_CODEC,
                                   "treedef": "", "entries": entries}))
        with open(os.path.join(step_dir, "COMMITTED"), "w") as f:
            f.write("5")

        mgr = CheckpointManager(d)   # restoring host count = 1 (elastic)
        target = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((4,))}
        restored, step = mgr.restore(None, target)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]), a)
        np.testing.assert_array_equal(np.asarray(restored["b"]), b)
