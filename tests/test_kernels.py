"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True).

Each Pallas kernel sweeps shapes and dtypes per the deliverable contract.
Sizes stay modest: interpret mode executes the grid in Python.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import BELL, CSR, DIA
from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.spmv_dia import spmv_dia_pallas


def _x(n, dtype=np.float32, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=n)
                       .astype(dtype))


# ---------------------------------------------------------------------------
# DIA kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bn", [(256, 128), (512, 128), (1024, 256)])
def test_dia_kernel_shapes(n, bn):
    csr = fd_matrix(n)
    dia = DIA.from_csr(csr)
    x = _x(n)
    got = ops.spmv_dia(dia, x, bn=bn)
    want = ref.spmv_dia_ref(dia.data, dia.offsets, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dia_kernel_dtypes(dtype):
    csr = banded_matrix(256, 8, nnz_per_row=5)
    dia = DIA.from_csr(csr)
    band = dia.data.astype(dtype)
    x = _x(256).astype(dtype)
    got = spmv_dia_pallas(band, dia.offsets, x, bn=128)
    want = ref.spmv_dia_ref(band.astype(jnp.float32), dia.offsets,
                            x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=2e-2, atol=2e-2)


def test_dia_negative_and_positive_offsets():
    # explicit band with offsets [-2, 0, 3]
    n = 256
    band = jnp.asarray(np.random.default_rng(1)
                       .normal(size=(3, n)).astype(np.float32))
    offs = jnp.asarray(np.array([-2, 0, 3], np.int32))
    x = _x(n, seed=2)
    got = spmv_dia_pallas(band, offs, x, bn=128)
    want = ref.spmv_dia_ref(band, offs, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# BELL kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,seed", [(256, 0), (512, 1)])
def test_bell_kernel_vs_oracle(n, seed):
    csr = rmat_matrix(n, seed=seed)
    bell = BELL.from_csr(csr)
    x = _x(n, seed=seed)
    got = ops.spmv_bell(bell, x)
    want = np.asarray(csr.to_dense()) @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_bell_bf16_inputs_fp32_accum():
    csr = rmat_matrix(256, seed=2)
    bell = BELL.from_csr(csr)
    data16 = bell.data.astype(jnp.bfloat16)
    import dataclasses
    bell16 = BELL(data=data16, block_cols=bell.block_cols,
                  n_rows=bell.n_rows, n_cols=bell.n_cols,
                  bm=bell.bm, bn=bell.bn, blocks_per_row=bell.blocks_per_row)
    x = _x(256, dtype=np.float32, seed=3).astype(jnp.bfloat16)
    got = ops.spmv_bell(bell16, x)
    want = ref.spmv_bell_ref(bell.data, bell.block_cols,
                             jnp.pad(x.astype(jnp.float32),
                                     (0, bell.bn * (-(-256 // bell.bn)) - 256)))
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want)[:256], rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# ELL kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,seed", [(256, 0), (512, 7)])
def test_ell_kernel_vs_dense(n, seed):
    from repro.core.formats import ELL
    csr = rmat_matrix(n, seed=seed)
    ell = ELL.from_csr(csr)
    x = _x(n, seed=seed)
    got = ops.spmv_ell(ell, x)
    want = np.asarray(csr.to_dense()) @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_ell_kernel_banded_and_blocksizes():
    from repro.core.formats import ELL
    csr = banded_matrix(384, 16, nnz_per_row=5)
    ell = ELL.from_csr(csr)
    x = _x(384, seed=11)
    want = np.asarray(csr.to_dense()) @ np.asarray(x)
    for bm in (64, 128, 256):
        got = ops.spmv_ell(ell, x, bm=bm)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-4, atol=1e-4)


def test_ell_pallas_routed_from_dispatcher():
    """use_pallas=True must run the ELL kernel, not fall back to jnp."""
    from repro.core.formats import ELL
    from repro.core.spmv import spmv
    csr = rmat_matrix(256, seed=3)
    ell = ELL.from_csr(csr)
    x = _x(256, seed=4)
    got = spmv(ell, x, use_pallas=True)
    want = spmv(ell, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Column-blocked CSR kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_stripes", [1, 2, 4])
def test_csr_colblock_stripes(n_stripes):
    csr = rmat_matrix(512, seed=4)
    x = _x(512, seed=5)
    got = ops.spmv_csr(csr, x, n_stripes=n_stripes)
    want = np.asarray(csr.to_dense()) @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_csr_prepared_reuse():
    csr = fd_matrix(256)
    prep = ops.prepare_csr(csr, n_stripes=2)
    for seed in range(3):
        x = _x(256, seed=seed)
        got = ops.spmv_csr_prepared(prep, x)
        want = np.asarray(csr.to_dense()) @ np.asarray(x)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-4, atol=1e-4)


def test_csr_padded_ref_matches_kernel_layout():
    csr = rmat_matrix(256, seed=6)
    prep = ops.prepare_csr(csr, n_stripes=2)
    xp = jnp.pad(_x(256, seed=7),
                 (0, 2 * prep.stripe_w - 256)).reshape(2, prep.stripe_w)
    want = ref.spmv_csr_padded_ref(prep.vals, prep.cols, prep.rowin, xp)
    got = ops.spmv_csr_prepared(prep, _x(256, seed=7))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want)[:256], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,skv,d,causal,window", [
    (128, 128, 64, True, None),
    (256, 256, 64, True, 64),
    (128, 256, 64, False, None),     # cross-attention shape
    (256, 256, 128, True, None),
])
def test_flash_attention_sweep(sq, skv, d, causal, window):
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(2, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, skv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, skv, d)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, causal=causal, window=window)
    want = ref.mha_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 64))).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True)
    want = ref.mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=5e-2, atol=5e-2)


def test_flash_window_equals_banded_mask():
    """Sliding-window attention == attention through a banded mask: the
    paper's FD structure applied to the attention matrix."""
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(1, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 64)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, causal=True, window=32)
    want = ref.mha_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# degenerate geometries + semiring inner loops
# ---------------------------------------------------------------------------

def _empty_csr(n=8):
    z = np.array([], dtype=np.int64)
    return CSR.from_coo(z, z, np.array([], dtype=np.float32), n, n)


def test_all_kernels_handle_empty_matrix():
    """nnz=0 regression: the DIA path used to crash on a zero-diagonal
    band (empty scalar-prefetch operand); every per-call wrapper must
    return exact zeros."""
    from repro.core.formats import ELL

    m = _empty_csr(8)
    x = _x(8)
    for name, got in [
        ("dia", ops.spmv_dia(DIA.from_csr(m), x)),
        ("bell", ops.spmv_bell(BELL.from_csr(m), x)),
        ("ell", ops.spmv_ell(ELL.from_csr(m), x)),
        ("csr", ops.spmv_csr(m, x)),
    ]:
        np.testing.assert_array_equal(np.asarray(got), np.zeros(8),
                                      err_msg=name)


def test_single_row_kernels_match_dense():
    from repro.core.formats import ELL

    m = CSR.from_coo([0, 0, 0], [0, 2, 5], [1.0, 2.0, 3.0], 1, 6)
    x = _x(6, seed=3)
    want = np.asarray(m.to_dense()) @ np.asarray(x)
    for got in (ops.spmv_csr(m, x), ops.spmv_ell(ELL.from_csr(m), x)):
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


@pytest.mark.parametrize("fmt", ["ell", "csr"])
def test_semiring_kernel_min_plus_matches_reference(fmt):
    """The generalized inner loop (⊗=+, ⊕=min) against a dense reference;
    padding slots must be absorbing (+inf), empty rows reduce to +inf."""
    from repro.core.formats import ELL
    from repro.graph.semiring import MIN_PLUS

    m = rmat_matrix(256, seed=6)
    x = jnp.asarray(np.abs(np.random.default_rng(0).normal(size=256))
                    .astype(np.float32))
    if fmt == "ell":
        container = ELL.from_csr(m, fill=MIN_PLUS.pad_value)
        got = ops.spmv_ell(container, x, semiring=MIN_PLUS)
    else:
        got = ops.spmv_csr(m, x, semiring=MIN_PLUS)

    dense = np.asarray(m.to_dense(), np.float64)
    nz = np.zeros(dense.shape, bool)
    ip, ci = np.asarray(m.indptr), np.asarray(m.indices)
    for r in range(256):
        nz[r, ci[ip[r]:ip[r + 1]]] = True
    want = np.where(nz, dense + np.asarray(x)[None, :], np.inf).min(axis=1)
    np.testing.assert_allclose(np.asarray(got), want.astype(np.float32),
                               rtol=1e-6)


def test_semiring_plus_times_arg_is_bit_identical():
    """Passing the plus_times semiring explicitly must take the exact
    historical kernel path (same bytes out)."""
    from repro.core.formats import ELL
    from repro.graph.semiring import PLUS_TIMES

    m = rmat_matrix(256, seed=8)
    ell = ELL.from_csr(m)
    x = _x(256, seed=9)
    np.testing.assert_array_equal(
        np.asarray(ops.spmv_ell(ell, x)),
        np.asarray(ops.spmv_ell(ell, x, semiring=PLUS_TIMES)))
    np.testing.assert_array_equal(
        np.asarray(ops.spmv_csr(m, x)),
        np.asarray(ops.spmv_csr(m, x, semiring=PLUS_TIMES)))
