"""Format containers: construction, round-trips, storage accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _opt_deps import given, settings, st

from repro.core.formats import BELL, CSR, DIA, ELL
from repro.core.generators import fd_matrix, rmat_matrix


def random_coo(n, m, nnz, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, nnz)
    cols = rng.integers(0, m, nnz)
    vals = rng.normal(size=nnz).astype(dtype)
    return rows, cols, vals


def test_csr_from_coo_dense_roundtrip():
    rows, cols, vals = random_coo(13, 17, 40)
    csr = CSR.from_coo(rows, cols, vals, 13, 17)
    dense = np.zeros((13, 17), np.float32)
    np.add.at(dense, (rows, cols), vals)
    np.testing.assert_allclose(np.asarray(csr.to_dense()), dense, rtol=1e-6)


def test_csr_storage_accounting_matches_paper():
    """Paper §II-A: CSR stores 2m + n + 1 elements."""
    csr = fd_matrix(64)
    n, m = csr.n_rows, csr.nnz
    n_elems = (csr.data.size + csr.indices.size + csr.indptr.size)
    assert n_elems == 2 * m + n + 1


@pytest.mark.parametrize("fmt", [ELL, BELL, DIA])
def test_format_conversion_preserves_matrix(fmt):
    csr = rmat_matrix(128, seed=3)
    other = fmt.from_csr(csr)
    x = jnp.asarray(np.random.default_rng(0).normal(size=128)
                    .astype(np.float32))
    from repro.core.spmv import spmv
    np.testing.assert_allclose(np.asarray(spmv(other, x)),
                               np.asarray(spmv(csr, x)),
                               rtol=1e-4, atol=1e-4)


def test_bell_blocks_are_lane_shaped():
    csr = rmat_matrix(256, seed=1)
    bell = BELL.from_csr(csr, bm=8, bn=128)
    assert bell.data.shape[2:] == (8, 128)
    assert 0.0 < bell.density() <= 1.0


def test_dia_offsets_sorted_unique():
    dia = DIA.from_csr(fd_matrix(144))
    offs = np.asarray(dia.offsets)
    assert (np.diff(offs) > 0).all()


def test_formats_are_pytrees():
    csr = fd_matrix(64)
    leaves = jax.tree.leaves(csr)
    assert len(leaves) == 3
    # jit through the container
    f = jax.jit(lambda c, x: c.data.sum() + x.sum())
    f(csr, jnp.ones(4))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 64), nnz=st.integers(1, 200), seed=st.integers(0, 99))
def test_property_all_formats_agree(n, nnz, seed):
    rows, cols, vals = random_coo(n, n, nnz, seed)
    csr = CSR.from_coo(rows, cols, vals, n, n)
    x = np.random.default_rng(seed).normal(size=n).astype(np.float32)
    xj = jnp.asarray(x)
    from repro.core.spmv import spmv
    ref = np.asarray(csr.to_dense()) @ x
    for fmt in (csr, ELL.from_csr(csr), BELL.from_csr(csr),
                DIA.from_csr(csr)):
        got = np.asarray(spmv(fmt, xj))
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
