"""Data pipeline: determinism, host sharding, resumability."""
import os
import tempfile

import numpy as np
from _opt_deps import given, settings, st

from repro.data.pipeline import (DataConfig, PackedFileDataset, SyntheticLM,
                                 make_pipeline, write_token_file)


def _cfg(**kw):
    base = dict(vocab=1000, seq_len=16, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_batch_is_pure_function_of_step():
    a = SyntheticLM(_cfg())
    b = SyntheticLM(_cfg())
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(np.asarray(a.batch_at(step)["tokens"]),
                                      np.asarray(b.batch_at(step)["tokens"]))


def test_restart_replays_exactly():
    pipe = SyntheticLM(_cfg())
    seen = [np.asarray(next(pipe)["tokens"]) for _ in range(5)]
    state = pipe.state()
    more = [np.asarray(next(pipe)["tokens"]) for _ in range(3)]
    pipe2 = SyntheticLM(_cfg())
    pipe2.restore(state)
    replay = [np.asarray(next(pipe2)["tokens"]) for _ in range(3)]
    for a, b in zip(more, replay):
        np.testing.assert_array_equal(a, b)
    del seen


def test_hosts_draw_disjoint_streams():
    h0 = SyntheticLM(_cfg(host_id=0, n_hosts=2))
    h1 = SyntheticLM(_cfg(host_id=1, n_hosts=2))
    t0 = np.asarray(h0.batch_at(0)["tokens"])
    t1 = np.asarray(h1.batch_at(0)["tokens"])
    assert t0.shape == (4, 16)      # global 8 split across 2 hosts
    assert not np.array_equal(t0, t1)


def test_labels_are_shifted_tokens():
    b = SyntheticLM(_cfg()).batch_at(0)
    # tokens/labels come from one (seq_len+1) window
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_packed_file_dataset_roundtrip():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 500, size=4096).astype(np.uint16)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tokens.bin")
        write_token_file(path, toks)
        pipe = make_pipeline(_cfg(global_batch=4), path)
        assert isinstance(pipe, PackedFileDataset)
        b = pipe.batch_at(0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][0]),
                                      toks[:16].astype(np.int32))
        # deterministic across instances
        pipe2 = make_pipeline(_cfg(global_batch=4), path)
        np.testing.assert_array_equal(np.asarray(pipe2.batch_at(3)["tokens"]),
                                      np.asarray(pipe.batch_at(3)["tokens"]))


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10_000), host=st.integers(0, 3))
def test_property_tokens_in_vocab(step, host):
    pipe = SyntheticLM(_cfg(host_id=host, n_hosts=4))
    b = pipe.batch_at(step)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < 1000
