"""Training loop: accumulation equivalence, end-to-end loss descent,
launcher fault-tolerance integration."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models.registry import get_model, random_train_batch
from repro.optim import OptimizerConfig
from repro.train.loop import TrainConfig, init_train_state, make_train_step


def _setup(accum=1, lr=1e-3):
    cfg = CONFIGS["stablelm-1.6b"].reduced()
    api = get_model(cfg)
    tc = TrainConfig(optimizer=OptimizerConfig(lr=lr, warmup_steps=1,
                                               total_steps=100),
                     remat="none", accum_steps=accum)
    params, opt = init_train_state(api, tc, jax.random.PRNGKey(0))
    return cfg, api, tc, params, opt


def test_accumulation_matches_single_batch():
    """accum=2 over a batch == accum=1 over the same batch (same update)."""
    cfg, api, tc1, params, opt = _setup(accum=1)
    _, _, tc2, params2, opt2 = _setup(accum=2)
    batch = random_train_batch(cfg, 4, 16)
    p1, _, m1 = make_train_step(api, tc1)(params, opt, batch)
    p2, _, m2 = make_train_step(api, tc2)(params2, opt2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_loss_descends_on_learnable_data():
    """Fixed repeating batch -> the model must memorize it."""
    cfg, api, tc, params, opt = _setup(lr=3e-3)
    step = jax.jit(make_train_step(api, tc))
    batch = random_train_batch(cfg, 2, 16, seed=1)
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_metrics_contract():
    cfg, api, tc, params, opt = _setup()
    batch = random_train_batch(cfg, 2, 16)
    _, _, metrics = make_train_step(api, tc)(params, opt, batch)
    assert set(metrics) >= {"loss", "grad_norm", "lr"}
    assert all(np.isfinite(float(v)) for v in metrics.values())


def test_launcher_crash_restart_deterministic():
    """launch.train with an injected crash must resume from the checkpoint
    and reach the same final state as an uninterrupted run."""
    from repro.launch import train as T

    def run(fail_at, ckpt):
        return T.main([
            "--arch", "stablelm-1.6b", "--reduced",
            "--steps", "12", "--batch", "2", "--seq", "16",
            "--ckpt-dir", ckpt, "--ckpt-every", "4",
            "--log-every", "100", "--fail-at-step", str(fail_at),
        ])

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        clean = run(-1, d1)
        crashed = run(7, d2)
    # identical last-step losses (deterministic data replay)
    assert clean[-1][0] == crashed[-1][0]
    assert clean[-1][1] == pytest.approx(crashed[-1][1], rel=1e-5)
