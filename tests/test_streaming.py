"""Streaming layer: delta semantics, overlays, lifecycle, engine swaps.

Four strata, mirroring the layers the streaming refactor crosses:

  * `EdgeDelta` container semantics against a dense reference
    (apply/diff round trips, merge = sequential application);
  * `OverlaidPlan` exactness and the chained-fingerprint cache keys;
  * warm-start policy and correctness guards in `graph.drivers`;
  * the serving engine's mutation lifecycle: overlays admit as warm
    hits, past-budget deltas force exactly one background re-plan with
    an atomic swap and no wrong-answer window, and identical traces
    replay deterministically.

Bit-exactness follows the kernel property suite's discipline: integer-
valued f32 operands make every summation order exact, so plus-times
comparisons are `array_equal`, not allclose.
"""
import numpy as np
import pytest

from repro.core.delta import EdgeDelta, apply_delta, csr_diff, csr_lookup
from repro.core.formats import CSR
from repro.core.generators import fd_matrix, rmat_matrix
from repro.graph.drivers import (connected_components, pagerank, sssp,
                                 warm_start_params)
from repro.plan import (PlanCache, chain_fingerprint, compile as compile_plan,
                        delta_fingerprint, matrix_fingerprint, overlay)
from repro.plan.overlay import OverlaidPlan, overlay_eligible
from repro.serve_graph import (AnalyticRequest, GraphEngine,
                               GraphEngineConfig, GraphMutation)

N = 64


def _adj(seed=3, n=N):
    return rmat_matrix(n, seed=seed)


def _coo(csr):
    ip = np.asarray(csr.indptr)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(ip))
    return rows, np.asarray(csr.indices, dtype=np.int64), \
        np.asarray(csr.data, dtype=np.float32)


def _fresh_coords(csr, k, rng, avoid=()):
    rows, cols, _ = _coo(csr)
    present = set(zip(rows.tolist(), cols.tolist())) | set(avoid)
    out = []
    while len(out) < k:
        r, c = int(rng.integers(csr.n_rows)), int(rng.integers(csr.n_cols))
        if (r, c) not in present:
            out.append((r, c))
            present.add((r, c))
    return out


# ---------------------------------------------------------------------------
# EdgeDelta semantics
# ---------------------------------------------------------------------------

def test_apply_delta_matches_dense_reference():
    adj = _adj()
    rng = np.random.default_rng(0)
    ins = [(r, c, 3.0) for r, c in _fresh_coords(adj, 5, rng)]
    rows, cols, _ = _coo(adj)
    dels = [(int(rows[p]), int(cols[p]))
            for p in rng.choice(rows.size, size=4, replace=False)]
    delta = EdgeDelta.from_updates(adj, inserts=ins, deletes=dels)
    got = adj.apply_delta(delta)

    dense = np.asarray(adj.to_dense()).copy()
    for r, c, v in ins:
        dense[r, c] = v
    mask = np.zeros_like(dense, dtype=bool)
    for r, c in dels:
        dense[r, c] = 0.0
        mask[r, c] = True
    # structural check: deleted coordinates are gone, not zero-valued
    gr, gc, gv = _coo(got)
    assert not any((r, c) in set(zip(gr.tolist(), gc.tolist()))
                   for r, c in dels)
    np.testing.assert_array_equal(np.asarray(got.to_dense()), dense)


def test_csr_diff_round_trip_and_merge():
    a = _adj(seed=5)
    rng = np.random.default_rng(1)
    d1 = EdgeDelta.from_updates(
        a, inserts=[(r, c, 2.0) for r, c in _fresh_coords(a, 4, rng)])
    b = a.apply_delta(d1)
    rows, cols, _ = _coo(b)
    d2 = EdgeDelta.from_updates(
        b, inserts=[(r, c, 5.0) for r, c in _fresh_coords(b, 3, rng)],
        deletes=[(int(rows[0]), int(cols[0]))])
    c_ = b.apply_delta(d2)

    # diff(a, c) reproduces c from a exactly
    diff = csr_diff(a, c_)
    np.testing.assert_array_equal(
        np.asarray(a.apply_delta(diff).to_dense()), np.asarray(c_.to_dense()))
    # merged deltas == sequential application
    merged = d1.merge(d2)
    np.testing.assert_array_equal(
        np.asarray(a.apply_delta(merged).to_dense()),
        np.asarray(c_.to_dense()))


def test_from_updates_validates_coordinates():
    adj = _adj()
    rows, cols, vals = _coo(adj)
    r0, c0 = int(rows[0]), int(cols[0])
    with pytest.raises(ValueError, match="stored coordinates"):
        EdgeDelta.from_updates(adj, inserts=[(r0, c0, 1.0)])
    rng = np.random.default_rng(2)
    (ra, ca), = _fresh_coords(adj, 1, rng)
    with pytest.raises(ValueError, match="absent coordinates"):
        EdgeDelta.from_updates(adj, deletes=[(ra, ca)])
    # delete looks up the removed value -- the caller never supplies it
    d = EdgeDelta.from_updates(adj, deletes=[(r0, c0)])
    looked, found = csr_lookup(adj, np.array([r0]), np.array([c0]))
    assert found.all() and d.vals[0] == looked[0]


def test_value_change_is_delete_plus_insert():
    adj = _adj()
    rows, cols, vals = _coo(adj)
    r0, c0 = int(rows[0]), int(cols[0])
    d = EdgeDelta.from_updates(adj, inserts=[(r0, c0, 9.0)],
                               deletes=[(r0, c0)])
    assert d.nnz == 2 and d.has_deletes
    out = adj.apply_delta(d)
    got = np.asarray(out.to_dense())
    assert got[r0, c0] == 9.0
    # signed stream nets to the value change under plus-times
    sr_rows, sr_cols, sr_vals = d.signed_coo()
    net = {}
    for r, c, v in zip(sr_rows, sr_cols, sr_vals):
        net[(r, c)] = net.get((r, c), 0.0) + v
    assert net[(r0, c0)] == pytest.approx(9.0 - float(vals[0]))


def test_empty_delta_and_summary():
    d = EdgeDelta.empty(8, 8)
    assert d.nnz == 0 and not d.has_deletes
    adj = _adj()
    same = adj.apply_delta(EdgeDelta.empty(adj.n_rows, adj.n_cols))
    np.testing.assert_array_equal(np.asarray(same.to_dense()),
                                  np.asarray(adj.to_dense()))
    assert "EdgeDelta" in EdgeDelta.empty(8, 8).summary()


# ---------------------------------------------------------------------------
# fingerprints and cache keys
# ---------------------------------------------------------------------------

def test_chained_fingerprints_distinguish_generations():
    adj = _adj(seed=9)
    rng = np.random.default_rng(3)
    base_fp = matrix_fingerprint(adj)
    d1 = EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(adj, 2, rng)])
    d2 = EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(
            adj, 2, rng, avoid=[(r, c) for r, c, _ in
                                zip(d1.rows, d1.cols, d1.vals)])])
    f1 = chain_fingerprint(base_fp, delta_fingerprint(d1))
    f2 = chain_fingerprint(base_fp, delta_fingerprint(d2))
    f11 = chain_fingerprint(f1, delta_fingerprint(d2))
    assert len({base_fp, f1, f2, f11}) == 4          # all generations distinct
    # deterministic: same chain -> same key, no full-matrix rehash needed
    assert f1 == chain_fingerprint(base_fp, delta_fingerprint(d1))


def test_plan_cache_overlay_install_and_swap_counters():
    adj = _adj(seed=11)
    cache = PlanCache(max_plans=8)
    p = cache.get_or_compile(adj, reorder="none", predictor="none")
    key = cache.key_for(adj, reorder="none", predictor="none")
    rng = np.random.default_rng(4)
    d = EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(adj, 2, rng)])
    ov = overlay(p, d)
    new_key = cache.chained_key(key, ov.fingerprint)
    assert new_key != key and new_key.endswith(key.split("|", 1)[1])

    cache.install_overlay(new_key, ov, supersedes=key)
    s = cache.stats()
    assert s["overlays"] == 1
    assert cache.peek(new_key) is ov
    assert cache.peek(key) is None                   # retired atomically

    mat = adj.apply_delta(d)
    swap_key = cache.key_for(mat, reorder="none", predictor="none")
    swapped = cache.swap(swap_key,
                         lambda: compile_plan(mat, reorder="none",
                                              predictor="none"),
                         supersedes=new_key)
    s = cache.stats()
    assert s["swaps"] == 1
    assert cache.peek(new_key) is None
    assert cache.peek(swap_key) is swapped
    cache.note_delta_recompile()
    assert cache.stats()["delta_recompiles"] == 1
    cache.clear()
    s = cache.stats()
    assert s["overlays"] == s["swaps"] == s["delta_recompiles"] == 0


def test_overlaid_plan_lifecycle_flags():
    adj = _adj(seed=13)
    p = compile_plan(adj, reorder="none", predictor="none")
    rng = np.random.default_rng(5)
    small = EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(adj, 1, rng)])
    ov = overlay(p, small, staleness_budget=0.05)
    assert isinstance(ov, OverlaidPlan)
    assert ov.eligible and not ov.stale
    assert ov.staleness == pytest.approx(1 / adj.nnz)

    big = EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(
            adj, int(0.1 * adj.nnz), rng)])
    assert overlay(p, big, staleness_budget=0.05).stale

    rows, cols, _ = _coo(adj)
    dels = EdgeDelta.from_updates(adj, deletes=[(int(rows[0]), int(cols[0]))])
    assert overlay_eligible(dels, "plus_times")
    assert not overlay_eligible(dels, "min_plus")
    # materialization equals CSR.apply_delta
    np.testing.assert_array_equal(
        np.asarray(overlay(p, small).materialize().to_dense()),
        np.asarray(adj.apply_delta(small).to_dense()))


# ---------------------------------------------------------------------------
# warm starts
# ---------------------------------------------------------------------------

def test_warm_start_policy():
    adj = _adj()
    rng = np.random.default_rng(6)
    ins = EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(adj, 2, rng)])
    rows, cols, _ = _coo(adj)
    dels = EdgeDelta.from_updates(adj, deletes=[(int(rows[0]), int(cols[0]))])
    v = np.zeros(adj.n_rows, np.float32)

    assert warm_start_params("bfs", v, ins) is None          # never
    assert warm_start_params("pagerank", v, dels) is not None  # always
    assert warm_start_params("sssp", v, ins) is not None     # insert-only
    assert warm_start_params("sssp", v, dels) is None        # deletes: reseed
    assert warm_start_params("connected_components", v, dels) is None


def test_warm_started_monotone_analytics_bit_identical():
    """Insert-only deltas: warm-started sssp/cc converge to the exact
    cold answer (old values are valid upper bounds the monotone
    iteration drives down)."""
    adj = _adj(seed=21, n=128)
    rng = np.random.default_rng(7)
    src = int(np.argmax(adj.row_lengths()))
    pre_d = sssp(adj, src)
    pre_l = connected_components(adj)
    mutated = adj.apply_delta(EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(adj, 4, rng)]))

    cold = sssp(mutated, src)
    warm = sssp(mutated, src, d0=pre_d.values.reshape(1, -1))
    np.testing.assert_array_equal(warm.values, cold.values)
    assert warm.n_iters <= cold.n_iters

    cold_l = connected_components(mutated)
    warm_l = connected_components(mutated, l0=pre_l.values)
    np.testing.assert_array_equal(warm_l.values, cold_l.values)
    assert warm_l.n_iters <= cold_l.n_iters


def test_warm_started_pagerank_converges_to_same_fixpoint():
    adj = _adj(seed=23, n=128)
    rng = np.random.default_rng(8)
    pre = pagerank(adj, tol=1e-6)
    mutated = adj.apply_delta(EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(adj, 1, rng)]))
    cold = pagerank(mutated, tol=1e-6)
    warm = pagerank(mutated, tol=1e-6, r0=pre.values)
    np.testing.assert_allclose(warm.values, cold.values,
                               rtol=1e-3, atol=1e-4)
    assert warm.n_iters <= cold.n_iters


# ---------------------------------------------------------------------------
# the serving engine's mutation lifecycle
# ---------------------------------------------------------------------------

def _engine(**over):
    cfg = GraphEngineConfig(**{**dict(n_lanes=8, compile_queue_cap=4,
                                      compiles_per_step=1), **over})
    eng = GraphEngine(cfg)
    eng.register_graph("g", _adj(seed=3, n=128))
    return eng


def _small_inserts(eng, k, seed=0):
    rng = np.random.default_rng(seed)
    return tuple((r, c, 1.0)
                 for r, c in _fresh_coords(eng.graphs["g"], k, rng))


def test_mutation_overlay_admits_next_request_warm():
    eng = _engine()
    eng.submit(AnalyticRequest(0, "g", "sssp", sources=(0,)))
    eng.run()
    compiles_before = eng.plan_cache.stats()["compiles"]

    eng.submit(GraphMutation(100, "g", inserts=_small_inserts(eng, 2)))
    eng.submit(AnalyticRequest(1, "g", "sssp", sources=(0,)))
    out = eng.run()

    res = eng.mutation_results[100]
    assert res.actions == {"sssp": "overlay"}
    s = eng.stats()
    assert s["plan_cache"]["overlays"] == 1
    assert s["plan_cache"]["compiles"] == compiles_before  # NO recompile
    assert s["mutations_applied"] == 1
    # the overlaid request was a warm hit, not a compile-queue miss
    assert s["cold_misses"] == 1                           # only request 0
    ref = sssp(eng.graphs["g"], 0)
    np.testing.assert_array_equal(out[1].values[0], ref.values)


def test_past_budget_delta_one_replan_one_swap_no_wrong_answers():
    eng = _engine(staleness_budget=0.0005)
    eng.submit(AnalyticRequest(0, "g", "sssp", sources=(0,)))
    eng.run()

    eng.submit(GraphMutation(100, "g", inserts=_small_inserts(eng, 4)))
    eng.submit(AnalyticRequest(1, "g", "sssp", sources=(0,)))
    out = eng.run()

    assert eng.mutation_results[100].actions == {"sssp": "replan"}
    s = eng.stats()["plan_cache"]
    assert s["delta_recompiles"] == 1                # exactly one re-plan
    assert s["swaps"] == 1                           # landed atomically
    assert s["overlays"] == 0
    # no wrong-answer window: the post-mutation answer is the mutated
    # graph's answer, bit for bit
    ref = sssp(eng.graphs["g"], 0)
    np.testing.assert_array_equal(out[1].values[0], ref.values)


def test_ineligible_delete_forces_replan_within_budget():
    eng = _engine()                                  # generous 5% budget
    eng.submit(AnalyticRequest(0, "g", "sssp", sources=(0,)))
    eng.run()
    rows, cols, _ = _coo(eng.graphs["g"])
    eng.submit(GraphMutation(100, "g",
                             deletes=((int(rows[0]), int(cols[0])),)))
    eng.submit(AnalyticRequest(1, "g", "sssp", sources=(0,)))
    out = eng.run()
    assert eng.mutation_results[100].actions == {"sssp": "replan"}
    ref = sssp(eng.graphs["g"], 0)
    np.testing.assert_array_equal(out[1].values[0], ref.values)


def test_chained_mutations_accumulate_and_then_swap():
    eng = _engine(staleness_budget=0.05)
    eng.submit(AnalyticRequest(0, "g", "sssp", sources=(0,)))
    eng.run()
    for i in range(2):                               # two overlay batches
        eng.submit(GraphMutation(100 + i, "g",
                                 inserts=_small_inserts(eng, 2, seed=i)))
        eng.submit(AnalyticRequest(1 + i, "g", "sssp", sources=(0,)))
        out = eng.run()
        assert eng.mutation_results[100 + i].actions == {"sssp": "overlay"}
    assert eng.stats()["plan_cache"]["overlays"] == 2
    # a big third batch blows the *accumulated* budget -> replan
    big = _small_inserts(eng, int(0.06 * eng.graphs["g"].nnz), seed=9)
    eng.submit(GraphMutation(102, "g", inserts=big))
    eng.submit(AnalyticRequest(3, "g", "sssp", sources=(0,)))
    out = eng.run()
    assert eng.mutation_results[102].actions == {"sssp": "replan"}
    ref = sssp(eng.graphs["g"], 0)
    np.testing.assert_array_equal(out[3].values[0], ref.values)


def test_inflight_request_rebinds_and_warm_starts():
    eng = _engine()
    src = int(np.argmax(eng.graphs["g"].row_lengths()))
    eng.submit(AnalyticRequest(0, "g", "sssp", sources=(src,)))
    for _ in range(3):
        eng.step()
    assert eng.scheduler.running
    eng.submit(GraphMutation(100, "g", inserts=_small_inserts(eng, 2)))
    out = eng.run()
    assert eng.mutation_results[100].actions == {"sssp": "overlay"}
    np.testing.assert_array_equal(out[0].values[0],
                                  sssp(eng.graphs["g"], src).values)


def test_mutation_trace_replays_deterministically():
    def run_once():
        eng = _engine(staleness_budget=0.002)
        eng.submit(AnalyticRequest(0, "g", "sssp", sources=(0, 1)))
        eng.submit(AnalyticRequest(1, "g", "pagerank",
                                   params={"tol": 1e-5}, max_iters=64))
        for _ in range(3):
            eng.step()
        eng.submit(GraphMutation(100, "g", inserts=_small_inserts(eng, 1)))
        eng.submit(AnalyticRequest(2, "g", "sssp", sources=(2,)))
        eng.submit(GraphMutation(101, "g", inserts=_small_inserts(eng, 6,
                                                                  seed=5)))
        out = eng.run()
        return (eng.scheduler.log,
                {r: (v.values.tobytes(), v.n_iters)
                 for r, v in out.items()},
                {m: eng.mutation_results[m].actions
                 for m in eng.mutation_results},
                eng.stats()["plan_cache"])
    a, b = run_once(), run_once()
    assert a[0] == b[0]                              # identical schedules
    assert a[1] == b[1]                              # bit-identical results
    assert a[2] == b[2]                              # identical lifecycle
    for k in ("overlays", "swaps", "delta_recompiles"):
        assert a[3][k] == b[3][k]


def test_mutation_before_any_request_rebases_cleanly():
    """A mutation on a registered graph with no derived plans yet is a
    pure adjacency update -- the first request then compiles the mutated
    operand cold."""
    eng = _engine()
    eng.submit(GraphMutation(100, "g", inserts=_small_inserts(eng, 2)))
    eng.submit(AnalyticRequest(0, "g", "sssp", sources=(0,)))
    out = eng.run()
    assert eng.mutation_results[100].actions == {}
    ref = sssp(eng.graphs["g"], 0)
    np.testing.assert_array_equal(out[0].values[0], ref.values)


def test_mutation_unknown_graph_rejected():
    eng = _engine()
    with pytest.raises(KeyError, match="not registered"):
        eng.submit(GraphMutation(0, "nope", inserts=((0, 1, 1.0),)))


def test_overlay_address_trace_extends_base():
    from repro.core.cache_model import SANDY_BRIDGE

    adj = _adj(seed=3, n=128)
    p = compile_plan(adj, reorder="none", predictor="none")
    rng = np.random.default_rng(9)
    d = EdgeDelta.from_updates(
        adj, inserts=[(r, c, 1.0) for r, c in _fresh_coords(adj, 6, rng)])
    base_trace = p.address_trace(SANDY_BRIDGE)
    ov_trace = overlay(p, d).address_trace(SANDY_BRIDGE)
    assert np.array_equal(ov_trace[:len(base_trace)], base_trace)
    assert len(ov_trace) > len(base_trace)
    # the delta pass is column-sorted: its x gathers ascend
    xg = ov_trace[len(base_trace):len(base_trace) + 4 * d.nnz][3::4]
    assert np.all(np.diff(xg) >= 0)
    # empty delta leaves the trace untouched
    empty = overlay(p, EdgeDelta.empty(adj.n_rows, adj.n_cols))
    assert np.array_equal(empty.address_trace(SANDY_BRIDGE), base_trace)


def test_plan_cache_report_renders_pre_streaming_stats():
    from repro.telemetry.report import plan_cache_report

    legacy = {"plans": 2, "hits": 5, "misses": 3, "evictions": 0,
              "compiles": 3, "compile_s": 0.1}       # no streaming counters
    out = plan_cache_report(legacy)
    assert "overlays" in out and "KeyError" not in out
    # windowed diff against a pre-streaming snapshot also renders
    now = dict(legacy, overlays=2, swaps=1, delta_recompiles=1, hits=9)
    out2 = plan_cache_report(now, before=legacy)
    assert out2.splitlines()[-1].split(",")[-3:] == ["2", "1", "1"]
