"""MoE: sorted dispatch (the paper's restructuring), sharded parity, aux."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.core.structure import analyze
from repro.distributed.api import use_mesh
from repro.launch.mesh import make_local_mesh
from repro.models import moe as M


def _cfg(capacity=8.0):
    cfg = CONFIGS["kimi-k2-1t-a32b"].reduced()
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capacity))


def test_dispatch_restructuring_improves_structure():
    """The paper's argument in reverse: sorting token slots by expert turns
    an unstructured assignment into a streaming-friendly one.  Needs an
    expert count spanning many x-lines (384 experts = 48 lines of 8)."""
    rng = np.random.default_rng(0)
    top_e = jnp.asarray(rng.integers(0, 384, (2048, 8)))
    unsorted, sorted_m = M.dispatch_structure_demo(top_e, 384)
    ru, rs = analyze(unsorted), analyze(sorted_m)
    assert rs.spatial_locality > 0.99 > ru.spatial_locality
    assert rs.stream_servable >= ru.stream_servable
    # sorted columns are monotone: zero-bandwidth row-to-row jumps
    cols = np.asarray(sorted_m.indices)
    assert (np.diff(cols) >= 0).all()


def test_moe_output_finite_and_shaped():
    cfg = _cfg()
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          dtype=jnp.bfloat16)
    y, aux = M.apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert all(bool(jnp.isfinite(v)) for v in aux.values())


def test_sharded_matches_reference():
    """shard_map EP dispatch == global reference when nothing is dropped."""
    cfg = _cfg(capacity=8.0)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          dtype=jnp.bfloat16)
    y_ref, aux_ref = M.apply_moe(p, cfg, x)
    with use_mesh(make_local_mesh()):
        y_sm, aux_sm = M.apply_moe_sharded(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_sm, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=0.05, atol=0.05)
    for k in aux_ref:
        assert float(aux_sm[k]) == pytest.approx(float(aux_ref[k]),
                                                 rel=1e-3)


def test_capacity_drops_tokens_not_correctness():
    """With tiny capacity the layer still runs; dropped tokens produce
    zero MoE output (residual passthrough semantics)."""
    cfg = _cfg(capacity=0.25)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model),
                          dtype=jnp.bfloat16)
    y, _ = M.apply_moe(p, cfg, x)
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


def test_aux_losses_push_balance():
    """Balance loss is minimal for uniform routing: a uniform router must
    score lower than a collapsed one."""
    cfg = _cfg()
    e = cfg.moe.n_experts
    t = 256
    probs_uniform = jnp.full((t, e), 1.0 / e)
    # collapsed: all mass on expert 0
    probs_collapsed = jnp.zeros((t, e)).at[:, 0].set(1.0)

    def balance(probs):
        top_w, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
        me = probs.mean(0)
        ce = jnp.zeros((e,)).at[top_e.reshape(-1)].add(
            1.0 / (t * cfg.moe.top_k))
        return float(e * jnp.sum(me * ce))

    assert balance(probs_uniform) < balance(probs_collapsed)


def test_shared_experts_always_on():
    """Kimi-style shared expert contributes even when router drops all."""
    cfg = _cfg(capacity=8.0)
    assert cfg.moe.n_shared_experts >= 1
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((1, 4, cfg.d_model), jnp.bfloat16)
    y, _ = M.apply_moe(p, cfg, x)
    assert float(jnp.abs(y.astype(jnp.float32)).sum()) > 0.0
