"""Learned plan compiler: fit determinism, shipped-artifact integrity,
replay agreement, the compiler's model fast path + oracle fallback, and
the plan cache's predictor/oracle compile-counter split."""
import os

import numpy as np
import pytest

from repro import plan
from repro.core.generators import banded_matrix, rmat_matrix
from repro.plan import costmodel as cm
from repro.plan.serial import load_model, save_model

CORPUS = os.path.join(os.path.dirname(cm.__file__), "_data",
                      "costmodel_corpus.json")


@pytest.fixture(scope="module")
def corpus():
    return cm.load_corpus(CORPUS)


@pytest.fixture(scope="module")
def shipped():
    model, step = load_model(cm.DEFAULT_MODEL_DIR)
    assert step == 0
    return model


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------

def test_features_width_and_determinism():
    from repro.core import structure

    rep = structure.analyze(rmat_matrix(256, seed=1))
    f1 = cm.features_for(rep, threads=4)
    f2 = cm.features_for(rep, threads=4)
    assert f1.shape == (len(cm.FEATURE_NAMES),)
    assert np.array_equal(f1, f2) and np.isfinite(f1).all()
    # the thread axis must actually reach the model
    f8 = cm.features_for(rep, threads=8)
    assert not np.array_equal(f1, f8)


def test_geometry_reaches_features():
    from repro.core import structure

    rep = structure.analyze(rmat_matrix(256, seed=1))
    default = cm.features_for(rep, threads=2)
    scaled = cm.features_for(rep, threads=2, l2_bytes=16 * 1024,
                             llc_bytes=64 * 1024)
    assert not np.array_equal(default, scaled)


# ---------------------------------------------------------------------------
# fit determinism + shipped-artifact integrity (what CI re-checks)
# ---------------------------------------------------------------------------

def test_fit_is_deterministic(corpus):
    sub = corpus[:120]
    cfg = {"n_trees": 12}
    a = cm.fit(sub, config=cfg)
    b = cm.fit(sub, config=cfg)
    assert cm.model_bytes(a) == cm.model_bytes(b)


def test_refit_matches_shipped_artifact(corpus, shipped):
    """The shipped model is exactly `fit(checked-in corpus)` -- anyone can
    regenerate it byte-for-byte with `python -m repro.plan.costmodel
    --fit`."""
    assert shipped.meta["corpus_digest"] == cm.corpus_digest(corpus)
    refit = cm.fit(corpus, config=shipped.config)
    assert cm.model_bytes(refit) == cm.model_bytes(shipped)


def test_shipped_agreement_floor(corpus, shipped):
    """Acceptance: the model picks the replay oracle's winner in >=90% of
    corpus cells (grouped per (kind, size, seed, geometry, threads))."""
    ev = cm.evaluate(shipped, corpus)
    assert ev["n_groups"] >= 300
    assert ev["agreement"] >= 0.90, ev
    assert ev["r2"] >= 0.95


def test_model_checkpoint_roundtrip_byte_exact(tmp_path, corpus):
    """float64 thresholds/leaf values survive the checkpoint (raw-byte
    leaves dodge the jnp.asarray float32 truncation)."""
    m = cm.fit(corpus[:120], config={"n_trees": 12})
    d = str(tmp_path / "model")
    save_model(m, d, step=2)
    m2, step = load_model(d)
    assert step == 2
    assert cm.model_bytes(m2) == cm.model_bytes(m)


# ---------------------------------------------------------------------------
# selection rule + evaluation harness
# ---------------------------------------------------------------------------

def test_pick_winner_margin_rule():
    from repro.plan.compiler import REORDER_MARGIN

    assert cm.pick_winner({"none": 1.0, "rcm": 2.0}) == "rcm"
    # inside the transport margin the identity order wins
    within = 1.0 + REORDER_MARGIN / 2
    assert cm.pick_winner({"none": 1.0, "rcm": within}) == "none"
    assert cm.pick_winner({"none": 2.0, "rcm": 1.0}) == "none"


# ---------------------------------------------------------------------------
# the compiler's model fast path
# ---------------------------------------------------------------------------

def _scrambled_banded(n=512, seed=1):
    from repro.reorder import Reordering

    base = banded_matrix(n, max(8, n // 32), seed=seed)
    perm = np.random.default_rng(0).permutation(n)
    return Reordering(row_perm=perm, col_perm=perm).apply(base)


def _scaled_spec():
    # the corpus's 'scaled' geometry: caches small enough that the
    # recovered band actually matters (at machine defaults the whole
    # working set fits in LLC and 'none' wins everywhere)
    from repro.parallel import ParallelSpec

    return ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)


def test_compile_auto_scores_with_model(shipped):
    csr = _scrambled_banded()
    p = plan.compile(csr, reorder="auto", predictor="auto", threads=4,
                     parallel_spec=_scaled_spec())
    assert p.compile_stats["scoring"] == "model"
    assert set(p.predicted) == {"none", "rcm"}
    assert all(v["predictor"] == "model" and v["gflops"] > 0
               for v in p.predicted.values())
    # RCM recovers the band here; the model must see that in the permuted
    # features and agree with the replay oracle's pick
    ref = plan.compile(csr, reorder="auto", predictor="replay", threads=4,
                       parallel_spec=_scaled_spec())
    assert p.chosen == ref.chosen == "rcm"


def test_model_and_oracle_plans_execute_identically(shipped):
    """Scoring mode picks the plan; it must never change what the chosen
    plan computes."""
    import jax.numpy as jnp

    csr = _scrambled_banded()
    x = jnp.asarray(np.random.default_rng(3).normal(size=512)
                    .astype(np.float32))
    pm = plan.compile(csr, reorder="auto", predictor="auto", threads=4,
                      parallel_spec=_scaled_spec())
    po = plan.compile(csr, reorder="auto", predictor="replay", threads=4,
                      parallel_spec=_scaled_spec())
    assert pm.chosen == po.chosen
    assert np.array_equal(np.asarray(pm.execute(x, interpret=True)),
                          np.asarray(po.execute(x, interpret=True)))


def test_predictor_model_falls_back_to_oracle_cleanly():
    prev = cm.set_default_model(None)
    try:
        p = plan.compile(rmat_matrix(512, seed=2), reorder="auto",
                         predictor="model", threads=4)
        assert p.compile_stats["model_fallback"] == 1.0
        assert p.compile_stats["scoring"] == "replay"   # nnz under cutoff
        assert all(v["predictor"] == "replay" for v in p.predicted.values())
    finally:
        cm.set_default_model(prev)


def test_predictor_auto_without_artifact_is_oracle():
    prev = cm.set_default_model(None)
    try:
        p = plan.compile(rmat_matrix(512, seed=2), reorder="auto",
                         predictor="auto", threads=4)
        assert "model_fallback" not in p.compile_stats    # auto, not forced
        assert p.compile_stats["scoring"] == "replay"
    finally:
        cm.set_default_model(prev)


def test_single_candidate_skips_scoring(shipped, monkeypatch):
    # reorder='none' enumerates one candidate: nothing to score
    p = plan.compile(rmat_matrix(256, seed=3), reorder="none",
                     predictor="auto")
    assert p.compile_stats["scoring"] == "none" and p.predicted == {}

    # dedup: when RCM returns a permutation equal to identity, the
    # candidate list collapses to one and scoring is skipped too
    from repro import reorder as _reorder

    def identity_rcm(csr):
        n = csr.n_rows
        perm = np.arange(n, dtype=np.int64)
        return _reorder.Reordering(row_perm=perm, col_perm=perm,
                                   strategy="rcm", params={}, stats={})

    def boom(self, X):
        raise AssertionError("deduped compile must not score")

    monkeypatch.setitem(_reorder.STRATEGIES, "rcm", identity_rcm)
    monkeypatch.setattr(type(shipped), "predict", boom)
    p2 = plan.compile(rmat_matrix(256, seed=3), reorder="auto",
                      predictor="auto", threads=4)
    assert p2.compile_stats["scoring"] == "none"
    assert p2.chosen == "none"


# ---------------------------------------------------------------------------
# plan cache counter split
# ---------------------------------------------------------------------------

def test_cache_splits_predictor_and_oracle_counters(shipped):
    cache = plan.PlanCache()
    a, b, c = (rmat_matrix(256, seed=s) for s in (21, 22, 23))
    cache.get_or_compile(a, reorder="auto", predictor="auto", threads=4)
    cache.get_or_compile(b, reorder="auto", predictor="replay", threads=4)
    cache.get_or_compile(c, reorder="none", predictor="none")
    s = cache.stats()
    assert s["compiles"] == 3
    assert s["predictor_compiles"] == 1 and s["oracle_compiles"] == 1
    assert 0.0 < s["predictor_compile_s"] <= s["compile_s"]
    assert 0.0 < s["oracle_compile_s"] <= s["compile_s"]
    # unscored compile lands in neither bucket
    assert s["predictor_compiles"] + s["oracle_compiles"] < s["compiles"]
    cache.clear()
    s2 = cache.stats()
    assert s2["predictor_compiles"] == s2["oracle_compiles"] == 0
    assert s2["predictor_compile_s"] == s2["oracle_compile_s"] == 0.0


def test_plan_cache_report_has_split_columns(shipped):
    from repro.telemetry.report import plan_cache_report

    cache = plan.PlanCache()
    before = cache.stats()
    cache.get_or_compile(rmat_matrix(256, seed=31), reorder="auto",
                         predictor="auto", threads=4)
    rep = plan_cache_report(cache.stats(), before=before)
    header, row = rep.splitlines()[1:3]
    cells = dict(zip(header.split(","), row.split(",")))
    assert cells["predictor_compiles"] == "1"
    assert cells["oracle_compiles"] == "0"
    assert float(cells["predictor_compile_s"]) > 0.0


# ---------------------------------------------------------------------------
# corpus I/O
# ---------------------------------------------------------------------------

def test_corpus_roundtrip(tmp_path, corpus):
    path = str(tmp_path / "corpus.json")
    cm.save_corpus(corpus[:10], path)
    back = cm.load_corpus(path)
    assert back == cm.sort_rows(corpus[:10])
    assert cm.corpus_digest(back) == cm.corpus_digest(corpus[:10])


def test_label_cell_replays_compiler_prediction():
    """A label row's gflops must equal what `predictor='replay'` scores
    for the same candidate -- the corpus labels ARE the oracle."""
    pt = cm.run_label_cell("banded", 8, "none", 4, spec_label="default")
    from repro.core.cache_model import SANDY_BRIDGE
    from repro.plan.compiler import _predict

    csr = cm.label_matrix("banded", 2 ** 8, 0)
    from repro.parallel import ParallelSpec

    ref = _predict(csr, 4, SANDY_BRIDGE, ParallelSpec(), "replay")
    assert pt.gflops == pytest.approx(ref["gflops"], rel=1e-12)
