"""Pipeline parallelism: schedule math + multi-stage parity (subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import PipelineConfig


def test_schedule_accounting():
    cfg = PipelineConfig(n_stages=4, n_microbatches=12)
    assert cfg.n_ticks == 15
    assert cfg.bubble_fraction == pytest.approx(3 / 15)


def test_bubble_shrinks_with_microbatches():
    b = [PipelineConfig(4, m).bubble_fraction for m in (4, 16, 64)]
    assert b[0] > b[1] > b[2]


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.pipeline import (PipelineConfig, make_pipelined_mlp,
                                            pipeline_apply, reference_apply)

    from repro.launch.mesh import make_mesh
    from repro.distributed.compat import shard_map
    mesh = make_mesh((4,), ("stage",))
    cfg = PipelineConfig(n_stages=4, n_microbatches=8, axis_name="stage")
    stacked, stage_fn = make_pipelined_mlp(cfg, [16]*9, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))   # (M, mb, d)

    def run(params, x):
        # shard_map keeps a leading size-1 stage dim on the local shard
        return pipeline_apply(stage_fn, cfg, params[0], x)

    outs = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(P("stage"), P()), out_specs=P("stage"), check_vma=False,
    ))(stacked, x)
    # out_specs P('stage') stacks per-stage outputs on axis 0: the LAST
    # stage's block holds the real outputs
    got = outs.reshape(4, 8 // 1, *outs.shape[1:])[-1] if False else outs
    # outs: (4*8, 4, 16) -> last stage block
    got = outs.reshape(4, 8, 4, 16)[-1]
    want = reference_apply(stacked, x.reshape(8, 4, 16))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("PIPELINE PARITY OK")
""")


@pytest.mark.slow
def test_pipeline_parity_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "PIPELINE PARITY OK" in r.stdout, (
        f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}")
