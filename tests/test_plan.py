"""Plan/executor pipeline: fingerprints, cache correctness, zero-work
cached execution, bit-identity with the per-call path, serialization."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import plan
from repro.core.formats import BELL, CSR, DIA, ELL, HYB
from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix
from repro.core.spmv import spmv
from repro.kernels import _layout as kl


def _x(n, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=n)
                       .astype(np.float32))


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------

def test_fingerprint_stable_across_equal_matrices():
    a = rmat_matrix(256, seed=3)
    b = rmat_matrix(256, seed=3)
    assert a is not b
    assert plan.matrix_fingerprint(a) == plan.matrix_fingerprint(b)


def test_fingerprint_changes_when_data_changes():
    a = rmat_matrix(256, seed=3)
    data = np.asarray(a.data).copy()
    data[0] += 1.0
    b = CSR(data=jnp.asarray(data), indices=a.indices, indptr=a.indptr,
            n_rows=a.n_rows, n_cols=a.n_cols)
    assert plan.matrix_fingerprint(a) != plan.matrix_fingerprint(b)


def test_fingerprint_distinguishes_container_types():
    csr = fd_matrix(64)
    assert plan.matrix_fingerprint(csr) != \
        plan.matrix_fingerprint(ELL.from_csr(csr))


# ---------------------------------------------------------------------------
# cache correctness
# ---------------------------------------------------------------------------

def test_cache_hit_on_equal_matrix_and_miss_on_changed_data():
    cache = plan.PlanCache()
    a = rmat_matrix(256, seed=1)
    p1 = cache.get_or_compile(a, reorder="none", predictor="none")
    p2 = cache.get_or_compile(rmat_matrix(256, seed=1),
                              reorder="none", predictor="none")
    stats = cache.stats()
    assert p1 is p2 and stats["plans"] == 1
    assert stats["hits"] == 1 and stats["misses"] == 1
    assert stats["compiles"] == 1 and stats["compile_s"] > 0.0
    assert stats["hit_rate"] == 0.5 and stats["evictions"] == 0

    data = np.asarray(a.data).copy()
    data[0] *= 2.0
    changed = CSR(data=jnp.asarray(data), indices=a.indices, indptr=a.indptr,
                  n_rows=a.n_rows, n_cols=a.n_cols)
    p3 = cache.get_or_compile(changed, reorder="none", predictor="none")
    assert p3 is not p1 and cache.misses == 2   # content-addressed invalidation


def test_cache_key_includes_options():
    cache = plan.PlanCache()
    a = fd_matrix(256)
    p1 = cache.get_or_compile(a, reorder="none", predictor="none")
    p2 = cache.get_or_compile(a, reorder="none", predictor="none",
                              format="ell")
    assert p1 is not p2 and p2.format_name == "ell"


def test_cache_lru_eviction_and_invalidate():
    cache = plan.PlanCache(max_plans=2)
    mats = [rmat_matrix(128, seed=s) for s in range(3)]
    for m in mats:
        cache.get_or_compile(m, reorder="none", predictor="none")
    assert len(cache) == 2                      # oldest evicted
    assert cache.invalidate(plan.matrix_fingerprint(mats[-1])) == 1
    assert len(cache) == 1


# ---------------------------------------------------------------------------
# zero-work cached execution + bit-identity (acceptance criterion)
# ---------------------------------------------------------------------------

def _install_work_counters(monkeypatch, counts):
    """Count every structure-analysis / reorder / conversion / layout-prep
    entry point; a cached plan execute must drive them all to zero."""
    from repro.core import structure as _structure

    def wrap(obj, name):
        orig = getattr(obj, name)

        def counting(*a, **k):
            counts[name] = counts.get(name, 0) + 1
            return orig(*a, **k)
        monkeypatch.setattr(obj, name, counting)

    wrap(_structure, "analyze")
    wrap(CSR, "permute")
    for cls in (DIA, BELL, ELL, HYB):
        wrap(cls, "from_csr")
    for fn in ("prepare_csr", "prepare_dia", "prepare_ell", "prepare_bell",
               "prepare_ell_shards", "prepare_csr_seg", "prepare_hyb"):
        wrap(kl, fn)


def test_cached_execute_zero_work_bit_identical_rmat_4k(monkeypatch):
    """R-MAT 2^12: a cached plan execute performs zero structure analysis,
    reordering, format conversion, or layout padding, and its result is
    bit-identical to the per-call `spmv(..., use_pallas=True)` path."""
    csr = rmat_matrix(2 ** 12, seed=0)
    x = _x(csr.n_cols, seed=5)
    y_percall = spmv(csr, x, use_pallas=True, interpret=True)

    cache = plan.PlanCache()
    # format pinned to csr: bit-identity against the per-call CSR path is
    # the point here (auto would pick hyb for this matrix; csr-seg/hyb
    # bit-identity to the CSR kernel is pinned by the property suite)
    opts = dict(reorder="none", predictor="analytic", threads=4,
                format="csr")
    p_cold = cache.get_or_compile(csr, **opts)
    p = cache.get_or_compile(csr, **opts)       # warm: cache hit
    assert p is p_cold and cache.hits == 1

    counts = {}
    _install_work_counters(monkeypatch, counts)
    y_plan = p.execute(x, interpret=True)
    assert counts == {}, f"cached execute did per-call work: {counts}"
    assert np.array_equal(np.asarray(y_plan), np.asarray(y_percall))


def test_reordered_plan_matches_reordered_spmv_bitwise():
    base = banded_matrix(512, 6, nnz_per_row=4, seed=1)
    perm = np.random.default_rng(0).permutation(512)
    from repro.reorder import Reordering
    scrambled = Reordering(row_perm=perm, col_perm=perm).apply(base)

    p = plan.compile(scrambled, reorder="rcm", predictor="none")
    assert p.reordering is not None
    x = _x(512, seed=2)
    y_plan = p.execute(x, interpret=True)
    y_ref = spmv(p.container, x, use_pallas=True, interpret=True,
                 reordering=p.reordering)
    assert np.array_equal(np.asarray(y_plan), np.asarray(y_ref))
    # and both equal the unpermuted multiply up to float tolerance
    np.testing.assert_allclose(np.asarray(y_plan),
                               np.asarray(spmv(scrambled, x)),
                               rtol=1e-4, atol=1e-4)


def test_predictor_scores_candidates():
    csr = rmat_matrix(2 ** 10, seed=4)
    p = plan.compile(csr, reorder="auto", predictor="replay", threads=4)
    assert set(p.predicted) == {"none", "rcm"}
    assert all(v["gflops"] > 0 for v in p.predicted.values())
    if p.chosen != "none":
        # a reordered winner must clear the transport margin over identity
        assert p.predicted[p.chosen]["gflops"] > \
            p.predicted["none"]["gflops"] * (1 + plan.compiler.REORDER_MARGIN)


# ---------------------------------------------------------------------------
# repeated-traffic surfaces
# ---------------------------------------------------------------------------

def test_execute_many_matches_per_vector_execute():
    csr = rmat_matrix(512, seed=6)
    p = plan.compile(csr, reorder="rcm", predictor="none")
    X = jnp.stack([_x(512, seed=s) for s in range(4)])
    Y = p.execute_many(X)
    assert Y.shape == (4, 512)
    for k in range(4):
        np.testing.assert_allclose(
            np.asarray(Y[k]), np.asarray(p.execute(X[k], interpret=True)),
            rtol=1e-4, atol=1e-4)


def test_power_iteration_amortized_driver():
    n = 128
    csr = banded_matrix(n, 4, nnz_per_row=3, seed=1)
    dense = np.asarray(csr.to_dense())
    spd = dense @ dense.T + n * np.eye(n, dtype=np.float32)
    rows, cols = np.nonzero(spd)
    spd_csr = CSR.from_coo(rows, cols, spd[rows, cols], n, n)
    p = plan.compile(spd_csr, reorder="none", predictor="none")
    lam, _ = p.power_iteration(jnp.ones((n,), jnp.float32) / np.sqrt(n),
                               n_iters=200)
    w = np.linalg.eigvalsh(spd)
    assert float(lam) == pytest.approx(float(w[-1]), rel=1e-3)


def test_warm_execute_amortizes_compile():
    csr = rmat_matrix(2 ** 11, seed=7)
    x = _x(csr.n_cols)
    t0 = time.perf_counter()
    p = plan.compile(csr, reorder="auto", predictor="analytic")
    p.execute(x, interpret=True).block_until_ready()
    cold = time.perf_counter() - t0

    warm_ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        p.execute(x, interpret=True).block_until_ready()
        warm_ts.append(time.perf_counter() - t0)
    warm = float(np.median(warm_ts))
    assert warm < cold / 2, f"warm {warm:.4f}s vs cold {cold:.4f}s"


# ---------------------------------------------------------------------------
# spmv thin client
# ---------------------------------------------------------------------------

def test_spmv_pallas_routes_through_default_cache():
    csr = rmat_matrix(256, seed=9)
    x = _x(256)
    y1 = spmv(csr, x, use_pallas=True, interpret=True)
    before = plan.DEFAULT_CACHE.stats()
    y2 = spmv(csr, x, use_pallas=True, interpret=True)
    after = plan.DEFAULT_CACHE.stats()
    assert after["hits"] == before["hits"] + 1
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


def test_spmv_still_works_under_jit_tracing():
    # tracer containers cannot be fingerprinted; spmv must fall back
    import jax

    dia = DIA.from_csr(fd_matrix(256))
    x = _x(256)

    @jax.jit
    def f(d, xv):
        return spmv(d, xv, use_pallas=True, interpret=True)

    np.testing.assert_allclose(np.asarray(f(dia, x)),
                               np.asarray(spmv(dia, x)), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# serialization through checkpoint
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind",
                         ["dia", "csr-reordered", "bell", "csr-seg", "hyb"])
def test_plan_checkpoint_roundtrip(tmp_path, kind):
    if kind == "dia":
        p = plan.compile(fd_matrix(256), reorder="none", predictor="none")
        assert p.format_name == "dia"
    elif kind == "bell":
        p = plan.compile(fd_matrix(256), reorder="none", predictor="none",
                         format="bell")
    elif kind in ("csr-seg", "hyb"):
        p = plan.compile(rmat_matrix(256, seed=2), reorder="none",
                         predictor="none", format=kind)
        assert p.format_name == kind
    else:
        p = plan.compile(rmat_matrix(256, seed=2), reorder="rcm",
                         predictor="none", format="csr")
        assert p.format_name == "csr" and p.reordering is not None

    d = str(tmp_path / kind)
    plan.save_plan(p, d, step=3)
    p2, step = plan.load_plan(d)
    assert step == 3
    assert p2.fingerprint == p.fingerprint
    assert p2.format_name == p.format_name
    assert p2.report == p.report
    if p.reordering is not None:
        assert np.array_equal(p2.reordering.row_perm, p.reordering.row_perm)

    x = _x(256, seed=4)
    assert np.array_equal(np.asarray(p.execute(x, interpret=True)),
                          np.asarray(p2.execute(x, interpret=True)))


def test_sharded_plan_roundtrip_and_execute(tmp_path):
    from repro.distributed import row_mesh

    csr = rmat_matrix(256, seed=8)
    mesh = row_mesh()
    p = plan.compile(csr, mesh=mesh, reorder="none", predictor="none")
    assert p.format_name == "ell-sharded"
    x = _x(256, seed=1)
    y = p.execute(x, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(spmv(csr, x)),
                               rtol=1e-4, atol=1e-4)

    d = str(tmp_path / "sharded")
    plan.save_plan(p, d)
    p2, _ = plan.load_plan(d)               # meshes are never serialized
    with pytest.raises(ValueError):
        p2.execute(x, interpret=True)
    p3, _ = plan.load_plan(d, mesh=mesh)    # rebind to this process's devices
    assert np.array_equal(np.asarray(y), np.asarray(p3.execute(x,
                                                               interpret=True)))


def test_sweep_reuses_plan_trace():
    """scaling_sweep replays ONE cached plan/trace across the thread axis
    (and across repeated sweeps in the same process)."""
    from repro.core.cache_model import SANDY_BRIDGE
    from repro.telemetry.sweep import scaling_sweep, sweep_plan_cache

    cache = sweep_plan_cache()
    before = cache.stats()
    pts = scaling_sweep(log2ns=(8,), kinds=("rmat",), threads_list=(1, 2),
                        seed=11, sweeps=1)
    mid = cache.stats()
    assert mid["misses"] == before["misses"] + 1     # compiled once
    scaling_sweep(log2ns=(8,), kinds=("rmat",), threads_list=(1,),
                  seed=11, sweeps=1)
    after = cache.stats()
    assert after["misses"] == mid["misses"]          # second sweep: all hits
    assert after["hits"] > mid["hits"]
    key = next(k for k in cache._plans)
    assert any(SANDY_BRIDGE in p._traces for p in cache._plans.values())
    assert len(pts) == 2
    del key


def test_cache_distinguishes_closures_over_different_constants():
    """Two lambdas with the same name but different closed-over constants
    must produce different cache keys (sweep reorderings pass these)."""
    from repro.reorder import cache_block

    a = rmat_matrix(256, seed=12)
    mk = [lambda c, k=k: cache_block(c, rows_per_block=k) for k in (4, 8)]
    cache = plan.PlanCache()
    p4 = cache.get_or_compile(a, reorder=mk[0], predictor="none")
    p8 = cache.get_or_compile(a, reorder=mk[1], predictor="none")
    assert cache.misses == 2 and p4 is not p8   # distinct keys, no collision
    assert p4.reordering.params != p8.reordering.params


def test_fingerprint_memoized_per_object():
    a = rmat_matrix(256, seed=13)
    from repro.plan import fingerprint as fpm

    fp1 = plan.matrix_fingerprint(a)
    assert fpm._FP_MEMO[id(a)][1] == fp1
    assert plan.matrix_fingerprint(a) == fp1      # served from the memo


def test_dropped_container_frees_fingerprint_memo_entry():
    """The memo holds containers by weak reference: dropping the last
    strong reference must evict the entry, or long-running serve fleets
    leak one entry per matrix ever fingerprinted (and id() reuse could
    then serve a *stale* digest for a new object at the same address)."""
    import gc

    from repro.core.delta import EdgeDelta
    from repro.plan import delta_fingerprint
    from repro.plan import fingerprint as fpm

    a = rmat_matrix(256, seed=17)
    plan.matrix_fingerprint(a)
    key = id(a)
    assert key in fpm._FP_MEMO
    del a
    gc.collect()
    assert key not in fpm._FP_MEMO

    d = EdgeDelta.from_updates(rmat_matrix(64, seed=3),
                               inserts=[(0, 1, 2.0)])
    delta_fingerprint(d)
    dkey = id(d)
    assert dkey in fpm._DELTA_MEMO
    del d
    gc.collect()
    assert dkey not in fpm._DELTA_MEMO


def test_fingerprint_memo_capped():
    """Even without collection pressure the memo cannot grow without
    bound: the FIFO backstop holds it at `_MEMO_CAP` entries."""
    from repro.plan import fingerprint as fpm

    keep = [rmat_matrix(16, seed=s) for s in range(8)]
    for m in keep:
        plan.matrix_fingerprint(m)
    assert len(fpm._FP_MEMO) <= fpm._MEMO_CAP


def test_execute_many_without_retained_csr_raises_clearly():
    from repro.distributed import row_mesh

    csr = rmat_matrix(128, seed=14)
    p = plan.compile(csr, mesh=row_mesh(), reorder="none",
                     predictor="none", keep_csr=False)
    with pytest.raises(ValueError, match="keep_csr"):
        p.execute_many(jnp.ones((2, 128), jnp.float32))


def test_predictor_none_with_auto_reorder_does_no_candidate_work(monkeypatch):
    calls = {}
    from repro import reorder as _reorder

    orig = _reorder.STRATEGIES["rcm"]

    def counting(csr):
        calls["rcm"] = calls.get("rcm", 0) + 1
        return orig(csr)

    monkeypatch.setitem(_reorder.STRATEGIES, "rcm", counting)
    p = plan.compile(rmat_matrix(256, seed=15), predictor="none")
    assert calls == {} and p.chosen == "none" and p.reordering is None


# ---------------------------------------------------------------------------
# degenerate geometries (nnz=0, single row, in-place mutation)
# ---------------------------------------------------------------------------

def _empty_csr(n=8):
    z = np.array([], dtype=np.int64)
    return CSR.from_coo(z, z, np.array([], dtype=np.float32), n, n)


def test_empty_matrix_plan_executes_to_zeros():
    """nnz=0 regression: the auto-chosen format (DIA with zero diagonals)
    used to crash the Pallas grid with a zero-size scalar-prefetch
    operand."""
    m = _empty_csr(8)
    x = jnp.ones((8,), jnp.float32)
    p = plan.compile(m)
    np.testing.assert_array_equal(np.asarray(p.execute(x)), np.zeros(8))
    np.testing.assert_array_equal(
        np.asarray(spmv(m, x, use_pallas=True)), np.zeros(8))
    # every forced format survives nnz=0 too
    for fmt in ("dia", "bell", "ell", "csr"):
        pf = plan.compile(m, format=fmt, reorder="none", predictor="none")
        np.testing.assert_array_equal(np.asarray(pf.execute(x)), np.zeros(8))


def test_empty_matrix_semiring_plan_yields_identity():
    m = _empty_csr(8)
    p = plan.compile(m, semiring="min_plus")
    y = np.asarray(p.execute(jnp.ones((8,), jnp.float32)))
    assert np.isinf(y).all()                     # min-plus ⊕-identity


def test_single_row_matrix_plan_and_spmv():
    m = CSR.from_coo([0, 0], [0, 2], [1.0, 2.0], 1, 3)
    x = jnp.asarray([1.0, 10.0, 100.0], jnp.float32)
    p = plan.compile(m)
    np.testing.assert_array_equal(np.asarray(p.execute(x)), [201.0])
    np.testing.assert_array_equal(
        np.asarray(spmv(m, x, use_pallas=True)), [201.0])


def test_invalidate_accepts_mutated_matrix():
    """In-place mutation regression: the per-object fingerprint memo used
    to keep serving the pre-mutation digest, so `invalidate` could never
    find (and the cache kept serving) the stale plan."""
    cache = plan.PlanCache()
    m = CSR(data=np.ones(2, np.float32), indices=np.array([0, 1], np.int32),
            indptr=np.array([0, 1, 2], np.int32), n_rows=2, n_cols=2)
    cache.get_or_compile(m, format="csr", reorder="none", predictor="none")
    fp_before = plan.matrix_fingerprint(m)
    np.asarray(m.data)[0] = 5.0                  # in-place: memo now stale
    assert plan.matrix_fingerprint(m) == fp_before   # the failure mode
    assert cache.invalidate(m) == 1              # drops the stale entry
    assert len(cache) == 0
    assert plan.matrix_fingerprint(m) != fp_before   # memo evicted, re-hashed
