"""Dispatcher + composite analytics (paper §I motivation)."""
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BELL, CSR, DIA, HYB
from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix
from repro.core.spmv import auto_format, pagerank, power_iteration, spmv


def test_auto_format_banded_goes_dia():
    assert isinstance(auto_format(fd_matrix(1024)), DIA)


def test_auto_format_unstructured_goes_csr_bell_or_hyb():
    fmt = auto_format(rmat_matrix(1024))
    assert isinstance(fmt, (CSR, BELL, HYB))


def test_spmv_pallas_path_matches_jnp():
    csr = fd_matrix(256)
    x = jnp.asarray(np.random.default_rng(0).normal(size=256)
                    .astype(np.float32))
    fmt = auto_format(csr)
    y_pallas = spmv(fmt, x, use_pallas=True, interpret=True)
    y_jnp = spmv(csr, x)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_jnp),
                               rtol=1e-4, atol=1e-4)


def test_power_iteration_converges_on_spd():
    # A = B B^T + n I is SPD with known dominant behaviour
    n = 128
    csr = banded_matrix(n, 4, nnz_per_row=3, seed=1)
    dense = np.asarray(csr.to_dense())
    spd = dense @ dense.T + n * np.eye(n, dtype=np.float32)
    lam, v = power_iteration(jnp.asarray(spd),
                             jnp.ones((n,), jnp.float32) / np.sqrt(n),
                             n_iters=200)
    w = np.linalg.eigvalsh(spd)
    assert float(lam) == pytest.approx(float(w[-1]), rel=1e-3)


def test_pagerank_is_distribution():
    r = pagerank(rmat_matrix(512), n_iters=16)
    assert float(jnp.sum(r)) == pytest.approx(1.0, abs=0.05)
    assert float(jnp.min(r)) >= 0.0


import pytest  # noqa: E402  (used above)
