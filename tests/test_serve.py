"""Serving: allocator invariants, scheduler policy, engine correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _opt_deps import given, settings, st

from repro.configs import CONFIGS
from repro.serve import (BlockAllocator, EngineConfig, PoolConfig, Request,
                         Scheduler, gather_kv, init_pool, make_engine,
                         write_token)
from repro.serve.engine import Engine


def _pool_cfg(n=16, block=8, max_blocks=8):
    return PoolConfig(n_blocks=n, block_size=block,
                      max_blocks_per_seq=max_blocks)


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_allocator_admit_extend_release():
    a = BlockAllocator(_pool_cfg())
    blocks = a.admit(1, 20)          # 20 tokens -> 3 blocks of 8
    assert len(blocks) == 3 and a.n_free == 13
    assert a.extend(1, 4)            # 24 tokens -> still 3 blocks
    assert a.n_free == 13
    assert a.extend(1, 1)            # 25 tokens -> 4th block
    assert a.n_free == 12
    a.release(1)
    assert a.n_free == 16


def test_allocator_exhaustion():
    a = BlockAllocator(_pool_cfg(n=2))
    a.admit(1, 16)
    assert not a.can_admit(8)
    with pytest.raises(MemoryError):
        a.admit(2, 8)


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 5), st.integers(1, 30)),
                    min_size=1, max_size=30))
def test_property_allocator_never_leaks(ops):
    a = BlockAllocator(_pool_cfg(n=32))
    live = set()
    for seq, toks in ops:
        if seq in live:
            a.release(seq)
            live.discard(seq)
        elif a.can_admit(toks) and a.blocks_needed(toks) <= 8:
            a.admit(seq, toks)
            live.add(seq)
    for seq in list(live):
        a.release(seq)
    assert a.n_free == 32
    total = sum(len(t) for t in a.tables.values())
    assert total == 0


# ---------------------------------------------------------------------------
# Paged pool device ops
# ---------------------------------------------------------------------------

def test_pool_write_gather_roundtrip():
    cfg = _pool_cfg(n=8, block=4, max_blocks=4)
    pool = init_pool(cfg, n_kv_heads=2, head_dim=8, n_layers=1,
                     dtype=jnp.float32)
    rng = np.random.default_rng(0)
    # write 6 tokens for one sequence across blocks [2, 5]
    table = jnp.asarray([[2, 5, 0, 0]], jnp.int32)
    ks = []
    for t in range(6):
        k_new = jnp.asarray(rng.normal(size=(1, 2, 8)).astype(np.float32))
        v_new = k_new * 2
        block_id = jnp.asarray([int(table[0, t // 4])])
        offset = jnp.asarray([t % 4])
        pool = write_token(pool, 0, block_id, offset, k_new, v_new)
        ks.append(np.asarray(k_new[0]))
    k_view, v_view = gather_kv(pool, 0, table)
    got = np.asarray(k_view[0, :6])
    np.testing.assert_allclose(got, np.stack(ks), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v_view[0, :6]), 2 * np.stack(ks),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def test_scheduler_fifo_admission():
    s = Scheduler(_pool_cfg(n=4, block=8), max_batch=2)
    for i in range(3):
        s.submit(Request(req_id=i, prompt=[1] * 4, max_new_tokens=2))
    newly = s.admit_waiting()
    assert [sl.req.req_id for sl in newly] == [0, 1]
    assert len(s.queue) == 1


def test_scheduler_preempts_youngest_on_exhaustion():
    s = Scheduler(_pool_cfg(n=3, block=4, max_blocks=4), max_batch=2)
    s.submit(Request(req_id=0, prompt=[1] * 4, max_new_tokens=50))
    s.admit_waiting()
    s.tick()
    s.submit(Request(req_id=1, prompt=[1] * 4, max_new_tokens=50))
    s.admit_waiting()
    # pool: 3 blocks, both seqs hold 1; extending both soon exhausts it
    for _ in range(12):
        active = s.pre_decode()
        for slot in active:
            s.post_decode(slot, token=0)
        if s.preemptions:
            break
    assert s.preemptions >= 1
    # the OLDER request must still be running or finished, not preempted
    assert all(r.req_id != 0 for r in s.queue)


def test_scheduler_key_collision_regression():
    """slot 4/req 0 and slot 0/req 4 must not share an allocator key (an
    additive slot+req scheme collides and corrupts the block tables)."""
    s = Scheduler(_pool_cfg(n=64, block=4, max_blocks=8), max_batch=6)
    for i in range(12):
        s.submit(Request(req_id=i, prompt=[1] * 6, max_new_tokens=8))
    for _ in range(200):
        if s.idle:
            break
        s.tick()
        for slot in s.admit_waiting():
            s.post_decode(slot, token=7)
        for slot in s.pre_decode():
            s.post_decode(slot, token=7)
    assert s.idle and len(s.finished) == 12
    assert s.alloc.n_free == 64          # no leaked blocks


def test_scheduler_completes_all():
    s = Scheduler(_pool_cfg(n=16, block=4), max_batch=2)
    for i in range(4):
        s.submit(Request(req_id=i, prompt=[1, 2], max_new_tokens=3))
    for _ in range(50):
        if s.idle:
            break
        s.tick()
        for slot in s.admit_waiting():
            s.post_decode(slot, token=7)
        for slot in s.pre_decode():
            s.post_decode(slot, token=7)
    assert s.idle and len(s.finished) == 4


# ---------------------------------------------------------------------------
# Engine end-to-end: continuous batching == sequential decoding
# ---------------------------------------------------------------------------

def test_engine_matches_single_request_decode():
    """Greedy generation through the batched engine must equal running the
    same request alone -- per-slot positions / cache isolation proof."""
    cfg = CONFIGS["stablelm-1.6b"].reduced()
    ecfg = EngineConfig(max_batch=3, max_context=64, block_size=8)
    eng = make_engine(cfg, ecfg=ecfg)
    prompts = [[5, 6, 7], [9, 10, 11, 12, 13], [2, 3]]
    reqs = [Request(req_id=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    batched = eng.run(reqs)

    for i, p in enumerate(prompts):
        solo_engine = Engine(cfg, eng.params, EngineConfig(
            max_batch=1, max_context=64, block_size=8))
        solo = solo_engine.run(
            [Request(req_id=0, prompt=list(p), max_new_tokens=5)])
        assert batched[i] == solo[0], f"request {i} diverged"


def test_engine_more_requests_than_slots():
    cfg = CONFIGS["stablelm-1.6b"].reduced()
    eng = make_engine(cfg, ecfg=EngineConfig(max_batch=2, max_context=32,
                                             block_size=8))
    reqs = [Request(req_id=i, prompt=[1 + i, 2], max_new_tokens=3)
            for i in range(5)]
    out = eng.run(reqs)
    assert len(out) == 5
    assert all(len(v) == 3 for v in out.values())
