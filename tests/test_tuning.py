"""§Perf knobs: every optimized code path must match its baseline path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.distributed.api import use_mesh
from repro.launch.mesh import make_local_mesh
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.models import tuning
from repro.models.common import _sdpa_chunked


@pytest.fixture(autouse=True)
def _restore_profile():
    yield
    tuning.set_profile("optimized")


def test_profiles_cover_all_knobs():
    base = tuning._PROFILES["baseline"]
    opt = tuning._PROFILES["optimized"]
    assert set(base) == set(opt)
    tuning.set_profile("baseline")
    assert not tuning.attn_chunk_remat
    tuning.set_profile("optimized")
    assert tuning.attn_chunk_remat


def test_causal_unroll_exact():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 512, 8, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 512, 4, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 512, 4, 32)).astype(np.float32))
    tuning.set_knob("causal_chunk_unroll", False)
    a = _sdpa_chunked(q, k, v, causal=True, window=None, q_offset=0,
                      chunk=128)
    tuning.set_knob("causal_chunk_unroll", True)
    b = _sdpa_chunked(q, k, v, causal=True, window=None, q_offset=0,
                      chunk=128)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rwkv_chunked_matches_sequential():
    cfg = CONFIGS["rwkv6-3b"].reduced()
    p = R.init_rwkv_time(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 512, cfg.d_model),
                          jnp.float32)
    tuning.set_knob("rwkv_chunked_scan", False)
    y_seq, _ = R.apply_rwkv_time(p, cfg, x)
    tuning.set_knob("rwkv_chunked_scan", True)
    y_chk, _ = R.apply_rwkv_time(p, cfg, x)
    err = float(jnp.abs(y_seq - y_chk).max())
    assert err / float(jnp.abs(y_seq).max()) < 1e-4


def test_rwkv_chunked_gradients_close():
    cfg = CONFIGS["rwkv6-3b"].reduced()
    p = R.init_rwkv_time(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 512, cfg.d_model),
                          jnp.float32)

    def loss(p, x):
        y, _ = R.apply_rwkv_time(p, cfg, x)
        return jnp.sum(y ** 2)

    tuning.set_knob("rwkv_chunked_scan", False)
    g_seq = jax.grad(loss)(p, x)
    tuning.set_knob("rwkv_chunked_scan", True)
    g_chk = jax.grad(loss)(p, x)
    for a, b in zip(jax.tree.leaves(g_seq), jax.tree.leaves(g_chk)):
        scale = float(jnp.abs(a.astype(jnp.float32)).max()) + 1e-6
        err = float(jnp.abs(a.astype(jnp.float32)
                            - b.astype(jnp.float32)).max())
        assert err / scale < 5e-3


def test_wkv_chunked_strong_decay_bounded_error():
    """The log-decay floor only distorts already-dead contributions."""
    from repro.models.rwkv6 import _wkv_chunked
    rng = np.random.default_rng(2)
    b, s, h, hd = 1, 512, 2, 16
    r = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    lw = jnp.asarray(-rng.uniform(0.01, 6.0, size=(b, s, h, hd))
                     .astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, hd)).astype(np.float32))
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(S, xs):
        rt, kt, vt, lwt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", rt, S + u[..., None] * kv)
        return jnp.exp(lwt)[..., None] * S + kv, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, lw))
    S_ref, outs = jax.lax.scan(step, s0, xs)
    ref = jnp.moveaxis(outs, 0, 1)
    S_got, got = _wkv_chunked(r, k, v, lw, u, s0, 256)
    rel = (float(jnp.abs(got.reshape(ref.shape) - ref).max())
           / float(jnp.abs(ref).max()))
    assert rel < 0.05                      # pathological uniform-strong decay
    np.testing.assert_allclose(np.asarray(S_got), np.asarray(S_ref),
                               rtol=1e-3, atol=1e-3)


def test_moe_decode_weight_stationary_parity():
    cfg = CONFIGS["jamba-v0.1-52b"].reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model),
                          dtype=jnp.bfloat16)
    y_ref, _ = MOE.apply_moe(p, cfg, x)
    with use_mesh(make_local_mesh()):
        y_ws, _ = MOE.apply_moe_decode(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_ws, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=0.05, atol=0.05)


def test_moe_a2a_parity_single_device():
    cfg = CONFIGS["kimi-k2-1t-a32b"].reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          dtype=jnp.bfloat16)
    y_ref, _ = MOE.apply_moe(p, cfg, x)
    with use_mesh(make_local_mesh()):
        y_a2a, _ = MOE.apply_moe_a2a(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_a2a, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=0.05, atol=0.05)


def test_baseline_profile_still_trains():
    """The paper-faithful lowering profile must remain runnable."""
    tuning.set_profile("baseline")
    from repro.models.registry import get_model, random_train_batch
    cfg = CONFIGS["stablelm-1.6b"].reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = random_train_batch(cfg, 2, 16)
    loss = api.loss_fn(params, batch, remat="none")
    assert bool(jnp.isfinite(loss))
