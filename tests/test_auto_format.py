"""auto_format dispatch: each structure kind routes to its format, every
routed path agrees with the dense reference."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import structure
from repro.core.formats import BELL, CSR, DIA, HYB
from repro.core.generators import (banded_matrix, fd_matrix, rmat_matrix,
                                   uniform_random_matrix)
from repro.core.spmv import auto_format, spmv


def _blocked_matrix(n=1024, n_blocks=12, seed=0) -> CSR:
    """A few dense 8x128 tiles: the BELL-native structure."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    rr, cc = np.meshgrid(np.arange(8), np.arange(128), indexing="ij")
    for _ in range(n_blocks):
        r0 = int(rng.integers(0, n // 8)) * 8
        c0 = int(rng.integers(0, n // 128)) * 128
        rows.append((r0 + rr).ravel())
        cols.append((c0 + cc).ravel())
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    vals = rng.normal(size=rows.shape[0]).astype(np.float32)
    return CSR.from_coo(rows, cols, vals, n, n)


def _assert_matches_dense(fmt, csr):
    x = jnp.asarray(np.random.default_rng(42)
                    .normal(size=csr.n_cols).astype(np.float32))
    want = np.asarray(csr.to_dense()) @ np.asarray(x)
    got = np.asarray(spmv(fmt, x))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_banded_dispatches_to_dia():
    csr = fd_matrix(1024)
    rep = structure.analyze(csr)
    assert rep.kind == "banded"
    fmt = auto_format(csr, rep)
    assert isinstance(fmt, DIA)
    _assert_matches_dense(fmt, csr)


def test_narrow_band_dispatches_to_dia():
    csr = banded_matrix(512, 8, nnz_per_row=5, seed=2)
    fmt = auto_format(csr)
    assert isinstance(fmt, DIA)
    _assert_matches_dense(fmt, csr)


def test_blocked_dispatches_to_bell():
    csr = _blocked_matrix()
    rep = structure.analyze(csr)
    assert rep.kind == "blocked"
    fmt = auto_format(csr, rep)
    assert isinstance(fmt, BELL)
    _assert_matches_dense(fmt, csr)


def test_power_law_dispatches_to_hyb():
    """Power-law row lengths (high nnz CV) route to the hybrid row split:
    hub rows go to the column-sorted heavy stream, the rest stay ELL."""
    csr = rmat_matrix(2048, seed=5)
    rep = structure.analyze(csr)
    assert rep.kind == "unstructured"
    assert rep.row_nnz_cv >= 1.0        # what triggers the hyb pick
    fmt = auto_format(csr, rep)
    assert isinstance(fmt, HYB)
    _assert_matches_dense(fmt, csr)


def test_flat_unstructured_stays_csr():
    """Unstructured but near-uniform row lengths (low CV): no hub rows to
    split off, so the dispatcher keeps CSR."""
    csr = uniform_random_matrix(2048, nnz_per_row=8, seed=5)
    rep = structure.analyze(csr)
    assert rep.kind == "unstructured"
    assert rep.row_nnz_cv < 1.0
    fmt = auto_format(csr, rep)
    assert fmt is csr
    _assert_matches_dense(fmt, csr)


def test_banded_with_many_offsets_falls_back_to_csr():
    """kind == 'banded' but > 64 distinct diagonals: DIA storage would
    blow up (n_diags x n dense), so the dispatcher must keep CSR."""
    csr = banded_matrix(512, 200, nnz_per_row=7, seed=3)
    rep = structure.analyze(csr)
    wide = dataclasses.replace(rep, kind="banded", n_distinct_offsets=100)
    fmt = auto_format(csr, wide)
    assert fmt is csr
    _assert_matches_dense(fmt, csr)


@pytest.mark.parametrize("gen,expected", [
    (lambda: fd_matrix(1024), DIA),
    (lambda: _blocked_matrix(), BELL),
    (lambda: rmat_matrix(2048, seed=5), HYB),
    (lambda: uniform_random_matrix(2048, nnz_per_row=8, seed=5), CSR),
])
def test_all_dispatch_paths_agree_with_dense(gen, expected):
    csr = gen()
    fmt = auto_format(csr)
    assert isinstance(fmt, expected)
    _assert_matches_dense(fmt, csr)
