import os

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here -- smoke tests and benches must see 1 device.
# Multi-device behaviour is tested via subprocess in test_multidevice.py.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    try:
        import hypothesis  # noqa: F401  (its plugin owns --hypothesis-seed)
    except ModuleNotFoundError:
        # Accept the flag anyway so one CI/local command line works in both
        # environments; without hypothesis the property tests skip.
        parser.addoption("--hypothesis-seed", action="store", default=None,
                         help="ignored: hypothesis is not installed, "
                              "property tests will be skipped")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    try:
        from hypothesis import HealthCheck, settings
    except ModuleNotFoundError:
        return
    # The kernel property suite's profile: no deadline (interpret-mode
    # Pallas launches are slow and jit caches warm up lazily), example
    # budget tunable from the environment so the CI kernel-properties job
    # can afford a deeper search than the default tier-1 run.  Combine
    # with the hypothesis plugin's own `--hypothesis-seed=N` for a fully
    # deterministic replay.
    settings.register_profile(
        "kernel-properties",
        deadline=None,
        max_examples=int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", "25")),
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large,
                               HealthCheck.filter_too_much],
    )
    settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "kernel-properties"))
