import numpy as np
import pytest

# NOTE: no XLA_FLAGS here -- smoke tests and benches must see 1 device.
# Multi-device behaviour is tested via subprocess in test_multidevice.py.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
