"""Reordering invariants: permutation validity, round-trips, SpMV
equivalence (bit-identical on exactly-representable values), RCM
bandwidth reduction, and the auto_format re-decision."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reorder
from repro.core.formats import CSR, DIA
from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix
from repro.core.spmv import auto_format, spmv
from repro.core.structure import analyze, analyze_reorder

N = 256


def _int_valued(csr: CSR, seed: int = 0) -> CSR:
    """Same pattern, small-integer values: f32 sums are exact, so SpMV
    results must be BIT-identical under any summation order."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(1, 5, size=csr.nnz).astype(np.float32)
    return CSR(data=jnp.asarray(vals), indices=csr.indices,
               indptr=csr.indptr, n_rows=csr.n_rows, n_cols=csr.n_cols)


@pytest.fixture(params=["fd", "rmat"])
def matrix(request):
    if request.param == "fd":
        return fd_matrix(N, seed=1)
    return rmat_matrix(N, seed=1)


@pytest.mark.parametrize("name", list(reorder.STRATEGIES))
def test_strategy_produces_true_permutations(matrix, name):
    r = reorder.STRATEGIES[name](matrix)
    r.validate()   # raises unless both perms are true permutations
    assert reorder.is_permutation(r.row_perm, matrix.n_rows)
    assert reorder.is_permutation(r.col_perm, matrix.n_cols)
    assert r.strategy != ""


@pytest.mark.parametrize("name", ["rcm", "degree-sort", "cache-block"])
def test_permute_roundtrips_through_inverse(matrix, name):
    r = reorder.STRATEGIES[name](matrix)
    back = r.apply(matrix).permute(r.inv_row_perm, r.inv_col_perm)
    np.testing.assert_array_equal(np.asarray(back.indptr),
                                  np.asarray(matrix.indptr))
    np.testing.assert_array_equal(np.asarray(back.indices),
                                  np.asarray(matrix.indices))
    np.testing.assert_array_equal(np.asarray(back.data),
                                  np.asarray(matrix.data))


def test_permute_rejects_non_permutation():
    m = rmat_matrix(N, seed=2)
    bad = np.arange(N)
    bad[1] = 0                                  # duplicate index
    with pytest.raises(ValueError, match="not a permutation"):
        m.permute(row_perm=bad)
    with pytest.raises(ValueError, match="not a permutation"):
        m.permute(col_perm=np.arange(N - 1))    # wrong length


def test_inverse_perm_definition():
    r = reorder.rcm(rmat_matrix(N, seed=2))
    np.testing.assert_array_equal(r.row_perm[r.inv_row_perm], np.arange(N))
    np.testing.assert_array_equal(r.inv_col_perm[r.col_perm], np.arange(N))


@pytest.mark.parametrize("name", list(reorder.STRATEGIES))
def test_spmv_bit_identical_under_reorder(matrix, name):
    """reorder -> multiply -> inverse-scatter == plain multiply, to the bit
    (integer-valued data, so float addition order cannot matter)."""
    m = _int_valued(matrix)
    x = jnp.asarray(np.random.default_rng(3).integers(
        0, 8, size=m.n_cols).astype(np.float32))
    y_ref = np.asarray(spmv(m, x))
    r = reorder.STRATEGIES[name](m)
    y = np.asarray(spmv(r.apply(m), x, reordering=r))
    np.testing.assert_array_equal(y, y_ref)


def test_rcm_strictly_reduces_bandwidth_on_scrambled_banded():
    banded = banded_matrix(512, bandwidth=8, seed=4)
    p = np.random.default_rng(5).permutation(512)
    scrambled = reorder.Reordering(row_perm=p, col_perm=p,
                                   strategy="scramble").apply(banded)
    r = reorder.rcm(scrambled)
    bw_before = analyze(scrambled).bandwidth
    bw_after = analyze(scrambled, reordering=r).bandwidth
    assert bw_after < bw_before                 # strict reduction
    assert bw_after <= 4 * analyze(banded).bandwidth   # near-recovery
    assert r.stats["bandwidth_before"] == bw_before
    assert r.stats["bandwidth_after"] == bw_after


def test_auto_format_redecides_after_rcm():
    """Scrambled banded dispatches to CSR; with the RCM reordering the
    re-analysis makes it DIA-eligible again, and the multiply (through the
    reordered DIA) still matches the unpermuted reference."""
    banded = banded_matrix(512, bandwidth=4, nnz_per_row=5, seed=6)
    p = np.random.default_rng(7).permutation(512)
    scrambled = reorder.Reordering(row_perm=p, col_perm=p).apply(banded)
    assert not isinstance(auto_format(scrambled), DIA)
    r = reorder.rcm(scrambled)
    fmt = auto_format(scrambled, reordering=r)
    assert isinstance(fmt, DIA)
    x = jnp.asarray(np.random.default_rng(8).normal(
        size=512).astype(np.float32))
    y = np.asarray(spmv(fmt, x, reordering=r))
    y_ref = np.asarray(spmv(scrambled, x))
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_chain_composes_to_single_equivalent_permutation():
    m = rmat_matrix(N, seed=9)
    chained = reorder.chain(reorder.rcm, reorder.cache_block)(m)
    chained.validate()
    step1 = reorder.rcm(m)
    step2 = reorder.cache_block(step1.apply(m))
    two_step = step2.apply(step1.apply(m))
    one_step = chained.apply(m)
    np.testing.assert_array_equal(np.asarray(one_step.indices),
                                  np.asarray(two_step.indices))
    np.testing.assert_array_equal(np.asarray(one_step.indptr),
                                  np.asarray(two_step.indptr))
    assert chained.strategy.startswith("chain(")


def test_degree_sort_backs_partition_wrapper():
    from repro.core.partition import sort_rows_by_nnz

    m = rmat_matrix(N, permute=False, seed=10)
    sorted_csr, perm = sort_rows_by_nnz(m)
    assert (np.diff(sorted_csr.row_lengths()) <= 0).all()
    assert reorder.is_permutation(perm, N)


def test_analyze_reorder_reports_improvement():
    m = rmat_matrix(N, seed=11)
    d = analyze_reorder(m, reorder.rcm(m))
    assert d.before.nnz == d.after.nnz          # permutation moves, not drops
    assert d.improved()
    assert "rcm" in d.summary()


def test_pallas_ops_accept_reordering():
    from repro.kernels import ops as kops

    m = _int_valued(rmat_matrix(N, seed=12))
    x = jnp.asarray(np.random.default_rng(13).integers(
        0, 8, size=N).astype(np.float32))
    r = reorder.cache_block(m)
    y_ref = np.asarray(spmv(m, x))
    y = np.asarray(kops.spmv_csr(r.apply(m), x, interpret=True,
                                 reordering=r))
    np.testing.assert_array_equal(y, y_ref)


def test_sweep_reorder_dimension():
    from repro.telemetry.report import reorder_gap_report
    from repro.telemetry.sweep import SweepPoint, reorder_sweep

    pts = reorder_sweep(log2ns=(9,),
                        reorderings={"none": None, "rcm": reorder.rcm})
    assert {p.reorder for p in pts} == {"none", "rcm"}
    assert "reorder" in SweepPoint.header()
    report = reorder_gap_report(pts)
    assert "gap_closed" in report.splitlines()[1]
    assert any(line.split(",")[2] == "rcm" for line in report.splitlines()[2:])
