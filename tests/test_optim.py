"""Optimizers + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptimizerConfig, adafactor_init, adafactor_update,
                         adamw_init, adamw_update, cosine_lr, make_optimizer)
from repro.optim.grad_compress import (CompressionState, compress_grads,
                                       compress_init, decompress_grads,
                                       dequantize_int8, quantize_int8)


def _quadratic_target():
    w_star = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8))
                         .astype(np.float32))

    def loss(params):
        return jnp.sum((params["w"] - w_star) ** 2)

    return loss, w_star


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_converge_on_quadratic(name):
    loss, w_star = _quadratic_target()
    cfg = OptimizerConfig(name=name, lr=0.05, weight_decay=0.0,
                          warmup_steps=1, total_steps=400)
    init, update = make_optimizer(cfg)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = init(params)
    l0 = float(loss(params))
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = update(grads, state, params)
    assert float(loss(params)) < 0.01 * l0


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[1] == pytest.approx(1.0, rel=1e-3)         # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)        # min_lr floor
    assert all(a >= b - 1e-6 for a, b in zip(lrs[1:], lrs[2:]))


def test_adamw_moments_fp32():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st = adamw_init(params)
    assert st.mu["w"].dtype == jnp.float32


def test_adafactor_memory_is_factored():
    params = {"w": jnp.zeros((64, 32), jnp.bfloat16)}
    st = adafactor_init(params)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (32,)


def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = quantize_int8(g)
    err = jnp.abs(dequantize_int8(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_keeps_running_sum():
    """Error feedback: the cumulative transmitted signal tracks the
    cumulative true gradient (bias -> 0)."""
    rng = np.random.default_rng(2)
    grads = [{"g": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
             for _ in range(50)]
    state = compress_init(grads[0])
    sent_sum = np.zeros(64, np.float32)
    true_sum = np.zeros(64, np.float32)
    for g in grads:
        payload, scales, state = compress_grads(g, state)
        sent = decompress_grads(payload, scales)
        sent_sum += np.asarray(sent["g"])
        true_sum += np.asarray(g["g"])
    # residual is bounded => averages converge
    resid = np.abs(sent_sum - true_sum).max()
    assert resid <= float(np.abs(np.asarray(state.residual["g"])).max()) + 1e-4


def test_compression_ratio():
    g = {"g": jnp.zeros((1024,), jnp.float32)}
    payload, scales, _ = compress_grads(g, compress_init(g))
    raw = 1024 * 4
    sent = 1024 * 1 + 4
    assert sent / raw < 0.26          # ~3.9x fewer DCN bytes
