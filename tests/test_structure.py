"""Structure metrics: FD vs R-MAT must order the way the paper says."""
import numpy as np

from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix
from repro.core.structure import (analyze, reuse_distance_histogram,
                                  x_access_stream)


def test_fd_locality_beats_rmat():
    fd = analyze(fd_matrix(4096))
    rm = analyze(rmat_matrix(4096))
    assert fd.stream_servable > 0.9 > rm.stream_servable
    assert fd.temporal_locality > rm.temporal_locality
    assert fd.spatial_locality > rm.spatial_locality


def test_fd_band_groups_few_and_trackable():
    """Interior FD rows have 3 band groups; periodic wrap rows add a few
    more offsets.  What matters for the prefetcher model: the group count
    is small (trackable by a 16-stream prefetcher), unlike R-MAT."""
    rep = analyze(fd_matrix(4096))
    assert 3 <= rep.n_band_groups <= 12
    rm = analyze(rmat_matrix(4096))
    assert rep.n_distinct_offsets < rm.n_distinct_offsets


def test_sampled_analysis_close_to_full():
    csr = rmat_matrix(1 << 14)
    full = analyze(csr, sample_rows=None)
    samp = analyze(csr, sample_rows=2048)
    assert abs(full.stream_servable - samp.stream_servable) < 0.1
    assert full.kind == samp.kind


def test_reuse_distance_exact_small():
    # stream: a b a b -> distances: cold, cold, 1, 1
    lines = np.array([0, 1, 0, 1])
    d = reuse_distance_histogram(lines)
    np.testing.assert_array_equal(d, [-1, -1, 1, 1])


def test_x_access_stream_is_column_sequence():
    csr = fd_matrix(256)
    stream = x_access_stream(csr)
    np.testing.assert_array_equal(stream, np.asarray(csr.indices))


def test_bandwidth_knob_orders_stream_servability():
    vals = [analyze(banded_matrix(4096, bw)).stream_servable
            for bw in (4, 64, 2048)]
    assert vals[0] > vals[1] > vals[2]
