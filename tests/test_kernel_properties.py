"""Property-based differential kernel suite.

Every registered SpMV execution path — csr, ell, csr-seg, hyb under all
semirings, plus dia and bell under plus-times — is pinned against a
dense reference on randomized matrices drawn from the structure families
the paper measures (FD stencils, R-MAT power laws) plus the degenerate
shapes that have historically broken padded layouts: empty rows, nnz=0,
a single dense row, duplicate-structure rows.

Bit-exactness strategy: data and x are small *integer-valued* float32,
so every summation order is exact in float32 and plus-times results must
be BIT-IDENTICAL across every kernel and the dense reference — not
merely allclose.  The non-plus-times semirings (min/max reductions and
integer adds) are exact too; their comparisons only relax to allclose to
let matching ±inf identities compare equal.

Property tests are driven by `hypothesis` when installed (CI installs
requirements-dev.txt; the `kernel-properties` profile in conftest.py
sets the example budget and `--hypothesis-seed` pins the search).
Without it they skip and the named regression tests below still run.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _opt_deps import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro import plan
from repro.core.formats import CSR, ELL, HYB
from repro.core.generators import fd_matrix, rmat_matrix
from repro.graph.semiring import SEMIRINGS
from repro.kernels import ops as kops

# Formats the plan compiler can be forced to, by semiring compatibility.
PLUS_TIMES_FORMATS = ("csr", "csr-seg", "ell", "hyb", "dia", "bell")
SEMIRING_FORMATS = ("csr", "csr-seg", "ell", "hyb")
REORDERINGS = ("none", "rcm", "degree-sort")
FAMILIES = ("fd", "rmat", "empty", "empty-rows", "single-dense-row",
            "duplicate-rows")


# ---------------------------------------------------------------------------
# matrix families (structure only; values are drawn separately)
# ---------------------------------------------------------------------------

def _structure(family: str, n: int, seed: int):
    """(rows, cols, n_rows, n_cols) nonzero pattern for one family."""
    rng = np.random.default_rng(seed)
    if family == "fd":
        m = fd_matrix(max(n, 16), seed=seed)
        rows = np.repeat(np.arange(m.n_rows, dtype=np.int64),
                         np.diff(np.asarray(m.indptr)))
        return rows, np.asarray(m.indices, dtype=np.int64), m.n_rows, m.n_cols
    if family == "rmat":
        n2 = 1 << max(int(np.ceil(np.log2(max(n, 16)))), 4)  # R-MAT: pow2
        m = rmat_matrix(n2, seed=seed)
        rows = np.repeat(np.arange(m.n_rows, dtype=np.int64),
                         np.diff(np.asarray(m.indptr)))
        return rows, np.asarray(m.indices, dtype=np.int64), m.n_rows, m.n_cols
    if family == "empty":
        z = np.empty(0, dtype=np.int64)
        return z, z, n, n
    if family == "empty-rows":
        # only even rows populated: every odd row (and any unlucky even
        # one) exercises the empty-row identity path
        nnz = max(1, 2 * n)
        rows = rng.integers(0, (n + 1) // 2, nnz) * 2
        cols = rng.integers(0, n, nnz)
        return rows.astype(np.int64), cols.astype(np.int64), n, n
    if family == "single-dense-row":
        # one hub row touching every column + a sparse remainder: the
        # heavy/light split and the segment carry both trigger
        hub = int(rng.integers(0, n))
        rows = [np.full(n, hub, dtype=np.int64)]
        cols = [np.arange(n, dtype=np.int64)]
        extra = max(1, n // 2)
        rows.append(rng.integers(0, n, extra).astype(np.int64))
        cols.append(rng.integers(0, n, extra).astype(np.int64))
        return np.concatenate(rows), np.concatenate(cols), n, n
    if family == "duplicate-rows":
        # every row shares one column pattern (degree-sort ties, identical
        # per-segment row windows)
        k = int(rng.integers(1, min(n, 6) + 1))
        pattern = rng.choice(n, size=k, replace=False).astype(np.int64)
        rows = np.repeat(np.arange(n, dtype=np.int64), k)
        cols = np.tile(pattern, n)
        return rows, cols, n, n
    raise ValueError(family)


def _int_csr(family: str, n: int, seed: int, lo: int = -8, hi: int = 8
             ) -> CSR:
    """Family structure + integer-valued float32 data in [lo, hi] \\ {0}
    (zero values at column 0 are indistinguishable from padding by
    design — see `_check_ell_padding_absorbing` — so they are avoided)."""
    rows, cols, n_rows, n_cols = _structure(family, n, seed)
    rng = np.random.default_rng(seed + 1)
    vals = rng.integers(lo, hi + 1, size=rows.shape[0])
    vals[vals == 0] = 1
    return CSR.from_coo(rows, cols, vals.astype(np.float32), n_rows, n_cols)


def _int_x(n: int, seed: int, lo: int = -8, hi: int = 8) -> np.ndarray:
    return np.random.default_rng(seed + 2).integers(
        lo, hi + 1, size=n).astype(np.float32)


def _dense_ref(csr: CSR, x: np.ndarray, sr_name: str = "plus_times"
               ) -> np.ndarray:
    """Entry-by-entry dense oracle in float32 (exact on integer values)."""
    ops = {"plus_times": (np.add, np.multiply, np.float32(0.0)),
           "min_plus": (np.minimum, np.add, np.float32(np.inf)),
           "or_and": (np.maximum, np.multiply, np.float32(0.0)),
           "max_times": (np.maximum, np.multiply, np.float32(0.0))}
    add, mul, ident = ops[sr_name]
    ip = np.asarray(csr.indptr)
    idx = np.asarray(csr.indices)
    d = np.asarray(csr.data, dtype=np.float32)
    y = np.full(csr.n_rows, ident, dtype=np.float32)
    for r in range(csr.n_rows):
        for p in range(int(ip[r]), int(ip[r + 1])):
            y[r] = add(y[r], np.float32(mul(d[p], np.float32(x[idx[p]]))))
    return y


def _execute(csr: CSR, x: np.ndarray, fmt: str, reorder: str = "none",
             semiring: str = "plus_times", seg_len: int = 512) -> np.ndarray:
    p = plan.compile(csr, format=fmt, reorder=reorder, predictor="none",
                     semiring=semiring, seg_len=seg_len)
    return np.asarray(p.execute(jnp.asarray(x), interpret=True))


# ---------------------------------------------------------------------------
# the differential properties
# ---------------------------------------------------------------------------

@given(family=st.sampled_from(FAMILIES), n=st.integers(4, 32),
       seed=st.integers(0, 2 ** 16), reorder=st.sampled_from(REORDERINGS))
def test_plus_times_bit_exact_across_all_formats(family, n, seed, reorder):
    """Every format's plan — reordered or not — returns the bit-identical
    float32 vector the dense reference computes on integer operands."""
    csr = _int_csr(family, n, seed)
    x = _int_x(csr.n_cols, seed)
    ref = _dense_ref(csr, x)
    for fmt in PLUS_TIMES_FORMATS:
        y = _execute(csr, x, fmt, reorder=reorder)
        assert y.dtype == ref.dtype and y.shape == ref.shape
        assert np.array_equal(y, ref), \
            f"{fmt}/{reorder} diverged on {family}(n={n}, seed={seed})"


@given(family=st.sampled_from(FAMILIES), n=st.integers(4, 32),
       seed=st.integers(0, 2 ** 16),
       sr_name=st.sampled_from(("min_plus", "or_and", "max_times")))
def test_semirings_match_dense_on_every_format(family, n, seed, sr_name):
    """min_plus / or_and / max_times agree with the dense oracle on every
    absorbing-pad format (allclose so paired ±inf identities compare)."""
    if sr_name == "or_and":         # boolean embedding: {0,1} indicators
        csr = _int_csr(family, n, seed, lo=1, hi=1)
        x = _int_x(csr.n_cols, seed, lo=0, hi=1)
    elif sr_name == "max_times":    # only a semiring over nonnegatives
        csr = _int_csr(family, n, seed, lo=1, hi=8)
        x = _int_x(csr.n_cols, seed, lo=0, hi=8)
    else:
        csr = _int_csr(family, n, seed)
        x = _int_x(csr.n_cols, seed)
    ref = _dense_ref(csr, x, sr_name)
    for fmt in SEMIRING_FORMATS:
        y = _execute(csr, x, fmt, semiring=sr_name)
        np.testing.assert_allclose(
            y, ref, rtol=1e-6, atol=0,
            err_msg=f"{fmt}/{sr_name} on {family}(n={n}, seed={seed})")


@given(n=st.integers(8, 48), seed=st.integers(0, 2 ** 16),
       seg_len=st.sampled_from((8, 16, 64)))
def test_segment_boundary_carry_is_exact(n, seed, seg_len):
    """A dense hub row split across many short segments must reassemble
    exactly through the carry-out merge (the seg kernel's one hard
    invariant)."""
    csr = _int_csr("single-dense-row", n, seed)
    x = _int_x(csr.n_cols, seed)
    ref = _dense_ref(csr, x)
    y = np.asarray(kops.spmv_csr_seg(csr, jnp.asarray(x), seg_len=seg_len,
                                     interpret=True))
    assert np.array_equal(y, ref)
    y_hyb = _execute(csr, x, "hyb", seg_len=seg_len)
    assert np.array_equal(y_hyb, ref)


def _random_delta(csr: CSR, n_ins: int, n_del: int, seed: int,
                  lo: int = 1, hi: int = 8):
    """Insert/delete batch against `csr`: inserts at absent coordinates
    with integer-valued f32 weights, deletes at present ones."""
    from repro.core.delta import EdgeDelta

    rng = np.random.default_rng(seed + 3)
    ip = np.asarray(csr.indptr)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(ip))
    cols = np.asarray(csr.indices, dtype=np.int64)
    present = set(zip(rows.tolist(), cols.tolist()))
    inserts, seen = [], set()
    tries = 0
    while len(inserts) < n_ins and tries < 200:
        tries += 1
        r = int(rng.integers(csr.n_rows)) if csr.n_rows else 0
        c = int(rng.integers(csr.n_cols)) if csr.n_cols else 0
        if csr.n_rows and (r, c) not in present and (r, c) not in seen:
            inserts.append((r, c, float(rng.integers(lo, hi + 1))))
            seen.add((r, c))
    deletes = []
    if n_del and rows.size:
        picks = rng.choice(rows.size, size=min(n_del, rows.size),
                           replace=False)
        deletes = [(int(rows[p]), int(cols[p])) for p in picks]
    return EdgeDelta.from_updates(csr, inserts=inserts, deletes=deletes)


@given(family=st.sampled_from(("fd", "rmat")), n=st.integers(8, 32),
       seed=st.integers(0, 2 ** 16),
       sr_name=st.sampled_from(("plus_times", "min_plus", "or_and",
                                "max_times")),
       reorder=st.sampled_from(("none", "rcm")),
       n_ins=st.integers(0, 6), n_del=st.integers(0, 4))
def test_overlaid_plan_matches_recompiled_materialization(
        family, n, seed, sr_name, reorder, n_ins, n_del):
    """An overlaid plan answers exactly like a fresh compile of the
    materialized matrix: bit-identical under plus_times (deletes ride as
    exact negations), allclose under the ⊕-only semirings (insert-only
    — their deletes are overlay-ineligible and must be refused)."""
    from repro.core.delta import EdgeDelta
    from repro.plan import overlay

    if sr_name == "or_and":
        csr = _int_csr(family, n, seed, lo=1, hi=1)
        x = _int_x(csr.n_cols, seed, lo=0, hi=1)
        lo = hi = 1
    elif sr_name == "max_times":
        csr = _int_csr(family, n, seed, lo=1, hi=8)
        x = _int_x(csr.n_cols, seed, lo=0, hi=8)
        lo, hi = 1, 8
    else:
        csr = _int_csr(family, n, seed)
        x = _int_x(csr.n_cols, seed)
        lo, hi = 1, 8
    if sr_name != "plus_times":
        n_del = 0                      # ⊕-only: deletes are ineligible
    delta = _random_delta(csr, n_ins, n_del, seed, lo=lo, hi=hi)

    base = plan.compile(csr, format="csr", reorder=reorder,
                        predictor="none", semiring=sr_name)
    ov = overlay(base, delta, staleness_budget=1.0)
    got = np.asarray(ov.execute(jnp.asarray(x), interpret=True))

    fresh = plan.compile(csr.apply_delta(delta), format="csr",
                         reorder=reorder, predictor="none",
                         semiring=sr_name)
    ref = np.asarray(fresh.execute(jnp.asarray(x), interpret=True))
    if delta.nnz == 0:
        assert ov.fingerprint != base.fingerprint or delta.nnz == 0
        assert np.array_equal(
            got, np.asarray(base.execute(jnp.asarray(x), interpret=True)))
    if sr_name == "plus_times":
        assert np.array_equal(got, ref), \
            f"overlay diverged: {family}(n={n}, seed={seed}) " \
            f"+{delta.n_inserts}/-{delta.n_deletes} {reorder}"
    else:
        np.testing.assert_allclose(got, ref, rtol=1e-6, atol=0)

    # ⊕-only deletes cannot be overlaid: the algebra has no inverse
    if sr_name != "plus_times" and rows_nonempty(csr):
        ip = np.asarray(csr.indptr)
        rr = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(ip))
        cc = np.asarray(csr.indices, dtype=np.int64)
        bad = EdgeDelta.from_updates(
            csr, deletes=[(int(rr[0]), int(cc[0]))])
        from repro.plan.overlay import overlay_eligible
        assert not overlay_eligible(bad, sr_name)


def rows_nonempty(csr: CSR) -> bool:
    return csr.nnz > 0


@given(n=st.integers(4, 48), seed=st.integers(0, 2 ** 16))
def test_permutation_round_trip_identity(n, seed):
    """permute_x then restore_y through any strategy is the identity on
    the multiply: a reordered plan's output is bit-identical to the
    unreordered plan of the same format."""
    csr = _int_csr("rmat", n, seed)
    x = _int_x(csr.n_cols, seed)
    base = _execute(csr, x, "csr", reorder="none")
    for reorder in ("rcm", "degree-sort"):
        assert np.array_equal(_execute(csr, x, "csr", reorder=reorder), base)


# ---------------------------------------------------------------------------
# named regressions (runnable without hypothesis)
# ---------------------------------------------------------------------------

def _empty_csr(n: int = 8) -> CSR:
    z = np.empty(0, dtype=np.int64)
    return CSR.from_coo(z, z, np.empty(0, dtype=np.float32), n, n)


@pytest.mark.parametrize("fmt", PLUS_TIMES_FORMATS)
def test_nnz0_every_forced_format(fmt):
    csr = _empty_csr(8)
    x = _int_x(8, seed=0)
    y = _execute(csr, x, fmt)
    assert np.array_equal(y, np.zeros(8, np.float32))


@pytest.mark.parametrize("sr_name", ["min_plus", "or_and", "max_times"])
@pytest.mark.parametrize("fmt", SEMIRING_FORMATS)
def test_nnz0_semiring_identity(fmt, sr_name):
    """An all-empty matrix reduces every row to the ⊕-identity."""
    csr = _empty_csr(8)
    x = _int_x(8, seed=0, lo=0, hi=1)
    y = _execute(csr, x, fmt, semiring=sr_name)
    ident = SEMIRINGS[sr_name].identity
    assert np.array_equal(y, np.full(8, ident, np.float32))


def test_zero_row_ell_layout():
    """n_rows=0: `prepare_ell` must not produce a zero-length Pallas grid
    (regression: round_up(0, bm) == 0)."""
    csr = CSR(data=jnp.zeros((0,), jnp.float32),
              indices=jnp.zeros((0,), jnp.int32),
              indptr=jnp.zeros((1,), jnp.int32), n_rows=0, n_cols=4)
    ell = ELL.from_csr(csr)
    y = kops.spmv_ell(ell, jnp.ones((4,), jnp.float32), interpret=True)
    assert y.shape == (0,)


def test_out_of_range_sources_rejected():
    from repro.graph.drivers import bfs, sssp

    csr = _int_csr("rmat", 16, seed=0, lo=1, hi=4)
    for bad in (-1, csr.n_rows, csr.n_rows + 7):
        with pytest.raises(ValueError, match="out of range"):
            bfs(csr, bad)
        with pytest.raises(ValueError, match="out of range"):
            sssp(csr, bad)


@pytest.mark.parametrize("container", ["ell", "hyb"])
def test_non_absorbing_padding_refused(container):
    """An ELL/HYB slab padded with (0.0, col 0) must be refused under a
    semiring whose absorbing element is not 0.0 — those slots would read
    as real weight-0 edges to vertex 0."""
    csr = _int_csr("empty-rows", 16, seed=3)
    x = jnp.asarray(_int_x(csr.n_cols, seed=3))
    sr = SEMIRINGS["min_plus"]
    if container == "ell":
        bad = ELL.from_csr(csr, fill=0.0)
        with pytest.raises(ValueError, match="absorbing"):
            kops.spmv_ell(bad, x, interpret=True, semiring=sr)
        good = ELL.from_csr(csr, fill=sr.pad_value)
        kops.spmv_ell(good, x, interpret=True, semiring=sr)
    else:
        bad = HYB.from_csr(csr, fill=0.0)
        with pytest.raises(ValueError, match="absorbing"):
            kops.spmv_hyb(bad, x, interpret=True, semiring=sr)
        good = HYB.from_csr(csr, fill=sr.pad_value)
        kops.spmv_hyb(good, x, interpret=True, semiring=sr)


def test_hyb_routes_hub_rows_to_heavy():
    """The dense hub row lands whole in the heavy partition and is
    all-padding in the light slab; light width stays <= threshold."""
    csr = _int_csr("single-dense-row", 32, seed=1)
    hyb = HYB.from_csr(csr)
    lengths = np.diff(np.asarray(csr.indptr))
    hub = int(np.argmax(lengths))
    assert hub in hyb.heavy_row_ids()
    assert hyb.light_width <= hyb.threshold
    assert np.all(np.asarray(hyb.data)[hub] == 0.0)     # all-padding row
    # heavy stream is column-sorted: the hub gathers stream x in order
    assert np.all(np.diff(np.asarray(hyb.hcols)) >= 0)


def test_repeated_compiles_produce_identical_plans():
    """Candidate enumeration is sorted by (format, reordering), so two
    compiles of the same matrix — and the same compile under a different
    dict insertion order — pick the same plan, bit for bit."""
    csr = rmat_matrix(256, seed=2)
    x = jnp.asarray(_int_x(csr.n_cols, seed=0))
    plans = [plan.compile(csr, reorder="auto", predictor="analytic",
                          threads=4) for _ in range(3)]
    first = plans[0]
    for p in plans[1:]:
        assert p.format_name == first.format_name
        assert p.chosen == first.chosen
        assert list(p.predicted) == list(first.predicted)
        assert np.array_equal(np.asarray(p.execute(x, interpret=True)),
                              np.asarray(first.execute(x, interpret=True)))


def test_nnz_trace_slices_tile_the_full_trace():
    """Merge-partition trace slices must tile the global trace exactly —
    including the headers of *leading* empty rows, which sit before the
    first cut's containing row and belong to thread 0 (regression: they
    were dropped from every slice)."""
    from repro.core.cache_model import SANDY_BRIDGE
    from repro.core.partition import nnz_split
    from repro.parallel import nnz_partitioned_traces
    from repro.telemetry.hierarchy import spmv_address_trace

    rows = np.array([5, 5, 6, 6, 7], dtype=np.int64)   # rows 0-4 empty
    cols = np.array([1, 3, 0, 2, 5], dtype=np.int64)
    vals = np.ones(5, dtype=np.float32)
    csr = CSR.from_coo(rows, cols, vals, 8, 8)
    trace = spmv_address_trace(csr, SANDY_BRIDGE)
    for parts in (1, 2, 3, 5):
        slices = nnz_partitioned_traces(csr, nnz_split(csr, parts),
                                        SANDY_BRIDGE)
        assert np.array_equal(np.concatenate(slices), trace)
