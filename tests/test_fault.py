"""Fault tolerance: heartbeat, stragglers, elastic rescale, supervisor."""
import pytest

from repro.distributed.fault import (HeartbeatMonitor, StragglerDetector,
                                     Supervisor, plan_elastic_rescale)


def test_heartbeat_detects_dead_worker():
    hb = HeartbeatMonitor(n_workers=3, timeout_s=10.0)
    hb.beat(0, 1, now=100.0)
    hb.beat(1, 1, now=100.0)
    hb.beat(2, 1, now=100.0)
    hb.beat(0, 2, now=120.0)
    hb.beat(1, 2, now=120.0)
    assert hb.dead_workers(now=120.5) == [2]
    assert not hb.healthy(now=120.5)


def test_heartbeat_never_seen_is_not_dead():
    hb = HeartbeatMonitor(n_workers=2, timeout_s=1.0)
    assert hb.healthy(now=1000.0)     # bootstrap grace


def test_straggler_detection():
    sd = StragglerDetector(k=2.0, window=8)
    for step in range(8):
        for w in range(4):
            sd.record(w, 1.0 if w != 3 else 5.0)
    assert sd.stragglers() == [3]
    assert "rebalance" in sd.mitigation(3) or "row-block" in sd.mitigation(3)


def test_rescale_plan_shrinks_data_axis():
    plan = plan_elastic_rescale({"pod": 2, "data": 16, "model": 16},
                                n_devices_now=384)   # lost 128 chips
    assert plan.new_mesh[0] == 2 and plan.new_mesh[2] == 16
    assert plan.new_mesh[1] == 8                     # next pow2 below 12
    assert plan.data_resize == 0.5


def test_rescale_plan_single_pod():
    plan = plan_elastic_rescale({"data": 16, "model": 16},
                                n_devices_now=128)
    assert plan.new_mesh == (8, 16)


def test_supervisor_restarts_and_succeeds():
    calls = {"makes": 0, "fails": 0}

    def make_state():
        calls["makes"] += 1
        # pretend checkpoint: resumes from the last multiple of 5
        return {"step": (calls["makes"] - 1) * 0}

    def step_fn(state, step):
        if step == 3 and calls["fails"] < 2:
            calls["fails"] += 1
            raise RuntimeError("boom")
        return {"step": step + 1}

    sup = Supervisor(max_restarts=3)
    state = sup.run(make_state, step_fn, n_steps=6)
    assert state["step"] == 6
    assert sup.restarts == 2


def test_supervisor_gives_up_after_max_restarts():
    def make_state():
        return {"step": 0}

    def step_fn(state, step):
        raise RuntimeError("always")

    sup = Supervisor(max_restarts=2)
    with pytest.raises(RuntimeError, match="exceeded"):
        sup.run(make_state, step_fn, n_steps=3)
