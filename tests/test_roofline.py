"""HLO cost analyzer: golden parsing, trip-count folding, dot flops."""
import textwrap

import pytest

from repro.roofline import analysis, hlo_costs

GOLDEN = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%y), replica_groups={}, to_apply=%add
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
    }

    %cond (p2: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]) parameter(0)
      %i3 = s32[] get-tuple-element(%p2), index=0
      %lim = s32[] constant(10)
      ROOT %lt = pred[] compare(%i3, %lim), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %a)
      %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      %big = f32[32,64]{1,0} constant({...})
      %v = f32[64,8]{1,0} constant({...})
      %final = f32[32,8]{1,0} dot(%big, %v), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
    }
""")


def test_shape_parsing():
    assert hlo_costs.shape_elems_bytes("f32[8,16]{1,0}") == (128, 512)
    assert hlo_costs.shape_elems_bytes("bf16[4]") == (4, 8)
    assert hlo_costs.shape_elems_bytes("(s32[], f32[2,2]{1,0})") == (5, 20)
    assert hlo_costs.shape_elems_bytes("pred[]") == (1, 1)


def test_module_parse_finds_computations():
    comps = hlo_costs.parse_module(GOLDEN)
    assert set(comps) == {"body", "cond", "main"}
    assert any(op.opcode == "while" for op in comps["main"].ops)


def test_trip_count_folding():
    mc = hlo_costs.module_costs(GOLDEN)
    # loop dot: 2*8*16*16 = 4096 flops, x10 trips = 40960
    # final dot: 2*32*8*64 = 32768
    dot_flops = 10 * 4096 + 32768
    # elementwise adds in body: 1 flop x10; compare in cond: 1 x11
    assert mc.flops == pytest.approx(dot_flops, rel=0.01)


def test_collective_inside_loop_multiplied():
    mc = hlo_costs.module_costs(GOLDEN)
    # all-reduce of f32[8,16] = 512B operand, wire 2x, x10 trips
    assert mc.collective_bytes["all-reduce"] == pytest.approx(
        2 * 512 * 10)
    assert mc.collective_counts["all-reduce"] == 10


def test_analysis_bottleneck_selection():
    rl = analysis.analyze({}, GOLDEN, n_chips=4, model_flops=1e6)
    assert rl.bottleneck in ("compute", "memory", "collective")
    assert rl.flops > 0 and rl.hbm_bytes > 0
    # with these tiny sizes, memory dominates compute
    assert rl.memory_s > rl.compute_s


def test_unknown_trip_count_flagged():
    hlo = GOLDEN.replace(', backend_config={"known_trip_count":{"n":"10"}}',
                         "")
    mc = hlo_costs.module_costs(hlo)
    assert mc.unknown_trip_counts == 1
    # body counted once without the multiplier
    assert mc.collective_counts["all-reduce"] == 1


def test_model_flops_helpers():
    assert analysis.model_flops_train(1e9, 1e6) == 6e15
    assert analysis.model_flops_decode(1e9, 128) == pytest.approx(2.56e11)
