"""Paged-attention decode kernel vs oracle: shape/dtype sweeps + pool
round-trip with the serve-layer allocator."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.serve import BlockAllocator, PoolConfig


def _setup(bsz=3, h=4, hd=32, n_blocks=16, block=8, max_blocks=4, seed=0,
           dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(bsz, h, hd)).astype(dtype))
    kp = jnp.asarray(rng.normal(size=(n_blocks, block, h, hd)).astype(dtype))
    vp = jnp.asarray(rng.normal(size=(n_blocks, block, h, hd)).astype(dtype))
    # distinct physical blocks per sequence
    perm = rng.permutation(n_blocks)[: bsz * max_blocks]
    tables = jnp.asarray(perm.reshape(bsz, max_blocks).astype(np.int32))
    lengths = jnp.asarray(rng.integers(1, max_blocks * block + 1, bsz)
                          .astype(np.int32))
    return q, kp, vp, tables, lengths


@pytest.mark.parametrize("bsz,h,hd,block", [
    (2, 4, 32, 8), (3, 8, 64, 16), (1, 2, 128, 8),
])
def test_paged_attention_sweep(bsz, h, hd, block):
    q, kp, vp, tables, lengths = _setup(bsz=bsz, h=h, hd=hd, block=block)
    got = ops.paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_gqa_broadcast():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(8, 8, 2, 32)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(8, 8, 2, 32)).astype(np.float32))
    tables = jnp.asarray(np.array([[0, 1], [2, 3]], np.int32))
    lengths = jnp.asarray(np.array([12, 9], np.int32))
    got = ops.paged_attention(q, kp, vp, tables, lengths)
    kpb = jnp.repeat(kp, 4, axis=2)
    vpb = jnp.repeat(vp, 4, axis=2)
    want = ref.paged_attention_ref(q, kpb, vpb, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_bf16():
    q, kp, vp, tables, lengths = _setup(seed=2)
    got = ops.paged_attention(q.astype(jnp.bfloat16),
                              kp.astype(jnp.bfloat16),
                              vp.astype(jnp.bfloat16), tables, lengths)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=5e-2, atol=5e-2)


def test_paged_attention_respects_lengths():
    """Changing pool content beyond a sequence's length must not change
    its output (the kernel never reads unowned/overflow positions)."""
    q, kp, vp, tables, lengths = _setup(seed=3)
    lengths = jnp.asarray(np.array([5, 9, 17], np.int32))
    out1 = ops.paged_attention(q, kp, vp, tables, lengths)
    # poison everything past each sequence's length within its blocks
    kp2 = np.asarray(kp).copy()
    block = kp2.shape[1]
    tb = np.asarray(tables)
    for b in range(3):
        ln = int(lengths[b])
        for j, blk in enumerate(tb[b]):
            lo = max(ln - j * block, 0)
            kp2[blk, lo:] = 1e3
    out2 = ops.paged_attention(q, jnp.asarray(kp2), vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_with_allocator_tables():
    """End-to-end with the serve-layer allocator's tables."""
    alloc = BlockAllocator(PoolConfig(n_blocks=16, block_size=8,
                                      max_blocks_per_seq=4))
    alloc.admit(0, 20)
    alloc.admit(1, 7)
    tables = jnp.asarray(np.stack([alloc.table_array(0),
                                   alloc.table_array(1)]))
    lengths = jnp.asarray(np.array([20, 7], np.int32))
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(2, 4, 32)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(16, 8, 4, 32)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(16, 8, 4, 32)).astype(np.float32))
    got = ops.paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
