"""Matrix generators: the paper's FD and R-MAT families."""
import numpy as np

from repro.core.generators import (banded_matrix, fd_matrix, paper_sizes,
                                   rmat_matrix, uniform_random_matrix)
from repro.core.structure import analyze


def test_fd_has_exactly_nine_nnz_per_row():
    csr = fd_matrix(1024)
    lengths = csr.row_lengths()
    assert (lengths == 9).all()
    assert csr.nnz == 9 * 1024        # paper footnote 1: nnz = 9 * 2^k


def test_fd_three_bands_of_three():
    """Rows away from the wrap boundary see three groups of three adjacent
    columns (paper Fig. 2)."""
    csr = fd_matrix(1024)   # 32 x 32 grid
    indptr = np.asarray(csr.indptr)
    cols = np.sort(np.asarray(csr.indices)[indptr[66]: indptr[67]])
    gaps = np.diff(cols)
    # two large gaps split the 9 columns into 3 bands of 3 adjacent cols
    assert (gaps > 1).sum() == 2
    assert (gaps == 1).sum() == 6


def test_rmat_avg_nnz_close_to_target():
    csr = rmat_matrix(4096, nnz_per_row=8)
    avg = csr.nnz / csr.n_rows
    assert 5.0 < avg <= 8.0   # dedup removes duplicate edges


def test_rmat_power_law_column_degrees():
    """Unpermuted R-MAT columns must be heavy-tailed: the top 1% of columns
    get far more than 1% of nonzeros."""
    csr = rmat_matrix(4096, permute=False)
    deg = np.bincount(np.asarray(csr.indices), minlength=4096)
    deg = np.sort(deg)[::-1]
    top1pct = deg[: 41].sum() / max(deg.sum(), 1)
    assert top1pct > 0.05


def test_rmat_permutation_preserves_degree_multiset():
    a = rmat_matrix(1024, permute=False, seed=7)
    b = rmat_matrix(1024, permute=True, seed=7)
    da = np.sort(np.bincount(np.asarray(a.indices), minlength=1024))
    db = np.sort(np.bincount(np.asarray(b.indices), minlength=1024))
    np.testing.assert_array_equal(da, db)
    assert a.nnz == b.nnz


def test_rmat_permutation_balances_rows():
    """The paper permutes to equalize thread load: with fine-grained blocks
    the unpermuted power-law clustering shows up as imbalance that the
    permutation removes."""
    from repro.core.partition import rowblock_equal
    unperm = rmat_matrix(4096, permute=False, seed=5)
    perm = rmat_matrix(4096, permute=True, seed=5)
    imb_u = rowblock_equal(unperm, 64).imbalance()
    imb_p = rowblock_equal(perm, 64).imbalance()
    assert imb_p < imb_u / 2        # permutation removes the clustering
    assert imb_p < 3.0              # hub ROWS remain (power law)
    # rowblock_balanced tightens further, down to the single-hub-row floor
    from repro.core.partition import rowblock_balanced
    bal = rowblock_balanced(perm, 64)
    assert bal.imbalance() <= imb_p
    floor = 1.0 + perm.row_lengths().max() / bal.nnz_per_part.mean()
    assert bal.imbalance() <= floor + 0.05


def test_banded_matrix_bandwidth_respected():
    csr = banded_matrix(512, bandwidth=16)
    rows = np.repeat(np.arange(512), csr.row_lengths())
    assert np.abs(np.asarray(csr.indices) - rows).max() <= 16


def test_structure_kinds_detected():
    assert analyze(fd_matrix(1024)).kind == "banded"
    assert analyze(rmat_matrix(1024)).kind in ("unstructured", "blocked")
    assert analyze(uniform_random_matrix(1024)).kind in (
        "unstructured", "blocked")


def test_paper_sizes_range():
    sizes = paper_sizes()
    assert sizes[0] == 2 ** 11 and sizes[-1] == 2 ** 26
    assert len(sizes) == 16
