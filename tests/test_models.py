"""Per-arch smoke tests (all ten assigned architectures, reduced configs)
+ the decode-vs-forward equivalence test that validates the cache path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, CONFIGS, applicable_shapes
from repro.models import registry, transformer
from repro.models.registry import get_model, random_train_batch

ALL_ARCHS = sorted(CONFIGS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_loss(arch):
    """REDUCED config: one loss evaluation, finite, correct shapes."""
    cfg = CONFIGS[arch].reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = random_train_batch(cfg, 2, 32)
    loss = api.loss_fn(params, batch, remat="none")
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ["granite-8b", "kimi-k2-1t-a32b",
                                  "rwkv6-3b", "jamba-v0.1-52b"])
def test_smoke_train_step_no_nans(arch):
    cfg = CONFIGS[arch].reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = random_train_batch(cfg, 2, 16)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, remat="none"))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("arch", ["granite-8b", "stablelm-1.6b",
                                  "rwkv6-3b", "jamba-v0.1-52b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode through the cache must reproduce the full
    forward pass logits -- the strongest correctness check on the KV /
    state cache machinery."""
    cfg = CONFIGS[arch].reduced()
    if cfg.moe is not None:
        # capacity drops depend on how many tokens share a dispatch call --
        # a real semantic difference between prefill and decode, not a
        # cache bug; give headroom so no token drops either way.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(np.random.default_rng(2)
                       .integers(0, cfg.vocab, (2, 12)), dtype=jnp.int32)

    # full forward logits
    x, _, _ = transformer.forward(params, cfg, tokens=toks, remat="none")
    full_logits = x @ transformer.head_matrix(params, cfg)

    # prefill on the first 6, decode the next 6 one at a time
    logits_p, cache = api.prefill(params, {"tokens": toks[:, :6]}, 16)
    got = [logits_p[:, -1]]
    for t in range(6, 12):
        step_logits, cache = api.decode_step(params, cache, toks[:, t:t + 1])
        got.append(step_logits[:, 0])
    got = jnp.stack(got, axis=1)          # (2, 7, V): positions 5..11
    want = full_logits[:, 5:12]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.08, atol=0.08)


def test_whisper_decode_matches_forward():
    cfg = CONFIGS["whisper-large-v3"].reduced()
    from repro.models import whisper
    params = whisper.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    frames = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model))
                         .astype(np.float32)).astype(jnp.bfloat16)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), dtype=jnp.int32)

    enc = whisper.encode(params, cfg, frames, remat="none")
    x, _ = whisper.decode(params, cfg, toks, enc, remat="none")
    want = (x @ params["tok_embed"].T)[:, 3:8]

    logits_p, cache = whisper.prefill(
        params, cfg, {"frames": frames, "tokens": toks[:, :4]}, 16)
    got = [logits_p[:, -1]]
    for t in range(4, 8):
        sl, cache = whisper.decode_step(params, cfg, cache, toks[:, t:t + 1])
        got.append(sl[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.08, atol=0.08)


def test_per_slot_positions_mixed_depth():
    """Two slots at different cache depths must each attend to their own
    prefix only (the serving correctness property)."""
    cfg = CONFIGS["stablelm-1.6b"].reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(0, cfg.vocab, (1, 9)), dtype=jnp.int32)
    b = jnp.asarray(rng.integers(0, cfg.vocab, (1, 5)), dtype=jnp.int32)

    # batched mixed-depth: prefill a[0:8] in slot0, b[0:4] in slot1
    cache = transformer.init_cache(cfg, 2, 16)
    sub_a = transformer.slice_cache(cache, 0)
    _, ca, _ = transformer.forward(params, cfg, tokens=a[:, :8],
                                   cache=sub_a, remat="none")
    cache = transformer.merge_cache(cache, ca, 0)
    sub_b = transformer.slice_cache(cache, 1)
    _, cb, _ = transformer.forward(params, cfg, tokens=b[:, :4],
                                   cache=sub_b, remat="none")
    cache = transformer.merge_cache(cache, cb, 1)
    toks = jnp.concatenate([a[:, 8:9], b[:, 4:5]], axis=0)
    logits, _ = api.decode_step(params, cache, toks)

    # reference: each sequence decoded alone
    _, cache_a = api.prefill(params, {"tokens": a[:, :8]}, 16)
    ref_a, _ = api.decode_step(params, cache_a, a[:, 8:9])
    _, cache_b = api.prefill(params, {"tokens": b[:, :4]}, 16)
    ref_b, _ = api.decode_step(params, cache_b, b[:, 4:5])

    np.testing.assert_allclose(np.asarray(logits[0], np.float32),
                               np.asarray(ref_a[0], np.float32),
                               rtol=0.08, atol=0.08)
    np.testing.assert_allclose(np.asarray(logits[1], np.float32),
                               np.asarray(ref_b[0], np.float32),
                               rtol=0.08, atol=0.08)


def test_configs_match_assignment_table():
    """Spot-check the published numbers the assignment pins."""
    c = CONFIGS["kimi-k2-1t-a32b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == \
        (61, 7168, 64, 8)
    assert c.vocab == 163840 and c.moe.n_experts == 384 and c.moe.top_k == 8
    c = CONFIGS["qwen2-72b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == \
        (80, 8192, 29568, 152064)
    assert c.qkv_bias
    c = CONFIGS["whisper-large-v3"]
    assert c.is_encdec and c.n_encoder_layers == 32 and c.vocab == 51866
    c = CONFIGS["jamba-v0.1-52b"]
    assert c.block_pattern.count("attn") == 1 and len(c.block_pattern) == 8
    assert c.moe.n_experts == 16 and c.moe.top_k == 2
    c = CONFIGS["rwkv6-3b"]
    assert c.block_pattern == ("rwkv",) and c.subquadratic


def test_applicable_shapes_long500k_rule():
    """long_500k only for sub-quadratic archs (SSM/hybrid)."""
    subq = {a for a in ALL_ARCHS
            if "long_500k" in applicable_shapes(CONFIGS[a])}
    assert subq == {"rwkv6-3b", "jamba-v0.1-52b"}


def test_param_counts_in_expected_range():
    """Sanity on the config-derived parameter counts (order of magnitude)."""
    assert 0.9e12 < CONFIGS["kimi-k2-1t-a32b"].param_count() < 1.4e12
    assert 25e9 < CONFIGS["kimi-k2-1t-a32b"].active_param_count() < 45e9
    assert 60e9 < CONFIGS["qwen2-72b"].param_count() < 85e9
    assert 1.2e9 < CONFIGS["stablelm-1.6b"].param_count() < 2.2e9
    assert 350e9 < CONFIGS["arctic-480b"].param_count() < 560e9


def test_input_specs_cover_all_cells():
    """input_specs must build for every (arch x applicable shape)."""
    from repro.configs import SHAPES
    for arch in ALL_ARCHS:
        cfg = CONFIGS[arch]
        for shape_name in applicable_shapes(cfg):
            specs = registry.input_specs(cfg, SHAPES[shape_name])
            assert specs, (arch, shape_name)
