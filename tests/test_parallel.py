"""Multithreaded scaling engine: determinism, 1-thread parity with the
single-stream simulator, shared-LLC contention, the paper's speedup
separation, and the sharded execution path."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cache_model import SANDY_BRIDGE, simulate_exact
from repro.core.generators import fd_matrix, rmat_matrix
from repro.core.partition import rowblock_balanced, rowblock_equal
from repro.parallel import (ParallelSpec, parallel_metrics,
                            partitioned_traces, replay_parallel,
                            simulate_parallel)
from repro.telemetry import events as ev
from repro.telemetry.hierarchy import spmv_address_trace
from repro.telemetry.report import scaling_gap_report, scaling_report
from repro.telemetry.sweep import scaling_sweep

# Working-set-scaled geometry (x ~ half the LLC at 2^11-2^12): the same
# methodology as telemetry_bench's mechanism table.
SCALED = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)


# ---------------------------------------------------------------------------
# Traces and partitions
# ---------------------------------------------------------------------------

def test_partitioned_traces_concatenate_to_single_stream():
    csr = rmat_matrix(2 ** 10, seed=3)
    part = rowblock_equal(csr, 5)
    traces = partitioned_traces(csr, part, SANDY_BRIDGE)
    assert len(traces) == 5
    np.testing.assert_array_equal(
        np.concatenate(traces), spmv_address_trace(csr, SANDY_BRIDGE))


def test_rowblock_equal_no_empty_parts_when_parts_exceed_rows():
    csr = rmat_matrix(16, seed=0)
    part = rowblock_equal(csr, 64)          # more parts than rows
    assert part.n_parts == 16               # capped at one row per part
    assert (np.diff(part.starts) == 1).all()
    # the old linspace split produced empty parts via float truncation
    for parts in (3, 7, 11, 16):
        p = rowblock_equal(csr, parts)
        assert (np.diff(p.starts) > 0).all(), parts
        assert p.starts[0] == 0 and p.starts[-1] == csr.n_rows


# ---------------------------------------------------------------------------
# Replay semantics
# ---------------------------------------------------------------------------

def test_replay_deterministic_bit_identical():
    csr = rmat_matrix(2 ** 10, seed=7)
    part = rowblock_equal(csr, 4)
    traces = partitioned_traces(csr, part, SANDY_BRIDGE)
    a = replay_parallel(traces, SANDY_BRIDGE, SCALED, sweeps=2)
    b = replay_parallel(traces, SANDY_BRIDGE, SCALED, sweeps=2)
    for ca, cb in zip(a.counters, b.counters):
        assert ca.as_dict() == cb.as_dict()


def test_one_thread_matches_simulate_exact():
    """Machine geometry, one thread: the parallel engine must reproduce
    the single-stream `cache_model.simulate_exact` counters exactly."""
    for gen, seed in ((fd_matrix, 0), (rmat_matrix, 1)):
        csr = gen(2 ** 11, seed=seed)
        part = rowblock_equal(csr, 1)
        run, _ = simulate_parallel(csr, part, SANDY_BRIDGE, ParallelSpec(),
                                   sweeps=2)
        c = run.counters[0]
        got = {"l2_demand": c[ev.L2_DEMAND_MISS],
               "l3_demand": c[ev.L3_DEMAND_MISS],
               "pf_fills": c[ev.L2_PREFETCH_FILL],
               "accesses": c[ev.ACCESS]}
        assert got == simulate_exact(csr, sweeps=2)


def test_access_conservation_across_threads():
    csr = rmat_matrix(2 ** 10, seed=2)
    part = rowblock_equal(csr, 8)
    run, _ = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED, sweeps=1)
    total = sum(c[ev.ACCESS] for c in run.counters)
    assert total == 2 * csr.n_rows + 3 * csr.nnz
    for c in run.counters:
        assert c[ev.ACCESS] == c[ev.L2_DEMAND_HIT] + c[ev.L2_DEMAND_MISS]


def test_private_l1_level_counts_events():
    csr = fd_matrix(2 ** 10)
    part = rowblock_equal(csr, 2)
    spec = ParallelSpec(l1_bytes=4 * 1024, l2_bytes=16 * 1024,
                        llc_bytes=64 * 1024)
    run, _ = simulate_parallel(csr, part, SANDY_BRIDGE, spec, sweeps=1)
    for c in run.counters:
        assert c["L1_DEMAND_HIT"] + c["L1_DEMAND_MISS"] == c[ev.ACCESS]


def test_l1_size_does_not_perturb_l2_prefetch_fills():
    """The prefetcher serves the L2: its fill filter must look at L2
    contents, so L2_PREFETCH_FILL is independent of the L1 in front."""
    csr = fd_matrix(2 ** 10)
    part = rowblock_equal(csr, 2)

    def pf_fills(l1_bytes):
        spec = ParallelSpec(l1_bytes=l1_bytes, l2_bytes=16 * 1024,
                            llc_bytes=64 * 1024)
        run, _ = simulate_parallel(csr, part, SANDY_BRIDGE, spec, sweeps=1)
        return [c[ev.L2_PREFETCH_FILL] for c in run.counters]

    assert pf_fills(1 * 1024) == pf_fills(8 * 1024) == pf_fills(None)


def test_shared_llc_contention_grows_with_threads():
    """More threads on the socket -> more streams competing for the same
    LLC -> each thread's shared-level misses per access rise."""
    csr = rmat_matrix(2 ** 11, seed=0)
    # tighter LLC than SCALED so x + streams genuinely overflow it
    spec = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=32 * 1024)

    def llc_miss_rate(threads):
        part = rowblock_equal(csr, threads)
        run, _ = simulate_parallel(csr, part, SANDY_BRIDGE, spec, sweeps=2)
        miss = sum(c[ev.L3_DEMAND_MISS] for c in run.counters)
        acc = sum(c[ev.ACCESS] for c in run.counters)
        return miss / acc

    assert llc_miss_rate(8) > llc_miss_rate(2) > llc_miss_rate(1)


# ---------------------------------------------------------------------------
# Time model + the paper's headline
# ---------------------------------------------------------------------------

def test_fd_speedup_dominates_rmat():
    """The paper's title result: FD scales strictly better than R-MAT at
    every thread count (shared-LLC contention + bandwidth saturation hit
    the random-gather workload first)."""
    speedups = {}
    for kind, gen in (("fd", fd_matrix), ("rmat", rmat_matrix)):
        csr = gen(2 ** 11)
        t1 = None
        for threads in (1, 2, 8, 32):
            part = rowblock_balanced(csr, threads)
            _, m = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED,
                                     sweeps=2)
            if threads == 1:
                t1 = m.time_s
            speedups[(kind, threads)] = t1 / m.time_s
    for threads in (2, 8, 32):
        assert speedups[("fd", threads)] > speedups[("rmat", threads)], \
            (threads, speedups)


def test_metrics_sane():
    csr = rmat_matrix(2 ** 10, seed=4)
    part = rowblock_equal(csr, 4)
    run, m = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED, sweeps=1)
    assert m.threads == 4
    assert m.time_s >= m.bw_time_s - 1e-18
    assert m.time_s >= m.lat_time_s / 3.0   # queueing never shrinks time
    assert 0.0 <= m.dram_util <= 1.0 + 1e-9
    assert len(m.l2_mpki) == 4 and all(v >= 0 for v in m.l2_mpki)
    assert m.dram_bytes > 0
    assert np.isfinite(m.gflops_est())


def test_metrics_reuse_prebuilt_traces():
    csr = fd_matrix(2 ** 10)
    part = rowblock_equal(csr, 2)
    traces = partitioned_traces(csr, part, SANDY_BRIDGE)
    run1, m1 = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED, sweeps=1)
    run2, m2 = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED, sweeps=1,
                                 traces=traces)
    assert m1 == m2


# ---------------------------------------------------------------------------
# Sweep + reports
# ---------------------------------------------------------------------------

def test_scaling_sweep_grid_and_reports():
    pts = scaling_sweep(log2ns=(10,), threads_list=(2, 4), spec=SCALED,
                        sweeps=1,
                        reorderings={"none": None})
    assert len(pts) == 2 * 2          # kinds x thread counts
    assert {p.threads for p in pts} == {2, 4}
    for p in pts:
        assert p.speedup > 0 and p.efficiency <= p.speedup
        assert p.imbalance >= 1.0
    csv = scaling_report(pts)
    assert "speedup" in csv and "fd" in csv and "rmat" in csv
    gap = scaling_gap_report(pts)
    assert "fd_speedup" in gap and "rmat_speedup" in gap


def test_scaling_sweep_reorder_axis():
    from repro import reorder

    pts = scaling_sweep(log2ns=(10,), threads_list=(2,), spec=SCALED,
                        sweeps=1, partition="balanced",
                        reorderings={"none": None, "rcm": reorder.rcm})
    assert {p.reorder for p in pts} == {"none", "rcm"}
    gap = scaling_gap_report(pts)
    assert "gap_closed_rcm" in gap and "gap_closed_gflops_rcm" in gap


# ---------------------------------------------------------------------------
# Sharded execution path (single device here; 8-device parity lives in
# test_multidevice.py)
# ---------------------------------------------------------------------------

def test_spmv_row_sharded_matches_dense():
    from repro.distributed import row_mesh, spmv_row_sharded

    csr = rmat_matrix(256, seed=3)
    x = jnp.asarray(np.random.default_rng(0).normal(size=256)
                    .astype(np.float32))
    want = np.asarray(csr.to_dense()) @ np.asarray(x)
    y = spmv_row_sharded(csr, x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)
    # explicit balanced partition on an explicit mesh
    mesh = row_mesh()
    part = rowblock_balanced(csr, mesh.shape["shards"])
    y2 = spmv_row_sharded(csr, x, mesh=mesh, partition=part)
    np.testing.assert_allclose(np.asarray(y2), want, rtol=1e-4, atol=1e-4)


def test_spmv_row_sharded_rejects_mismatched_partition():
    from repro.distributed import row_mesh, spmv_row_sharded

    csr = fd_matrix(128)
    mesh = row_mesh()
    bad = rowblock_equal(csr, mesh.shape["shards"] + 1)
    with pytest.raises(ValueError):
        spmv_row_sharded(csr, jnp.ones(128, jnp.float32), mesh=mesh,
                         partition=bad)
