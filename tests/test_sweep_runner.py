"""The sharded, resumable sweep runner's contract, locked down.

`repro.telemetry.runner` promises bit-identical results no matter how a
sweep is executed: serial or sharded across worker processes, straight
through or killed-and-resumed from a checkpoint, cells listed once or
twice, axes enumerated in any order.  Everything here compares the
*canonical JSON payloads* (`encode_point`) byte-for-byte -- value-close
is not good enough for a resume contract.

Also here: the sorted-enumeration pin (checkpoint keys are an on-disk
format; reordering the cell sort silently orphans old checkpoints) and
the empty/single-cell report-helper regressions.
"""
import dataclasses

import pytest

from repro.parallel import ParallelSpec
from repro.telemetry import runner
from repro.telemetry.report import (gap_report, graph_gap_report,
                                    graph_report, partition_gap_report,
                                    plan_cache_report, scaling_gap_report,
                                    scaling_report, to_csv, to_markdown)
from repro.telemetry.runner import (SweepCell, SweepConfig, decode_point,
                                    encode_point, execute_cells, graph_cells,
                                    mech_cells, scaling_cells, sort_cells)

# Tiny scaled grid: big enough to shard, small enough to run in seconds.
SCALED = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)
CFG = SweepConfig(parallel_spec=SCALED, sweeps=1)
GRID = scaling_cells(log2ns=(7,), kinds=("fd", "rmat"),
                     threads_list=(1, 2), partition="balanced")


def _payloads(points):
    return [encode_point(p) for p in points]


# ---------------------------------------------------------------------------
# sorted, deduplicated, order-independent enumeration (pinned)
# ---------------------------------------------------------------------------


def test_enumeration_order_independent():
    a = mech_cells(log2ns=(8, 7), kinds=("rmat", "fd"),
                   mechanisms=("victim-cache", "baseline"),
                   threads_list=(2, 1))
    b = mech_cells(log2ns=(7, 8), kinds=("fd", "rmat"),
                   mechanisms=("baseline", "victim-cache"),
                   threads_list=(1, 2))
    assert a == b == sort_cells(a)
    assert len(a) == len(set(a)) == 2 * 2 * 2 * 2
    assert scaling_cells((7,), ("rmat", "fd", "rmat"), (2, 1, 2)) == \
        scaling_cells((7,), ("fd", "rmat"), (1, 2))


def test_cell_keys_pinned():
    """Checkpoint keys are an on-disk format: changing `SweepCell.key()`
    or the sort orphans every existing checkpoint.  Pin both."""
    assert [c.key() for c in GRID] == [
        "scaling|fd|7|none|-|1|balanced|-|-",
        "scaling|fd|7|none|-|2|balanced|-|-",
        "scaling|rmat|7|none|-|1|balanced|-|-",
        "scaling|rmat|7|none|-|2|balanced|-|-",
    ]
    g = graph_cells((6,), ("fd",), ("pagerank",))
    assert [c.key() for c in g] == ["graph|fd|6|none|-|1|-|-|pagerank"]
    m = mech_cells((7,), ("fd",), ("baseline",))
    assert [c.key() for c in m] == ["mech|fd|7|none|-|1|-|baseline|-"]


def test_keys_unique_across_sweeps():
    cells = (GRID + mech_cells((7,), ("fd", "rmat"), ("baseline",))
             + graph_cells((6,), ("fd",), ("bfs", "pagerank")))
    keys = [c.key() for c in cells]
    assert len(keys) == len(set(keys))


# ---------------------------------------------------------------------------
# payload round-trips (value-exact both directions)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cell", [
    SweepCell(sweep="mech", kind="rmat", log2n=7, mechanism="victim-cache"),
    SweepCell(sweep="scaling", kind="rmat", log2n=7, threads=2,
              partition="balanced"),
    SweepCell(sweep="graph", kind="fd", log2n=6, analytic="pagerank"),
    SweepCell(sweep="label", kind="banded", log2n=7, reorder="rcm",
              threads=2, mechanism="scaled"),
], ids=["mech", "scaling", "graph", "label"])
def test_encode_decode_roundtrip(cell):
    cfg = dataclasses.replace(CFG, max_iters=4)
    p = runner.run_cell(cell, cfg)
    blob = encode_point(p)
    q = decode_point(blob)
    assert q == p
    assert encode_point(q) == blob


def test_label_cells_ride_the_runner():
    """The cost-model labeler is a fourth sweep family: `run_cell`
    dispatches on sweep='label' (geometry label riding the `mechanism`
    field, seed from the config) and returns the exact row the direct
    entry point produces."""
    from repro.plan.costmodel import label_cells, run_label_cell

    cells = label_cells(kinds=("banded",), log2ns=(7,), threads_list=(2,),
                        reorders=("none",), specs=("scaled",))
    assert [c.key() for c in cells] == \
        ["label|banded|7|none|-|2|-|scaled|-"]
    cfg = SweepConfig(seed=5, sweeps=1)
    got = runner.run_cell(cells[0], cfg)
    want = run_label_cell("banded", 7, "none", 2, spec_label="scaled",
                          seed=5, sweeps=1)
    assert got == want and got.seed == 5


# ---------------------------------------------------------------------------
# execution equivalence: duplicates, interrupts, shards
# ---------------------------------------------------------------------------


def test_duplicate_cells_idempotent():
    once = _payloads(execute_cells(GRID, CFG))
    twice = _payloads(execute_cells(list(GRID) + list(GRID), CFG))
    assert twice == once
    assert len(once) == len(GRID)


def test_interrupt_and_resume_bit_identical(tmp_path):
    """Kill the runner after K cells; a resumed run must be
    byte-identical to one that never stopped."""
    straight = _payloads(execute_cells(GRID, CFG))

    ckpt = str(tmp_path / "ckpt")
    first = execute_cells(GRID, CFG, ckpt_dir=ckpt, checkpoint_every=1,
                          max_cells=2)
    assert len(first) == 2                      # the "killed" run
    resumed = execute_cells(GRID, CFG, ckpt_dir=ckpt)
    assert _payloads(resumed) == straight


def test_resume_skips_completed_cells(tmp_path, monkeypatch):
    """A complete checkpoint means zero recomputation on resume."""
    ckpt = str(tmp_path / "ckpt")
    want = _payloads(execute_cells(GRID, CFG, ckpt_dir=ckpt))

    def boom(cell, cfg):
        raise AssertionError(f"recomputed {cell.key()}")

    monkeypatch.setattr(runner, "run_cell", boom)
    again = execute_cells(GRID, CFG, ckpt_dir=ckpt)
    assert _payloads(again) == want


def test_no_resume_ignores_checkpoint(tmp_path, monkeypatch):
    ckpt = str(tmp_path / "ckpt")
    execute_cells(GRID[:1], CFG, ckpt_dir=ckpt)
    seen = []
    real = runner.run_cell
    monkeypatch.setattr(runner, "run_cell",
                        lambda cell, cfg: seen.append(cell) or real(cell, cfg))
    execute_cells(GRID[:1], CFG, ckpt_dir=ckpt, resume=False)
    assert seen == list(GRID[:1])


def test_checkpoint_only_returns_requested_cells(tmp_path):
    """A checkpoint holding extra cells does not leak them into the
    result -- only the requested grid comes back, in canonical order."""
    ckpt = str(tmp_path / "ckpt")
    execute_cells(GRID, CFG, ckpt_dir=ckpt)
    sub = [c for c in GRID if c.kind == "fd"]
    pts = execute_cells(sub, CFG, ckpt_dir=ckpt)
    assert [(p.kind, p.threads) for p in pts] == [("fd", 1), ("fd", 2)]


@pytest.mark.parametrize("workers", [2, 4])
def test_workers_bit_identical_to_serial(workers):
    serial = _payloads(execute_cells(GRID, CFG, workers=1))
    sharded = _payloads(execute_cells(GRID, CFG, workers=workers))
    assert sharded == serial


def test_workers_resume_bit_identical(tmp_path):
    """Interrupt serially, finish sharded: still byte-identical."""
    straight = _payloads(execute_cells(GRID, CFG))
    ckpt = str(tmp_path / "ckpt")
    execute_cells(GRID, CFG, ckpt_dir=ckpt, checkpoint_every=1, max_cells=1)
    resumed = execute_cells(GRID, CFG, ckpt_dir=ckpt, workers=2)
    assert _payloads(resumed) == straight


def test_thin_clients_match_runner():
    """`scaling_sweep` is a thin client of the runner: same cells, same
    payloads."""
    from repro.telemetry.sweep import scaling_sweep

    pts = scaling_sweep(log2ns=(7,), threads_list=(1, 2), spec=SCALED,
                        partition="balanced", sweeps=1)
    assert _payloads(pts) == _payloads(execute_cells(GRID, CFG))


# ---------------------------------------------------------------------------
# report helpers on empty / single-cell results (regressions)
# ---------------------------------------------------------------------------


def test_reports_empty_inputs_well_formed():
    for fn in (to_csv, to_markdown, gap_report, scaling_report,
               scaling_gap_report, partition_gap_report, graph_report,
               graph_gap_report):
        out = fn([])
        assert isinstance(out, str) and out.strip()


def test_plan_cache_report_empty_stats():
    out = plan_cache_report({})
    assert "0" in out and len(out.splitlines()) >= 2
    # windowed view with a missing counter key must not KeyError either
    assert plan_cache_report({"hits": 3}, before={})


def test_reports_single_cell():
    pts = execute_cells(GRID[:1], CFG)
    assert len(pts) == 1
    assert str(pts[0].threads) in scaling_report(pts)
    assert scaling_gap_report(pts)          # one kind only: no gap rows
    assert partition_gap_report(pts)        # one partition only


def test_graph_point_empty_iters_row():
    from repro.telemetry.sweep import GraphPoint

    p = GraphPoint(kind="fd", log2n=6, nnz=0, analytic="bfs",
                   semiring="boolean", n_iters=0, converged=False,
                   format_name="csr", iters=())
    row = p.row()
    assert len(row) == len(GraphPoint.header())
    assert graph_report([p])
