"""Simulator-invariant suite for the staged topdown accounting.

The staged pipeline's claims, pinned:

  1. **Exactness contract** — for every sweep cell, the stage cycles
     (Retiring / Frontend / Backend-{L1,L2,LLC,DRAM,contention,
     bandwidth}) sum BIT-EXACTLY (`==`, not approx) to the
     `simulate_parallel` total, and `time_s` is exactly that total over
     the clock.  The sum is recomputed here, independently, in the
     canonical `STAGE_FIELDS` order.
  2. **Sane fractions** — every stage share lies in [0, 1] and the
     shares sum to ~1 on non-empty runs.
  3. **Monotonicity under cache shrink** — with the prefetcher and
     queueing model off (LRU stack property holds only for pure demand
     streams), shrinking the shared LLC never reduces total cycles, and
     shrinking the private L2 never reduces a thread's L2 demand misses.
  4. **Pinned FD-vs-R-MAT bound categories** — at the 2^12
     working-set-scaled cell the paper's gap has a *cause*: R-MAT is
     DRAM-side bound (LLC/DRAM/contention/bandwidth stages dominate),
     FD is retiring-dominated.

Property tests are hypothesis-driven when installed (CI pins
`--hypothesis-seed`); the named regression tests below run regardless.
"""
import math

import numpy as np
import pytest
from _opt_deps import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.core.cache_model import SANDY_BRIDGE
from repro.core.generators import fd_matrix, rmat_matrix
from repro.core.partition import rowblock_balanced, rowblock_equal
from repro.parallel import ParallelSpec, simulate_parallel
from repro.telemetry import events as ev
from repro.telemetry.topdown import (STAGE_FIELDS, TopdownStages,
                                     machine_stages, stage_cycles,
                                     topdown_summary, topdown_tree)

FREQ = SANDY_BRIDGE.freq_ghz * 1e9

# The scaling/telemetry benches' working-set-scaled reference cell.
SCALED = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)


def _matrix(kind, log2n, seed=0):
    gen = fd_matrix if kind == "fd" else rmat_matrix
    return gen(2 ** log2n, seed=seed)


def _canonical_sum(stages: TopdownStages) -> float:
    """The contract's sum, recomputed independently of total_cycles()."""
    total = 0.0
    for f in STAGE_FIELDS:
        total = total + getattr(stages, f)
    return total


def _assert_contract(m):
    """Exactness + fraction invariants for one ParallelMetrics."""
    assert _canonical_sum(m.stages) == m.total_cycles          # bit-exact
    assert m.time_s == m.total_cycles / FREQ                    # bit-exact
    for f in STAGE_FIELDS:
        assert getattr(m.stages, f) >= 0.0
    fr = m.stages.fractions()
    for f in STAGE_FIELDS:
        assert 0.0 <= fr[f] <= 1.0
    if m.total_cycles > 0:
        assert math.fsum(fr.values()) == pytest.approx(1.0, abs=1e-9)
    # the machine roll-up is the critical thread + the bandwidth stage,
    # so every per-thread staged sum is itself exact and bounded by it
    for ts in m.thread_stages:
        assert _canonical_sum(ts) == ts.total_cycles()
        assert ts.total_cycles() <= m.total_cycles + 1e-9


# ---------------------------------------------------------------------------
# exactness contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["fd", "rmat"])
@pytest.mark.parametrize("threads", [1, 2, 4])
def test_stage_sum_exact_scaled_cell(kind, threads):
    csr = _matrix(kind, 8)
    part = rowblock_balanced(csr, threads)
    _, m = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED, sweeps=2)
    _assert_contract(m)


@pytest.mark.parametrize("spec", [
    ParallelSpec(),                                        # machine geometry
    ParallelSpec(l1_bytes=4 * 1024, l2_bytes=16 * 1024,
                 llc_bytes=64 * 1024),                     # with private L1
    ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024,
                 victim_entries=16, stream_buffers=4),     # §V mechanisms
    ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024,
                 prefetcher=False, pf_shutoff=False),      # demand-only
    ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024,
                 queueing=False),                          # no queueing term
])
def test_stage_sum_exact_across_specs(spec):
    csr = _matrix("rmat", 8)
    part = rowblock_equal(csr, 4)
    _, m = simulate_parallel(csr, part, SANDY_BRIDGE, spec, sweeps=2)
    _assert_contract(m)


def test_stage_sum_exact_smt_oversubscription():
    # more threads than cores on the socket: the frontend stage activates
    csr = _matrix("fd", 9)
    threads = 2 * SANDY_BRIDGE.cores_per_socket * SANDY_BRIDGE.sockets
    part = rowblock_equal(csr, threads)
    _, m = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED, sweeps=1)
    _assert_contract(m)
    assert m.stages.frontend > 0.0


if HAVE_HYPOTHESIS:
    @settings(deadline=None)
    @given(kind=st.sampled_from(("fd", "rmat")),
           log2n=st.integers(6, 8),
           threads=st.integers(1, 5),
           seed=st.integers(0, 3),
           l2_kb=st.sampled_from((8, 16, 32)),
           llc_kb=st.sampled_from((32, 64)),
           prefetcher=st.booleans(),
           victim=st.sampled_from((0, 16)),
           stream=st.sampled_from((0, 4)),
           balanced=st.booleans())
    def test_stage_sum_exact_property(kind, log2n, threads, seed, l2_kb,
                                      llc_kb, prefetcher, victim, stream,
                                      balanced):
        """Random (structure x geometry x threads x mechanisms) cells all
        satisfy the bit-exact staged accounting."""
        csr = _matrix(kind, log2n, seed=seed)
        part_fn = rowblock_balanced if balanced else rowblock_equal
        part = part_fn(csr, threads)
        spec = ParallelSpec(l2_bytes=l2_kb * 1024, llc_bytes=llc_kb * 1024,
                            prefetcher=prefetcher, pf_shutoff=prefetcher,
                            victim_entries=victim, stream_buffers=stream)
        _, m = simulate_parallel(csr, part, SANDY_BRIDGE, spec, sweeps=1)
        _assert_contract(m)


# ---------------------------------------------------------------------------
# machine roll-up algebra
# ---------------------------------------------------------------------------


def test_machine_stages_bandwidth_excess():
    a = TopdownStages(retiring=100.0, backend_dram=50.0)  # critical: 150
    b = TopdownStages(retiring=120.0, backend_llc=10.0)   # total: 130
    roll = machine_stages([a, b], bw_cycles=200.0)
    assert roll.retiring == 100.0 and roll.backend_dram == 50.0
    assert roll.backend_bandwidth == 200.0 - 150.0
    assert roll.total_cycles() == 200.0
    # below the critical thread the bandwidth stage clamps to zero
    assert machine_stages([a, b], bw_cycles=50.0).backend_bandwidth == 0.0
    assert machine_stages([], bw_cycles=9.9).total_cycles() == 0.0


def test_empty_run_stages_are_zero():
    c = ev.EventCounters()
    s = stage_cycles(c, SANDY_BRIDGE, nnz=0)
    assert s.total_cycles() == 0.0
    assert all(v == 0.0 for v in s.fractions().values())
    assert s.bound() == STAGE_FIELDS[0]          # deterministic tie-break
    # the metric tree renders an nnz=0 replay without dividing by zero
    tree = topdown_tree(c, SANDY_BRIDGE, nnz=0)
    assert all(np.isfinite(v) for v in tree.flatten().values())


# ---------------------------------------------------------------------------
# monotonicity under cache shrink (demand-only LRU: stack property)
# ---------------------------------------------------------------------------


def _demand_spec(l2_kb, llc_kb):
    return ParallelSpec(l2_bytes=l2_kb * 1024, llc_bytes=llc_kb * 1024,
                        prefetcher=False, pf_shutoff=False, queueing=False)


@pytest.mark.parametrize("kind", ["fd", "rmat"])
@pytest.mark.parametrize("threads", [1, 4])
def test_llc_shrink_never_speeds_up(kind, threads):
    csr = _matrix(kind, 8)
    part = rowblock_balanced(csr, threads)
    _, small = simulate_parallel(csr, part, SANDY_BRIDGE,
                                 _demand_spec(8, 32), sweeps=2)
    _, big = simulate_parallel(csr, part, SANDY_BRIDGE,
                               _demand_spec(8, 128), sweeps=2)
    assert small.total_cycles >= big.total_cycles - 1e-6


@pytest.mark.parametrize("kind", ["fd", "rmat"])
def test_l2_shrink_never_reduces_misses(kind):
    csr = _matrix(kind, 8)
    part = rowblock_balanced(csr, 4)
    run_s, _ = simulate_parallel(csr, part, SANDY_BRIDGE,
                                 _demand_spec(4, 64), sweeps=2)
    run_b, _ = simulate_parallel(csr, part, SANDY_BRIDGE,
                                 _demand_spec(32, 64), sweeps=2)
    for cs, cb in zip(run_s.counters, run_b.counters):
        assert cs[ev.L2_DEMAND_MISS] >= cb[ev.L2_DEMAND_MISS]


if HAVE_HYPOTHESIS:
    @settings(deadline=None)
    @given(kind=st.sampled_from(("fd", "rmat")),
           log2n=st.integers(6, 8),
           threads=st.integers(1, 4),
           seed=st.integers(0, 3),
           llc_pair=st.sampled_from(((16, 32), (32, 64), (16, 128))))
    def test_llc_shrink_monotone_property(kind, log2n, threads, seed,
                                          llc_pair):
        """Fully-associative LRU + pure demand stream: a smaller shared
        LLC can never lower simulated total cycles."""
        lo, hi = llc_pair
        csr = _matrix(kind, log2n, seed=seed)
        part = rowblock_balanced(csr, threads)
        _, small = simulate_parallel(csr, part, SANDY_BRIDGE,
                                     _demand_spec(8, lo), sweeps=1)
        _, big = simulate_parallel(csr, part, SANDY_BRIDGE,
                                   _demand_spec(8, hi), sweeps=1)
        assert small.total_cycles >= big.total_cycles - 1e-6


# ---------------------------------------------------------------------------
# pinned FD-vs-R-MAT bound categories (the paper's gap, explained)
# ---------------------------------------------------------------------------

DRAM_SIDE = {"backend_llc", "backend_dram", "backend_contention",
             "backend_bandwidth"}


def test_bound_categories_fd_vs_rmat_2e12_scaled():
    """At the 2^12 scaled cell (4 threads, nnz-balanced rows): FD retires,
    R-MAT stalls on the DRAM side.  This is the regression pin for the
    staged attribution -- if it moves, the time model changed meaning."""
    results = {}
    for kind in ("fd", "rmat"):
        csr = _matrix(kind, 12)
        part = rowblock_balanced(csr, 4)
        _, m = simulate_parallel(csr, part, SANDY_BRIDGE, SCALED, sweeps=2)
        _assert_contract(m)
        results[kind] = m

    fd, rmat = results["fd"], results["rmat"]
    assert fd.stages.bound() == "retiring"
    assert fd.stages.fractions()["retiring"] > 0.5
    assert rmat.stages.bound() in DRAM_SIDE
    assert rmat.stages.memory_frac() > 0.5
    # the gap has a direction: R-MAT burns strictly more of its cycles on
    # the memory system than FD does
    assert rmat.stages.memory_frac() > fd.stages.memory_frac() + 0.2


def test_bound_label_single_stream_summary():
    """The flat TopdownSummary agrees with the staged view on the
    single-stream 2^12 scaled-geometry replay."""
    from repro.telemetry.hierarchy import HierarchySpec
    from repro.telemetry.sweep import run_point

    spec = HierarchySpec(l2_bytes=16 * 1024, l3_bytes=64 * 1024)
    summaries = {}
    for kind in ("fd", "rmat"):
        csr = _matrix(kind, 12)
        c = run_point(csr, spec, SANDY_BRIDGE, sweeps=2)
        s = topdown_summary(c, SANDY_BRIDGE, csr.nnz)
        for f in ("retiring_frac", "mech_bound", "llc_bound", "dram_bound",
                  "l2_eff", "llc_eff"):
            assert 0.0 <= getattr(s, f) <= 1.0
        summaries[kind] = s

    fd, rmat = summaries["fd"], summaries["rmat"]
    # FD's bands stay resident: overwhelmingly retiring even single-stream
    assert fd.bound() == "retiring" and fd.retiring_frac > 0.9
    # R-MAT single-stream is split (queueing/bandwidth only bite with
    # threads), but its memory-side share is already large and dwarfs FD's
    rmat_mem = rmat.llc_bound + rmat.dram_bound + rmat.mech_bound
    assert rmat_mem > 0.4
    assert rmat_mem > fd.llc_bound + fd.dram_bound + fd.mech_bound + 0.3


def test_tree_stage_fractions_sum_to_one():
    csr = _matrix("rmat", 8)
    from repro.telemetry.hierarchy import HierarchySpec
    from repro.telemetry.sweep import run_point

    c = run_point(csr, HierarchySpec(), SANDY_BRIDGE, sweeps=2)
    flat = topdown_tree(c, SANDY_BRIDGE, csr.nnz).flatten()
    total = math.fsum(flat[f"spmv.stages.{f}"] for f in STAGE_FIELDS)
    assert total == pytest.approx(1.0, abs=1e-9)
