"""Semiring graph layer: drivers vs dense references on FD and R-MAT,
plus-times bit-identity with the existing Pallas path, empty-frontier
termination, and the per-iteration telemetry hook."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plan
from repro.core.formats import CSR, ELL
from repro.core.generators import fd_matrix, rmat_matrix
from repro.core.spmv import spmv
from repro.graph import (MIN_PLUS, OR_AND, PLUS_TIMES, SEMIRINGS, bfs,
                         connected_components, pagerank,
                         spmv_semiring_jnp, sssp, transpose_csr)
from repro.kernels import ops as kops

N = 256


def _graphs():
    return [("fd", fd_matrix(N, seed=2)), ("rmat", rmat_matrix(N, seed=2))]


def _empty(n=16):
    z = np.array([], dtype=np.int64)
    return CSR.from_coo(z, z, np.array([], dtype=np.float32), n, n)


# ---------------------------------------------------------------------------
# dense references (pure numpy, independent of the kernel stack)
# ---------------------------------------------------------------------------

def _nz_mask(csr):
    m = np.zeros((csr.n_rows, csr.n_cols), dtype=bool)
    ip, ci = np.asarray(csr.indptr), np.asarray(csr.indices)
    for r in range(csr.n_rows):
        m[r, ci[ip[r]:ip[r + 1]]] = True
    return m


def _bfs_ref(csr, src):
    """Hop depths along edges i->j by frontier expansion on the dense
    adjacency."""
    adj = _nz_mask(csr)
    n = csr.n_rows
    depth = np.full(n, np.inf)
    depth[src] = 0
    frontier = {src}
    level = 0
    while frontier:
        level += 1
        nxt = set()
        for u in frontier:
            for v in np.nonzero(adj[u])[0]:
                if np.isinf(depth[v]):
                    depth[v] = level
                    nxt.add(v)
        frontier = nxt
    return depth


def _sssp_ref(csr, src):
    """Bellman-Ford on the dense weights."""
    w = np.where(_nz_mask(csr), np.asarray(csr.to_dense(), np.float64),
                 np.inf)
    n = csr.n_rows
    d = np.full(n, np.inf)
    d[src] = 0.0
    for _ in range(n):
        nd = np.minimum(d, (w + d[:, None]).min(axis=0))
        if np.array_equal(nd, d):
            break
        d = nd
    return d


# ---------------------------------------------------------------------------
# semiring algebra + kernels
# ---------------------------------------------------------------------------

def test_semiring_registry_padding_is_absorbing():
    for name, sr in SEMIRINGS.items():
        x = jnp.asarray([0.5, 2.0, 0.0], jnp.float32)
        contrib = sr.mul(jnp.full_like(x, sr.pad_value), x)
        assert np.all(np.asarray(contrib) == sr.identity), name


@pytest.mark.parametrize("fmt", ["ell", "csr"])
@pytest.mark.parametrize("srname", ["min_plus", "or_and", "max_times"])
def test_semiring_pallas_matches_dense_reference(fmt, srname):
    sr = SEMIRINGS[srname]
    m = rmat_matrix(N, seed=1)
    if srname != "min_plus":
        # nonnegative values for the max-family semirings
        m = CSR(data=jnp.abs(m.data), indices=m.indices, indptr=m.indptr,
                n_rows=N, n_cols=N)
    x = jnp.asarray(np.abs(np.random.default_rng(0).normal(size=N))
                    .astype(np.float32))
    p = plan.compile(m, semiring=srname, format=fmt, reorder="none",
                     predictor="none")
    got = np.asarray(p.execute(x))

    nz = _nz_mask(m)
    dense = np.asarray(m.to_dense(), np.float64)
    xv = np.asarray(x, np.float64)
    if srname == "min_plus":
        want = np.where(nz, dense + xv[None, :], np.inf).min(axis=1)
    else:
        want = np.where(nz, dense * xv[None, :], 0.0).max(axis=1)
    np.testing.assert_allclose(got, want.astype(np.float32), rtol=1e-6)
    # jnp reference path agrees with the Pallas path
    np.testing.assert_allclose(
        np.asarray(spmv_semiring_jnp(p.container, x, sr)), got, rtol=1e-6)


def test_plus_times_semiring_bit_identical_to_existing_pallas():
    m = rmat_matrix(N, seed=4)
    x = jnp.asarray(np.random.default_rng(1).normal(size=N)
                    .astype(np.float32))
    for fmt in ("ell", "csr"):
        container = plan.convert(m, fmt)
        base = spmv(container, x, use_pallas=True)
        via_semiring = {
            "ell": kops.spmv_ell, "csr": kops.spmv_csr,
        }[fmt](container, x, semiring=PLUS_TIMES)
        np.testing.assert_array_equal(np.asarray(base),
                                      np.asarray(via_semiring))
        p = plan.compile(m, semiring="plus_times", format=fmt,
                         reorder="none", predictor="none")
        np.testing.assert_array_equal(np.asarray(base),
                                      np.asarray(p.execute(x)))


def test_semiring_plan_requires_sparse_slot_format():
    m = fd_matrix(64)
    with pytest.raises(ValueError, match="ell.*csr|csr.*ell"):
        plan.compile(m, semiring="min_plus", format="dia")
    p = plan.compile(m, semiring="min_plus")        # default: ell
    assert p.format_name == "ell" and p.semiring == "min_plus"


def test_semiring_plan_checkpoint_roundtrip(tmp_path):
    from repro.plan import load_plan, save_plan

    m = rmat_matrix(128, seed=5)
    p = plan.compile(m, semiring="min_plus", reorder="none",
                     predictor="none")
    x = jnp.asarray(np.abs(np.random.default_rng(2).normal(size=128))
                    .astype(np.float32))
    save_plan(p, str(tmp_path / "ck"))
    p2, _ = load_plan(str(tmp_path / "ck"))
    assert p2.semiring == "min_plus"
    np.testing.assert_array_equal(np.asarray(p.execute(x)),
                                  np.asarray(p2.execute(x)))


# ---------------------------------------------------------------------------
# drivers vs references
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["fd", "rmat"])
def test_pagerank_matches_dense_power_iteration(kind):
    m = dict(_graphs())[kind]
    n = m.n_rows
    res = pagerank(m, tol=1e-10, max_iters=300)
    assert res.converged

    out_deg = np.diff(np.asarray(m.indptr)).astype(np.float64)
    nz = _nz_mask(m)
    P = np.where(nz, 1.0 / np.maximum(out_deg[:, None], 1.0), 0.0).T
    dang = (out_deg == 0).astype(np.float64)
    r = np.full(n, 1.0 / n)
    for _ in range(300):
        r = 0.85 * (P @ r + (dang @ r) / n) + 0.15 / n
    np.testing.assert_allclose(res.values, r, atol=1e-6)
    assert abs(float(res.values.sum()) - 1.0) < 1e-4


@pytest.mark.parametrize("kind", ["fd", "rmat"])
def test_bfs_depths_match_reference(kind):
    m = dict(_graphs())[kind]
    src = int(np.argmax(np.diff(np.asarray(m.indptr))))
    res = bfs(m, src)
    assert res.converged
    np.testing.assert_array_equal(res.values, _bfs_ref(m, src))


def test_bfs_multi_source_execute_many():
    m = rmat_matrix(N, seed=2)
    lens = np.diff(np.asarray(m.indptr))
    srcs = list(np.argsort(lens)[-3:])
    res = bfs(m, srcs)
    assert res.values.shape == (3, N)
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(res.values[i], _bfs_ref(m, int(s)))


@pytest.mark.parametrize("kind", ["fd", "rmat"])
def test_sssp_matches_bellman_ford(kind):
    m = dict(_graphs())[kind]
    mw = CSR(data=jnp.abs(m.data), indices=m.indices, indptr=m.indptr,
             n_rows=m.n_rows, n_cols=m.n_cols)
    src = int(np.argmax(np.diff(np.asarray(m.indptr))))
    res = sssp(mw, src)
    assert res.converged
    np.testing.assert_allclose(res.values, _sssp_ref(mw, src), atol=1e-5)


@pytest.mark.parametrize("kind", ["fd", "rmat"])
def test_connected_components_labels(kind):
    m = dict(_graphs())[kind]
    res = connected_components(m)
    assert res.converged
    # reference: union-find over the symmetrized edge list
    parent = list(range(m.n_rows))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    ip, ci = np.asarray(m.indptr), np.asarray(m.indices)
    for r in range(m.n_rows):
        for c in ci[ip[r]:ip[r + 1]]:
            ra, rb = find(r), find(int(c))
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)
    want = np.asarray([find(v) for v in range(m.n_rows)], np.float32)
    np.testing.assert_array_equal(res.values, want)


# ---------------------------------------------------------------------------
# degenerate graphs / termination
# ---------------------------------------------------------------------------

def test_bfs_empty_graph_terminates_immediately():
    res = bfs(_empty(), 3)
    assert res.converged and res.n_iters == 1
    assert res.values[3] == 0.0
    assert np.isinf(np.delete(res.values, 3)).all()


def test_sssp_empty_graph_all_unreachable():
    res = sssp(_empty(), 0)
    assert res.converged
    assert res.values[0] == 0.0 and np.isinf(res.values[1:]).all()


def test_connected_components_edgeless_graph_is_all_singletons():
    res = connected_components(_empty(8))
    assert res.converged
    np.testing.assert_array_equal(res.values, np.arange(8, dtype=np.float32))


def test_pagerank_empty_graph_is_uniform():
    res = pagerank(_empty(8), max_iters=50)
    assert res.converged
    np.testing.assert_allclose(res.values, np.full(8, 1 / 8), rtol=1e-5)


# ---------------------------------------------------------------------------
# source edge cases through the plan path (empty / duplicate / bad sources)
# ---------------------------------------------------------------------------

def test_bfs_empty_sources_is_well_defined():
    """An empty source list is a zero-lane run: (0, n) values, converged
    at zero iterations, nothing executed through the plan."""
    m = fd_matrix(N, seed=2)
    res = bfs(m, [])
    assert res.values.shape == (0, N)
    assert res.converged and res.n_iters == 0 and res.history == []


@pytest.mark.parametrize("reorder", ["none", "rcm"])
def test_bfs_duplicate_sources_produce_equal_rows(reorder):
    """Duplicate source indices are distinct lanes with identical
    frontiers -- the batched path (including the reordered gather /
    scatter) must keep them bit-identical to the deduplicated run."""
    m = rmat_matrix(N, seed=2)
    res = bfs(m, [7, 7, 3], reorder=reorder)
    assert res.values.shape == (3, N)
    np.testing.assert_array_equal(res.values[0], res.values[1])
    solo = bfs(m, 7, reorder=reorder)
    np.testing.assert_array_equal(res.values[0], solo.values)


@pytest.mark.parametrize("bad", [[-1], [0, N + 3], N + 3])
def test_bfs_out_of_range_sources_raise_value_error(bad):
    m = fd_matrix(N, seed=2)
    with pytest.raises(ValueError, match="out of range"):
        bfs(m, bad)


def test_sssp_out_of_range_source_raises_value_error():
    with pytest.raises(ValueError, match="out of range"):
        sssp(fd_matrix(N, seed=2), N)


def test_transpose_csr_roundtrip():
    m = rmat_matrix(128, seed=7)
    tt = transpose_csr(transpose_csr(m))
    np.testing.assert_array_equal(np.asarray(tt.to_dense()),
                                  np.asarray(m.to_dense()))


# ---------------------------------------------------------------------------
# telemetry wiring
# ---------------------------------------------------------------------------

def test_iteration_telemetry_warm_iterations_miss_less():
    from repro.graph import iteration_summaries

    res = pagerank(rmat_matrix(512, seed=0), max_iters=8, tol=0.0)
    sums = iteration_summaries(res.plan, res.n_iters)
    assert len(sums) == res.n_iters
    # cold first pass misses at least as much as any warm iteration
    assert sums[0].l2_mpki >= max(s.l2_mpki for s in sums[1:])


def test_graph_sweep_produces_gap_rows():
    from repro.telemetry import graph_gap_report, graph_sweep

    pts = graph_sweep(log2ns=(8,), analytics=("bfs",), max_iters=16)
    assert {p.kind for p in pts} == {"fd", "rmat"}
    rep = graph_gap_report(pts)
    assert "gap_total" in rep and "bfs" in rep


# ---------------------------------------------------------------------------
# review regressions
# ---------------------------------------------------------------------------

def test_graph_sweep_runs_connected_components():
    from repro.telemetry import graph_sweep

    pts = graph_sweep(log2ns=(8,), analytics=("connected_components",),
                      max_iters=16)
    assert all(p.analytic == "connected_components" for p in pts)
    assert {p.kind for p in pts} == {"fd", "rmat"}


def test_spmv_ell_rejects_non_absorbing_container():
    """An ELL built with the default fill=0.0 must be refused under
    min-plus (its padding would read as real weight-0 edges), while the
    correctly built container is accepted."""
    m = rmat_matrix(128, seed=3)
    x = jnp.ones((128,), jnp.float32)
    with pytest.raises(ValueError, match="fill=semiring.pad_value"):
        kops.spmv_ell(ELL.from_csr(m), x, semiring=MIN_PLUS)
    y = kops.spmv_ell(ELL.from_csr(m, fill=MIN_PLUS.pad_value), x,
                      semiring=MIN_PLUS)
    assert y.shape == (128,)


def test_compile_rejects_unregistered_semiring_instance():
    import dataclasses

    from repro.graph.semiring import MIN_PLUS as REG

    custom = dataclasses.replace(REG, name="my_custom_sr")
    with pytest.raises(ValueError, match="not registered"):
        plan.compile(fd_matrix(64), semiring=custom)
    # registry instances pass through fine
    p = plan.compile(fd_matrix(64), semiring=REG)
    assert p.semiring == "min_plus"


def test_core_spmv_pagerank_delegates_with_legacy_semantics():
    """The compatibility wrapper must reproduce the historical
    column-stochastic iteration exactly (same math, fixed iterations)."""
    from repro.core.spmv import pagerank as legacy_pagerank

    m = rmat_matrix(256, seed=9)
    n = m.n_rows
    got = np.asarray(legacy_pagerank(m, n_iters=16))

    ip, ci = np.asarray(m.indptr), np.asarray(m.indices)
    col_deg = np.bincount(ci, minlength=n).astype(np.float64)
    rows = np.repeat(np.arange(n), np.diff(ip))
    S = np.zeros((n, n))
    for r_, c_ in zip(rows, ci):
        S[r_, c_] += 1.0 / max(col_deg[c_], 1.0)
    dang = (col_deg == 0).astype(np.float64)
    r = np.full(n, 1.0 / n)
    for _ in range(16):
        r = 0.85 * (S @ r + (dang @ r) / n) + 0.15 / n
    np.testing.assert_allclose(got, r, atol=1e-6)
