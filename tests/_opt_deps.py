"""Optional test dependencies (see requirements-dev.txt).

`hypothesis` powers the property tests but is not required to run the
suite: when it is absent, `given` turns each property test into a single
skipped test and `st`/`settings` become inert stand-ins, so example-based
tests in the same module still run.

Usage (instead of importing hypothesis directly):

    from _opt_deps import HAVE_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy constructor call; never draws."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def skipped():
                pass  # property test body needs hypothesis to drive it

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
