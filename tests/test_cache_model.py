"""Cache model: Table I exact, paper findings F1-F4, sim cross-validation."""
import numpy as np
import pytest

from repro.core.cache_model import (SANDY_BRIDGE, analytic_metrics,
                                    analytic_metrics_from_profile,
                                    profile_fd, profile_of, profile_rmat,
                                    simulate_exact, table1_capacity)
from repro.core.generators import fd_matrix, rmat_matrix


def test_table1_matches_paper_exactly():
    """Paper Table I numbers, all eight cells."""
    assert table1_capacity(nnz_per_row=9) == {"L2": 18432, "L3": 1474560}
    assert table1_capacity(nnz_per_row=8) == {"L2": 18078, "L3": 1446311}
    par9 = table1_capacity(nnz_per_row=9, parallel=True)
    assert par9 == {"L2": 294912, "L3": 2949120}
    par8 = table1_capacity(nnz_per_row=8, parallel=True)
    assert par8 == {"L2": 289262, "L3": 2892623}


def test_f1_fd_miss_rates_low_and_flat():
    rates = [analytic_metrics(fd_matrix(2 ** k)).l2_miss_rate
             for k in (12, 16, 18)]
    assert max(rates) < 0.5
    big = analytic_metrics_from_profile(profile_fd(2 ** 26))
    assert big.l2_miss_rate < 0.5 and big.l3_miss_rate < 0.5


def test_f1_rmat_l2_plateau_near_paper():
    big = analytic_metrics_from_profile(profile_rmat(2 ** 24))
    assert 20.0 < big.l2_miss_rate < 35.0       # paper: ~26


def test_f1_l3_jump_past_capacity():
    small = analytic_metrics(rmat_matrix(2 ** 16))     # fits L3
    big = analytic_metrics_from_profile(profile_rmat(2 ** 24))
    assert small.l3_miss_rate < 0.5
    assert big.l3_miss_rate > 8.0


def test_f2_serial_equals_parallel_miss_rate():
    m = rmat_matrix(2 ** 18)
    s = analytic_metrics(m, threads=1)
    p = analytic_metrics(m, threads=16)
    assert p.l2_miss_rate == pytest.approx(s.l2_miss_rate, rel=0.5)


def test_f3_rmat_stalls_dwarf_fd():
    m_fd = analytic_metrics_from_profile(profile_fd(2 ** 24))
    m_rm = analytic_metrics_from_profile(profile_rmat(2 ** 24))
    assert m_rm.l2_stall_frac > 0.6                   # paper: ~0.7 plateau
    assert m_rm.l2_stall_frac > m_fd.l2_stall_frac


def test_f4_thread_scaling_and_ratio():
    prof_fd = profile_fd(2 ** 26)
    prof_rm = profile_rmat(2 ** 26)
    g = [analytic_metrics_from_profile(profile_fd(2 ** 16), threads=t).gflops
         for t in (1, 2, 4, 8)]
    for i in range(len(g) - 1):
        assert g[i + 1] / g[i] == pytest.approx(2.0, rel=0.2)
    ratio = (analytic_metrics_from_profile(prof_rm, threads=16).gflops
             / analytic_metrics_from_profile(prof_fd, threads=16).gflops)
    assert 0.1 < ratio < 0.35                          # paper: ~0.20


def test_synthetic_profile_matches_empirical():
    """The synthetic profiles must track empirical ones where both exist."""
    for kind, gen, prof_fn in (("fd", fd_matrix, profile_fd),
                               ("rmat", rmat_matrix, profile_rmat)):
        emp = analytic_metrics(gen(2 ** 16))
        syn = analytic_metrics_from_profile(prof_fn(2 ** 16))
        assert syn.l2_miss_rate == pytest.approx(emp.l2_miss_rate,
                                                 rel=0.5, abs=0.5), kind
        assert syn.nnz == pytest.approx(emp.nnz, rel=0.05), kind


def test_exact_sim_orders_fd_below_rmat():
    """Trace-driven simulator agrees with the analytic model's ordering at
    a size where x exceeds the per-core L2 (the paper's regime)."""
    n = 2 ** 16          # x = 512 KiB > 256 KiB L2
    fd_stats = simulate_exact(fd_matrix(n), sweeps=1)
    rm_stats = simulate_exact(rmat_matrix(n), sweeps=1)
    fd_rate = fd_stats["l2_demand"] / fd_stats["accesses"]
    rm_rate = rm_stats["l2_demand"] / rm_stats["accesses"]
    assert rm_rate > 3 * fd_rate
    # FD demand misses stay rare (prefetcher + windows reuse)
    assert fd_rate < 0.03


def test_prefetcher_shutoff_for_large_rmat():
    """Paper §IV-C: DRAM congestion shuts off the prefetcher for R-MAT."""
    big_rm = analytic_metrics_from_profile(profile_rmat(2 ** 24))
    big_fd = analytic_metrics_from_profile(profile_fd(2 ** 24))
    assert big_fd.prefetch_miss_rate > 5.0       # FD prefetcher working
    assert big_rm.prefetch_miss_rate < big_fd.prefetch_miss_rate
