"""Multi-device behaviour under 8 virtual CPU devices (subprocess: the
XLA device count is locked at first jax import, so these cannot run in
the main pytest process).

Covers: sharded-MoE parity on a real (2, 4) mesh, collective helpers
(ring all-gather matmul, LSE-merged attention), sharding-rule lowering
through pjit, and a miniature dry-run (lower+compile with real SPMD).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    assert len(jax.devices()) == 8
    from repro.launch.mesh import make_mesh
    from repro.distributed.compat import shard_map
    mesh = make_mesh((2, 4), ("data", "model"))

    # ---- 1. sharded MoE parity on a real multi-device mesh ----
    from repro.configs import CONFIGS
    from repro.distributed.api import use_mesh
    from repro.models import moe as M

    cfg = CONFIGS["kimi-k2-1t-a32b"].reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          dtype=jnp.bfloat16)
    y_ref, _ = M.apply_moe(p, cfg, x)
    with use_mesh(mesh):
        y_sm, _ = jax.jit(lambda p, x: M.apply_moe_sharded(p, cfg, x))(p, x)
    err = float(jnp.abs(y_sm.astype(jnp.float32)
                        - y_ref.astype(jnp.float32)).max())
    assert err < 0.06, f"sharded moe diverged: {err}"
    print("moe parity ok", err)

    # ---- 2. ring all-gather matmul == dense matmul ----
    from repro.distributed.collectives import ring_allgather_matmul
    d_in, d_out = 32, 16
    xs = jax.random.normal(jax.random.PRNGKey(2), (8, d_in))
    w = jax.random.normal(jax.random.PRNGKey(3), (d_in, d_out))
    w_sharded = jax.device_put(
        w, NamedSharding(mesh, P("model", None)))

    def f(x, w_shard):
        return ring_allgather_matmul(x, w_shard, "model")

    y = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(None, None), P("model", None)),
        out_specs=P(None, None), check_vma=False))(xs, w_sharded)
    np.testing.assert_allclose(np.asarray(y), np.asarray(xs @ w),
                               rtol=1e-4, atol=1e-4)
    print("ring matmul ok")

    # ---- 3. LSE-merged attention over seq-sharded KV ----
    from repro.distributed.collectives import lse_merge_attention
    b, h, s, hd = 2, 4, 32, 16
    q = jax.random.normal(jax.random.PRNGKey(4), (b, h, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, h, hd))
    valid = jnp.ones((b, s), bool)

    def merged(q, k, v, valid):
        return lse_merge_attention(q, k, v, "model", valid)

    out = jax.jit(shard_map(
        merged, mesh=mesh,
        in_specs=(P(), P(None, "model", None, None),
                  P(None, "model", None, None), P(None, "model")),
        out_specs=P(), check_vma=False))(q, k, v, valid)
    # reference (h == kvh here, so head h of q attends to head h of k/v)
    scores = jnp.einsum("bhqd,bshd->bhqs", q, k) / np.sqrt(hd)
    probs = jax.nn.softmax(scores, -1)
    want = jnp.einsum("bhqs,bshd->bhqd", probs, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("lse merge ok")

    # ---- 4. miniature dry-run: full train-step lower+compile on the mesh
    from repro.configs import SHAPES, ShapeConfig
    from repro.launch.steps import build_plan
    tiny_shape = ShapeConfig("tiny_train", seq_len=64, global_batch=8,
                             kind="train")
    plan = build_plan(CONFIGS["stablelm-1.6b"].reduced(), tiny_shape, mesh)
    compiled = plan.lower(mesh).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # older jax: one dict per device
        cost = cost[0]
    assert float(cost.get("flops", 0)) > 0
    print("mini dryrun ok")

    # ---- 5. cross-pod compressed all-reduce ----
    from repro.optim.grad_compress import (compress_init,
                                           crosspod_allreduce_compressed)
    mesh_p = make_mesh((2, 4), ("pod", "data"))
    g = {"w": jax.random.normal(jax.random.PRNGKey(7), (16,))}
    st = compress_init(g)

    def cp(g, r):
        st2 = type(st)(residual=r)
        out, _ = crosspod_allreduce_compressed(g, st2, "pod")
        return out

    got = jax.jit(shard_map(
        cp, mesh=mesh_p, in_specs=(P(), P()), out_specs=P(),
        check_vma=False))(g, st.residual)
    # psum of identical replicas / n == original (up to int8 quantization)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(g["w"]),
                               atol=0.05)
    print("compressed allreduce ok")

    # ---- 6. row-parallel Pallas SpMV across 8 devices ----
    from repro.core.generators import rmat_matrix
    from repro.core.partition import rowblock_balanced
    from repro.distributed.spmv import row_mesh, spmv_row_sharded
    csr = rmat_matrix(512, seed=9)
    xs = jnp.asarray(np.random.default_rng(9).normal(size=512)
                     .astype(np.float32))
    want = np.asarray(csr.to_dense()) @ np.asarray(xs)
    rmesh = row_mesh()
    assert rmesh.shape["shards"] == 8
    y8 = spmv_row_sharded(csr, xs, mesh=rmesh)
    np.testing.assert_allclose(np.asarray(y8), want, rtol=1e-4, atol=1e-4)
    yb = spmv_row_sharded(csr, xs, mesh=rmesh,
                          partition=rowblock_balanced(csr, 8))
    np.testing.assert_allclose(np.asarray(yb), want, rtol=1e-4, atol=1e-4)
    # fewer rows than devices: trailing shards get empty row slabs
    tiny = rmat_matrix(4, seed=0)
    yt = spmv_row_sharded(tiny, jnp.ones(4, jnp.float32), mesh=rmesh)
    np.testing.assert_allclose(
        np.asarray(yt), np.asarray(tiny.to_dense()) @ np.ones(4, np.float32),
        rtol=1e-4, atol=1e-4)
    print("row-parallel spmv ok")
    print("ALL MULTIDEVICE TESTS PASSED")
""")


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ALL MULTIDEVICE TESTS PASSED" in r.stdout, (
        f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}")
