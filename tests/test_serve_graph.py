"""Analytics serving: admission, coalescing, preemption, determinism.

The determinism tests are parametrized over BOTH engines (`serve` and
`serve_graph`): an identical request trace replayed twice must produce
identical schedules and identical preemption order -- and for the
analytics engine, bit-identical result values.
"""
import numpy as np
import pytest

from repro.core.generators import fd_matrix, rmat_matrix
from repro.graph.drivers import bfs, pagerank, sssp
from repro.serve import PoolConfig, Request, Scheduler
from repro.serve_graph import (AnalyticRequest, GraphEngine,
                               GraphEngineConfig)

N = 64


def _engine(**over):
    cfg = GraphEngineConfig(**{**dict(n_lanes=8, compile_queue_cap=4,
                                      compiles_per_step=1), **over})
    eng = GraphEngine(cfg)
    eng.register_graph("fd", fd_matrix(N, seed=3))
    eng.register_graph("rmat", rmat_matrix(N, seed=3))
    return eng


def _prime(eng, *pairs):
    """Compile (graph, analytic) plans up front so the scenario under
    test starts from a warm pool."""
    for gid, analytic in pairs:
        eng._compile_key(eng._derive(gid, analytic).key)


# ---------------------------------------------------------------------------
# engine correctness vs the blocking drivers
# ---------------------------------------------------------------------------

def test_engine_matches_blocking_drivers():
    eng = _engine()
    eng.submit(AnalyticRequest(0, "fd", "bfs", sources=(0, 5)))
    eng.submit(AnalyticRequest(1, "rmat", "pagerank",
                               params={"tol": 1e-6}))
    eng.submit(AnalyticRequest(2, "fd", "sssp", sources=(3,)))
    out = eng.run()
    fd, rmat = eng.graphs["fd"], eng.graphs["rmat"]
    np.testing.assert_array_equal(out[0].values, bfs(fd, [0, 5]).values)
    ref = pagerank(rmat, tol=1e-6)
    np.testing.assert_allclose(out[1].values[0], ref.values, rtol=1e-6)
    assert out[1].n_iters == ref.n_iters
    np.testing.assert_array_equal(out[2].values[0], sssp(fd, 3).values)


def test_engine_empty_sources_and_iteration_cap():
    eng = _engine()
    eng.submit(AnalyticRequest(0, "fd", "bfs", sources=()))
    eng.submit(AnalyticRequest(1, "rmat", "pagerank",
                               params={"tol": 0.0}, max_iters=3))
    out = eng.run()
    assert out[0].values.shape == (0, N) and out[0].converged
    assert out[0].n_iters == 0
    assert out[1].n_iters == 3 and not out[1].converged


def test_engine_rejects_malformed_requests():
    eng = _engine()
    with pytest.raises(KeyError, match="not registered"):
        eng.submit(AnalyticRequest(0, "nope", "bfs", sources=(0,)))
    with pytest.raises(ValueError, match="unknown analytic"):
        eng.submit(AnalyticRequest(1, "fd", "betweenness"))
    with pytest.raises(ValueError, match="out of range"):
        eng.submit(AnalyticRequest(2, "fd", "bfs", sources=(N + 5,)))
    with pytest.raises(ValueError, match="lanes"):
        eng.submit(AnalyticRequest(3, "fd", "bfs",
                                   sources=tuple(range(9))))
    with pytest.raises(ValueError, match="no sources"):
        eng.submit(AnalyticRequest(4, "fd", "connected_components",
                                   sources=(1,)))
    assert eng.submitted == 0 and eng.idle


# ---------------------------------------------------------------------------
# admission: warm pool vs bounded compile queue
# ---------------------------------------------------------------------------

def test_admission_warm_hits_skip_compile_queue():
    eng = _engine()
    _prime(eng, ("fd", "bfs"))
    eng.submit(AnalyticRequest(0, "fd", "bfs", sources=(0,)))   # warm
    eng.submit(AnalyticRequest(1, "rmat", "bfs", sources=(0,)))  # cold
    eng.step()
    s = eng.stats()
    assert s["warm_hits"] == 1 and s["cold_misses"] == 1
    # the warm request started iterating on the very first step
    assert (1, "admit", 0) in eng.scheduler.log
    eng.run()
    assert len(eng.results) == 2


def test_admission_backpressure_does_not_block_warm_requests():
    eng = _engine(compile_queue_cap=1)
    _prime(eng, ("fd", "bfs"))
    eng.submit(AnalyticRequest(0, "rmat", "bfs", sources=(0,)))     # cold
    eng.submit(AnalyticRequest(1, "rmat", "pagerank"))              # cold
    eng.submit(AnalyticRequest(2, "fd", "bfs", sources=(1,)))       # warm
    eng.step()
    s = eng.stats()
    # queue cap 1: request 1 is pushed back, but the warm request 2
    # passed it and was admitted this same step
    assert s["backpressure"] >= 1
    assert (1, "admit", 2) in eng.scheduler.log
    assert all(e[2] != 1 for e in eng.scheduler.log)
    out = eng.run()                  # back-pressure drains, everyone finishes
    assert sorted(out) == [0, 1, 2] and all(r.converged
                                            for r in out.values())


def test_admission_coalesces_duplicate_compiles():
    eng = _engine()
    for i in range(5):               # five misses on the same plan
        eng.submit(AnalyticRequest(i, "rmat", "bfs", sources=(i,)))
    eng.run()
    assert eng.plan_cache.stats()["compiles"] == 1
    assert len(eng.results) == 5


# ---------------------------------------------------------------------------
# coalescing: one execute_many per plan per step
# ---------------------------------------------------------------------------

def test_engine_coalesces_same_plan_requests():
    eng = _engine(n_lanes=16)
    _prime(eng, ("fd", "bfs"))
    for i in range(4):
        eng.submit(AnalyticRequest(i, "fd", "bfs", sources=(i, i + 8)))
    out = eng.run()
    total_iters = sum(r.n_iters for r in out.values())
    # 4 requests iterated together: far fewer SpMV dispatches than the
    # sum of per-request iterations
    assert eng.spmm_calls < total_iters
    assert eng.spmm_calls == max(r.n_iters for r in out.values())
    for i in range(4):
        np.testing.assert_array_equal(
            out[i].values, bfs(eng.graphs["fd"], [i, i + 8]).values)


# ---------------------------------------------------------------------------
# learned-predictor serving: scoring mode never changes results
# ---------------------------------------------------------------------------

def _run_fleet(**cfg_over):
    eng = _engine(**cfg_over)
    for i in range(4):
        eng.submit(AnalyticRequest(i, "fd" if i % 2 else "rmat", "bfs",
                                   sources=(i,)))
    eng.submit(AnalyticRequest(4, "rmat", "pagerank", params={"tol": 1e-6}))
    out = eng.run()
    return eng, {rid: (r.values.tobytes(), r.n_iters, r.converged)
                 for rid, r in sorted(out.items())}


def test_model_scored_serving_matches_oracle_bitwise():
    """predictor='model' (cost-model compiles, queue drained per step)
    must serve bit-identical results to the replay-scored oracle config
    -- scoring picks the plan, never what it computes."""
    em, dm = _run_fleet(reorder="auto", predictor="model",
                        compiles_per_step=None)
    eo, do = _run_fleet(reorder="auto", predictor="replay",
                        compiles_per_step=1)
    assert dm == do
    sm, so = em.plan_cache.stats(), eo.plan_cache.stats()
    assert sm["predictor_compiles"] == sm["compiles"] > 0
    assert sm["oracle_compiles"] == 0
    assert so["oracle_compiles"] == so["compiles"] > 0
    assert so["predictor_compiles"] == 0


def test_drain_compile_queue_admits_in_one_step():
    """compiles_per_step=None pairs with the learned fast path: every
    queued plan compiles the same step it is enqueued, so no cold
    request waits behind the per-step ration."""
    paced = _engine(reorder="auto", predictor="model", compiles_per_step=1)
    drain = _engine(reorder="auto", predictor="model",
                    compiles_per_step=None)
    for eng in (paced, drain):
        eng.submit(AnalyticRequest(0, "fd", "bfs", sources=(0,)))
        eng.submit(AnalyticRequest(1, "rmat", "bfs", sources=(0,)))
        eng.submit(AnalyticRequest(2, "fd", "sssp", sources=(1,)))
        eng.step()
    assert len(drain.admission.compile_q) == 0
    assert len(paced.admission.compile_q) > 0
    admitted = {e[2] for e in drain.scheduler.log if e[1] == "admit"}
    assert admitted == {0, 1, 2}
    out_p, out_d = paced.run(), drain.run()
    assert {r: out_d[r].values.tobytes() for r in out_d} == \
        {r: out_p[r].values.tobytes() for r in out_p}


# ---------------------------------------------------------------------------
# preemption: oldest delayed work evicts the youngest runner
# ---------------------------------------------------------------------------

def _preemption_scenario():
    eng = _engine(n_lanes=3, compile_queue_cap=4, max_iters_default=12)
    _prime(eng, ("fd", "pagerank"))
    # req 0 (oldest by id) pends on the LAST of three queued compiles;
    # meanwhile warm never-converging pagerank requests fill the pool.
    eng.submit(AnalyticRequest(10, "rmat", "bfs", sources=(0,)))
    eng.submit(AnalyticRequest(11, "rmat", "pagerank"))
    eng.submit(AnalyticRequest(0, "rmat", "sssp", sources=(0,)))
    for i in (1, 2, 3):
        eng.submit(AnalyticRequest(i, "fd", "pagerank",
                                   params={"tol": 0.0}))
    out = eng.run()
    return eng, out


def test_preemption_youngest_first_when_pool_exhausted():
    eng, out = _preemption_scenario()
    log = eng.scheduler.log
    preempts = [e for e in log if e[1] == "preempt"]
    assert preempts, "expected the delayed oldest request to preempt"
    # victims are always the youngest runners (warm ids 1-3 admitted
    # after reqs 10/11/0 arrived -> preempted in reverse-id order)
    assert preempts[0][2] == 3
    assert out[0].converged and out[0].restarts == 0
    # the victim restarted from scratch and still produced the capped run
    victim = out[preempts[0][2]]
    assert victim.restarts >= 1 and victim.n_iters == 12
    assert len(out) == 6


# ---------------------------------------------------------------------------
# determinism, parametrized over both engines
# ---------------------------------------------------------------------------

def _serve_trace():
    """Fixed request trace through the token-serving scheduler; returns
    the full schedule log (admissions, running sets, preemptions,
    finish order)."""
    s = Scheduler(PoolConfig(n_blocks=3, block_size=4, max_blocks_per_seq=4),
                  max_batch=2)
    arrivals = {0: [Request(req_id=0, prompt=[1] * 4, max_new_tokens=9)],
                1: [Request(req_id=1, prompt=[1] * 4, max_new_tokens=9),
                    Request(req_id=2, prompt=[1] * 4, max_new_tokens=4)]}
    log = []
    for step in range(60):
        for req in arrivals.get(step, ()):
            s.submit(req)
        if s.idle and step > max(arrivals):
            break
        s.tick()
        for slot in s.admit_waiting():
            log.append((step, "admit", slot.req.req_id))
            s.post_decode(slot, token=7)
        pre = s.pre_decode()
        log.append((step, "running", tuple(sl.req.req_id for sl in pre),
                    s.preemptions))
        for slot in pre:
            s.post_decode(slot, token=7)
    log.append(("finished", tuple(r.req_id for r in s.finished)))
    return log


def _serve_graph_trace():
    """Fixed request trace through the analytics engine; returns the
    schedule log plus a bit-exact digest of every result."""
    eng, out = _preemption_scenario()
    digest = {rid: (r.values.tobytes(), r.n_iters, r.converged, r.restarts,
                    r.admitted_step, r.finished_step)
              for rid, r in sorted(out.items())}
    stats = eng.stats()
    del stats["plan_cache"]          # compile_s is wall-clock time
    return [tuple(eng.scheduler.log), digest, stats]


@pytest.mark.parametrize("engine", ["serve", "serve_graph"])
def test_identical_traces_produce_identical_schedules(engine):
    runner = {"serve": _serve_trace, "serve_graph": _serve_graph_trace}[engine]
    assert runner() == runner()


def test_serve_graph_trace_exercises_preemption():
    """Guard: the shared determinism trace must actually cover the
    interesting events, or the test above pins nothing."""
    log = _serve_graph_trace()[0]
    events = {e[1] for e in log}
    assert {"admit", "preempt", "finish"} <= events
