"""TPU traffic model invariants (hardware-adaptation layer)."""
import pytest

from repro.core import traffic
from repro.core.formats import BELL
from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix


def test_colblock_beats_gather_always():
    for gen in (fd_matrix, rmat_matrix):
        csr = gen(1 << 12)
        g = traffic.gather_policy(csr)
        c = traffic.col_blocked_policy(csr)
        assert c.bytes_per_nnz < g.bytes_per_nnz
        assert c.roofline_gflops > g.roofline_gflops


def test_stream_policy_optimal_for_banded():
    csr = fd_matrix(1 << 12)
    s = traffic.stream_policy(csr, bandwidth=70)
    # theoretical floor: val+idx bytes per nnz = 8
    assert 8.0 <= s.bytes_per_nnz < 16.0


def test_bell_quality_tracks_density():
    csr_good = banded_matrix(1 << 12, 8)      # dense-ish blocks
    csr_bad = rmat_matrix(1 << 12)            # scattered blocks
    b_good = traffic.bell_policy(BELL.from_csr(csr_good).density(), csr_good)
    b_bad = traffic.bell_policy(BELL.from_csr(csr_bad).density(), csr_bad)
    assert b_good.roofline_gflops > b_bad.roofline_gflops


def test_roofline_never_exceeds_peak():
    csr = fd_matrix(1 << 10)
    for rep in (traffic.gather_policy(csr),
                traffic.col_blocked_policy(csr),
                traffic.stream_policy(csr, 40)):
        assert rep.roofline_gflops <= traffic.TPU_V5E.peak_flops_bf16 / 1e9


def test_spmv_is_memory_bound_on_v5e():
    """The paper's kernel stays bandwidth-bound on TPU too: even the best
    policy's arithmetic intensity is far below the v5e ridge point."""
    csr = fd_matrix(1 << 12)
    best = traffic.col_blocked_policy(csr)
    ridge = traffic.TPU_V5E.peak_flops_bf16 / traffic.TPU_V5E.hbm_bw
    assert best.arithmetic_intensity < ridge / 100
