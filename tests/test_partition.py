"""Partitioners: load balance + stripe reassembly property."""
import jax.numpy as jnp
import numpy as np
from _opt_deps import given, settings, st

from repro.core.formats import CSR
from repro.core.generators import rmat_matrix
from repro.core.partition import (col_stripes, rowblock_balanced,
                                  rowblock_equal, sort_rows_by_nnz)
from repro.core.spmv import spmv


def test_balanced_beats_equal_on_skewed():
    csr = rmat_matrix(2048, permute=False, seed=2)   # skewed rows
    eq = rowblock_equal(csr, 8)
    bal = rowblock_balanced(csr, 8)
    assert bal.imbalance() <= eq.imbalance() + 1e-9
    assert bal.imbalance() < 1.6


def test_rowblocks_cover_all_rows():
    csr = rmat_matrix(1024, seed=3)
    part = rowblock_balanced(csr, 7)
    assert part.starts[0] == 0 and part.starts[-1] == 1024
    assert (np.diff(part.starts) >= 0).all()
    assert part.nnz_per_part.sum() == csr.nnz


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([64, 128, 256]), stripes=st.integers(1, 6),
       seed=st.integers(0, 50))
def test_property_stripe_reassembly(n, stripes, seed):
    """y = sum_s A_s @ x_s must equal A @ x for any striping."""
    csr = rmat_matrix(n, seed=seed)
    x = np.random.default_rng(seed).normal(size=n).astype(np.float32)
    want = np.asarray(csr.to_dense()) @ x
    parts = col_stripes(csr, stripes)
    stripe_w = -(-n // stripes)
    got = np.zeros(n, np.float32)
    for s, sub in enumerate(parts):
        lo = s * stripe_w
        hi = min(lo + stripe_w, n)
        got += np.asarray(spmv(sub, jnp.asarray(x[lo:hi])))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rowblock_equal_exact_integer_split():
    """Regression: the float-linspace split could produce empty parts;
    the integer split guarantees row counts differ by at most one and
    caps parts at n_rows."""
    csr = rmat_matrix(64, seed=1)
    for parts in (1, 3, 7, 63, 64, 100):
        p = rowblock_equal(csr, parts)
        sizes = np.diff(p.starts)
        assert (sizes > 0).all(), parts
        assert sizes.max() - sizes.min() <= 1
        assert p.starts[0] == 0 and p.starts[-1] == 64
        assert p.n_parts == min(parts, 64)
        assert p.nnz_per_part.sum() == csr.nnz


def test_rowblock_balanced_imbalance_invariant_under_rcm():
    """RCM clusters heavy rows (bad for equal-row splits) but the nnz-CDF
    split must keep the load balanced on the permuted matrix too."""
    from repro import reorder

    csr = rmat_matrix(2048, permute=False, seed=2)
    rcm = reorder.rcm(csr).apply(csr)
    for parts in (4, 8, 16):
        bal = rowblock_balanced(rcm, parts)
        assert bal.imbalance() < 1.6, parts
        assert bal.imbalance() <= rowblock_equal(rcm, parts).imbalance() + 1e-9
        assert bal.nnz_per_part.sum() == rcm.nnz


def test_sort_rows_by_nnz_permutation_correct():
    csr = rmat_matrix(256, permute=False, seed=4)
    sorted_csr, perm = sort_rows_by_nnz(csr)
    lengths = sorted_csr.row_lengths()
    assert (np.diff(lengths) <= 0).all()          # descending
    x = np.random.default_rng(0).normal(size=256).astype(np.float32)
    y_perm = np.asarray(spmv(sorted_csr, jnp.asarray(x)))
    y = np.asarray(spmv(csr, jnp.asarray(x)))
    np.testing.assert_allclose(y_perm, y[perm], rtol=1e-4, atol=1e-4)
