"""Quickstart: the paper in two minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Generate the paper's two matrix families (FD 9-point stencil, R-MAT).
2. Measure their structure (the quantity the paper shows determines
   performance).
3. Reproduce the paper's five metrics at one size (Sandy Bridge model).
4. Show the TPU adaptation: traffic per placement policy, and the
   structure-aware dispatcher picking the right format + kernel.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (analyze, auto_format, fd_matrix, rmat_matrix, spmv,
                        traffic)
from repro.core.cache_model import analytic_metrics
from repro.core.formats import BELL

N = 1 << 14

print("=== 1. the paper's matrices ===")
fd = fd_matrix(N)
rm = rmat_matrix(N)
print(f"FD    : {fd.n_rows} rows, {fd.nnz} nnz ({fd.nnz/fd.n_rows:.1f}/row)")
print(f"R-MAT : {rm.n_rows} rows, {rm.nnz} nnz ({rm.nnz/rm.n_rows:.1f}/row)")

print("\n=== 2. structure is the variable ===")
for name, m in (("FD", fd), ("R-MAT", rm)):
    print(f"{name:6}: {analyze(m).summary()}")

print("\n=== 3. the paper's five metrics (Sandy Bridge model, 16 threads) ===")
for name, m in (("FD", fd), ("R-MAT", rm)):
    met = analytic_metrics(m, threads=16)
    print(f"{name:6}: L2={met.l2_miss_rate:6.2f}/kinst  "
          f"L3={met.l3_miss_rate:5.2f}/kinst  "
          f"pf={met.prefetch_miss_rate:5.2f}  "
          f"stall={met.l2_stall_frac:4.2f}  "
          f"GFLOPS={met.gflops:6.2f}")

print("\n=== 4. TPU adaptation: bytes moved per placement policy ===")
for name, m in (("FD", fd), ("R-MAT", rm)):
    rep = analyze(m)
    print(f"{name}:")
    print("  " + traffic.gather_policy(m).summary())
    print("  " + traffic.stream_policy(m, rep.bandwidth_p95).summary())
    print("  " + traffic.col_blocked_policy(m).summary())
    print("  " + traffic.bell_policy(BELL.from_csr(m).density(), m).summary())

print("\n=== 5. structure-aware dispatch (detect -> format -> kernel) ===")
x = jnp.asarray(np.random.default_rng(0).normal(size=N).astype(np.float32))
for name, m in (("FD", fd), ("R-MAT", rm)):
    fmt = auto_format(m)
    y = spmv(fmt, x)
    y_ref = spmv(m, x)
    err = float(jnp.abs(y - y_ref).max())
    print(f"{name:6}: dispatched to {type(fmt).__name__:5} "
          f"(max err vs CSR ref: {err:.2e})")

print("\nDone. Next: benchmarks (python -m benchmarks.run), training "
      "(python -m repro.launch.train --reduced), serving "
      "(python -m repro.launch.serve --reduced).")
