"""Batched serving with continuous batching + paged-KV admission control.

    PYTHONPATH=src python examples/serve_lm.py

Submits a burst of mixed-length requests against a 4-slot engine whose KV
pool is deliberately undersized -- exercising admission control and
(depending on trace) preemption, while per-slot cache positions keep
mixed-depth batches correct.
"""
from repro.launch import serve as serve_mod

out, stats = serve_mod.main([
    "--arch", "stablelm-1.6b", "--reduced",
    "--requests", "12",
    "--max-new", "16",
    "--max-batch", "4",
    "--max-context", "128",
    "--block-size", "16",
])

assert len(out) == 12, "all requests must complete"
print(f"\n[example] completed {len(out)} requests; "
      f"pool peak utilization seen via stats={stats}")
