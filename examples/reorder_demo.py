"""Reordering demo: recover FD-like structure from an unstructured matrix.

    PYTHONPATH=src python examples/reorder_demo.py

1. Scramble a banded matrix and watch RCM recover the band (and with it,
   DIA eligibility in `auto_format`).
2. Apply every registered strategy to an R-MAT matrix and compare the
   structure metrics the paper ties to performance (before/after).
3. Replay the x-access traces through the telemetry hierarchy: how much
   of the FD-vs-R-MAT first-level miss gap does each permutation close,
   alone and on top of PR-1's stream buffers?
4. Correctness: reorder-then-multiply-then-inverse-scatter returns the
   same y as the unpermuted multiply.
"""
import numpy as np
import jax.numpy as jnp

from repro import reorder
from repro.core import analyze, auto_format, banded_matrix, rmat_matrix, spmv
from repro.core.structure import analyze_reorder
from repro.telemetry.hierarchy import HierarchySpec
from repro.telemetry.report import reorder_gap_report
from repro.telemetry.sweep import reorder_sweep

N = 1 << 11

print("=== 1. RCM un-scrambles a banded matrix ===")
banded = banded_matrix(N, bandwidth=8, seed=0)
p = np.random.default_rng(0).permutation(N)
scrambled = reorder.Reordering(row_perm=p, col_perm=p,
                               strategy="scramble").apply(banded)
r = reorder.rcm(scrambled)
print(f"bandwidth: original {analyze(banded).bandwidth}, "
      f"scrambled {r.stats['bandwidth_before']}, "
      f"after RCM {r.stats['bandwidth_after']}")
print(f"auto_format: scrambled -> {type(auto_format(scrambled)).__name__}, "
      f"with RCM -> {type(auto_format(scrambled, reordering=r)).__name__}")

print("\n=== 2. structure before/after, R-MAT ===")
rm = rmat_matrix(N)
for name, strategy in reorder.STRATEGIES.items():
    if name == "none":
        continue
    print(analyze_reorder(rm, strategy(rm)).summary())

print("\n=== 3. miss-rate gap closed per strategy (trace-driven) ===")
scaled = dict(l2_bytes=32 * 1024, l3_bytes=256 * 1024)
points = reorder_sweep(
    log2ns=(11,),
    mechanisms={"baseline": HierarchySpec(**scaled),
                "stream-buffers": HierarchySpec(stream_buffers=8,
                                                stream_depth=4, **scaled)})
print(reorder_gap_report(points))

print("\n=== 4. correctness under reordering ===")
x = jnp.asarray(np.random.default_rng(1).normal(size=N).astype(np.float32))
y_ref = spmv(rm, x)
for name, strategy in reorder.STRATEGIES.items():
    rr = strategy(rm)
    y = spmv(rr.apply(rm), x, reordering=rr)
    err = float(jnp.abs(y - y_ref).max())
    print(f"{name:18}: max |y - y_ref| = {err:.2e}")

print("\nDone. Full sweep: PYTHONPATH=src python -m benchmarks.reorder_bench")
