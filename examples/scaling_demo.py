"""Multithreaded scaling demo: the paper's title axis, end to end.

    PYTHONPATH=src python examples/scaling_demo.py

1. Partition an R-MAT matrix across 4 threads and replay it through the
   shared-LLC engine: per-thread counters, load imbalance, time model.
2. Speedup curves: FD vs R-MAT across the thread axis at the scaled
   geometry, plus how much of the gap RCM closes.
3. Run the same row partition on real devices via the shard_map
   Pallas path and check the sharded product bit-for-bit against the
   single-kernel multiply.
"""
import numpy as np
import jax.numpy as jnp

from repro import reorder
from repro.core import fd_matrix, rmat_matrix, spmv
from repro.core.partition import rowblock_balanced
from repro.core.cache_model import SANDY_BRIDGE
from repro.distributed import row_mesh, spmv_row_sharded
from repro.parallel import ParallelSpec, simulate_parallel
from repro.telemetry import events as ev
from repro.telemetry.report import scaling_gap_report, scaling_report
from repro.telemetry.sweep import scaling_sweep

N = 1 << 11
SPEC = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)

print("=== 1. one partitioned replay, 4 threads ===")
rm = rmat_matrix(N)
part = rowblock_balanced(rm, 4)
run, m = simulate_parallel(rm, part, SANDY_BRIDGE, SPEC, sweeps=2)
print(f"imbalance {part.imbalance():.3f}, time {m.time_s*1e6:.1f} us "
      f"(latency {m.lat_time_s*1e6:.1f}, bandwidth {m.bw_time_s*1e6:.1f}), "
      f"DRAM util {m.dram_util:.2f}")
for t, c in enumerate(run.counters):
    print(f"  thread {t}: {c[ev.ACCESS]:6d} accesses, "
          f"L2 miss {c[ev.L2_DEMAND_MISS]:5d}, "
          f"LLC miss {c[ev.L3_DEMAND_MISS]:4d}, "
          f"L2 MPKI {m.l2_mpki[t]:.2f}")

print("\n=== 2. FD vs R-MAT speedup, and the RCM answer ===")
pts = scaling_sweep(log2ns=(11,), threads_list=(2, 4, 8), spec=SPEC,
                    partition="balanced", sweeps=2,
                    reorderings={"none": None, "rcm": reorder.rcm})
print(scaling_report(pts))
print()
print(scaling_gap_report(pts))

print("\n=== 3. the same partition on real devices (shard_map + Pallas) ===")
mesh = row_mesh()
fd = fd_matrix(N)
x = jnp.asarray(np.random.default_rng(0).normal(size=N).astype(np.float32))
y_sharded = spmv_row_sharded(fd, x, mesh=mesh)
y_ref = spmv(fd, x)
err = float(jnp.abs(y_sharded - y_ref).max())
print(f"{mesh.shape['shards']} device(s), max |sharded - single| = {err:.2e}")
assert err < 1e-4
