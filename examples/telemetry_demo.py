"""Telemetry walkthrough: measure, then evaluate the paper's §V fixes.

    PYTHONPATH=src python examples/telemetry_demo.py

1. Replay the exact SpMV address trace (paper Fig. 2) for an FD and an
   R-MAT matrix through the default hierarchy and print the topdown tree
   -- the "why is R-MAT slow" answer in one picture.
2. Attach the §V candidate mechanisms (victim cache + stream buffers) and
   show how much of the FD-vs-R-MAT gap they close.
"""
from repro.core.cache_model import SANDY_BRIDGE
from repro.core.generators import fd_matrix, rmat_matrix
from repro.telemetry import topdown
from repro.telemetry.hierarchy import HierarchySpec
from repro.telemetry.report import gap_report, to_markdown
from repro.telemetry.sweep import run_sweep

N_LOG2 = 13

print("=== 1. topdown: where do the cycles go? ===")
# scaled geometry (L2=32K, L3=256K) puts this size in the paper's >L2
# regime while keeping the pure-Python trace replay quick
spec = HierarchySpec(l2_bytes=32 * 1024, l3_bytes=256 * 1024)
for name, gen in (("FD", fd_matrix), ("R-MAT", rmat_matrix)):
    csr = gen(1 << N_LOG2)
    counters = spec.instantiate(SANDY_BRIDGE).run_spmv(
        csr, SANDY_BRIDGE, sweeps=2)
    print(f"\n--- {name} ---")
    print(topdown.topdown_tree(counters, SANDY_BRIDGE, csr.nnz).render())

print("\n=== 2. do the paper's §V mechanisms close the gap? ===")
mechanisms = {
    "baseline": spec,
    "victim-cache": HierarchySpec(l2_bytes=32 * 1024, l3_bytes=256 * 1024,
                                  victim_entries=64),
    "combined": HierarchySpec(l2_bytes=32 * 1024, l3_bytes=256 * 1024,
                              victim_entries=64, stream_buffers=8),
}
points = run_sweep(log2ns=(N_LOG2,), mechanisms=mechanisms, sweeps=2)
print(to_markdown(points))
print()
print(gap_report(points))
