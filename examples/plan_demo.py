"""Compile-once SpMV demo: plans, the cache, and amortized traffic.

    PYTHONPATH=src python examples/plan_demo.py

1. Compile an R-MAT matrix into a `SpmvPlan`: candidate reorderings
   scored by predicted contended-LLC throughput, winning format frozen,
   Pallas layout pre-padded.
2. Repeated traffic: cached `execute`, batched `execute_many` (SpMM),
   and an amortized `power_iteration` -- timed against cold compiles.
3. Serialize the plan through `repro.checkpoint` and restore it in a
   fresh cache, as a restarted serving process would.
"""
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro import plan
from repro.core import rmat_matrix

N = 1 << 11
rm = rmat_matrix(N, seed=0)
x = jnp.asarray(np.random.default_rng(0).normal(size=N).astype(np.float32))

print("=== 1. compile once ===")
t0 = time.perf_counter()
p = plan.get_plan(rm, threads=8, reorder="auto", predictor="analytic")
p.execute(x).block_until_ready()
cold = time.perf_counter() - t0
print(p.summary())
for label, score in p.predicted.items():
    print(f"  candidate {label:>5s}: {score['gflops']:.2f} predicted GF "
          f"({score['predictor']})")
print(f"cold compile+execute: {cold*1e3:.1f} ms, "
      f"phases {dict((k, round(v, 3)) for k, v in p.compile_stats.items())}")

print("\n=== 2. amortized traffic ===")
t0 = time.perf_counter()
for _ in range(8):
    p.execute(x).block_until_ready()
warm = (time.perf_counter() - t0) / 8
print(f"warm execute: {warm*1e3:.2f} ms/call "
      f"({warm/cold:.1%} of cold -> {cold/warm:.0f}x amortization)")

X = jnp.stack([x] * 8)
Y = p.execute_many(X)                      # batched SpMM path
print(f"execute_many: {Y.shape} in one vmapped multiply")

lam, _ = p.power_iteration(jnp.ones((N,), jnp.float32), n_iters=16)
print(f"power_iteration over the cached plan: lambda ~ {float(lam):.3f}")
print(f"cache stats: {plan.DEFAULT_CACHE.stats()}")

print("\n=== 3. a plan survives restart ===")
with tempfile.TemporaryDirectory() as d:
    plan.save_plan(p, d)
    restored, step = plan.load_plan(d)
    same = np.array_equal(np.asarray(p.execute(x)),
                          np.asarray(restored.execute(x)))
print(f"restored step {step}: {restored.summary()}; "
      f"bit-identical execute: {same}")
