"""Graph analytics on semiring SpMV plans (paper §I motivation): PageRank,
BFS, SSSP, and connected components on structured vs unstructured graphs.

    PYTHONPATH=src python examples/graph_analytics.py

Each analytic compiles ONE `SpmvPlan` under its semiring (plus-times /
or-and / min-plus) and iterates `execute` to convergence, so the
per-iteration cost is exactly one SpMV's memory traffic -- the paper's
point, applied end-to-end: the structure-driven gap compounds across
every iteration of the analytic (see `benchmarks.graph_bench` for the
measured table).
"""
import time

import numpy as np

from repro.core import analyze, fd_matrix, rmat_matrix
from repro.graph import bfs, connected_components, pagerank, sssp
from repro.graph.telemetry import iteration_summaries

N = 1 << 10

for name, gen in (("FD", fd_matrix), ("R-MAT", rmat_matrix)):
    m = gen(N)
    rep = analyze(m)
    print(f"=== {name}: {rep.kind}, {m.nnz} nnz ===")
    hub = int(np.argmax(np.diff(np.asarray(m.indptr))))

    t0 = time.time()
    pr = pagerank(m, r0=np.random.default_rng(0).uniform(0.5, 1.5, N))
    print(f"  pagerank  : {time.time()-t0:5.2f}s  iters={pr.n_iters:3d}  "
          f"mass={float(pr.values.sum()):.4f}  via {pr.plan.summary()}")

    t0 = time.time()
    b = bfs(m, hub)
    reached = int(np.isfinite(b.values).sum())
    print(f"  bfs       : {time.time()-t0:5.2f}s  levels={b.n_iters:3d}  "
          f"reached={reached}/{N}  via {b.plan.summary()}")

    # generator weights are uniform(0.5, 1.5) -- already valid distances
    t0 = time.time()
    s = sssp(m, hub)
    finite = np.isfinite(s.values)
    print(f"  sssp      : {time.time()-t0:5.2f}s  iters={s.n_iters:3d}  "
          f"max dist={float(s.values[finite].max()):.2f}  "
          f"via {s.plan.summary()}")

    t0 = time.time()
    cc = connected_components(m)
    ncomp = len(set(cc.values.astype(int)))
    print(f"  components: {time.time()-t0:5.2f}s  iters={cc.n_iters:3d}  "
          f"n={ncomp}")

    # per-iteration cache view of the BFS run, from the plan's memoized
    # trace: iteration 1 is cold, the rest show what stays resident
    sums = iteration_summaries(b.plan, b.n_iters)
    print(f"  bfs L2 MPKI: cold={sums[0].l2_mpki:.3f}  "
          f"warm={sums[-1].l2_mpki:.3f}  over {b.n_iters} iterations")
