"""Graph analytics on SpMV (paper §I motivation): PageRank and the dominant
eigenvector via power iteration, on structured vs unstructured graphs.

    PYTHONPATH=src python examples/graph_analytics.py

SpMV dominates both analytics' runtime, so the structure-aware dispatch is
what decides end-to-end throughput -- the paper's point, applied.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import analyze, auto_format, fd_matrix, rmat_matrix
from repro.core.spmv import pagerank, power_iteration, spmv

N = 1 << 13

for name, gen in (("FD", fd_matrix), ("R-MAT", rmat_matrix)):
    m = gen(N)
    rep = analyze(m)
    print(f"=== {name}: {rep.kind}, {m.nnz} nnz ===")

    # PageRank (network anomaly pipelines run this repeatedly)
    t0 = time.time()
    r = pagerank(m, n_iters=24)
    r.block_until_ready()
    print(f"  pagerank  : {time.time()-t0:5.2f}s   "
          f"mass={float(r.sum()):.4f}  top={float(r.max()):.3e}")

    # Dominant eigenvalue via repeated SpMV on the dispatched format
    fmt = auto_format(m, rep)
    x0 = jnp.ones((N,), jnp.float32) / np.sqrt(N)
    t0 = time.time()
    lam, v = power_iteration(fmt, x0, n_iters=24)
    v.block_until_ready()
    print(f"  power-iter: {time.time()-t0:5.2f}s   "
          f"lambda~{float(lam):8.3f}  via {type(fmt).__name__}")

    # residual check: ||A v - lam v|| / ||lam v||
    av = spmv(m, v)
    res = float(jnp.linalg.norm(av - lam * v) / jnp.linalg.norm(lam * v))
    print(f"  eig residual: {res:.3e}")
