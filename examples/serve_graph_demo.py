"""Analytics serving demo: a mixed FD/R-MAT request stream through the
continuous-batching engine.

    PYTHONPATH=src python examples/serve_graph_demo.py

Registers a small fleet of structured (FD) and unstructured (R-MAT)
graphs, fires a seeded stream of BFS / SSSP / PageRank requests at the
`repro.serve_graph` engine, and prints what serving at scale looks like
on top of the compile-once plan pipeline:

  * per-analytic latency percentiles, split by matrix family -- R-MAT's
    warm per-iteration penalty (the paper's structure gap) surfaces as
    the serving tail;
  * the plan-cache hit rate: after the first request per (graph,
    analytic) compiles, everything else rides warm plans, and dozens of
    concurrent sources on one graph coalesce into single `execute_many`
    batches per step.
"""
import numpy as np

from repro.core.generators import fd_matrix, rmat_matrix
from repro.serve_graph import (AnalyticRequest, GraphEngine,
                               GraphEngineConfig)
from repro.telemetry import plan_cache_report

N = 1 << 8
N_GRAPHS = 6          # per family
N_REQUESTS = 150

eng = GraphEngine(GraphEngineConfig(n_lanes=128, compiles_per_step=2))
for i in range(N_GRAPHS):
    eng.register_graph(f"fd{i}", fd_matrix(N, seed=10 + i))
    eng.register_graph(f"rmat{i}", rmat_matrix(N, seed=20 + i))
gids = sorted(eng.graphs)

rng = np.random.default_rng(0)
# arrive in waves: the first wave compiles the fleet's plans, later
# waves ride the warm pool
for wave in range(0, N_REQUESTS, 30):
    for rid in range(wave, min(wave + 30, N_REQUESTS)):
        gid = gids[int(rng.integers(len(gids)))]
        analytic = ("bfs", "sssp", "pagerank")[int(rng.integers(3))]
        if analytic == "pagerank":
            req = AnalyticRequest(rid, gid, "pagerank",
                                  params={"tol": 1e-5}, max_iters=64)
        else:
            sources = tuple(int(s) for s in
                            rng.choice(N, size=int(rng.integers(1, 4)),
                                       replace=False))
            req = AnalyticRequest(rid, gid, analytic, sources=sources)
        eng.submit(req)
    for _ in range(8):
        eng.step()

results = eng.run()
stats = eng.stats()

print(f"=== served {stats['finished']} requests in {stats['steps']} engine "
      f"steps ({stats['spmm_calls']} coalesced SpMV dispatches, "
      f"max {stats['max_running']} running) ===\n")

print(f"{'analytic':>10s} {'family':>6s} {'n':>4s} "
      f"{'p50':>5s} {'p95':>5s} {'p99':>5s}   latency in engine steps")
for analytic in ("bfs", "sssp", "pagerank"):
    for fam in ("fd", "rmat"):
        lat = [r.latency_steps for r in results.values()
               if r.analytic == analytic and r.graph_id.startswith(fam)]
        if not lat:
            continue
        p50, p95, p99 = (np.percentile(lat, q) for q in (50, 95, 99))
        print(f"{analytic:>10s} {fam:>6s} {len(lat):>4d} "
              f"{p50:>5.1f} {p95:>5.1f} {p99:>5.1f}")

print(f"\nadmission: {stats['warm_hits']} warm hits, "
      f"{stats['cold_misses']} cold misses "
      f"(hit rate {stats['admission_hit_rate']:.1%}), "
      f"{stats['preemptions']} preemptions\n")
print(plan_cache_report(eng.plan_cache.stats()))
