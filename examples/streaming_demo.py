"""Streaming demo: an edge stream interleaved with served analytics.

    PYTHONPATH=src python examples/streaming_demo.py

Registers one R-MAT graph, serves PageRank and SSSP against it, and
feeds the engine a stream of edge-insert batches between queries.  What
to watch:

  * small batches land as **overlays**: the resident plan keeps serving,
    a chained-fingerprint cache entry adds an O(delta) COO pass, and the
    next request is a warm hit -- no recompile on the request path;
  * a batch past the staleness budget (or an edge *delete* against a
    min-plus analytic, which no overlay can express) forces a **replan**:
    the serving key retires immediately, one background compile of the
    materialized matrix is parked on the admission queue, and the new
    plan swaps in atomically when it lands;
  * requests already iterating when a mutation arrives are re-bound (or
    migrated through admission) and **warm-started** from their pre-delta
    state where the analytic's algebra allows it.

The closing report shows the plan cache's streaming counters: overlays
installed, atomic swaps, and delta-forced recompiles.
"""
import numpy as np

from repro.core.generators import rmat_matrix
from repro.serve_graph import (AnalyticRequest, GraphEngine,
                               GraphEngineConfig, GraphMutation)
from repro.telemetry import plan_cache_report

N = 1 << 8
rng = np.random.default_rng(0)

eng = GraphEngine(GraphEngineConfig(n_lanes=32, staleness_budget=0.05))
eng.register_graph("g", rmat_matrix(N, seed=3))


def fresh_edges(k, weight=1.0, max_degree=None):
    """k absent off-diagonal coordinates of the engine's current graph.

    `max_degree` caps the source vertex's out-degree: a one-edge insert
    costs ~2*degree+1 entries in the pagerank operand (the whole row
    renormalizes), so an edge out of a hub can blow the staleness budget
    all by itself -- exactly the amplification the lifecycle's per-plan
    `actions` make visible."""
    adj = eng.graphs["g"]
    indptr = np.asarray(adj.indptr)
    deg = np.diff(indptr)
    present = set(zip(np.repeat(np.arange(N), np.diff(indptr)).tolist(),
                      np.asarray(adj.indices).tolist()))
    out = []
    while len(out) < k:
        r, c = int(rng.integers(N)), int(rng.integers(N))
        if max_degree is not None and deg[r] > max_degree:
            continue
        if r != c and (r, c) not in present and \
                (r, c) not in {(a, b) for a, b, _ in out}:
            out.append((r, c, weight))
    return tuple(out)


rid = 0
# prime the fleet: one pagerank + one sssp compile the two plans
for analytic, sources in (("pagerank", ()), ("sssp", (0,))):
    eng.submit(AnalyticRequest(rid, "g", analytic, sources=sources,
                               params={"tol": 1e-5} if sources == () else {},
                               max_iters=64))
    rid += 1
eng.run()

# a stream of small batches out of low-degree vertices: each lands as
# an overlay on both plans, each query stays a warm hit
for batch in range(3):
    eng.submit(GraphMutation(1000 + batch, "g",
                             inserts=fresh_edges(1, max_degree=8)))
    eng.submit(AnalyticRequest(rid, "g", "sssp", sources=(0,)))
    rid += 1
    eng.run()

# one oversized batch: past the 5% budget -> background replan + swap
big = fresh_edges(int(0.10 * eng.graphs["g"].nnz))
eng.submit(GraphMutation(1100, "g", inserts=big))
eng.submit(AnalyticRequest(rid, "g", "pagerank", params={"tol": 1e-5},
                           max_iters=64))
rid += 1
eng.run()

print("=== mutation lifecycle ===")
for mid in sorted(eng.mutation_results):
    res = eng.mutation_results[mid]
    acts = ", ".join(f"{a}:{v}" for a, v in sorted(res.actions.items()))
    print(f"batch {mid}: {res.delta_nnz} adjacency edges @ step "
          f"{res.applied_step} -> {acts or 'no derived plans yet'}")

stats = eng.stats()
pc = stats["plan_cache"]
print(f"\n{stats['finished']} analytics served across "
      f"{stats['mutations_applied']} mutations: "
      f"{pc['overlays']} overlays installed, {pc['swaps']} atomic swaps, "
      f"{pc['delta_recompiles']} delta-forced recompiles")
assert pc["overlays"] >= 1 and pc["delta_recompiles"] >= 1
print()
print(plan_cache_report(eng.plan_cache.stats(), title="plan cache, lifetime"))
