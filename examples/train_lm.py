"""End-to-end LM training with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Drives launch.train on a reduced StableLM config: deterministic synthetic
data, AdamW + cosine schedule, checkpoints every 50 steps, and an injected
crash at step ~60% through -- the Supervisor restores from the last
committed checkpoint and replays data deterministically, finishing the run.
"""
import argparse
import shutil
import tempfile

from repro.launch import train as train_mod

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="stablelm-1.6b")
args = ap.parse_args()

ckpt = tempfile.mkdtemp(prefix="repro_train_lm_")
try:
    losses = train_mod.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--lr", "1e-3",
        "--ckpt-dir", ckpt,
        "--ckpt-every", "50",
        "--log-every", "25",
        "--fail-at-step", str(int(args.steps * 0.6)),
    ])
    first = losses[0][1]
    last = losses[-1][1]
    print(f"\n[example] loss {first:.3f} -> {last:.3f} over "
          f"{args.steps} steps (crash survived at step "
          f"{int(args.steps*0.6)})")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
