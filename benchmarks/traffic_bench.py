"""TPU traffic model: structured vs unstructured SpMV under each policy.

The hardware-adaptation table (DESIGN.md §2) made quantitative: bytes/nnz,
arithmetic intensity and bandwidth-roofline GFLOP/s on v5e for

    gather     per-nonzero random DMA (naive CPU port -- the pathology)
    stream     DIA banded streaming   (FD fast path)
    col-block  column stripes pinned in VMEM (paper P2+P3)
    bell       blocked-ELL tile gathers (unstructured fast path)

across matrix structures.  The headline: restructuring recovers ~100x of
the gather policy's lost intensity for unstructured matrices -- the paper's
conclusion ("structure determines performance, so restructure") as TPU
numbers.
"""
from __future__ import annotations

import numpy as np

from repro.core import traffic
from repro.core.formats import BELL
from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix
from repro.core.structure import analyze

from .common import emit


def policy_table(n: int = 1 << 16) -> str:
    rows = []
    for name, gen in (("fd", fd_matrix), ("rmat", rmat_matrix),
                      ("banded256", lambda m: banded_matrix(m, 256))):
        csr = gen(n)
        rep = analyze(csr)
        reports = [
            traffic.gather_policy(csr),
            traffic.stream_policy(csr, rep.bandwidth_p95),
            traffic.col_blocked_policy(csr),
            traffic.bell_policy(BELL.from_csr(csr).density(), csr),
        ]
        for r in reports:
            rows.append([name, rep.kind, r.policy, r.bytes_per_nnz,
                         r.arithmetic_intensity, r.roofline_gflops,
                         r.x_reload_factor])
    return emit(rows, ["matrix", "structure", "policy", "bytes_per_nnz",
                       "arith_intensity", "v5e_gflops", "x_reload"],
                "traffic_bench: HBM<->VMEM bytes per policy (v5e roofline)")


def structure_sweep(n: int = 1 << 15) -> str:
    """Bandwidth knob: FD-like -> R-MAT-like, col-block vs gather gap."""
    rows = []
    for bw in (8, 64, 512, 4096, n // 2):
        csr = banded_matrix(n, bw)
        rep = analyze(csr)
        g = traffic.gather_policy(csr)
        c = traffic.col_blocked_policy(csr)
        rows.append([bw, rep.kind, rep.stream_servable,
                     g.roofline_gflops, c.roofline_gflops,
                     c.roofline_gflops / max(g.roofline_gflops, 1e-9)])
    return emit(rows, ["bandwidth", "detected_kind", "stream_servable",
                       "gather_gflops", "colblock_gflops", "speedup"],
                "structure_sweep: restructuring win vs matrix bandwidth")


def main() -> None:
    policy_table()
    structure_sweep()


if __name__ == "__main__":
    main()
