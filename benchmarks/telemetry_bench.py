"""Paper §V evaluation: does smarter caching close the FD vs R-MAT gap?

Two tables, both produced by the telemetry subsystem
(`repro.telemetry`), each at >= 3 sizes for FD and R-MAT:

  1. headline -- baseline hierarchy at the machine's real geometry:
     reproduces the cache_model headline (R-MAT L2 demand-miss rate >> FD)
     with the trace-driven simulator.
  2. mechanisms -- the §V candidates (victim cache / miss cache / stream
     buffers / combined) at a working-set-scaled geometry (the
     SimpleScalar-study methodology: shrink the caches so the Python-
     tractable trace sizes sit in the paper's >L2/>L3 regime), plus the
     gap report: estimated-GFLOPS FD/R-MAT ratio per mechanism and the
     fraction of the baseline gap each one closes.

Invoked by `benchmarks.run` (section name: telemetry) or directly:

    PYTHONPATH=src python -m benchmarks.telemetry_bench [--fast]
"""
from __future__ import annotations

from repro.telemetry.hierarchy import HierarchySpec
from repro.telemetry.report import gap_report, to_csv, to_markdown
from repro.telemetry.sweep import run_sweep

from . import common

# Scaled geometry for the mechanism table (see module docstring).
SCALED_L2 = 32 * 1024
SCALED_L3 = 256 * 1024

SCALED_MECHANISMS = {
    "baseline": HierarchySpec(l2_bytes=SCALED_L2, l3_bytes=SCALED_L3),
    "victim-cache": HierarchySpec(l2_bytes=SCALED_L2, l3_bytes=SCALED_L3,
                                  victim_entries=64),
    "miss-cache": HierarchySpec(l2_bytes=SCALED_L2, l3_bytes=SCALED_L3,
                                miss_entries=64),
    "stream-buffers": HierarchySpec(l2_bytes=SCALED_L2, l3_bytes=SCALED_L3,
                                    stream_buffers=8, stream_depth=4),
    "combined": HierarchySpec(l2_bytes=SCALED_L2, l3_bytes=SCALED_L3,
                              victim_entries=64, stream_buffers=8,
                              stream_depth=4),
}


def _sizes(shift: int = 0):
    hi = min(common.EMPIRICAL_MAX_LOG2, 16) - shift
    return (hi - 4, hi - 2, hi)             # >= 3 sizes, largest > L2


def headline(log2ns=None) -> str:
    pts = run_sweep(
        log2ns=log2ns or _sizes(),
        mechanisms={"baseline": HierarchySpec()}, sweeps=2,
        workers=common.WORKERS,
        ckpt_dir=(f"{common.SWEEP_CKPT}/telemetry-headline"
                  if common.SWEEP_CKPT else None))
    return to_csv(pts, title="telemetry headline: default hierarchy "
                             "(machine geometry), trace-driven")


def mechanisms(log2ns=None) -> str:
    # the scaled geometry reaches the paper's >L2/>L3 regime two sizes
    # earlier, so the 5x-mechanism grid can stop at 2^14
    pts = run_sweep(log2ns=log2ns or _sizes(shift=2),
                    mechanisms=SCALED_MECHANISMS, sweeps=2,
                    workers=common.WORKERS,
                    ckpt_dir=(f"{common.SWEEP_CKPT}/telemetry-mechanisms"
                              if common.SWEEP_CKPT else None))
    out = [to_csv(pts, title="telemetry mechanisms: paper §V candidates "
                             "(scaled geometry L2=32K L3=256K)"),
           "", "## topdown summary (markdown)", to_markdown(pts),
           "", gap_report(pts)]
    return "\n".join(out)


def main() -> None:
    print(headline())
    print()
    print(mechanisms())


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="cap trace sizes at 2^14 rows")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 14
    main()
