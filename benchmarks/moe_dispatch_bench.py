"""MoE dispatch restructuring: the paper's technique inside the LM stack.

Quantifies what models/moe.py does: the token->expert assignment matrix is
unstructured (R-MAT-like row pattern); sorting slots by expert id permutes
it into a block-diagonal (FD-like) operator.  We measure the structure
metrics before/after and the TPU traffic consequence (gather policy on the
unsorted assignment vs streamed dense per-expert GEMMs after sorting).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import traffic
from repro.core.structure import analyze
from repro.models.moe import dispatch_structure_demo

from .common import emit


def dispatch_restructuring(t: int = 8192, n_experts: int = 64,
                           top_k: int = 2) -> str:
    rng = np.random.default_rng(0)
    # power-law-ish expert popularity (hot experts), like real routers
    pop = rng.zipf(1.3, size=10 * n_experts) % n_experts
    probs = np.bincount(pop, minlength=n_experts).astype(np.float64)
    probs /= probs.sum()
    top_e = np.stack([rng.choice(n_experts, size=top_k, replace=False,
                                 p=probs) for _ in range(t)])
    unsorted, sorted_m = dispatch_structure_demo(jnp.asarray(top_e),
                                                 n_experts)
    ru = analyze(unsorted)
    rs = analyze(sorted_m)
    gu = traffic.gather_policy(unsorted)
    cs = traffic.col_blocked_policy(sorted_m)
    rows = [
        ["unsorted", ru.kind, ru.spatial_locality, ru.stream_servable,
         gu.bytes_per_nnz, gu.roofline_gflops],
        ["sorted", rs.kind, rs.spatial_locality, rs.stream_servable,
         cs.bytes_per_nnz, cs.roofline_gflops],
    ]
    return emit(rows, ["dispatch", "kind", "spatial_loc", "stream_servable",
                       "bytes_per_nnz", "v5e_gflops"],
                "moe_dispatch: assignment matrix before/after expert-sort "
                "(paper's permute-into-structure, run in reverse)")


def main() -> None:
    dispatch_restructuring()


if __name__ == "__main__":
    main()
