"""Kernel microbenchmarks: each Pallas kernel vs its jnp oracle.

Wall-times on this container measure the *interpret-mode* kernel (Python
loop over grid cells) and the jit'd jnp oracle on CPU -- meaningful for
correctness and relative shape scaling, NOT for TPU throughput.  The TPU
throughput story is the traffic model (traffic_bench) + the dry-run
roofline; this bench additionally reports the model-predicted v5e GFLOP/s
per (kernel x matrix) from core.traffic.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import traffic
from repro.core.formats import BELL, CSR, DIA
from repro.core.generators import banded_matrix, fd_matrix, rmat_matrix
from repro.core.spmv import spmv_csr_jnp
from repro.kernels import ops

from .common import emit, time_fn


def _err(a, b) -> float:
    return float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                 - b.astype(jnp.float32))))


def spmv_kernels(n: int = 1024) -> str:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    rows = []
    for name, gen in (("fd", fd_matrix), ("rmat", rmat_matrix),
                      ("banded32", lambda m: banded_matrix(m, 32, nnz_per_row=6))):
        csr = gen(n)
        y_ref = spmv_csr_jnp(csr, x)
        t_ref = time_fn(lambda: spmv_csr_jnp(csr, x))

        dia = DIA.from_csr(csr)
        if dia.n_diags <= 160:
            y = ops.spmv_dia(dia, x, bn=128)
            rows.append(["dia", name, n, dia.n_diags, _err(y, y_ref),
                         time_fn(lambda: ops.spmv_dia(dia, x, bn=128), iters=2),
                         t_ref,
                         traffic.stream_policy(
                             csr, int(np.abs(np.asarray(dia.offsets)).max())
                         ).roofline_gflops])

        bell = BELL.from_csr(csr)
        y = ops.spmv_bell(bell, x)
        rows.append(["bell", name, n, bell.blocks_per_row, _err(y, y_ref),
                     time_fn(lambda: ops.spmv_bell(bell, x), iters=2), t_ref,
                     traffic.bell_policy(bell.density(), csr)
                     .roofline_gflops])

        prep = ops.prepare_csr(csr, n_stripes=4)
        y = ops.spmv_csr_prepared(prep, x)
        rows.append(["csr_colblock", name, n, 4, _err(y, y_ref),
                     time_fn(lambda: ops.spmv_csr_prepared(prep, x), iters=2), t_ref,
                     traffic.col_blocked_policy(csr, 4).roofline_gflops])
    return emit(rows, ["kernel", "matrix", "n", "param", "max_err",
                       "t_interp_s", "t_jnp_s", "v5e_roofline_gflops"],
                "kernel_bench: Pallas kernels (interpret) vs jnp oracle + "
                "v5e traffic-model roofline")


def flash_attention_bench() -> str:
    rng = np.random.default_rng(1)
    rows = []
    from repro.kernels import ref as kref
    for (sq, window) in ((256, None), (256, 128)):
        q = jnp.asarray(rng.normal(size=(4, sq, 64)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(4, sq, 64)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(4, sq, 64)).astype(np.float32))
        from repro.kernels.flash_attention import flash_attention_pallas
        o = flash_attention_pallas(q, k, v, causal=True, window=window)
        o_ref = kref.mha_ref(q, k, v, causal=True, window=window)
        rows.append(["flash", sq, str(window), _err(o, o_ref),
                     time_fn(lambda: flash_attention_pallas(
                         q, k, v, causal=True, window=window)),
                     time_fn(lambda: kref.mha_ref(
                         q, k, v, causal=True, window=window))])
    return emit(rows, ["kernel", "seq", "window", "max_err", "t_interp_s",
                       "t_ref_s"],
                "flash_attention: banded (sliding-window) attention vs ref")


def paged_attention_bench() -> str:
    rng = np.random.default_rng(2)
    from repro.kernels import ref as kref
    rows = []
    for (bsz, h, hd, block, mb) in ((2, 4, 64, 16, 4), (4, 8, 128, 16, 8)):
        n_blocks = bsz * mb
        q = jnp.asarray(rng.normal(size=(bsz, h, hd)).astype(np.float32))
        kp = jnp.asarray(rng.normal(size=(n_blocks, block, h, hd))
                         .astype(np.float32))
        vp = jnp.asarray(rng.normal(size=(n_blocks, block, h, hd))
                         .astype(np.float32))
        tables = jnp.asarray(rng.permutation(n_blocks)
                             .reshape(bsz, mb).astype(np.int32))
        lengths = jnp.asarray(
            rng.integers(1, mb * block + 1, bsz).astype(np.int32))
        got = ops.paged_attention(q, kp, vp, tables, lengths)
        want = kref.paged_attention_ref(q, kp, vp, tables, lengths)
        rows.append(["paged", bsz, h, block, mb, _err(got, want),
                     time_fn(lambda: ops.paged_attention(
                         q, kp, vp, tables, lengths), iters=2),
                     time_fn(lambda: kref.paged_attention_ref(
                         q, kp, vp, tables, lengths))])
    return emit(rows, ["kernel", "batch", "heads", "block", "max_blocks",
                       "max_err", "t_interp_s", "t_ref_s"],
                "paged_attention: block-table decode kernel vs oracle")


def main() -> None:
    spmv_kernels()
    flash_attention_bench()
    paged_attention_bench()


if __name__ == "__main__":
    main()
