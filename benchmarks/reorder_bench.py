"""Reordering evaluation: how much of the FD-vs-R-MAT gap does software
permutation close, alone and combined with the PR-1 hardware mechanisms?

Three blocks, at >= 1 R-MAT size drawn from `generators.paper_sizes()`:

  1. structure -- before/after structure metrics per strategy (bandwidth,
     locality, stream servability): the *cause* the paper identifies.
  2. sweep     -- trace-driven miss rates for every (kind, reorder,
     mechanism) cell at the working-set-scaled geometry telemetry_bench
     uses (L2=32K, L3=256K puts Python-tractable traces in the paper's
     >L2 regime).
  3. gap       -- `reorder_gap_report`: fraction of the first-level
     (simulated L2) demand-miss gap each strategy closes on its own
     (mechanism=baseline) and combined with stream buffers.

Invoked by `benchmarks.run` (section name: reorder) or directly:

    PYTHONPATH=src python -m benchmarks.reorder_bench [--fast]
"""
from __future__ import annotations

from repro.core.generators import paper_sizes, rmat_matrix
from repro.core.structure import analyze_reorder
from repro.reorder import STRATEGIES
from repro.telemetry.report import reorder_gap_report, to_csv
from repro.telemetry.sweep import reorder_sweep

from . import common
from .telemetry_bench import SCALED_MECHANISMS

# Same scaled geometry as telemetry_bench's mechanism table, so the two
# reports stay directly comparable.
MECHANISMS = {k: SCALED_MECHANISMS[k] for k in ("baseline",
                                                "stream-buffers")}


def _log2ns():
    # smallest paper sizes keep the RCM BFS + trace replay CI-friendly
    sizes = paper_sizes(min_log2_rows=11,
                        max_log2_rows=11 if common.EMPIRICAL_MAX_LOG2 <= 14
                        else 12)
    return tuple(s.bit_length() - 1 for s in sizes)


def structure_table(log2ns) -> str:
    rows = []
    for log2n in log2ns:
        rm = rmat_matrix(2 ** log2n)
        for name, strategy in STRATEGIES.items():
            if name == "none":
                continue
            d = analyze_reorder(rm, strategy(rm))
            rows.append([
                log2n, name,
                d.before.bandwidth_p95, d.after.bandwidth_p95,
                d.before.spatial_locality, d.after.spatial_locality,
                d.before.temporal_locality, d.after.temporal_locality,
                d.before.stream_servable, d.after.stream_servable,
            ])
    return common.emit(
        rows,
        ["log2n", "strategy", "bw95_before", "bw95_after",
         "spatial_before", "spatial_after", "temporal_before",
         "temporal_after", "stream_before", "stream_after"],
        "reorder structure: R-MAT before/after each strategy")


def main() -> None:
    log2ns = _log2ns()
    structure_table(log2ns)
    print()
    pts = reorder_sweep(log2ns=log2ns, mechanisms=MECHANISMS, sweeps=2)
    print(to_csv(pts, title="reorder sweep: trace-driven, scaled geometry "
                            "(L2=32K L3=256K)"))
    print()
    print(reorder_gap_report(pts))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="single 2^11 size (CI)")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 14
    main()
