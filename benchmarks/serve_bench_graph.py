"""Analytics serving under load: throughput, tail latency, cache hit rate.

Drives the `repro.serve_graph` engine with a synthetic fleet of FD and
R-MAT graphs and a randomized (but seeded -- the run is deterministic)
request stream of BFS / SSSP / PageRank queries:

  1. **warmup** -- one request per (graph, analytic) primes the plan
     cache, so the measured phase starts from a warm pool;
  2. **measured** -- hundreds (smoke) to thousands (full) of concurrent
     requests, including a couple of graphs *not* seen during warmup so
     the admission path still exercises cold compiles under load.

Output: the engine's serving counters, the windowed plan-cache report
(measured phase only, via `telemetry.plan_cache_report`), and a
per-family latency table.  Latency is reported two ways:

  * `steps` -- engine steps from arrival to completion: queueing,
    compile stalls and preemption restarts included (the scheduling
    view);
  * modelled milliseconds -- each request's iterations costed through
    `graph.telemetry.iteration_summaries` on the working-set-scaled
    reference cell (cold first iteration + warm steady state, at the
    Sandy Bridge clock).  This is where matrix *structure* shows up:
    R-MAT's warm per-iteration penalty (~1.8x cycles/nnz vs FD at this
    geometry, PR 5's graph bench) lands directly on the serving tail.

Third section, cache pressure: the same fleet served with `max_plans`
below its plan-key count, so the LRU keeps evicting and every re-arrival
is a fresh compile.  Two configs run the identical request stream with
`reorder='auto'` (so every compile scores candidates): the replay
oracle rationed at one compile per step, and the learned cost model
with the queue drained every step (`compiles_per_step=None`).  The
windowed plan-cache report's split counters show where compile seconds
went; completion steps and tail latency show what eviction-driven
recompiles cost each mode.

Invoked by `benchmarks.run` (section name: serve_graph) or directly:

    PYTHONPATH=src python -m benchmarks.serve_bench_graph [--fast] [--smoke]
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.cache_model import SANDY_BRIDGE
from repro.core.generators import fd_matrix, rmat_matrix
from repro.graph.telemetry import iteration_summaries
from repro.serve_graph import (AnalyticRequest, GraphEngine,
                               GraphEngineConfig)
from repro.telemetry.hierarchy import HierarchySpec
from repro.telemetry.report import plan_cache_report

from . import common

# Working-set-scaled reference cell (same as graph_bench / scaling_bench):
# at these geometries R-MAT's x gathers fall out of the L2 while FD's
# bands stay resident -- the warm-iteration gap the tail latency inherits.
SCALED_CELL = HierarchySpec(l2_bytes=16 * 1024, l3_bytes=64 * 1024)

ANALYTICS = ("bfs", "sssp", "pagerank")
ANALYTIC_WEIGHTS = (0.5, 0.3, 0.2)


def _config():
    if common.SMOKE:
        return dict(log2n=7, per_family=12, n_requests=240, n_cold=2)
    if common.EMPIRICAL_MAX_LOG2 <= 16:                  # --fast
        return dict(log2n=8, per_family=12, n_requests=600, n_cold=2)
    return dict(log2n=10, per_family=16, n_requests=3000, n_cold=4)


def _fleet(log2n: int, per_family: int, n_cold: int):
    """(graph_id -> adjacency) for the warm fleet plus `n_cold` extra
    graphs per family that only appear mid-stream."""
    n = 2 ** log2n
    warm, cold = {}, {}
    for i in range(per_family):
        warm[f"fd{i:02d}"] = fd_matrix(n, seed=100 + i)
        warm[f"rmat{i:02d}"] = rmat_matrix(n, seed=200 + i)
    for i in range(n_cold):
        cold[f"fd_cold{i}"] = fd_matrix(n, seed=300 + i)
        cold[f"rmat_cold{i}"] = rmat_matrix(n, seed=400 + i)
    return warm, cold


def _request(rng, req_id: int, gid: str, n: int,
             analytic: str = None) -> AnalyticRequest:
    if analytic is None:
        analytic = rng.choice(ANALYTICS, p=ANALYTIC_WEIGHTS)
    if analytic == "pagerank":
        return AnalyticRequest(req_id, gid, "pagerank",
                               params={"tol": 1e-5}, max_iters=64)
    n_src = int(rng.choice((1, 1, 1, 2, 4)))    # mostly single-source
    sources = tuple(int(s) for s in rng.choice(n, size=n_src,
                                               replace=False))
    return AnalyticRequest(req_id, gid, analytic, sources=sources)


def _modelled_ms(eng: GraphEngine, results, memo: Dict) -> Dict[int, float]:
    """Per-request modelled service time: nnz x (cold + warm x (iters-1))
    cycles on the scaled cell, at the machine clock.  The memo also keeps
    each plan's warm-iteration bound category (staged topdown label) so
    the latency table can say *why* a family's tail is slow."""
    out = {}
    for rid, res in results.items():
        ck = (res.graph_id, res.analytic)
        if ck not in memo:
            st = eng._derive(*ck)
            plan = eng.plan_cache.get_or_compile(st.matrix, **st.opts)
            s = iteration_summaries(plan, 2, spec=SCALED_CELL)
            nnz = plan.csr.nnz if plan.csr is not None else plan.n_rows
            memo[ck] = (nnz, s[0].cycles_per_nnz, s[1].cycles_per_nnz,
                        s[1].bound())
        nnz, cold, warm, _ = memo[ck]
        cycles = nnz * (cold + warm * max(res.n_iters - 1, 0)) \
            if res.n_iters else 0.0
        out[rid] = cycles / (SANDY_BRIDGE.freq_ghz * 1e9) * 1e3
    return out


def _family_bound(memo: Dict, fam: str) -> str:
    """Most common warm-iteration bound label among a family's plans."""
    labels = [v[3] for (gid, _), v in memo.items() if gid.startswith(fam)]
    if not labels:
        return ""
    return max(sorted(set(labels)), key=labels.count)


def _pcts(xs: List[float]):
    return [float(np.percentile(xs, q)) for q in (50, 95, 99)] if xs else \
        [0.0, 0.0, 0.0]


def main() -> None:
    cfg = _config()
    n = 2 ** cfg["log2n"]
    warm, cold = _fleet(cfg["log2n"], cfg["per_family"], cfg["n_cold"])
    eng = GraphEngine(GraphEngineConfig(
        n_lanes=256, compile_queue_cap=16, compiles_per_step=2,
        max_plans=max(4 * cfg["per_family"] + 4 * cfg["n_cold"], 64)))
    for gid, adj in {**warm, **cold}.items():
        eng.register_graph(gid, adj)

    # -- warmup: prime one plan per (warm graph, analytic) -------------------
    rng = np.random.default_rng(7)
    rid = 0
    for gid in warm:
        for analytic in ANALYTICS:
            eng.submit(AnalyticRequest(
                rid, gid, analytic,
                sources=(0,) if analytic != "pagerank" else (),
                params={"tol": 1e-5} if analytic == "pagerank" else {},
                max_iters=64))
            rid += 1
    eng.run()
    warm_stats = eng.plan_cache.stats()
    steps_before = eng.step_count

    # -- measured phase ------------------------------------------------------
    gids = sorted(warm)
    cold_gids = sorted(cold)
    t0 = time.perf_counter()
    first_measured = rid
    for i in range(cfg["n_requests"]):
        if cold_gids and i == cfg["n_requests"] // 3:
            # mid-stream cold graphs: admission must compile under load
            for gid in cold_gids:
                eng.submit(_request(rng, rid, gid, n))
                rid += 1
        eng.submit(_request(rng, rid, gids[int(rng.integers(len(gids)))], n))
        rid += 1
    out = eng.run()
    wall_s = time.perf_counter() - t0

    measured = {r: v for r, v in out.items() if r >= first_measured}
    steps = eng.step_count - steps_before
    stats = eng.stats()

    memo: Dict = {}
    ms = _modelled_ms(eng, measured, memo)
    fams = {"fd": [r for r in measured.values()
                   if r.graph_id.startswith("fd")],
            "rmat": [r for r in measured.values()
                     if r.graph_id.startswith("rmat")]}
    rows = []
    for fam, rs in fams.items():
        lat = [ms[r.req_id] for r in rs]
        stp = [float(r.latency_steps) for r in rs]
        iters = [r.n_iters for r in rs]
        rows.append([fam, len(rs), float(np.mean(iters))]
                    + _pcts(stp) + _pcts(lat) + [_family_bound(memo, fam)])
    common.emit(rows,
                ["family", "requests", "mean_iters", "p50_steps",
                 "p95_steps", "p99_steps", "p50_model_ms", "p95_model_ms",
                 "p99_model_ms", "warm_bound"],
                f"serving latency by matrix family (n=2^{cfg['log2n']}, "
                f"{len(warm) + len(cold)} graphs)")

    thr = [["requests", len(measured)], ["engine_steps", steps],
           ["requests_per_step", len(measured) / max(steps, 1)],
           ["wall_s", wall_s],
           ["requests_per_s", len(measured) / max(wall_s, 1e-9)],
           ["spmm_calls", stats["spmm_calls"]],
           ["max_running", stats["max_running"]],
           ["max_inflight", stats["max_inflight"]],
           ["preemptions", stats["preemptions"]],
           ["admission_hit_rate", stats["admission_hit_rate"]]]
    common.emit(thr, ["metric", "value"], "serving throughput")

    print(plan_cache_report(eng.plan_cache.stats(), before=warm_stats,
                            title="plan cache, measured phase"))

    if common.SMOKE:
        # acceptance floor: real concurrency over a real fleet, warm pool
        assert len(warm) + len(cold) >= 20
        assert stats["max_inflight"] >= 100
        win = eng.plan_cache.stats()
        served = (win["hits"] - warm_stats["hits"]) + \
            (win["misses"] - warm_stats["misses"])
        rate = (win["hits"] - warm_stats["hits"]) / max(served, 1)
        assert rate > 0.8, f"measured-phase hit rate {rate:.2f} <= 0.8"

    _pressure_section(cfg)


def _pressure_section(cfg) -> None:
    """Eviction churn: max_plans below the fleet's plan-key count, every
    compile scoring reorder candidates.  Oracle-paced vs model-drained."""
    from repro.plan.costmodel import default_model

    if default_model() is None:
        print("# cache pressure: no model artifact shipped, skipping")
        return

    n = 2 ** cfg["log2n"]
    graphs = {}
    for i in range(6):
        graphs[f"fd{i:02d}"] = fd_matrix(n, seed=100 + i)
        graphs[f"rmat{i:02d}"] = rmat_matrix(n, seed=200 + i)
    n_keys = len(graphs) * len(ANALYTICS)            # 36 plan keys
    n_req = 150 if common.SMOKE else 600
    max_plans = 8                                    # << n_keys: constant churn

    rows, steps_by = [], {}
    for label, over in (
            ("oracle_paced", dict(predictor="replay", compiles_per_step=1)),
            ("model_drain", dict(predictor="model", compiles_per_step=None))):
        eng = GraphEngine(GraphEngineConfig(
            n_lanes=256, compile_queue_cap=16, max_plans=max_plans,
            reorder="auto", **over))
        for gid, adj in graphs.items():
            eng.register_graph(gid, adj)
        rng = np.random.default_rng(11)              # same stream both runs
        gids = sorted(graphs)
        t0 = time.perf_counter()
        for rid in range(n_req):
            # cyclic over every (graph, analytic) pair: reuse distance
            # far above max_plans, so re-arrivals find their plan evicted
            eng.submit(_request(rng, rid, gids[(rid // len(ANALYTICS))
                                               % len(gids)], n,
                                analytic=ANALYTICS[rid % len(ANALYTICS)]))
        out = eng.run()
        wall_s = time.perf_counter() - t0
        cs = eng.plan_cache.stats()
        stp = [float(r.latency_steps) for r in out.values()]
        touched = len({v.key for v in eng._derived.values()})
        rows.append([label, n_req, eng.step_count, wall_s,
                     cs["misses"], cs["misses"] - touched, cs["evictions"],
                     cs["predictor_compiles"], cs["oracle_compiles"],
                     cs["predictor_compile_s"], cs["oracle_compile_s"]]
                    + _pcts(stp))
        steps_by[label] = eng.step_count
        print(plan_cache_report(cs, title=f"plan cache, {label}"))
    common.emit(rows,
                ["config", "requests", "engine_steps", "wall_s", "compiles",
                 "recompiles", "evictions", "predictor_compiles",
                 "oracle_compiles", "predictor_compile_s",
                 "oracle_compile_s", "p50_steps", "p95_steps", "p99_steps"],
                f"cache pressure: {n_keys} plan keys through a "
                f"{max_plans}-plan LRU (n=2^{cfg['log2n']}, reorder=auto)")

    # the pressure must be real (LRU evicting in both configs), each
    # config must score on its own path only, and the drain config must
    # actually pay eviction-driven recompiles -- the pacing config
    # absorbs churn by parking requests instead, which is exactly the
    # tail-latency trade the table shows
    oracle, model = rows[0], rows[1]
    assert oracle[6] > 0 and model[6] > 0, "no LRU pressure"
    assert model[5] > 0, "drain config saw no eviction-driven recompiles"
    assert oracle[8] == oracle[4] and oracle[7] == 0
    assert model[7] == model[4] and model[8] == 0
    # cheap model-scored compiles, drained every step, finish the same
    # stream in no more steps than the rationed oracle
    assert steps_by["model_drain"] <= steps_by["oracle_paced"]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 16
    if args.smoke:
        common.SMOKE = True
    main()
