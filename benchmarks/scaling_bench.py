"""Multithreaded scaling evaluation: the paper's title axis.

Replays FD and R-MAT through the `repro.parallel` engine — every thread
a private L2, one shared contended LLC per socket, a DRAM bandwidth
model — across the thread axis, crossed with the reorder axis so the
report answers both headline questions:

  1. speedup separation — FD's speedup strictly dominates R-MAT's at
     every thread count (shared-LLC contention and bandwidth saturation
     hit the random-gather workload first);
  2. gap closed by RCM — how much of the FD-vs-R-MAT throughput gap the
     software permutation recovers at each thread count
     (`gap_closed_gflops_rcm` in the gap report).

Geometry is the working-set-scaled reference cell (L2 16 KiB, shared
LLC 64 KiB: x is about half the LLC at 2^12, the paper's >LLC regime at
Python-tractable trace sizes).  The partition axis runs twice: row
blocks split on the nnz CDF (`balanced` -- the best a row-granular
split can do, and the axis the historical gap reports use) and equal
nonzero segments that may cut mid-row (`merge` -- the segmented /
merge-CSR execution).  `partition_gap_report` tabulates what nnz
balancing buys per cell; in smoke mode the bench *asserts* merge wins
at least one R-MAT cell.

Invoked by `benchmarks.run` (section name: scaling) or directly:

    PYTHONPATH=src python -m benchmarks.scaling_bench [--fast] [--smoke]
"""
from __future__ import annotations

from repro import reorder
from repro.parallel import ParallelSpec
from repro.telemetry.report import (partition_gap_report, scaling_gap_report,
                                    scaling_report)
from repro.telemetry.sweep import scaling_sweep

from . import common

# Reference scaled geometry for the thread axis (see module docstring).
SCALED_PARALLEL = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)

THREADS = (1, 2, 4, 8, 16, 32)


def _config():
    if common.SMOKE:
        return (10,), (1, 2)
    if common.EMPIRICAL_MAX_LOG2 <= 16:          # --fast (here or via run.py)
        return (11,), (1, 2, 4, 8)
    return (12,), THREADS


def _assert_merge_wins_rmat(points) -> None:
    """Smoke gate: the nnz-balanced merge partition must beat the best
    row-granular split on at least one R-MAT cell (hub rows defeat any
    row-granular cut, so if this fails the merge slicing is broken)."""
    by = {(p.kind, p.log2n, p.reorder, p.threads, p.partition): p
          for p in points}
    wins = [
        (kind, log2n, rl, t)
        for (kind, log2n, rl, t, part) in by
        if part == "merge" and kind == "rmat" and t > 1
        and (kind, log2n, rl, t, "balanced") in by
        and by[(kind, log2n, rl, t, "merge")].metrics.time_s
        < by[(kind, log2n, rl, t, "balanced")].metrics.time_s
    ]
    assert wins, ("merge partition beat row-balanced on no R-MAT cell: "
                  "nnz-balanced slicing is not delivering its win")
    print(f"# smoke: merge partition wins {len(wins)} R-MAT cell(s), "
          f"e.g. {wins[0]}")


def main() -> None:
    log2ns, threads = _config()
    pts = []
    for partition in ("balanced", "merge"):
        ckpt = (f"{common.SWEEP_CKPT}/scaling-{partition}"
                if common.SWEEP_CKPT else None)
        pts += scaling_sweep(
            log2ns=log2ns, threads_list=threads, spec=SCALED_PARALLEL,
            partition=partition, sweeps=2,
            reorderings={"none": None, "rcm": reorder.rcm},
            workers=common.WORKERS, ckpt_dir=ckpt)
    print(scaling_report(pts))
    print()
    # speedup-gap view keyed by (kind, size, reorder, threads): keep it on
    # the row-balanced axis it has always reported
    print(scaling_gap_report([p for p in pts if p.partition == "balanced"]))
    print()
    print(partition_gap_report(pts))
    if common.SMOKE:
        _assert_merge_wins_rmat(pts)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="2^11 rows, threads 1-8 (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="2^10 rows, threads {1,2} (benchmark smoke job)")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 14
    if args.smoke:
        common.SMOKE = True
    main()
