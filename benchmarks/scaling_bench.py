"""Multithreaded scaling evaluation: the paper's title axis.

Replays FD and R-MAT through the `repro.parallel` engine — every thread
a private L2, one shared contended LLC per socket, a DRAM bandwidth
model — across the thread axis, crossed with the reorder axis so the
report answers both headline questions:

  1. speedup separation — FD's speedup strictly dominates R-MAT's at
     every thread count (shared-LLC contention and bandwidth saturation
     hit the random-gather workload first);
  2. gap closed by RCM — how much of the FD-vs-R-MAT throughput gap the
     software permutation recovers at each thread count
     (`gap_closed_gflops_rcm` in the gap report).

Geometry is the working-set-scaled reference cell (L2 16 KiB, shared
LLC 64 KiB: x is about half the LLC at 2^12, the paper's >LLC regime at
Python-tractable trace sizes).  Partitioning is `rowblock_balanced`, so
RCM's row clustering is not mistaken for a scaling defect.

Invoked by `benchmarks.run` (section name: scaling) or directly:

    PYTHONPATH=src python -m benchmarks.scaling_bench [--fast] [--smoke]
"""
from __future__ import annotations

from repro import reorder
from repro.parallel import ParallelSpec
from repro.telemetry.report import scaling_gap_report, scaling_report
from repro.telemetry.sweep import scaling_sweep

from . import common

# Reference scaled geometry for the thread axis (see module docstring).
SCALED_PARALLEL = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)

THREADS = (1, 2, 4, 8, 16, 32)


def _config():
    if common.SMOKE:
        return (10,), (1, 2)
    if common.EMPIRICAL_MAX_LOG2 <= 16:          # --fast (here or via run.py)
        return (11,), (1, 2, 4, 8)
    return (12,), THREADS


def main() -> None:
    log2ns, threads = _config()
    pts = scaling_sweep(
        log2ns=log2ns, threads_list=threads, spec=SCALED_PARALLEL,
        partition="balanced", sweeps=2,
        reorderings={"none": None, "rcm": reorder.rcm})
    print(scaling_report(pts))
    print()
    print(scaling_gap_report(pts))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="2^11 rows, threads 1-8 (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="2^10 rows, threads {1,2} (benchmark smoke job)")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 14
    if args.smoke:
        common.SMOKE = True
    main()
