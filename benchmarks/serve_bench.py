"""Serving engine throughput: continuous batching vs one-at-a-time.

CPU wall-clock on a reduced model -- the point is the SCHEDULING win
(slots kept busy, admission under a constrained pool), which is
hardware-independent, not absolute tok/s.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import CONFIGS
from repro.serve import EngineConfig, Request, make_engine

from .common import emit


def _requests(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(req_id=i,
                    prompt=rng.integers(1, vocab, rng.integers(4, 24))
                    .tolist(),
                    max_new_tokens=int(rng.integers(4, 10)))
            for i in range(n)]


def continuous_vs_serial(n_requests: int = 8) -> str:
    cfg = CONFIGS["stablelm-1.6b"].reduced()
    rows = []
    for max_batch in (1, 4):
        eng = make_engine(cfg, ecfg=EngineConfig(
            max_batch=max_batch, max_context=64, block_size=8))
        reqs = _requests(n_requests, cfg.vocab)
        t0 = time.time()
        out = eng.run(reqs)
        dt = time.time() - t0
        toks = sum(len(v) for v in out.values())
        stats = eng.sched.stats()
        rows.append([max_batch, n_requests, toks, round(dt, 2),
                     round(toks / dt, 1), stats["steps"],
                     stats["preemptions"]])
    return emit(rows, ["max_batch", "requests", "tokens", "wall_s",
                       "tok_per_s", "decode_steps", "preemptions"],
                "serve_bench: continuous batching vs serial slots "
                "(reduced model, CPU wall-clock)")


def main() -> None:
    continuous_vs_serial()


if __name__ == "__main__":
    main()
