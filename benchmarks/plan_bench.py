"""Amortization benchmark: cold plan compile vs cached execute vs SpMM.

The compile-once claim, measured: for FD and R-MAT at the paper-regime
2^12 rows (2^10 under --smoke / --fast), time

  cold     `plan.compile` + first execute (analysis, predictor scoring,
           format conversion, layout padding, kernel warm-up);
  warm     median cached `SpmvPlan.execute` over `REPEATS` multiplies
           (zero matrix-side work per call);
  spmm     `execute_many` on a REPEATS-vector batch, per vector (the
           batched jnp SpMM path).

`warm_frac` = warm / cold must stay < 0.20 for the amortized path to be
doing its job (asserted here so `run.py --smoke` fails on regression).

Invoked by `benchmarks.run` (section name: plan) or directly:

    PYTHONPATH=src python -m benchmarks.plan_bench [--fast] [--smoke]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import plan
from repro.core.generators import fd_matrix, rmat_matrix

from . import common

REPEATS = 8          # acceptance: warm < 20% of cold over >= 8 multiplies
WARM_FRAC_MAX = 0.20


def _log2n() -> int:
    if common.SMOKE or common.EMPIRICAL_MAX_LOG2 <= 16:
        return 10
    return 12


def _time(fn) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def main() -> None:
    log2n = _log2n()
    n = 2 ** log2n
    rows = []
    for kind, gen in (("fd", fd_matrix), ("rmat", rmat_matrix)):
        csr = gen(n, seed=0)
        x = jnp.asarray(np.random.default_rng(0).normal(size=n)
                        .astype(np.float32))
        cache = plan.PlanCache()
        opts = dict(reorder="auto", predictor="analytic", threads=8)

        cold = _time(lambda: cache.get_or_compile(csr, **opts)
                     .execute(x, interpret=True))
        p = cache.get_or_compile(csr, **opts)        # cache hit

        warm = float(np.median([
            _time(lambda: p.execute(x, interpret=True))
            for _ in range(REPEATS)]))

        X = jnp.stack([x] * REPEATS)
        p.execute_many(X)                            # build + jit once
        spmm = _time(lambda: p.execute_many(X)) / REPEATS

        frac = warm / max(cold, 1e-12)
        rows.append([kind, log2n, csr.nnz, p.format_name, p.chosen,
                     cold * 1e3, warm * 1e3, frac, spmm * 1e3,
                     cold / max(warm, 1e-12)])
        assert frac < WARM_FRAC_MAX, (
            f"{kind} 2^{log2n}: warm per-call cost is {frac:.1%} of cold "
            f"(must be < {WARM_FRAC_MAX:.0%}) — the amortized path regressed")

    common.emit(rows,
                ["kind", "log2n", "nnz", "format", "reorder", "cold_ms",
                 "warm_ms", "warm_frac", "spmm_per_vec_ms", "amortization_x"],
                f"plan amortization: cold compile vs cached execute "
                f"(2^{log2n}, {REPEATS} repeats)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="2^10 rows")
    ap.add_argument("--smoke", action="store_true",
                    help="2^10 rows (benchmark smoke job)")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 14
    if args.smoke:
        common.SMOKE = True
    main()
