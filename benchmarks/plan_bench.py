"""Amortization benchmark: cold plan compile vs cached execute vs SpMM.

The compile-once claim, measured: for FD and R-MAT at the paper-regime
2^12 rows (2^10 under --smoke / --fast), time

  cold     `plan.compile` + first execute (analysis, predictor scoring,
           format conversion, layout padding, kernel warm-up);
  warm     median cached `SpmvPlan.execute` over `REPEATS` multiplies
           (zero matrix-side work per call);
  spmm     `execute_many` on a REPEATS-vector batch, per vector (the
           batched jnp SpMM path).

`warm_frac` = warm / cold must stay < 0.20 for the amortized path to be
doing its job (asserted here so `run.py --smoke` fails on regression).

Second section, the learned-compiler claim: on a scrambled-banded
matrix at the paper-regime 2^12 scaled-geometry cell, candidate scoring
through the shipped cost model must be >= 50x faster than the replay
oracle it replaces (`SCORING_SPEEDUP_MIN`, on `compile_stats.predict_s`
-- the component the model eliminates; reordering/analysis/conversion
are shared by both modes, so the end-to-end cold-compile ratio is
reported as its own column, not asserted).  Both modes must also pick
the same reordering here, or the speedup is bought with a wrong plan.

Invoked by `benchmarks.run` (section name: plan) or directly:

    PYTHONPATH=src python -m benchmarks.plan_bench [--fast] [--smoke]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import plan
from repro.core.generators import fd_matrix, rmat_matrix

from . import common

REPEATS = 8          # acceptance: warm < 20% of cold over >= 8 multiplies
WARM_FRAC_MAX = 0.20
SCORING_SPEEDUP_MIN = 50.0   # model vs replay scoring at the 2^12 cell
SCORING_SPEEDUP_MIN_SMOKE = 10.0   # 2^10: replay is ~4x cheaper there


def _log2n() -> int:
    if common.SMOKE or common.EMPIRICAL_MAX_LOG2 <= 16:
        return 10
    return 12


def _time(fn) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def main() -> None:
    log2n = _log2n()
    n = 2 ** log2n
    rows = []
    for kind, gen in (("fd", fd_matrix), ("rmat", rmat_matrix)):
        csr = gen(n, seed=0)
        x = jnp.asarray(np.random.default_rng(0).normal(size=n)
                        .astype(np.float32))
        cache = plan.PlanCache()
        opts = dict(reorder="auto", predictor="analytic", threads=8)

        cold = _time(lambda: cache.get_or_compile(csr, **opts)
                     .execute(x, interpret=True))
        p = cache.get_or_compile(csr, **opts)        # cache hit

        warm = float(np.median([
            _time(lambda: p.execute(x, interpret=True))
            for _ in range(REPEATS)]))

        X = jnp.stack([x] * REPEATS)
        p.execute_many(X)                            # build + jit once
        spmm = _time(lambda: p.execute_many(X)) / REPEATS

        frac = warm / max(cold, 1e-12)
        rows.append([kind, log2n, csr.nnz, p.format_name, p.chosen,
                     cold * 1e3, warm * 1e3, frac, spmm * 1e3,
                     cold / max(warm, 1e-12)])
        assert frac < WARM_FRAC_MAX, (
            f"{kind} 2^{log2n}: warm per-call cost is {frac:.1%} of cold "
            f"(must be < {WARM_FRAC_MAX:.0%}) — the amortized path regressed")

    common.emit(rows,
                ["kind", "log2n", "nnz", "format", "reorder", "cold_ms",
                 "warm_ms", "warm_frac", "spmm_per_vec_ms", "amortization_x"],
                f"plan amortization: cold compile vs cached execute "
                f"(2^{log2n}, {REPEATS} repeats)")
    _scoring_section(log2n)


def _scoring_section(log2n: int) -> None:
    """Learned cost model vs replay oracle on the hot compile path."""
    from repro.parallel import ParallelSpec
    from repro.plan.costmodel import default_model
    from repro.reorder import Reordering

    if default_model() is None:
        print("# learned scoring: no model artifact shipped, skipping")
        return

    n = 2 ** log2n
    from repro.core.generators import banded_matrix

    bandm = banded_matrix(n, max(8, n // 32), seed=0)
    perm = np.random.default_rng(0).permutation(n)
    csr = Reordering(row_perm=perm, col_perm=perm).apply(bandm)
    spec = ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)

    timed = {}
    for pred in ("auto", "replay"):
        t0 = time.perf_counter()
        p = plan.compile(csr, reorder="auto", predictor=pred, threads=8,
                         parallel_spec=spec)
        timed[pred] = (time.perf_counter() - t0, p)
    cold_m, pm = timed["auto"]
    cold_o, po = timed["replay"]
    assert pm.compile_stats["scoring"] == "model"
    score_m = pm.compile_stats["predict_s"]
    score_o = po.compile_stats["predict_s"]
    speedup = score_o / max(score_m, 1e-12)
    floor = SCORING_SPEEDUP_MIN_SMOKE if log2n < 12 else SCORING_SPEEDUP_MIN
    common.emit(
        [["scrambled", log2n, csr.nnz, pm.chosen, po.chosen,
          score_m * 1e3, score_o * 1e3, speedup,
          cold_m * 1e3, cold_o * 1e3, cold_o / max(cold_m, 1e-12)]],
        ["kind", "log2n", "nnz", "model_pick", "oracle_pick",
         "model_score_ms", "oracle_score_ms", "scoring_speedup_x",
         "model_cold_ms", "oracle_cold_ms", "cold_speedup_x"],
        f"learned scoring vs replay oracle (scaled LLC cell, 2^{log2n}, "
        f"threads=8)")
    assert pm.chosen == po.chosen, (
        f"model picked {pm.chosen!r} but the replay oracle picked "
        f"{po.chosen!r} on the scrambled-banded cell")
    assert speedup >= floor, (
        f"scoring speedup {speedup:.1f}x below the {floor:.0f}x floor at "
        f"2^{log2n} — the learned fast path regressed")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="2^10 rows")
    ap.add_argument("--smoke", action="store_true",
                    help="2^10 rows (benchmark smoke job)")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 14
    if args.smoke:
        common.SMOKE = True
    main()
