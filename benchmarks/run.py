"""Benchmark aggregator: `PYTHONPATH=src python -m benchmarks.run`.

Runs one bench per paper table/figure plus the TPU-side benches, printing
CSV blocks.  `--fast` trims the empirical sweep (CI); default reproduces
the full paper sweep via synthetic profiles to 2^26.  `--smoke` is the
benchmark smoke job: reorder + scaling + plan amortization + a
tiny-geometry graph-analytic case + the analytics serving bench
(hundreds of requests, ≥20 graphs, asserted warm hit rate) + the
streaming bench (asserted overlay-vs-recompile update latency and
warm-start savings), thread axis {1, 2} — just enough execution that
those benches (and the plan warm/cold ratio and serving hit-rate
assertions) cannot silently rot.
"""
from __future__ import annotations

import argparse
import sys
import time

ALL = ("paper,kernels,traffic,moe,serve,telemetry,reorder,scaling,plan,"
       "graph,serve_graph,stream")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="cap empirical matrices at 2^16 rows")
    ap.add_argument("--smoke", action="store_true",
                    help="reorder+scaling+plan only, tiny geometry, "
                         "threads {1,2}")
    ap.add_argument("--only", default=None, help=f"comma list: {ALL}")
    ap.add_argument("--workers", type=int, default=1,
                    help="shard runner-backed sweeps across N processes")
    ap.add_argument("--resume", default=None, metavar="CKPT_DIR",
                    help="checkpoint sweep cells under this directory and "
                         "resume from whatever is already committed there")
    args = ap.parse_args(argv)

    from . import common
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 16
    if args.smoke:
        common.SMOKE = True
        common.EMPIRICAL_MAX_LOG2 = 12
    common.WORKERS = max(args.workers, 1)
    common.SWEEP_CKPT = args.resume

    default = ("reorder,scaling,plan,graph,serve_graph,stream"
               if args.smoke else ALL)
    want = set((args.only or default).split(","))
    t0 = time.time()

    if "paper" in want:
        from . import paper_metrics
        paper_metrics.main()
    if "kernels" in want:
        from . import kernel_bench
        kernel_bench.main()
    if "traffic" in want:
        from . import traffic_bench
        traffic_bench.main()
    if "moe" in want:
        from . import moe_dispatch_bench
        moe_dispatch_bench.main()
    if "serve" in want:
        from . import serve_bench
        serve_bench.main()
    if "telemetry" in want:
        from . import telemetry_bench
        telemetry_bench.main()
    if "reorder" in want:
        from . import reorder_bench
        reorder_bench.main()
    if "scaling" in want:
        from . import scaling_bench
        scaling_bench.main()
    if "plan" in want:
        from . import plan_bench
        plan_bench.main()
    if "graph" in want:
        from . import graph_bench
        graph_bench.main()
    if "serve_graph" in want:
        from . import serve_bench_graph
        serve_bench_graph.main()
    if "stream" in want:
        from . import stream_bench
        stream_bench.main()

    print(f"# benchmarks.run completed in {time.time()-t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
