"""Shared benchmark helpers: size sweeps, CSV emission, timers."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List

import numpy as np

# Empirical matrices up to 2^22 rows are generated for real; beyond that the
# synthetic profiles (core.cache_model.profile_fd / profile_rmat) carry the
# sweep to the paper's 2^26 without materializing 5x10^8-nnz matrices.
EMPIRICAL_MAX_LOG2 = 20        # keep CI fast; paper sweep goes to 26
PAPER_MIN_LOG2, PAPER_MAX_LOG2 = 11, 26
THREADS = (1, 2, 4, 8, 16)
SMOKE = False                  # run.py --smoke: tiny geometry, threads {1,2}
# Sweep execution knobs (run.py --workers / --resume): sweeps backed by
# telemetry.runner shard their grids across WORKERS processes and
# checkpoint/resume completed cells under SWEEP_CKPT when set.
WORKERS = 1
SWEEP_CKPT = None


def emit(rows: Iterable[Iterable], header: List[str], title: str) -> str:
    lines = [f"# {title}", ",".join(header)]
    for row in rows:
        lines.append(",".join(
            f"{v:.4g}" if isinstance(v, float) else str(v) for v in row))
    out = "\n".join(lines)
    print(out)
    return out


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (seconds) with block_until_ready on jax outputs."""
    import jax

    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def size_sweep(max_log2: int = EMPIRICAL_MAX_LOG2,
               min_log2: int = PAPER_MIN_LOG2) -> List[int]:
    return [2 ** k for k in range(min_log2, max_log2 + 1)]
