"""Streaming updates: overlay vs recompile latency, warm-start savings.

The streaming layer's two bottom lines, measured on an R-MAT graph (the
paper's unstructured family -- the case where a full re-plan is most
expensive, because reordering/format scoring rides on every compile):

  1. **Update latency** -- after an edge batch of `rate * nnz` inserts,
     how long until a servable plan for the mutated matrix exists?
     Two paths: `plan.overlay` (chained fingerprint + lazy delta pass,
     O(delta) host work) vs a cold `plan.compile` of the materialized
     matrix (full fingerprint, format scoring, kernel prep).  The table
     reports both and their ratio across update rates; the overlay's
     answers are verified bit-identical to the recompiled plan's on
     integer-valued copies (exact f32 summation -- the same discipline
     as the kernel property suite) before its latency is allowed to
     count.

  2. **Warm-start savings** -- iterations to re-converge an analytic on
     the mutated graph, from scratch vs seeded with the pre-delta
     state (`r0`/`d0` driver kwargs).  PageRank re-converges from a
     one-edge delta in well under half the from-scratch iterations;
     insert-only SSSP collapses to the few frontier waves the new edges
     actually open (old distances stay valid upper bounds).

Smoke asserts overlay availability < 20% of recompile at 2^10; the full
run asserts the >= 50x plan-availability speedup at 2^12 and the < 50%
single-edge warm-start ratio.

Invoked by `benchmarks.run` (section name: stream) or directly:

    PYTHONPATH=src python -m benchmarks.stream_bench [--fast] [--smoke]
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.delta import EdgeDelta
from repro.core.formats import CSR
from repro.core.generators import rmat_matrix
from repro.graph.drivers import pagerank, sssp
from repro.plan import compile as compile_plan, overlay

from . import common


def _config():
    if common.SMOKE:
        return dict(log2n=10, rates=(0.001, 0.005, 0.01), timing_iters=3)
    if common.EMPIRICAL_MAX_LOG2 <= 16:                  # --fast
        return dict(log2n=11, rates=(0.001, 0.005, 0.01), timing_iters=3)
    return dict(log2n=12, rates=(0.001, 0.005, 0.01), timing_iters=5)


def _random_inserts(adj: CSR, k: int, rng) -> List[Tuple[int, int, float]]:
    """`k` absent off-diagonal coordinates with small integer weights."""
    n = adj.n_rows
    indptr = np.asarray(adj.indptr)
    present = set(zip(np.repeat(np.arange(n), np.diff(indptr)).tolist(),
                      np.asarray(adj.indices).tolist()))
    out: List[Tuple[int, int, float]] = []
    seen = set()
    while len(out) < k:
        r, c = int(rng.integers(n)), int(rng.integers(n))
        if r != c and (r, c) not in present and (r, c) not in seen:
            out.append((r, c, float(rng.integers(1, 4))))
            seen.add((r, c))
    return out


def _int_valued(adj: CSR) -> CSR:
    """Same pattern, small integer f32 values: every summation order is
    exact in f32, so overlay vs recompile can be compared bit-for-bit."""
    n = adj.n_rows
    indptr = np.asarray(adj.indptr)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    cols = np.asarray(adj.indices, dtype=np.int64)
    vals = 1.0 + (np.arange(adj.nnz) % 7).astype(np.float32)
    return CSR.from_coo(rows, cols, vals, n, adj.n_cols)


def _median_ms(fn, iters: int) -> float:
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e3


def _latency_section(cfg) -> None:
    n = 1 << cfg["log2n"]
    rng = np.random.default_rng(7)
    adj = _int_valued(rmat_matrix(n, seed=7))
    plan = compile_plan(adj)
    x = rng.integers(0, 8, size=n).astype(np.float32)

    rows = []
    for rate in cfg["rates"]:
        k = max(int(rate * adj.nnz), 1)
        delta = EdgeDelta.from_updates(adj, inserts=_random_inserts(
            adj, k, rng))
        mat = adj.apply_delta(delta)

        # exactness first: the overlay answer must be bit-identical to
        # the recompiled materialized matrix before its speed counts
        ref_plan = compile_plan(mat)
        ov = overlay(plan, delta, staleness_budget=1.0)
        exact = bool(np.array_equal(np.asarray(ov.execute(x)),
                                    np.asarray(ref_plan.execute(x))))

        t_overlay = _median_ms(lambda: overlay(plan, delta,
                                               staleness_budget=1.0),
                               cfg["timing_iters"])
        # fresh materialization per run: no fingerprint-memo hit, the
        # honest cold path a past-budget re-plan pays
        t_recompile = _median_ms(
            lambda: compile_plan(adj.apply_delta(delta)),
            cfg["timing_iters"])
        speedup = t_recompile / max(t_overlay, 1e-9)
        rows.append([rate, k, delta.nnz / adj.nnz, t_overlay, t_recompile,
                     speedup, exact])
        assert exact, f"overlay answer diverged at rate {rate}"

    common.emit(rows,
                ["rate", "delta_nnz", "staleness", "overlay_ms",
                 "recompile_ms", "speedup", "bit_identical"],
                f"plan availability after an edge batch (R-MAT, "
                f"n=2^{cfg['log2n']}, nnz={adj.nnz})")

    if common.SMOKE:
        for row in rows:
            assert row[3] < 0.2 * row[4], \
                f"overlay {row[3]:.2f} ms not < 20% of recompile " \
                f"{row[4]:.2f} ms at rate {row[0]}"
    if cfg["log2n"] >= 12:
        for row in rows:
            assert row[5] >= 50, \
                f"plan availability speedup {row[5]:.0f}x < 50x at " \
                f"rate {row[0]}"


def _warm_start_section(cfg) -> None:
    n = 1 << cfg["log2n"]
    rng = np.random.default_rng(11)
    adj = rmat_matrix(n, seed=7)
    tol = 1e-5              # resolvable in f32; tighter tolerances grind
                            # both runs at the float noise floor

    rows = []
    # pagerank: unique fixpoint from any start -> always warm-startable
    pre = pagerank(adj, tol=tol)
    for label, k in (("1 edge", 1), ("0.1% nnz", max(adj.nnz // 1000, 2))):
        delta = EdgeDelta.from_updates(adj, inserts=_random_inserts(
            adj, k, rng))
        mutated = adj.apply_delta(delta)
        cold = pagerank(mutated, tol=tol)
        warm = pagerank(mutated, tol=tol, r0=pre.values)
        # both runs stop inside the tol-ball of the fixpoint; they can
        # legitimately differ by ~tol/(1-damping)
        np.testing.assert_allclose(warm.values, cold.values,
                                   rtol=1e-3, atol=1e-4)
        rows.append(["pagerank", label, k, cold.n_iters, warm.n_iters,
                     warm.n_iters / max(cold.n_iters, 1)])

    # sssp: insert-only deltas keep old distances valid upper bounds
    src = int(np.argmax(adj.row_lengths()))
    pre_d = sssp(adj, src)
    delta = EdgeDelta.from_updates(adj, inserts=_random_inserts(adj, 3, rng))
    mutated = adj.apply_delta(delta)
    cold = sssp(mutated, src)
    warm = sssp(mutated, src, d0=pre_d.values.reshape(1, -1))
    np.testing.assert_array_equal(warm.values, cold.values)
    rows.append(["sssp", "3 edges", 3, cold.n_iters, warm.n_iters,
                 warm.n_iters / max(cold.n_iters, 1)])

    common.emit(rows,
                ["analytic", "delta", "delta_nnz", "cold_iters",
                 "warm_iters", "warm_ratio"],
                f"warm-start re-convergence after an edge batch "
                f"(R-MAT, n=2^{cfg['log2n']}, tol={tol:g})")

    # single-edge pagerank must re-converge in under half the
    # from-scratch iterations, sssp in no more than from-scratch
    assert rows[0][4] < 0.5 * rows[0][3], \
        f"warm pagerank {rows[0][4]} iters not < 50% of cold {rows[0][3]}"
    assert rows[-1][4] <= rows[-1][3]


def main() -> None:
    cfg = _config()
    _latency_section(cfg)
    _warm_start_section(cfg)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 16
    if args.smoke:
        common.SMOKE = True
    main()
