"""Paper Figs. 3-6 + Table I: the five compound metrics across the size
sweep, FD vs R-MAT, serial and parallel.

Empirical profiles up to 2^EMPIRICAL_MAX_LOG2 rows; synthetic profiles
(exactly the same analytic machinery) continue the sweep to the paper's
2^26.  One benchmark function per paper artifact:

    fig3a_l2_miss_rate   fig3b_l3_miss_rate   fig4_l2_stalls
    fig5_prefetch_rate   fig6_gflops          table1_capacity

Each returns CSV rows; `main()` prints them all (invoked by benchmarks.run).
"""
from __future__ import annotations

import functools

from repro.core.cache_model import (CacheMetrics, analytic_metrics_from_profile,
                                    profile_fd, profile_of, profile_rmat,
                                    table1_capacity)
from repro.core.generators import fd_matrix, rmat_matrix

from . import common
from .common import PAPER_MAX_LOG2, PAPER_MIN_LOG2, THREADS, emit


@functools.lru_cache(maxsize=None)
def _profile(kind: str, log2n: int):
    n = 2 ** log2n
    if log2n <= common.EMPIRICAL_MAX_LOG2:
        gen = fd_matrix if kind == "fd" else rmat_matrix
        return profile_of(gen(n)), "empirical"
    syn = profile_fd(n) if kind == "fd" else profile_rmat(n)
    return syn, "synthetic"


@functools.lru_cache(maxsize=None)
def _metrics(kind: str, log2n: int, threads: int) -> CacheMetrics:
    prof, _ = _profile(kind, log2n)
    return analytic_metrics_from_profile(prof, threads=threads)


def _sweep_rows(metric_fn, threads_list=(1, 16)):
    rows = []
    for kind in ("fd", "rmat"):
        for log2n in range(PAPER_MIN_LOG2, PAPER_MAX_LOG2 + 1):
            _, src = _profile(kind, log2n)
            nnz = _metrics(kind, log2n, 1).nnz
            for t in threads_list:
                m = _metrics(kind, log2n, t)
                rows.append([kind, log2n, nnz, t, src, metric_fn(m)])
    return rows


_HDR = ["matrix", "log2_rows", "nnz", "threads", "profile", "value"]


def fig3a_l2_miss_rate() -> str:
    return emit(_sweep_rows(lambda m: m.l2_miss_rate), _HDR,
                "paper_fig3a: L2 miss rate / kinst (FD~0.1 flat; R-MAT "
                "jumps past L2 capacity, plateau ~26)")


def fig3b_l3_miss_rate() -> str:
    return emit(_sweep_rows(lambda m: m.l3_miss_rate), _HDR,
                "paper_fig3b: L3 miss rate / kinst (FD~0.1; R-MAT jumps "
                "past L3 capacity, plateau ~25 -> L3 useless)")


def fig4_l2_stalls() -> str:
    return emit(_sweep_rows(lambda m: m.l2_stall_frac), _HDR,
                "paper_fig4: L2 stall cycle fraction (R-MAT plateau ~0.7)")


def fig5_prefetch_rate() -> str:
    return emit(_sweep_rows(lambda m: m.prefetch_miss_rate), _HDR,
                "paper_fig5: prefetch fills / kinst (high = prefetcher "
                "working; R-MAT shutoff under DRAM congestion)")


def fig6_gflops() -> str:
    return emit(_sweep_rows(lambda m: m.gflops, threads_list=THREADS), _HDR,
                "paper_fig6: GFLOPS across sizes and 1..16 threads "
                "(FD flat; R-MAT falls past L3 to ~20% of FD)")


def table1() -> str:
    rows = []
    for par in (False, True):
        for kind, nnzr in (("fd", 9.0), ("rmat", 8.0)):
            caps = table1_capacity(nnz_per_row=nnzr, parallel=par)
            rows.append(["parallel" if par else "serial", kind,
                         caps["L2"], caps["L3"]])
    return emit(rows, ["mode", "matrix", "L2_max_nnz", "L3_max_nnz"],
                "paper_table1: max nnz fitting each cache level")


def paper_claims() -> str:
    """The four findings (F1-F4) as checkable numbers."""
    big = PAPER_MAX_LOG2
    rows = []
    fd_l2 = [_metrics("fd", k, 1).l2_miss_rate for k in range(11, big + 1)]
    rm_l2 = _metrics("rmat", big, 1).l2_miss_rate
    rm_l3 = _metrics("rmat", big, 1).l3_miss_rate
    rows.append(["F1_fd_l2_max", max(fd_l2), "~0.1 (near zero, flat)"])
    rows.append(["F1_rmat_l2_plateau", rm_l2, "~26"])
    rows.append(["F1_rmat_l3_plateau", rm_l3, "~25"])
    rows.append(["F1_l3_useless_ratio", rm_l3 / max(rm_l2, 1e-9),
                 "->1 (every L2 miss misses L3)"])
    s1 = _metrics("rmat", big, 1).l2_miss_rate
    s16 = _metrics("rmat", big, 16).l2_miss_rate
    rows.append(["F2_serial_vs_parallel_l2", s16 / max(s1, 1e-9),
                 "~1 (per-core capacity is what matters)"])
    rows.append(["F3_rmat_stall_plateau",
                 _metrics("rmat", big, 1).l2_stall_frac, "~0.7"])
    g = [_metrics("fd", 16, t).gflops for t in THREADS]
    scaling = [g[i + 1] / g[i] for i in range(len(g) - 1)]
    rows.append(["F4_fd_thread_scaling_min", min(scaling),
                 "~2x per doubling"])
    ratio = (_metrics("rmat", big, 16).gflops
             / _metrics("fd", big, 16).gflops)
    rows.append(["F4_rmat_over_fd_gflops", ratio, "~0.20"])
    return emit(rows, ["claim", "value", "paper_target"],
                "paper_claims: findings F1-F4 vs paper targets")


def main() -> None:
    table1()
    fig3a_l2_miss_rate()
    fig3b_l3_miss_rate()
    fig4_l2_stalls()
    fig5_prefetch_rate()
    fig6_gflops()
    paper_claims()


if __name__ == "__main__":
    main()
