"""Whole-analytic structure gap: the paper's §I motivation, measured.

The paper measures one SpMV; its motivation is iterated SpMV inside
"network and graph analytics".  This bench runs the `repro.graph`
drivers (PageRank, BFS, SSSP -- plus-times / or-and / min-plus semiring
plans compiled once, executed per iteration) on FD and R-MAT, then
replays each run's memoized address trace per iteration through a warm
hierarchy, so the FD-vs-R-MAT gap is reported on the *analytic*, not
the single multiply:

  * gap_cold    one cold SpMV -- the paper's single-kernel view;
  * gap_warm    a steady-state iteration (what survives in cache
                between SpMVs);
  * gap_total   the whole analytic, iteration counts included -- the
                end-to-end number the motivation actually implies.

Geometry is the working-set-scaled reference cell (L2 16 KiB, LLC
64 KiB -- same cell as scaling_bench): at 2^12, R-MAT's x gathers no
longer fit the L2, so warm iterations keep missing while FD's bands
stay resident -- the compounding regime.

The sweep runs twice: once with structure-driven format choice (R-MAT
plans auto-route to the hybrid row split) and once with every plan
pinned to CSR -- the historical baseline -- so the final section
reports how much of the warm R-MAT gap the nnz-balanced containers
recover.  In smoke mode the bench asserts the R-MAT plans actually
picked an nnz-balanced container.

Invoked by `benchmarks.run` (section name: graph) or directly:

    PYTHONPATH=src python -m benchmarks.graph_bench [--fast] [--smoke]
"""
from __future__ import annotations

from repro.telemetry.hierarchy import HierarchySpec
from repro.telemetry.report import graph_gap_report, graph_report
from repro.telemetry.sweep import graph_sweep

from . import common

# Working-set-scaled cell (see module docstring / scaling_bench).
SCALED_CELL = HierarchySpec(l2_bytes=16 * 1024, l3_bytes=64 * 1024)

ANALYTICS = ("pagerank", "bfs", "sssp")


def _config():
    # caps sized so every analytic converges at the paired geometry
    # (FD pagerank is the slowest: 76 iters at 2^12); runs that still
    # hit a cap are starred in the gap report rather than silently
    # truncating gap_total
    if common.SMOKE:
        return (8,), 96
    if common.EMPIRICAL_MAX_LOG2 <= 16:          # --fast (here or via run.py)
        return (10,), 96
    return (12,), 128


def _recovered_gap_report(auto_pts, csr_pts) -> str:
    """How much of the warm FD-vs-R-MAT gap the auto-picked nnz-balanced
    containers recover, vs the same sweep with every plan pinned to CSR.

    warm_gap = rmat.warm_cyc_nnz / fd.warm_cyc_nnz per (size, analytic);
    the csr column is the historical baseline (EXPERIMENTS.md's ~1.8x),
    the auto column is with structure-driven format choice (R-MAT plans
    route to the hybrid row split), recovered = 1 - auto/csr."""
    def by(pts):
        return {(p.kind, p.log2n, p.analytic): p for p in pts}
    a, c = by(auto_pts), by(csr_pts)
    lines = ["# warm R-MAT gap recovered by nnz-balanced containers",
             "log2n,analytic,rmat_format,warm_gap_csr,warm_gap_auto,"
             "recovered"]
    for (log2n, analytic) in sorted({(p.log2n, p.analytic)
                                     for p in auto_pts}):
        cells = [m.get(("fd", log2n, analytic)) for m in (a, c)]
        cells += [m.get(("rmat", log2n, analytic)) for m in (a, c)]
        fd_a, fd_c, rm_a, rm_c = cells
        if None in cells:
            continue
        gap_a = rm_a.warm_cycles_per_nnz / max(fd_a.warm_cycles_per_nnz,
                                               1e-12)
        gap_c = rm_c.warm_cycles_per_nnz / max(fd_c.warm_cycles_per_nnz,
                                               1e-12)
        lines.append(",".join([
            str(log2n), analytic, rm_a.format_name,
            f"{gap_c:.3f}", f"{gap_a:.3f}", f"{1.0 - gap_a / gap_c:.3f}"]))
    return "\n".join(lines)


def main() -> None:
    log2ns, max_iters = _config()
    pts = graph_sweep(log2ns=log2ns, analytics=ANALYTICS, spec=SCALED_CELL,
                      max_iters=max_iters)
    # fixed-format baseline: the same sweep with every plan pinned to CSR,
    # to measure what the auto-picked nnz-balanced containers recover
    pts_csr = graph_sweep(log2ns=log2ns, analytics=ANALYTICS,
                          spec=SCALED_CELL, max_iters=max_iters,
                          format="csr")
    print(graph_report(pts))
    print()
    print(graph_gap_report(pts))
    print()
    print(_recovered_gap_report(pts, pts_csr))
    if common.SMOKE:
        picked = {p.format_name for p in pts if p.kind == "rmat"}
        assert picked & {"hyb", "csr-seg"}, (
            f"R-MAT plans auto-picked only {picked}: the nnz-balanced "
            "candidates are not being selected")
        print(f"# smoke: R-MAT plans auto-picked {sorted(picked)}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 16
    if args.smoke:
        common.SMOKE = True
    main()
