"""Whole-analytic structure gap: the paper's §I motivation, measured.

The paper measures one SpMV; its motivation is iterated SpMV inside
"network and graph analytics".  This bench runs the `repro.graph`
drivers (PageRank, BFS, SSSP -- plus-times / or-and / min-plus semiring
plans compiled once, executed per iteration) on FD and R-MAT, then
replays each run's memoized address trace per iteration through a warm
hierarchy, so the FD-vs-R-MAT gap is reported on the *analytic*, not
the single multiply:

  * gap_cold    one cold SpMV -- the paper's single-kernel view;
  * gap_warm    a steady-state iteration (what survives in cache
                between SpMVs);
  * gap_total   the whole analytic, iteration counts included -- the
                end-to-end number the motivation actually implies.

Geometry is the working-set-scaled reference cell (L2 16 KiB, LLC
64 KiB -- same cell as scaling_bench): at 2^12, R-MAT's x gathers no
longer fit the L2, so warm iterations keep missing while FD's bands
stay resident -- the compounding regime.

Invoked by `benchmarks.run` (section name: graph) or directly:

    PYTHONPATH=src python -m benchmarks.graph_bench [--fast] [--smoke]
"""
from __future__ import annotations

from repro.telemetry.hierarchy import HierarchySpec
from repro.telemetry.report import graph_gap_report, graph_report
from repro.telemetry.sweep import graph_sweep

from . import common

# Working-set-scaled cell (see module docstring / scaling_bench).
SCALED_CELL = HierarchySpec(l2_bytes=16 * 1024, l3_bytes=64 * 1024)

ANALYTICS = ("pagerank", "bfs", "sssp")


def _config():
    # caps sized so every analytic converges at the paired geometry
    # (FD pagerank is the slowest: 76 iters at 2^12); runs that still
    # hit a cap are starred in the gap report rather than silently
    # truncating gap_total
    if common.SMOKE:
        return (8,), 96
    if common.EMPIRICAL_MAX_LOG2 <= 16:          # --fast (here or via run.py)
        return (10,), 96
    return (12,), 128


def main() -> None:
    log2ns, max_iters = _config()
    pts = graph_sweep(log2ns=log2ns, analytics=ANALYTICS, spec=SCALED_CELL,
                      max_iters=max_iters)
    print(graph_report(pts))
    print()
    print(graph_gap_report(pts))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.fast:
        common.EMPIRICAL_MAX_LOG2 = 16
    if args.smoke:
        common.SMOKE = True
    main()
