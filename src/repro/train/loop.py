"""Training step factory: loss -> grads -> optimizer, with microbatching.

The returned step is a pure function suitable for pjit: the launcher wraps
it with in/out shardings from distributed.sharding and the dry-run lowers
it with ShapeDtypeStructs.  Gradient accumulation runs as a lax.scan over
microbatches (activation memory / accum trade-off is a config knob).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import ModelAPI
from repro.optim import OptimizerConfig, make_optimizer

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    remat: str = "full"           # none | dots | full
    accum_steps: int = 1          # microbatch count (grad accumulation)
    log_every: int = 10
    checkpoint_every: int = 500
    n_steps: int = 100


def make_train_step(api: ModelAPI, tc: TrainConfig
                    ) -> Callable[[Params, Any, Dict[str, jax.Array]],
                                  Tuple[Params, Any, Dict[str, jax.Array]]]:
    _, opt_update = make_optimizer(tc.optimizer)

    def loss_fn(params, batch):
        return api.loss_fn(params, batch, remat=tc.remat)

    def train_step(params, opt_state, batch):
        if tc.accum_steps <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            a = tc.accum_steps

            def slice_mb(x):
                b = x.shape[0]
                return jnp.moveaxis(
                    x.reshape((a, b // a) + x.shape[1:]), 0, 0)

            mbs = jax.tree.map(slice_mb, batch)

            def acc_body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(
                    lambda ga, g: ga + g.astype(jnp.float32),
                    grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), zeros), mbs)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)

        new_params, new_opt_state, metrics = opt_update(grads, opt_state,
                                                        params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt_state, metrics

    return train_step


def init_train_state(api: ModelAPI, tc: TrainConfig, rng) -> Tuple[Params, Any]:
    params = api.init(rng)
    opt_init, _ = make_optimizer(tc.optimizer)
    return params, opt_init(params)
