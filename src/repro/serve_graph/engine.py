"""The analytics serving engine: continuous batching over the plan cache.

`GraphEngine` is `serve.Engine`'s sibling with the decode step swapped
for a semiring SpMV: registered graphs play the role of model weights,
compiled `SpmvPlan`s the role of the compiled decode program, and one
engine step advances *every* running analytic by one iteration.

Per step:

  1. admission (`AdmissionController.intake`): warm requests -- plan
     already resident in the `PlanCache` -- go ready immediately; misses
     queue behind a bounded compile queue with FIFO back-pressure;
  2. at most `compiles_per_step` queued plans compile, releasing every
     request pending on them (so compiles never stall running work for
     longer than the configured budget);
  3. the lane scheduler admits ready requests FIFO, preempting
     youngest-first when the lane pool is exhausted;
  4. running requests are grouped by plan: all lanes iterating the same
     compiled plan -- e.g. forty BFS sources across a dozen requests on
     one graph -- coalesce into a single `execute_many` call, padded up
     to a power-of-two lane count so only O(log lanes) batched programs
     ever JIT per plan (the same discipline as `serve`'s prefill
     bucketing); per-request convergence then releases lanes
     individually.

The engine is host-side deterministic: identical request traces produce
identical schedules, preemption logs, and bit-identical results
(pinned by `tests/test_serve_graph.py`).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.drivers import (ANALYTICS, analytic_operand, check_sources,
                                 make_stepper, plan_options)
from repro.plan import PlanCache

from .admission import AdmissionController
from .requests import AnalyticRequest, AnalyticResult
from .scheduler import GraphScheduler, RunningRequest


@dataclasses.dataclass
class GraphEngineConfig:
    n_lanes: int = 64               # batch-lane pool (= max coalesced width)
    compile_queue_cap: int = 8      # bounded miss queue (back-pressure past it)
    compiles_per_step: Optional[int] = 1   # compile budget per engine step;
                                    # None drains the queue every step (the
                                    # right pairing with predictor='model',
                                    # where a compile is microseconds)
    max_plans: int = 64             # plan-cache LRU capacity
    reorder: str = "none"           # compile option for every served plan
    predictor: str = "none"         # candidate scoring mode for served plans
                                    # ('none' keeps cache keys identical to
                                    # the blocking drivers' defaults; 'model'
                                    # enables the learned fast path for
                                    # reorder='auto' fleets)
    use_pallas: bool = True
    interpret: Optional[bool] = None
    max_iters_default: int = 256    # per-request iteration cap
    lane_bucket: bool = True        # pad batches to pow2 lane counts


class GraphEngine:
    def __init__(self, cfg: Optional[GraphEngineConfig] = None,
                 plan_cache: Optional[PlanCache] = None):
        self.cfg = cfg or GraphEngineConfig()
        self.plan_cache = (plan_cache if plan_cache is not None
                           else PlanCache(max_plans=self.cfg.max_plans))
        self.admission = AdmissionController(
            self.plan_cache, compile_queue_cap=self.cfg.compile_queue_cap)
        self.scheduler = GraphScheduler(self.cfg.n_lanes)
        self.graphs: Dict[str, object] = {}
        self._derived: Dict[Tuple[str, str], Tuple[object, Dict, Dict, str]] = {}
        self._by_key: Dict[str, Tuple[object, Dict]] = {}
        self.results: Dict[int, AnalyticResult] = {}
        self.step_count = 0
        self.submitted = 0
        self.spmm_calls = 0
        self.max_running = 0
        self.max_inflight = 0

    # -- registration / intake ----------------------------------------------

    def register_graph(self, graph_id: str, adj) -> None:
        """Register an adjacency under a serving id.  Operand derivation
        (stochastic/pattern transposes) and plan compilation stay lazy:
        nothing is paid until a request arrives for the graph."""
        if adj.n_rows != adj.n_cols:
            raise ValueError(f"graph {graph_id!r} must be square, "
                             f"got {adj.n_rows}x{adj.n_cols}")
        self.graphs[graph_id] = adj

    def submit(self, req: AnalyticRequest) -> None:
        """Validate and enqueue.  Rejections are immediate (unknown
        graph/analytic, out-of-range sources, wider than the lane pool)
        so malformed requests can never deadlock admission."""
        adj = self.graphs.get(req.graph_id)
        if adj is None:
            raise KeyError(f"graph {req.graph_id!r} is not registered; "
                           f"have {sorted(self.graphs)}")
        if req.analytic not in ANALYTICS:
            raise ValueError(f"unknown analytic {req.analytic!r}; "
                             f"have {sorted(ANALYTICS)}")
        if req.sources and req.analytic == "connected_components":
            raise ValueError("connected_components takes no sources")
        check_sources(np.asarray(req.sources, dtype=np.int64), adj.n_rows,
                      req.analytic)
        if req.lanes > self.cfg.n_lanes:
            raise ValueError(f"request {req.req_id} needs {req.lanes} lanes "
                             f"but the pool has {self.cfg.n_lanes}")
        req.arrived_step = self.step_count
        self.submitted += 1
        self.admission.submit(req)

    # -- plan resolution -----------------------------------------------------

    def _derive(self, graph_id: str, analytic: str):
        """(operand matrix, compile opts, aux, plan key) for one
        (graph, analytic) -- derived once, then reused by every request.
        Uses the drivers' own `plan_options`, so engine-compiled plans
        and blocking-driver plans share cache entries."""
        ck = (graph_id, analytic)
        hit = self._derived.get(ck)
        if hit is not None:
            return hit
        matrix, semiring, aux = analytic_operand(analytic,
                                                 self.graphs[graph_id])
        opts = plan_options(semiring, reorder=self.cfg.reorder,
                            predictor=self.cfg.predictor,
                            use_pallas=self.cfg.use_pallas,
                            interpret=self.cfg.interpret)
        key = self.plan_cache.key_for(matrix, **opts)
        self._derived[ck] = (matrix, opts, aux, key)
        self._by_key[key] = (matrix, opts)
        return self._derived[ck]

    def _key_of(self, req: AnalyticRequest) -> str:
        return self._derive(req.graph_id, req.analytic)[3]

    def _compile_key(self, key: str):
        matrix, opts = self._by_key[key]
        return self.plan_cache.get_or_compile(matrix, **opts)

    def _start(self, req: AnalyticRequest) -> RunningRequest:
        matrix, opts, aux, key = self._derive(req.graph_id, req.analytic)
        plan = self.plan_cache.get_or_compile(matrix, **opts)  # warm: a hit
        stepper = make_stepper(req.analytic, plan, aux,
                               sources=np.asarray(req.sources, np.int64),
                               params=req.params)
        cap = (req.max_iters if req.max_iters is not None
               else self.cfg.max_iters_default)
        return RunningRequest(req=req, stepper=stepper, plan=plan,
                              plan_key=key, max_iters=cap)

    # -- the engine step ------------------------------------------------------

    def step(self) -> None:
        self.step_count += 1
        for req in self.admission.intake(self._key_of):
            self.scheduler.push_ready(req)
        for req in self.admission.run_compiles(self.cfg.compiles_per_step,
                                               self._compile_key):
            self.scheduler.push_ready(req)
        self.scheduler.admit(self.step_count, self._start)
        self.max_running = max(self.max_running, len(self.scheduler.running))
        self.max_inflight = max(
            self.max_inflight, self.submitted - len(self.results))
        self._iterate_running()

    def _iterate_running(self) -> None:
        """One coalesced SpMV iteration per distinct plan, then release
        every request that converged (or hit its iteration cap)."""
        groups: "OrderedDict[str, List[RunningRequest]]" = OrderedDict()
        for run in self.scheduler.running:
            if not run.stepper.done:
                groups.setdefault(run.plan_key, []).append(run)
        for key, members in groups.items():
            fronts = [np.asarray(m.stepper.frontier(), np.float32)
                      for m in members]
            F = np.concatenate(fronts, axis=0)
            k = F.shape[0]
            kpad = 1 << max(k - 1, 0).bit_length() if self.cfg.lane_bucket \
                else k
            if kpad > k:
                F = np.concatenate(
                    [F, np.zeros((kpad - k, F.shape[1]), F.dtype)], axis=0)
            y = np.asarray(members[0].plan.execute_many(jnp.asarray(F)))[:k]
            self.spmm_calls += 1
            off = 0
            for m, f in zip(members, fronts):
                w = f.shape[0]
                m.stepper.advance(y[off:off + w])
                m.iters += 1
                off += w
        for run in list(self.scheduler.running):
            if run.stepper.done or run.iters >= run.max_iters:
                self._finish(run)

    def _finish(self, run: RunningRequest) -> None:
        self.scheduler.finish(run, self.step_count)
        req = run.req
        self.results[req.req_id] = AnalyticResult(
            req_id=req.req_id, graph_id=req.graph_id, analytic=req.analytic,
            values=np.asarray(run.stepper.values()), n_iters=run.iters,
            converged=bool(run.stepper.done),
            arrived_step=req.arrived_step, admitted_step=req.admitted_step,
            finished_step=req.finished_step, restarts=req.restarts)

    # -- driving --------------------------------------------------------------

    @property
    def idle(self) -> bool:
        return self.admission.idle and self.scheduler.idle

    def run(self, max_steps: int = 100_000) -> Dict[int, AnalyticResult]:
        """Step until every submitted request has a result (or the step
        budget runs out -- a stuck engine raises rather than spinning)."""
        for _ in range(max_steps):
            if self.idle:
                return self.results
            self.step()
        if not self.idle:
            raise RuntimeError(
                f"engine not idle after {max_steps} steps: "
                f"{self.admission.stats()} {self.scheduler.stats()}")
        return self.results

    def stats(self) -> Dict:
        adm = self.admission.stats()
        served = adm["warm_hits"] + adm["cold_misses"]
        return {
            "steps": self.step_count,
            "submitted": self.submitted,
            "finished": len(self.results),
            "preemptions": self.scheduler.preemptions,
            "warm_hits": adm["warm_hits"],
            "cold_misses": adm["cold_misses"],
            "backpressure": adm["backpressure"],
            "admission_hit_rate": adm["warm_hits"] / served if served else 0.0,
            "max_running": self.max_running,
            "max_inflight": self.max_inflight,
            "spmm_calls": self.spmm_calls,
            "plan_cache": self.plan_cache.stats(),
        }


__all__ = ["GraphEngine", "GraphEngineConfig"]
