"""The analytics serving engine: continuous batching over the plan cache.

`GraphEngine` is `serve.Engine`'s sibling with the decode step swapped
for a semiring SpMV: registered graphs play the role of model weights,
compiled `SpmvPlan`s the role of the compiled decode program, and one
engine step advances *every* running analytic by one iteration.

Per step:

  1. admission (`AdmissionController.intake`): warm requests -- plan
     already resident in the `PlanCache` -- go ready immediately; misses
     queue behind a bounded compile queue with FIFO back-pressure;
  2. at most `compiles_per_step` queued plans compile, releasing every
     request pending on them (so compiles never stall running work for
     longer than the configured budget);
  3. the lane scheduler admits ready requests FIFO, preempting
     youngest-first when the lane pool is exhausted;
  4. running requests are grouped by plan: all lanes iterating the same
     compiled plan -- e.g. forty BFS sources across a dozen requests on
     one graph -- coalesce into a single `execute_many` call, padded up
     to a power-of-two lane count so only O(log lanes) batched programs
     ever JIT per plan (the same discipline as `serve`'s prefill
     bucketing); per-request convergence then releases lanes
     individually.

The engine is host-side deterministic: identical request traces produce
identical schedules, preemption logs, and bit-identical results
(pinned by `tests/test_serve_graph.py`).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.delta import EdgeDelta, csr_diff
from repro.graph.drivers import (ANALYTICS, analytic_operand, check_sources,
                                 make_stepper, plan_options,
                                 warm_start_params)
from repro.plan import PlanCache, compile as compile_plan
from repro.plan.overlay import OverlaidPlan, overlay, overlay_eligible

from .admission import AdmissionController
from .requests import (AnalyticRequest, AnalyticResult, GraphMutation,
                       MutationResult)
from .scheduler import GraphScheduler, RunningRequest


@dataclasses.dataclass
class GraphEngineConfig:
    n_lanes: int = 64               # batch-lane pool (= max coalesced width)
    compile_queue_cap: int = 8      # bounded miss queue (back-pressure past it)
    compiles_per_step: Optional[int] = 1   # compile budget per engine step;
                                    # None drains the queue every step (the
                                    # right pairing with predictor='model',
                                    # where a compile is microseconds)
    max_plans: int = 64             # plan-cache LRU capacity
    reorder: str = "none"           # compile option for every served plan
    predictor: str = "none"         # candidate scoring mode for served plans
                                    # ('none' keeps cache keys identical to
                                    # the blocking drivers' defaults; 'model'
                                    # enables the learned fast path for
                                    # reorder='auto' fleets)
    use_pallas: bool = True
    interpret: Optional[bool] = None
    max_iters_default: int = 256    # per-request iteration cap
    lane_bucket: bool = True        # pad batches to pow2 lane counts
    staleness_budget: float = 0.05  # delta_nnz/base_nnz past which a
                                    # mutation forces a background re-plan
                                    # + atomic swap instead of an overlay


@dataclasses.dataclass
class _Derived:
    """Per-(graph, analytic) plan lineage state.

    `matrix` is the CURRENT effective operand (what a cold compile under
    `key` would freeze); `base_matrix` is the operand the resident base
    plan froze, and `delta` the accumulated operand delta between them
    (None once rebased).  `key` is the serving cache key -- content key
    for a fresh/rebased lineage, chained key for an overlaid one."""

    matrix: object
    opts: Dict
    aux: Dict
    key: str
    base_matrix: object
    delta: Optional[EdgeDelta] = None


class GraphEngine:
    def __init__(self, cfg: Optional[GraphEngineConfig] = None,
                 plan_cache: Optional[PlanCache] = None):
        self.cfg = cfg or GraphEngineConfig()
        self.plan_cache = (plan_cache if plan_cache is not None
                           else PlanCache(max_plans=self.cfg.max_plans))
        self.admission = AdmissionController(
            self.plan_cache, compile_queue_cap=self.cfg.compile_queue_cap)
        self.scheduler = GraphScheduler(self.cfg.n_lanes)
        self.graphs: Dict[str, object] = {}
        self._derived: Dict[Tuple[str, str], _Derived] = {}
        self._by_key: Dict[str, Tuple[object, Dict]] = {}
        self.results: Dict[int, AnalyticResult] = {}
        self.mutation_results: Dict[int, MutationResult] = {}
        self._mutations: Deque[GraphMutation] = deque()
        self._swap_on_land: Dict[str, str] = {}   # new key -> key to retire
        self._warm_state: Dict[int, Dict] = {}    # req_id -> stepper params
        self.step_count = 0
        self.submitted = 0
        self.mutations_applied = 0
        self.spmm_calls = 0
        self.max_running = 0
        self.max_inflight = 0

    # -- registration / intake ----------------------------------------------

    def register_graph(self, graph_id: str, adj) -> None:
        """Register an adjacency under a serving id.  Operand derivation
        (stochastic/pattern transposes) and plan compilation stay lazy:
        nothing is paid until a request arrives for the graph."""
        if adj.n_rows != adj.n_cols:
            raise ValueError(f"graph {graph_id!r} must be square, "
                             f"got {adj.n_rows}x{adj.n_cols}")
        self.graphs[graph_id] = adj

    def submit(self, req) -> None:
        """Validate and enqueue.  Rejections are immediate (unknown
        graph/analytic, out-of-range sources, wider than the lane pool)
        so malformed requests can never deadlock admission.
        `GraphMutation`s queue separately and apply at the top of the
        next step, before any admission or iteration -- submit order is
        the serialization order of the edge stream."""
        if isinstance(req, GraphMutation):
            if req.graph_id not in self.graphs:
                raise KeyError(f"graph {req.graph_id!r} is not registered; "
                               f"have {sorted(self.graphs)}")
            req.arrived_step = self.step_count
            self._mutations.append(req)
            return
        adj = self.graphs.get(req.graph_id)
        if adj is None:
            raise KeyError(f"graph {req.graph_id!r} is not registered; "
                           f"have {sorted(self.graphs)}")
        if req.analytic not in ANALYTICS:
            raise ValueError(f"unknown analytic {req.analytic!r}; "
                             f"have {sorted(ANALYTICS)}")
        if req.sources and req.analytic == "connected_components":
            raise ValueError("connected_components takes no sources")
        check_sources(np.asarray(req.sources, dtype=np.int64), adj.n_rows,
                      req.analytic)
        if req.lanes > self.cfg.n_lanes:
            raise ValueError(f"request {req.req_id} needs {req.lanes} lanes "
                             f"but the pool has {self.cfg.n_lanes}")
        req.arrived_step = self.step_count
        self.submitted += 1
        self.admission.submit(req)

    # -- plan resolution -----------------------------------------------------

    def _derive(self, graph_id: str, analytic: str) -> _Derived:
        """The `_Derived` lineage record for one (graph, analytic) --
        derived once, then kept current by `_apply_mutation`.  Uses the
        drivers' own `plan_options`, so engine-compiled plans and
        blocking-driver plans share cache entries."""
        ck = (graph_id, analytic)
        hit = self._derived.get(ck)
        if hit is not None:
            return hit
        matrix, semiring, aux = analytic_operand(analytic,
                                                 self.graphs[graph_id])
        opts = plan_options(semiring, reorder=self.cfg.reorder,
                            predictor=self.cfg.predictor,
                            use_pallas=self.cfg.use_pallas,
                            interpret=self.cfg.interpret)
        key = self.plan_cache.key_for(matrix, **opts)
        st = _Derived(matrix=matrix, opts=opts, aux=aux, key=key,
                      base_matrix=matrix)
        self._derived[ck] = st
        self._by_key[key] = (matrix, opts)
        return st

    def _key_of(self, req: AnalyticRequest) -> str:
        return self._derive(req.graph_id, req.analytic).key

    def _compile_key(self, key: str):
        """Compile (or fetch) the plan stored under `key`.  Keys are
        looked up, never re-derived from matrix content -- an overlaid
        generation's chained key has no content-key equivalent.  A key
        flagged by the mutation lifecycle lands as a `PlanCache.swap`:
        the superseded generation retires atomically with the insert."""
        matrix, opts = self._by_key[key]
        supersedes = self._swap_on_land.pop(key, None)
        if supersedes is not None:
            return self.plan_cache.swap(
                key, lambda: compile_plan(matrix, **opts),
                supersedes=supersedes)
        return self.plan_cache.get_or_build(
            key, lambda: compile_plan(matrix, **opts))

    def _start(self, req: AnalyticRequest) -> RunningRequest:
        st = self._derive(req.graph_id, req.analytic)
        plan = self._compile_key(st.key)          # warm: a hit
        params = dict(req.params)
        warm = self._warm_state.pop(req.req_id, None)
        if warm is not None:
            params.update(warm)                   # resume migrated state
        stepper = make_stepper(req.analytic, plan, st.aux,
                               sources=np.asarray(req.sources, np.int64),
                               params=params)
        cap = (req.max_iters if req.max_iters is not None
               else self.cfg.max_iters_default)
        return RunningRequest(req=req, stepper=stepper, plan=plan,
                              plan_key=st.key, max_iters=cap)

    # -- the streaming mutation lifecycle -------------------------------------

    def _apply_mutation(self, mut: GraphMutation) -> None:
        """Apply one edge batch: mutate the adjacency, then move every
        derived (graph, analytic) lineage through the plan state
        machine -- overlay / background re-plan + swap / cold rebase --
        and rebind in-flight requests.  Runs at the top of a step, so
        within a step every iteration serves one generation."""
        adj = self.graphs[mut.graph_id]
        adj_delta = EdgeDelta.from_updates(adj, inserts=mut.inserts,
                                           deletes=mut.deletes)
        self.graphs[mut.graph_id] = adj.apply_delta(adj_delta)
        actions: Dict[str, str] = {}
        for (gid, analytic), st in list(self._derived.items()):
            if gid != mut.graph_id:
                continue
            actions[analytic] = self._shift_lineage(gid, analytic, st)
        self.mutations_applied += 1
        self.mutation_results[mut.req_id] = MutationResult(
            req_id=mut.req_id, graph_id=mut.graph_id,
            applied_step=self.step_count, delta_nnz=adj_delta.nnz,
            actions=actions)

    def _shift_lineage(self, gid: str, analytic: str, st: _Derived) -> str:
        """Move one derived lineage onto the mutated graph.  Returns the
        action taken (see `MutationResult`).  The serving key flips
        *here*, synchronously: new requests either warm-hit the
        installed overlay or wait on the parked compile -- there is no
        window in which a request can be admitted against the retired
        generation."""
        new_matrix, _, new_aux = analytic_operand(analytic,
                                                  self.graphs[gid])
        op_delta = csr_diff(st.matrix, new_matrix)
        old_key = st.key
        if op_delta.nnz == 0:
            st.matrix, st.aux = new_matrix, new_aux
            self._by_key[old_key] = (new_matrix, st.opts)
            return "noop"
        total = st.delta.merge(op_delta) if st.delta is not None else op_delta
        semiring = st.opts["semiring"]
        within = (overlay_eligible(total, semiring)
                  and total.nnz / max(st.base_matrix.nnz, 1)
                  <= self.cfg.staleness_budget)
        resident = self.plan_cache.peek(old_key) if within else None
        if resident is not None:
            if isinstance(resident, OverlaidPlan):
                over = overlay(resident, op_delta)
            else:
                over = overlay(resident, total, base_matrix=st.base_matrix,
                               staleness_budget=self.cfg.staleness_budget)
            new_key = self.plan_cache.chained_key(old_key, over.fingerprint)
            self.plan_cache.install_overlay(new_key, over,
                                            supersedes=old_key)
            st.delta = total
            action = "overlay"
        elif within:
            # nothing resident to overlay: re-root the lineage at the
            # materialized operand; the next request compiles it cold
            st.base_matrix, st.delta = new_matrix, None
            new_key = self.plan_cache.key_for(new_matrix, **st.opts)
            action = "rebase"
        else:
            # past budget or overlay-ineligible delete: retire the
            # serving key now, park exactly one background re-plan of
            # the materialized matrix, swap atomically when it lands
            st.base_matrix, st.delta = new_matrix, None
            new_key = self.plan_cache.key_for(new_matrix, **st.opts)
            self.plan_cache.note_delta_recompile()
            if new_key != old_key:
                self._swap_on_land[new_key] = old_key
            self.admission.park(new_key)
            action = "replan"
        st.matrix, st.aux, st.key = new_matrix, new_aux, new_key
        self._by_key[new_key] = (new_matrix, st.opts)
        self._rebind_running((gid, analytic), new_key, op_delta, st, action)
        return action

    def _rebind_running(self, ck: Tuple[str, str], new_key: str,
                        op_delta: EdgeDelta, st: _Derived,
                        action: str) -> None:
        """Move in-flight requests on a shifted lineage to its new
        generation.  Overlay: rebind in place (fresh stepper on the
        overlaid plan, warm-started when `warm_start_params` allows).
        Re-plan/rebase: migrate back through admission -- the request
        waits for the new plan like any cold arrival, stashing warm
        state for `_start` to consume, and keeps its original arrival
        seniority."""
        migrated: List[AnalyticRequest] = []
        for run in list(self.scheduler.running):
            if (run.req.graph_id, run.req.analytic) != ck:
                continue
            warm = warm_start_params(run.req.analytic, run.stepper.values(),
                                     op_delta)
            if action == "overlay":
                plan = self.plan_cache.peek(new_key)
                params = dict(run.req.params)
                if warm is not None:
                    params.update(warm)
                run.plan, run.plan_key = plan, new_key
                run.stepper = make_stepper(
                    run.req.analytic, plan, st.aux,
                    sources=np.asarray(run.req.sources, np.int64),
                    params=params)
            else:
                self.scheduler.migrate(run, self.step_count)
                if warm is not None:
                    self._warm_state[run.req.req_id] = warm
                migrated.append(run.req)
        for req in reversed(migrated):
            self.admission.waiting.appendleft(req)

    # -- the engine step ------------------------------------------------------

    def step(self) -> None:
        self.step_count += 1
        while self._mutations:
            self._apply_mutation(self._mutations.popleft())
        for req in self.admission.intake(self._key_of):
            self.scheduler.push_ready(req)
        for req in self.admission.run_compiles(self.cfg.compiles_per_step,
                                               self._compile_key):
            self.scheduler.push_ready(req)
        self.scheduler.admit(self.step_count, self._start)
        self.max_running = max(self.max_running, len(self.scheduler.running))
        self.max_inflight = max(
            self.max_inflight, self.submitted - len(self.results))
        self._iterate_running()

    def _iterate_running(self) -> None:
        """One coalesced SpMV iteration per distinct plan, then release
        every request that converged (or hit its iteration cap)."""
        groups: "OrderedDict[str, List[RunningRequest]]" = OrderedDict()
        for run in self.scheduler.running:
            if not run.stepper.done:
                groups.setdefault(run.plan_key, []).append(run)
        for key, members in groups.items():
            fronts = [np.asarray(m.stepper.frontier(), np.float32)
                      for m in members]
            F = np.concatenate(fronts, axis=0)
            k = F.shape[0]
            kpad = 1 << max(k - 1, 0).bit_length() if self.cfg.lane_bucket \
                else k
            if kpad > k:
                F = np.concatenate(
                    [F, np.zeros((kpad - k, F.shape[1]), F.dtype)], axis=0)
            y = np.asarray(members[0].plan.execute_many(jnp.asarray(F)))[:k]
            self.spmm_calls += 1
            off = 0
            for m, f in zip(members, fronts):
                w = f.shape[0]
                m.stepper.advance(y[off:off + w])
                m.iters += 1
                off += w
        for run in list(self.scheduler.running):
            if run.stepper.done or run.iters >= run.max_iters:
                self._finish(run)

    def _finish(self, run: RunningRequest) -> None:
        self.scheduler.finish(run, self.step_count)
        req = run.req
        self.results[req.req_id] = AnalyticResult(
            req_id=req.req_id, graph_id=req.graph_id, analytic=req.analytic,
            values=np.asarray(run.stepper.values()), n_iters=run.iters,
            converged=bool(run.stepper.done),
            arrived_step=req.arrived_step, admitted_step=req.admitted_step,
            finished_step=req.finished_step, restarts=req.restarts)

    # -- driving --------------------------------------------------------------

    @property
    def idle(self) -> bool:
        return (not self._mutations and self.admission.idle
                and self.scheduler.idle)

    def run(self, max_steps: int = 100_000) -> Dict[int, AnalyticResult]:
        """Step until every submitted request has a result (or the step
        budget runs out -- a stuck engine raises rather than spinning)."""
        for _ in range(max_steps):
            if self.idle:
                return self.results
            self.step()
        if not self.idle:
            raise RuntimeError(
                f"engine not idle after {max_steps} steps: "
                f"{self.admission.stats()} {self.scheduler.stats()}")
        return self.results

    def stats(self) -> Dict:
        adm = self.admission.stats()
        served = adm["warm_hits"] + adm["cold_misses"]
        return {
            "steps": self.step_count,
            "submitted": self.submitted,
            "finished": len(self.results),
            "mutations_applied": self.mutations_applied,
            "preemptions": self.scheduler.preemptions,
            "warm_hits": adm["warm_hits"],
            "cold_misses": adm["cold_misses"],
            "backpressure": adm["backpressure"],
            "admission_hit_rate": adm["warm_hits"] / served if served else 0.0,
            "max_running": self.max_running,
            "max_inflight": self.max_inflight,
            "spmm_calls": self.spmm_calls,
            "plan_cache": self.plan_cache.stats(),
        }


__all__ = ["GraphEngine", "GraphEngineConfig"]
