"""Graph-analytics serving: continuous batching over the plan cache.

The vLLM-style `repro.serve` pattern (scheduler / engine / admission)
re-based onto semiring analytics: a request is (graph, analytic,
sources, params), admission is a `PlanCache` warm-pool check with a
bounded compile queue, and each engine step coalesces every running
request on the same compiled plan into one `execute_many` SpMV.

  requests    AnalyticRequest / AnalyticResult records
  admission   warm-hit vs bounded compile queue with FIFO back-pressure
  scheduler   lane-pool FIFO admission, youngest-first preemption
  engine      the per-step loop: intake -> compile budget -> admit ->
              coalesced iterate -> per-request convergence release
"""
from .admission import AdmissionController
from .engine import GraphEngine, GraphEngineConfig
from .requests import AnalyticRequest, AnalyticResult
from .scheduler import GraphScheduler, RunningRequest

__all__ = ["AdmissionController", "GraphEngine", "GraphEngineConfig",
           "AnalyticRequest", "AnalyticResult", "GraphScheduler",
           "RunningRequest"]
