"""Graph-analytics serving: continuous batching over the plan cache.

The vLLM-style `repro.serve` pattern (scheduler / engine / admission)
re-based onto semiring analytics: a request is (graph, analytic,
sources, params), admission is a `PlanCache` warm-pool check with a
bounded compile queue, and each engine step coalesces every running
request on the same compiled plan into one `execute_many` SpMV.

  requests    AnalyticRequest / AnalyticResult records, plus the edge
              stream: GraphMutation batches and their MutationResult
  admission   warm-hit vs bounded compile queue with FIFO back-pressure;
              `park` queues forced background re-plans past the cap
  scheduler   lane-pool FIFO admission, youngest-first preemption,
              `migrate` for streaming plan retirement
  engine      the per-step loop: apply mutations -> intake -> compile
              budget -> admit -> coalesced iterate -> convergence
              release; mutations move each derived plan through the
              overlay / background-replan / rebase lifecycle
"""
from .admission import AdmissionController
from .engine import GraphEngine, GraphEngineConfig
from .requests import (AnalyticRequest, AnalyticResult, GraphMutation,
                       MutationResult)
from .scheduler import GraphScheduler, RunningRequest

__all__ = ["AdmissionController", "GraphEngine", "GraphEngineConfig",
           "AnalyticRequest", "AnalyticResult", "GraphMutation",
           "MutationResult", "GraphScheduler", "RunningRequest"]
