"""Lane-pool scheduler: FIFO admission, youngest-first preemption.

The policy is `serve.Scheduler`'s, re-based from KV blocks onto batch
lanes (one lane = one state vector riding a coalesced `execute_many`):

  * the ready set is ordered by (arrived_step, req_id) -- global
    seniority, so a preempted request re-enters at its arrival position
    rather than jumping the line or losing its place;
  * admission is strict FIFO: while the *oldest* ready request fits the
    free lanes, admit it; when it does not fit, preempt the youngest
    running request that is strictly younger than it, and only give up
    (no skip-ahead) when no such victim exists;
  * preemption restarts the victim from scratch (its stepper state is
    discarded, matching `serve`'s re-prefill discipline), and finished
    requests release their lanes individually the step they converge.

Everything is host-side and deterministic: identical request traces
produce identical `log` sequences of (step, event, req_id), which the
cross-engine determinism tests pin.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Dict, List, Tuple

from .requests import AnalyticRequest


@dataclasses.dataclass
class RunningRequest:
    """One admitted request: its per-iteration state machine plus the
    plan it multiplies through (plan_key groups co-batched work)."""
    req: AnalyticRequest
    stepper: object
    plan: object
    plan_key: str
    iters: int = 0
    max_iters: int = 0

    def seniority(self) -> Tuple[int, int]:
        return (self.req.arrived_step, self.req.req_id)


class GraphScheduler:
    def __init__(self, n_lanes: int):
        self.n_lanes = n_lanes
        self.ready: List[Tuple[int, int, AnalyticRequest]] = []
        self.running: List[RunningRequest] = []      # admission order
        self.finished: List[AnalyticRequest] = []
        self.preemptions = 0
        self.log: List[Tuple[int, str, int]] = []

    @property
    def lanes_used(self) -> int:
        return sum(r.req.lanes for r in self.running)

    @property
    def lanes_free(self) -> int:
        return self.n_lanes - self.lanes_used

    def push_ready(self, req: AnalyticRequest) -> None:
        bisect.insort(self.ready, (req.arrived_step, req.req_id, req))

    def admit(self, step: int, start: Callable[[AnalyticRequest],
                                               RunningRequest]
              ) -> List[RunningRequest]:
        """Admit ready requests in seniority order while lanes allow;
        `start` materializes the stepper (fresh state -- also the restart
        path after preemption).  Returns the newly admitted runs."""
        admitted: List[RunningRequest] = []
        while self.ready:
            arrived, rid, req = self.ready[0]
            if req.lanes <= self.lanes_free:
                self.ready.pop(0)
                req.admitted_step = step
                run = start(req)
                self.running.append(run)
                admitted.append(run)
                self.log.append((step, "admit", req.req_id))
                continue
            victim = self._youngest_younger_than((arrived, rid))
            if victim is None:
                break        # FIFO: do not skip ahead of the head request
            self._preempt(victim, step)
        return admitted

    def _youngest_younger_than(self, head_key: Tuple[int, int]):
        candidates = [r for r in self.running if r.seniority() > head_key]
        if not candidates:
            return None
        return max(candidates, key=RunningRequest.seniority)

    def _preempt(self, run: RunningRequest, step: int) -> None:
        self.running.remove(run)
        run.req.restarts += 1
        self.push_ready(run.req)     # re-enters at its arrival seniority
        self.preemptions += 1
        self.log.append((step, "preempt", run.req.req_id))

    def migrate(self, run: RunningRequest, step: int) -> None:
        """Pull a running request off the lane pool because its plan
        went cold (streaming re-plan): unlike preemption the caller
        re-routes the request through admission itself -- usually
        carrying warm stepper state over -- so nothing is pushed to
        `ready` here and no restart is counted."""
        self.running.remove(run)
        self.log.append((step, "migrate", run.req.req_id))

    def finish(self, run: RunningRequest, step: int) -> None:
        self.running.remove(run)
        run.req.finished_step = step
        self.finished.append(run.req)
        self.log.append((step, "finish", run.req.req_id))

    @property
    def idle(self) -> bool:
        return not self.ready and not self.running

    def stats(self) -> Dict[str, float]:
        return {"ready": len(self.ready), "running": len(self.running),
                "finished": len(self.finished),
                "lane_utilization": self.lanes_used / max(self.n_lanes, 1),
                "preemptions": self.preemptions}


__all__ = ["GraphScheduler", "RunningRequest"]
