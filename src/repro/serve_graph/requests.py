"""Request/result records for the graph-analytics serving engine.

A serving request is `(graph_id, analytic, sources, params)` -- the
graph-analytics analogue of a token prompt: `graph_id` names a
registered adjacency (admission resolves it to a plan-cache fingerprint),
`analytic` picks a semiring iteration from `graph.drivers.ANALYTICS`,
`sources` are the seed vertices (one batch lane each), and `params`
forwards analytic-specific knobs (PageRank damping/tol).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class AnalyticRequest:
    req_id: int
    graph_id: str
    analytic: str
    sources: Tuple[int, ...] = ()
    params: Dict = dataclasses.field(default_factory=dict)
    max_iters: Optional[int] = None     # None -> engine default
    # bookkeeping, stamped by the engine
    arrived_step: int = 0
    admitted_step: int = -1
    finished_step: int = -1
    restarts: int = 0

    @property
    def lanes(self) -> int:
        """Batch lanes this request occupies while running.  One per
        source; sourceless analytics (classic PageRank, connected
        components) carry one state vector -> one lane.  An explicit
        empty source list is a zero-work request that still passes
        through the pipeline (admitted, finished, (0, n) values) --
        it is billed one lane for the step it occupies."""
        return max(1, len(self.sources))


@dataclasses.dataclass
class GraphMutation:
    """An edge-stream batch against a registered graph, interleaved with
    analytic requests.  `inserts` are (row, col, value) triples naming
    absent coordinates, `deletes` are (row, col) pairs naming present
    ones (change a weight by deleting + inserting in one batch) -- the
    `repro.core.delta.EdgeDelta` contract.  The engine applies pending
    mutations at the top of the next step, in submit order: every
    analytic submitted after a mutation sees the mutated graph."""

    req_id: int
    graph_id: str
    inserts: Tuple = ()
    deletes: Tuple = ()
    arrived_step: int = 0


@dataclasses.dataclass
class MutationResult:
    """How one mutation moved each derived (graph, analytic) plan:
    `actions[analytic]` is 'overlay' (delta-overlaid plan installed
    warm), 'replan' (past budget / ineligible delete -- background
    re-plan parked, atomic swap on landing), 'rebase' (no plan was
    resident; next request compiles the materialized matrix cold), or
    'noop' (the analytic's operand was unchanged)."""

    req_id: int
    graph_id: str
    applied_step: int
    delta_nnz: int
    actions: Dict[str, str]


@dataclasses.dataclass
class AnalyticResult:
    req_id: int
    graph_id: str
    analytic: str
    values: np.ndarray          # (lanes, n) -- (0, n) for empty sources
    n_iters: int
    converged: bool
    arrived_step: int
    admitted_step: int
    finished_step: int
    restarts: int

    @property
    def latency_steps(self) -> int:
        """End-to-end steps from arrival to completion -- queueing,
        compile stalls, preemption restarts included.  The serving
        benchmark converts this to modelled time by costing each
        request's iterations through `graph.telemetry`."""
        return self.finished_step - self.arrived_step


__all__ = ["AnalyticRequest", "AnalyticResult", "GraphMutation",
           "MutationResult"]
