"""Admission control: warm plans schedule now, cold plans queue to compile.

The serving engine's front door separates the two costs the plan cache
exists to separate: a request whose (graph, analytic) plan is already
resident is *warm* and goes straight to the scheduler's ready set, while
a cache miss parks the request behind a bounded FIFO compile queue.
Compiles burn a per-step budget (`run_compiles`) so they never stall
running iterations, and the queue bound applies back-pressure: when it
is full, missing requests simply stay in `waiting` -- but warm requests
behind them still pass (head-of-line blocking applies to *compiles*, not
to admission).

Concurrent misses on the same plan key join one pending entry -- dozens
of requests against a just-uploaded graph trigger exactly one compile,
and all of them release together when it lands.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from .requests import AnalyticRequest


class AdmissionController:
    def __init__(self, plan_cache, compile_queue_cap: int = 8):
        self.cache = plan_cache
        self.compile_queue_cap = compile_queue_cap
        self.waiting: Deque[AnalyticRequest] = deque()
        self.compile_q: Deque[str] = deque()          # unique plan keys, FIFO
        self.pending: Dict[str, List[AnalyticRequest]] = {}
        self.warm_hits = 0       # requests admitted off a resident plan
        self.cold_misses = 0     # requests that had to wait on a compile
        self.backpressure = 0    # request-steps stalled on a full queue

    def submit(self, req: AnalyticRequest) -> None:
        self.waiting.append(req)

    def intake(self, key_of: Callable[[AnalyticRequest], str]
               ) -> List[AnalyticRequest]:
        """One admission pass over `waiting` (FIFO).  Returns the warm
        requests, ready to schedule this step; misses join or enqueue
        their plan key, or stay in `waiting` under back-pressure."""
        ready: List[AnalyticRequest] = []
        still: Deque[AnalyticRequest] = deque()
        while self.waiting:
            req = self.waiting.popleft()
            key = key_of(req)
            if self.cache.contains(key):
                self.warm_hits += 1
                ready.append(req)
            elif key in self.pending:
                self.cold_misses += 1
                self.pending[key].append(req)
            elif len(self.compile_q) < self.compile_queue_cap:
                self.cold_misses += 1
                self.compile_q.append(key)
                self.pending[key] = [req]
            else:
                self.backpressure += 1
                still.append(req)
        self.waiting = still
        return ready

    def park(self, key: str) -> None:
        """Schedule a *background* compile for `key` -- no requester yet.

        The streaming lifecycle parks past-budget re-plans here: the key
        shares the per-step compile budget with request-driven misses
        (FIFO behind whatever is already queued) but bypasses the queue
        cap, because a forced re-plan cannot be dropped -- its old plan
        generation has already been retired from the serving key.  Later
        misses on the same key join the pending entry as usual."""
        if key in self.pending:
            return
        self.compile_q.append(key)
        self.pending[key] = []

    def run_compiles(self, budget: Optional[int],
                     compile_key: Callable[[str], object]
                     ) -> List[AnalyticRequest]:
        """Compile up to `budget` queued keys (FIFO) and release every
        request that was pending on them.  `budget=None` drains the whole
        queue this step -- the right setting when compiles are scored by
        the learned cost model (microseconds each), where rationing them
        one per step would park requests for no reason."""
        released: List[AnalyticRequest] = []
        if budget is None:
            budget = len(self.compile_q)
        while budget > 0 and self.compile_q:
            key = self.compile_q.popleft()
            compile_key(key)
            released.extend(self.pending.pop(key))
            budget -= 1
        return released

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.compile_q and not self.pending

    def stats(self) -> Dict[str, int]:
        return {"waiting": len(self.waiting),
                "compile_queue": len(self.compile_q),
                "warm_hits": self.warm_hits,
                "cold_misses": self.cold_misses,
                "backpressure": self.backpressure}


__all__ = ["AdmissionController"]
