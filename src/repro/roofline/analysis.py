"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step per chip:

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = sum(collective wire bytes) / (links * link_bw)

HLO_FLOPs / bytes come from `compiled.cost_analysis()` (per-device numbers:
the SPMD-partitioned module is the per-chip program).  Collective bytes are
NOT in cost_analysis, so we parse the optimized HLO (`compiled.as_text()`)
and sum result-shape bytes of every collective op, weighted by the standard
ring-algorithm wire factor.

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3 usable links assumed on a 2-D torus -> model axis uses
1 link-pair per neighbor; we report with links=1 for conservatism and list
link count separately).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# wire-bytes multiplier per result byte (ring algorithms, n >> 1)
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,      # counts the (larger) input side below
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<ty>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(ty: str, shape_str: str) -> int:
    if ty not in _DTYPE_BYTES:
        return 0
    n = 1
    if shape_str:
        for d in shape_str.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[ty]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, int]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by_op: Dict[str, float] = {}
    count_by_op: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if m.group("ty"):
            nbytes = _shape_bytes(m.group("ty"), m.group("shape"))
        else:
            # tuple result (grouped collective): sum element shapes before '('
            head = line.split(f" {op}", 1)[0]
            nbytes = sum(_shape_bytes(t, s)
                         for t, s in _TUPLE_SHAPE_RE.findall(head))
        wire = nbytes * _WIRE_FACTOR[op]
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + wire
        count_by_op[op] = count_by_op.get(op, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per chip per step
    hbm_bytes: float             # per chip per step
    collective_bytes: float      # wire bytes per chip per step
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6 * N_active * tokens (whole job)
    useful_flops_frac: float     # model_flops / (chips * HLO_flops)
    collectives: Dict[str, float]
    collective_counts: Dict[str, int]

    def summary(self) -> str:
        return (f"compute {self.compute_s*1e3:8.3f} ms | "
                f"memory {self.memory_s*1e3:8.3f} ms | "
                f"collective {self.collective_s*1e3:8.3f} ms "
                f"-> {self.bottleneck}-bound; "
                f"useful-FLOP frac {self.useful_flops_frac:5.3f}")


def analyze(cost: dict, hlo_text: str, *, n_chips: int,
            model_flops: float = 0.0,
            peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
            link_bw: float = LINK_BW) -> Roofline:
    """Roofline from the optimized HLO (loop-aware; see hlo_costs).

    `cost` (XLA's cost_analysis dict) is kept for cross-checking: its raw
    flops equal ours when nothing is scanned, and under-count by the scan
    trip counts otherwise.
    """
    from . import hlo_costs

    mc = hlo_costs.module_costs(hlo_text)
    flops = mc.flops
    hbm = mc.bytes
    compute_s = flops / peak_flops
    memory_s = hbm / hbm_bw
    collective_s = mc.total_collective_bytes / link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_hlo = flops * n_chips
    return Roofline(
        flops=flops, hbm_bytes=hbm,
        collective_bytes=mc.total_collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_flops_frac=(model_flops / total_hlo) if total_hlo else 0.0,
        collectives=dict(mc.collective_bytes),
        collective_counts={k: int(v) for k, v in
                           mc.collective_counts.items()},
    )


def model_flops_train(n_active_params: float, n_tokens: float) -> float:
    return 6.0 * n_active_params * n_tokens


def model_flops_decode(n_active_params: float, n_tokens: float) -> float:
    return 2.0 * n_active_params * n_tokens
