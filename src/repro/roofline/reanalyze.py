"""Re-derive roofline records from saved HLO dumps with the CURRENT
analyzer -- keeps baseline and optimized numbers measured identically even
when the cost model improves after a sweep ran.

    PYTHONPATH=src python -m repro.roofline.reanalyze \
        --jsonl experiments/dryrun.jsonl --hlo-dir experiments/hlo \
        --out experiments/dryrun_reanalyzed.jsonl
"""
from __future__ import annotations

import argparse
import json
import os

from . import analysis


def reanalyze_record(rec: dict, hlo_dir: str) -> dict:
    if rec.get("status") != "ok":
        return rec
    mp = rec["mesh"].get("pod", 1) > 1
    profile = rec.get("profile", "baseline")
    tag = (f"{rec['arch']}_{rec['shape']}_{'mp' if mp else 'sp'}"
           + ("" if profile == "baseline" else f"_{profile}"))
    path = os.path.join(hlo_dir, tag + ".hlo.txt")
    if not os.path.exists(path):
        rec["reanalyzed"] = False
        return rec
    hlo = open(path).read()
    rl = analysis.analyze(rec.get("cost", {}), hlo,
                          n_chips=rec["n_chips"],
                          model_flops=rec.get("model_flops", 0.0))
    rec.update(
        flops_per_chip=rl.flops,
        hbm_bytes_per_chip=rl.hbm_bytes,
        collective_bytes_per_chip=rl.collective_bytes,
        collectives=rl.collectives,
        collective_counts=rl.collective_counts,
        compute_s=rl.compute_s, memory_s=rl.memory_s,
        collective_s=rl.collective_s, bottleneck=rl.bottleneck,
        useful_flops_frac=rl.useful_flops_frac,
        reanalyzed=True,
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="experiments/dryrun.jsonl")
    ap.add_argument("--hlo-dir", default="experiments/hlo")
    ap.add_argument("--out", default="experiments/dryrun_reanalyzed.jsonl")
    args = ap.parse_args(argv)
    n = 0
    with open(args.out, "w") as out:
        for line in open(args.jsonl):
            rec = reanalyze_record(json.loads(line), args.hlo_dir)
            out.write(json.dumps(rec) + "\n")
            n += rec.get("reanalyzed", False)
    print(f"reanalyzed {n} records -> {args.out}")


if __name__ == "__main__":
    main()
