"""Roofline report generator: dryrun.jsonl -> markdown tables + bottleneck
diagnosis for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.roofline.report \
        --in experiments/dryrun.jsonl --out experiments/roofline.md
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict

MOVE_HINTS = {
    # what would move the dominant term down, per (kind, bottleneck)
    ("train", "memory"): "shard activations over 'model' (sequence "
        "parallelism) and cut remat recompute of cheap ops",
    ("train", "collective"): "replace Megatron per-layer all-reduce with "
        "reduce-scatter+all-gather (SP); overlap FSDP gathers with compute",
    ("train", "compute"): "already MXU-bound: raise per-chip batch or "
        "accept (near roofline)",
    ("prefill", "memory"): "fuse attention (flash) so scores never hit HBM; "
        "shard sequence over 'model'",
    ("prefill", "collective"): "sequence-parallel norms + qkv projections",
    ("decode", "memory"): "decode is KV-bandwidth-bound by nature; pack "
        "more concurrent sequences per chip or quantize KV to int8",
    ("decode", "collective"): "keep KV sequence-sharded and merge partial "
        "attention with LSE-psum instead of re-gathering the cache",
    ("decode", "compute"): "batch more sequences",
}


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(path):
    recs = [json.loads(line) for line in open(path)]
    best = {}
    for r in recs:      # last record per cell wins (re-runs append)
        key = (r["arch"], r["shape"], "pod" in r["mesh"] and
               r["mesh"].get("pod", 1) > 1)
        best[key] = r
    return best


def table(recs, multi_pod=False):
    rows = []
    hdr = ("| arch | shape | kind | compute_s | memory_s | collective_s | "
           "bottleneck | useful-FLOP frac | roofline frac |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for (arch, shape, mp), r in sorted(recs.items()):
        if mp != multi_pod:
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | {r['kind']} | ERROR: "
                        f"{r['error'][:60]} | | | | | |")
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        # roofline fraction: the compute term is the ideal-time floor;
        # fraction = compute_s / max(all terms) (1.0 = compute-bound at peak)
        frac = r["compute_s"] / dom if dom > 0 else 0.0
        rows.append(
            f"| {arch} | {shape} | {r['kind']} "
            f"| {r['compute_s']*1e3:9.2f}ms | {r['memory_s']*1e3:9.2f}ms "
            f"| {r['collective_s']*1e3:9.2f}ms | {r['bottleneck']} "
            f"| {r['useful_flops_frac']:.3f} | {frac:.4f} |")
    return "\n".join(rows)


def diagnosis(recs):
    out = []
    for (arch, shape, mp), r in sorted(recs.items()):
        if mp or r["status"] != "ok":
            continue
        hint = MOVE_HINTS.get((r["kind"], r["bottleneck"]), "n/a")
        colls = ", ".join(f"{k}={fmt_bytes(v)}" for k, v in
                          sorted(r.get("collectives", {}).items()))
        out.append(f"- **{arch} x {shape}**: {r['bottleneck']}-bound "
                   f"(compute {r['compute_s']*1e3:.1f}ms / memory "
                   f"{r['memory_s']*1e3:.1f}ms / collective "
                   f"{r['collective_s']*1e3:.1f}ms; {colls}). "
                   f"Move it down: {hint}.")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="experiments/dryrun.jsonl")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args(argv)
    recs = load(args.inp)
    parts = [
        "## Roofline (single-pod 16x16 = 256 chips)",
        "", table(recs, multi_pod=False), "",
        "## Multi-pod check (2x16x16 = 512 chips)",
        "", table(recs, multi_pod=True), "",
        "## Per-cell bottleneck diagnosis (single-pod)",
        "", diagnosis(recs), "",
    ]
    text = "\n".join(parts)
    with open(args.out, "w") as f:
        f.write(text)
    print(text[:4000])
    print(f"... -> {args.out}")


if __name__ == "__main__":
    main()
