"""Loop-aware cost analysis of optimized HLO text.

XLA's built-in `compiled.cost_analysis()` visits every computation ONCE, so
anything inside a `while` (every `lax.scan` -- our layer stacks, attention
chunks, loss chunks) is under-counted by its trip count: an 80-layer scanned
transformer reports ~1/80th of its true FLOPs, and collectives inside the
scan disappear from the totals.  The optimized HLO, however, carries
`backend_config={"known_trip_count":{"n":"24"}}` on every counted loop, so
this module re-derives module costs by

  1. parsing the HLO text into computations and ops,
  2. computing per-op flops (exact for `dot`: 2 * result * contraction) and
     bytes (operands + result for memory-moving ops, fusion interiors
     excluded),
  3. folding the call graph bottom-up with while-loop trip-count
     multipliers (fusion/call/conditional weight 1).

Collective wire bytes use ring-algorithm factors:
  all-reduce      2x operand bytes  (reduce-scatter + all-gather phases)
  all-gather      1x result bytes
  reduce-scatter  1x operand bytes
  all-to-all      1x operand bytes
  collective-permute 1x result bytes

All returned numbers are per-device (the SPMD-partitioned module IS the
per-device program).  CPU-backend caveat: fusion boundaries differ from the
TPU backend, so `bytes` is an upper-bound style proxy for HBM traffic --
used consistently across baselines and hillclimb steps, so *deltas* are
meaningful even where absolute calibration is not.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

# ops that move no real data / are free relabelings
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
}
# container ops whose operand/result bytes double-count their interior
_CONTAINER_OPS = {"while", "call", "conditional", "fusion"}

# elementwise-ish ops: 1 flop per output element
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "power", "remainder", "atan2", "and", "or", "xor",
    "not", "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "compare", "select", "clamp", "map",
}
# transcendental: count as 1 flop too (XLA convention), tracked separately
_TRANS_OPS = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "cbrt", "tanh", "logistic", "sine", "cosine", "tan", "erf",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(?P<type>\([^()]*\)|\S+?)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<operands>[^)]*)\)(?P<attrs>.*)$")
_TRIP_RE = re.compile(r'known_trip_count"?:\{"?n"?:"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_COMP_RE = re.compile(r"(?:true|false)_computation=%?([\w\.\-]+)")
_DIMS_RE = {
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "lhs_b": re.compile(r"lhs_batch_dims=\{([\d,]*)\}"),
}


def shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) of an HLO type string (tuples summed)."""
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    defs: Dict[str, str]        # %name -> type string


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = Op(name=m.group(1), opcode=m.group("opcode"),
                type_str=m.group("type"),
                operands=[t.strip().lstrip("%") for t in
                          m.group("operands").split(",") if t.strip()
                          .startswith("%")],
                attrs=m.group("attrs"))
        cur.ops.append(op)
        cur.defs[op.name] = op.type_str
    if cur is not None:
        comps[cur.name] = cur
    return comps


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    transcendental: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    unknown_trip_counts: int = 0

    def scaled(self, k: float) -> "Costs":
        return Costs(
            flops=self.flops * k,
            transcendental=self.transcendental * k,
            bytes=self.bytes * k,
            collective_bytes={o: b * k for o, b in
                              self.collective_bytes.items()},
            collective_counts={o: c * k for o, c in
                               self.collective_counts.items()},
            unknown_trip_counts=self.unknown_trip_counts,
        )

    def add(self, other: "Costs") -> None:
        self.flops += other.flops
        self.transcendental += other.transcendental
        self.bytes += other.bytes
        for o, b in other.collective_bytes.items():
            self.collective_bytes[o] = self.collective_bytes.get(o, 0.0) + b
        for o, c in other.collective_counts.items():
            self.collective_counts[o] = (
                self.collective_counts.get(o, 0.0) + c)
        self.unknown_trip_counts += other.unknown_trip_counts

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(op: Op, defs: Dict[str, str]) -> float:
    out_elems, _ = shape_elems_bytes(op.type_str)
    contract = 1
    m = _DIMS_RE["lhs_c"].search(op.attrs)
    if m and op.operands:
        lhs_type = defs.get(op.operands[0])
        if lhs_type:
            sm = _SHAPE_RE.search(lhs_type)
            if sm and sm.group(2):
                lhs_dims = [int(d) for d in sm.group(2).split(",")]
                for idx_s in m.group(1).split(","):
                    if idx_s:
                        idx = int(idx_s)
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


def _op_local_costs(op: Op, defs: Dict[str, str],
                    comps: Optional[Dict[str, "Computation"]] = None
                    ) -> Costs:
    c = Costs()
    opcode = op.opcode
    if opcode in _FREE_OPS:
        return c
    out_elems, out_bytes = shape_elems_bytes(op.type_str)
    in_bytes = 0
    for name in op.operands:
        t = defs.get(name)
        if t:
            in_bytes += shape_elems_bytes(t)[1]

    # In-place / sliced accesses move only the slice, not the buffer:
    # XLA aliases the big operand of (dynamic-)update-slice on TPU, and a
    # (dynamic-)slice/gather reads just the addressed region.  Counting the
    # full buffer would charge a 32k-deep KV cache per decoded token.
    if opcode == "dynamic-update-slice":
        upd = (shape_elems_bytes(defs.get(op.operands[1], ""))[1]
               if len(op.operands) > 1 else out_bytes)
        c.bytes = float(2 * upd)
        return c
    if opcode in ("dynamic-slice", "slice"):
        c.bytes = float(2 * out_bytes)
        return c
    if opcode == "gather":
        idx = (shape_elems_bytes(defs.get(op.operands[1], ""))[1]
               if len(op.operands) > 1 else 0)
        c.bytes = float(2 * out_bytes + idx)
        return c
    if opcode == "scatter":
        upd = (shape_elems_bytes(defs.get(op.operands[2], ""))[1]
               if len(op.operands) > 2 else out_bytes)
        idx = (shape_elems_bytes(defs.get(op.operands[1], ""))[1]
               if len(op.operands) > 1 else 0)
        c.bytes = float(2 * upd + idx)
        return c
    if opcode == "fusion" and comps is not None:
        # A fusion that is an in-place buffer update writes only the slice.
        # Two shapes of this: (a) root IS a dynamic-update-slice; (b) the
        # CPU emitter's bf16 quirk -- convert(DUS(convert(buf), update)) --
        # which round-trips the whole buffer through f32 *on CPU only*
        # (TPU has native bf16 DUS).  Detect any interior DUS whose result
        # covers the fusion output and charge 2x the update slice.
        m = _CALLS_RE.search(op.attrs)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None and callee.ops:
            for cop in callee.ops:
                if cop.opcode != "dynamic-update-slice":
                    continue
                if (shape_elems_bytes(cop.type_str)[0] != out_elems):
                    continue
                upd_name = cop.operands[1] if len(cop.operands) > 1 else ""
                upd = shape_elems_bytes(callee.defs.get(upd_name, ""))[1]
                if upd == 0:
                    upd = out_bytes       # conservative fallback
                small_ins = max(in_bytes - out_bytes, 0)
                c.bytes = float(min(small_ins, 2 * upd) + 2 * upd)
                return c

    if opcode.startswith(_COLLECTIVES):
        base = opcode
        for coll in _COLLECTIVES:
            if opcode.startswith(coll):
                base = coll
                break
        if base == "all-reduce":
            wire = 2.0 * in_bytes
        elif base in ("reduce-scatter", "all-to-all"):
            wire = float(in_bytes)
        else:                      # all-gather, permute, broadcast
            wire = float(out_bytes)
        c.collective_bytes[base] = wire
        c.collective_counts[base] = 1.0
        c.bytes = float(in_bytes + out_bytes)
        return c

    if opcode in _CONTAINER_OPS:
        if opcode == "fusion":
            # fusion interior not counted for bytes; call site moves data
            c.bytes = float(in_bytes + out_bytes)
        return c

    if opcode == "dot":
        c.flops = _dot_flops(op, defs)
    elif opcode == "convolution":
        c.flops = 2.0 * out_elems   # lower bound; no convs in our models
    elif opcode in _TRANS_OPS:
        c.flops = float(out_elems)
        c.transcendental = float(out_elems)
    elif opcode in _EW_OPS or opcode == "reduce" or opcode == "convert":
        ref = max(out_elems, 1)
        if opcode == "reduce":
            ref = max(in_bytes // 4, out_elems)
        c.flops = float(ref) if opcode != "convert" else 0.0
    c.bytes = float(in_bytes + out_bytes)
    return c


def top_ops(hlo_text: str, by: str = "bytes", k: int = 20):
    """Top-k individual ops by bytes or flops, with loop multipliers applied.

    The hillclimb profiler: shows WHERE the dominant roofline term lives
    (op name, opcode, metadata op_name tag, cost x trip multiplier).
    """
    comps = parse_module(hlo_text)
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    entry_name = m.group(1) if m else next(reversed(comps))

    # compute multiplier per computation by walking the call graph
    mult: Dict[str, float] = {entry_name: 1.0}
    order = [entry_name]
    seen = {entry_name}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        base = mult.get(cname, 1.0)
        for op in comp.ops:
            callees: List[Tuple[str, float]] = []
            if op.opcode == "fusion" or op.opcode == "call":
                cm = _CALLS_RE.search(op.attrs)
                if cm:
                    callees.append((cm.group(1), 1.0))
            elif op.opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(op.attrs)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(op.attrs)
                if bm:
                    callees.append((bm.group(1), float(trip)))
        # second pass handled below; simple BFS accumulate
            for callee, w in callees:
                mult[callee] = mult.get(callee, 0.0) + base * w
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    rows = []
    for cname, comp in comps.items():
        k_mult = mult.get(cname, 0.0)
        if k_mult <= 0:
            continue
        for op in comp.ops:
            c = _op_local_costs(op, comp.defs, comps)
            val = c.bytes if by == "bytes" else c.flops
            if val <= 0:
                continue
            tag = ""
            tm = re.search(r'op_name="([^"]*)"', op.attrs)
            if tm:
                tag = tm.group(1)[-80:]
            rows.append((val * k_mult, op.opcode, op.name, k_mult, tag))
    rows.sort(reverse=True)
    return rows[:k]


def module_costs(hlo_text: str, entry: Optional[str] = None) -> Costs:
    comps = parse_module(hlo_text)
    if not comps:
        return Costs()
    # identify entry: the computation named in "ENTRY %name" line
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
        entry_name = m.group(1) if m else next(reversed(comps))

    memo: Dict[str, Costs] = {}
    visiting: set = set()

    def cost_of(comp_name: str) -> Costs:
        if comp_name in memo:
            return memo[comp_name]
        if comp_name in visiting or comp_name not in comps:
            return Costs()
        visiting.add(comp_name)
        comp = comps[comp_name]
        total = Costs()
        for op in comp.ops:
            total.add(_op_local_costs(op, comp.defs, comps))
            if op.opcode == "fusion":
                m = _CALLS_RE.search(op.attrs)
                if m:
                    total.add(cost_of(m.group(1)))
            elif op.opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(op.attrs)
                if tm:
                    trip = int(tm.group(1))
                else:
                    total.unknown_trip_counts += 1
                bm = _BODY_RE.search(op.attrs)
                if bm:
                    total.add(cost_of(bm.group(1)).scaled(trip))
                cm = _COND_RE.search(op.attrs)
                if cm:
                    total.add(cost_of(cm.group(1)).scaled(trip + 1))
            elif op.opcode == "call":
                m = _CALLS_RE.search(op.attrs) or re.search(
                    r"to_apply=%?([\w\.\-]+)", op.attrs)
                if m:
                    total.add(cost_of(m.group(1)))
            elif op.opcode == "conditional":
                branches: List[str] = []
                bm = _BRANCHES_RE.search(op.attrs)
                if bm:
                    branches = [b.strip().lstrip("%") for b in
                                bm.group(1).split(",") if b.strip()]
                else:
                    branches = _TF_COMP_RE.findall(op.attrs)
                if branches:
                    worst = None
                    for b in branches:
                        cb = cost_of(b)
                        if worst is None or cb.flops > worst.flops:
                            worst = cb
                    if worst is not None:
                        total.add(worst)
        visiting.discard(comp_name)
        memo[comp_name] = total
        return total

    return cost_of(entry_name)
