"""Reordering strategies: turn unstructured (R-MAT) inputs FD-like.

The paper shows SpMV performance is set by the *structure* of the x-access
stream; PR 1 attacked the unstructured case from the hardware side (victim
caches, stream buffers).  These strategies are the software-side answer:
permute the matrix so the stream the kernel actually issues becomes
sequential/reused -- i.e. prefetchable -- and `auto_format` can re-decide
the storage format afterwards (an RCM'd scrambled-banded matrix becomes
DIA-eligible again).

  rcm          reverse Cuthill-McKee bandwidth reduction (pure-numpy BFS)
  degree_sort  rows ordered by nnz (absorbs partition.sort_rows_by_nnz)
  cache_block  column tiling: pack each row-block's x working set
  chain        composable combinator over any of the above

Every strategy is a callable `CSR -> Reordering`; `STRATEGIES` maps names
to callables for sweeps and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from repro.core.formats import CSR

from .types import Reordering, identity_reordering, invert_permutation

Strategy = Callable[[CSR], Reordering]


# ---------------------------------------------------------------------------
# Reverse Cuthill-McKee
# ---------------------------------------------------------------------------

def _symmetric_adjacency(csr: CSR):
    """(indptr, indices) of the symmetrized pattern A | A^T, self-loops
    dropped, neighbours sorted by (degree, id) -- the CM visiting order."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cols = np.asarray(csr.indices, dtype=np.int64)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    n = max(csr.n_rows, csr.n_cols)
    u = np.concatenate([rows, cols])
    v = np.concatenate([cols, rows])
    keep = u != v
    u, v = u[keep], v[keep]
    # dedup (u, v)
    key = u * n + v
    order = np.argsort(key, kind="stable")
    u, v, key = u[order], v[order], key[order]
    uniq = np.ones(key.size, dtype=bool)
    uniq[1:] = key[1:] != key[:-1]
    u, v = u[uniq], v[uniq]
    deg = np.bincount(u, minlength=n)
    # sort each node's neighbours by (degree, id): lexsort with u major
    order = np.lexsort((v, deg[v], u))
    v = v[order]
    adj_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=adj_ptr[1:])
    return adj_ptr, v, deg


def _pseudo_peripheral(start: int, adj_ptr, adj, deg) -> int:
    """George-Liu: repeat BFS from the farthest min-degree node until the
    eccentricity stops growing; returns a near-peripheral start node."""
    node = start
    last_ecc = -1
    for _ in range(8):                      # converges in 2-3 in practice
        level, ecc = _bfs_levels(node, adj_ptr, adj)
        if ecc <= last_ecc:
            break
        last_ecc = ecc
        frontier = np.flatnonzero(level == ecc)
        node = int(frontier[np.argmin(deg[frontier])])
    return node


def _bfs_levels(start: int, adj_ptr, adj):
    n = adj_ptr.size - 1
    level = np.full(n, -1, dtype=np.int64)
    level[start] = 0
    frontier = np.array([start], dtype=np.int64)
    ecc = 0
    while frontier.size:
        nbrs = np.concatenate([adj[adj_ptr[f]:adj_ptr[f + 1]]
                               for f in frontier]) if frontier.size else \
            np.zeros(0, np.int64)
        nbrs = np.unique(nbrs)
        nbrs = nbrs[level[nbrs] < 0]
        if nbrs.size == 0:
            break
        ecc += 1
        level[nbrs] = ecc
        frontier = nbrs
    return level, ecc


def rcm(csr: CSR) -> Reordering:
    """Reverse Cuthill-McKee: symmetric permutation minimizing bandwidth.

    Pure numpy + a Python BFS loop (no scipy).  Each connected component
    is traversed breadth-first from a pseudo-peripheral min-degree node,
    neighbours visited in increasing-degree order; the concatenated visit
    order is reversed (the "R" -- reversing halves the profile).  Rows and
    columns get the same permutation, so symmetric structure is preserved.
    """
    n = max(csr.n_rows, csr.n_cols)
    adj_ptr, adj, deg = _symmetric_adjacency(csr)
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    # component seeds in increasing-degree order (isolated nodes included)
    for seed in np.argsort(deg, kind="stable"):
        if visited[seed]:
            continue
        if deg[seed] > 0:
            seed = _pseudo_peripheral(int(seed), adj_ptr, adj, deg)
            if visited[seed]:
                continue
        visited[seed] = True
        order[pos] = seed
        head = pos
        pos += 1
        while head < pos:                   # queue-based BFS
            node = order[head]
            head += 1
            nbrs = adj[adj_ptr[node]:adj_ptr[node + 1]]
            nbrs = nbrs[~visited[nbrs]]     # already (degree, id)-sorted
            k = nbrs.size
            if k:
                visited[nbrs] = True
                order[pos:pos + k] = nbrs
                pos += k
    perm = order[::-1].copy()               # the reversal
    # non-square: restrict the node ordering to each side's id range
    row_perm = perm if csr.n_rows == n else perm[perm < csr.n_rows]
    col_perm = perm if csr.n_cols == n else perm[perm < csr.n_cols]
    r = Reordering(row_perm=row_perm, col_perm=col_perm, strategy="rcm")
    return dataclasses.replace(
        r, stats={"bandwidth_before": _bandwidth(csr),
                  "bandwidth_after": _bandwidth(csr, r)})


def _bandwidth(csr: CSR, reordering: Reordering | None = None) -> int:
    """max |col - row|, optionally under a reordering -- computed straight
    from the coordinate arrays (no permuted CSR is materialized)."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cols = np.asarray(csr.indices, dtype=np.int64)
    if cols.size == 0:
        return 0
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    if reordering is not None:
        rows = reordering.inv_row_perm[rows]
        cols = reordering.inv_col_perm[cols]
    return int(np.abs(cols - rows).max())


# ---------------------------------------------------------------------------
# Degree / nnz row sorting (SELL-style)
# ---------------------------------------------------------------------------

def degree_sort(csr: CSR, descending: bool = True) -> Reordering:
    """Rows ordered by nnz (stable).  Groups similar-length rows so ELL
    padding within row blocks is minimal; generalizes (and now backs)
    `partition.sort_rows_by_nnz`.  Columns are untouched."""
    lengths = np.diff(np.asarray(csr.indptr, dtype=np.int64))
    key = -lengths if descending else lengths
    perm = np.argsort(key, kind="stable").astype(np.int64)
    return Reordering(
        row_perm=perm,
        col_perm=np.arange(csr.n_cols, dtype=np.int64),
        strategy="degree-sort",
        params={"descending": descending},
        stats={"max_nnz_row": int(lengths.max()) if lengths.size else 0},
    )


# ---------------------------------------------------------------------------
# Column / cache blocking of the x working set
# ---------------------------------------------------------------------------

def cache_block(csr: CSR, rows_per_block: int = 1024) -> Reordering:
    """Pack each row-block's x working set into contiguous columns.

    Columns are ordered by (row block that first touches them, access
    count descending, id): while the kernel sweeps one block of rows, its
    x gathers land in one contiguous (hot-first) column segment instead of
    being scattered over the whole vector -- the software analogue of the
    paper's P2/P3 column-blocked software cache, expressed as a pure
    permutation.  Rows are untouched."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cols = np.asarray(csr.indices, dtype=np.int64)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    n_cols = csr.n_cols
    first_block = np.full(n_cols, csr.n_rows // rows_per_block + 1,
                          dtype=np.int64)
    np.minimum.at(first_block, cols, rows // rows_per_block)
    counts = np.bincount(cols, minlength=n_cols)
    col_perm = np.lexsort((np.arange(n_cols), -counts, first_block))
    touched = int((counts > 0).sum())
    return Reordering(
        row_perm=np.arange(csr.n_rows, dtype=np.int64),
        col_perm=col_perm.astype(np.int64),
        strategy="cache-block",
        params={"rows_per_block": rows_per_block},
        stats={"touched_cols": touched},
    )


# ---------------------------------------------------------------------------
# Composition
# ---------------------------------------------------------------------------

def chain(*strategies: Strategy) -> Strategy:
    """Compose strategies left-to-right into one: each runs on the matrix
    as permuted by its predecessors, and the returned `Reordering` is the
    single equivalent permutation pair (provenance lists every step)."""
    def run(csr: CSR) -> Reordering:
        combined = identity_reordering(csr.n_rows, csr.n_cols)
        cur = csr
        names = []
        for strat in strategies:
            step = strat(cur)
            step.validate()
            cur = step.apply(cur)
            names.append(step.strategy)
            combined = combined.then(step)
        return Reordering(
            row_perm=combined.row_perm, col_perm=combined.col_perm,
            strategy=f"chain({','.join(names)})" if names else "identity",
            params=combined.params, stats=combined.stats)
    return run


def identity(csr: CSR) -> Reordering:
    return identity_reordering(csr.n_rows, csr.n_cols)


# name -> strategy, what sweeps and benchmarks iterate over
STRATEGIES: Dict[str, Strategy] = {
    "none": identity,
    "rcm": rcm,
    "degree-sort": degree_sort,
    "cache-block": cache_block,
    "rcm+cache-block": chain(rcm, cache_block),
}

__all__ = ["Strategy", "STRATEGIES", "rcm", "degree_sort", "cache_block",
           "chain", "identity", "invert_permutation"]
