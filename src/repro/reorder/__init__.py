"""repro.reorder — permutations that turn R-MAT into FD-like structure.

The software-side counterpart of the telemetry subsystem's §V hardware
mechanisms: instead of adding victim caches / stream buffers to tolerate
an unstructured x-access stream, permute the matrix so the stream becomes
structured in the first place, then let `core.spmv.auto_format` re-decide
the storage format on the reordered matrix.

  types        Reordering (row/col perms + inverses + provenance), compose
  strategies   rcm / degree_sort / cache_block / chain + STRATEGIES registry

Quick use:

    from repro import reorder
    r = reorder.rcm(csr)          # Reordering
    a2 = r.apply(csr)             # permuted CSR
    fmt = auto_format(a2)         # may now pick DIA/BELL
    y = spmv(fmt, x, reordering=r)   # == spmv(csr, x), original order
"""
from .strategies import (STRATEGIES, Strategy, cache_block, chain,
                         degree_sort, identity, rcm)
from .types import (Reordering, identity_reordering, invert_permutation,
                    is_permutation)

__all__ = [
    "Reordering", "Strategy", "STRATEGIES", "identity_reordering",
    "invert_permutation", "is_permutation", "rcm", "degree_sort",
    "cache_block", "chain", "identity",
]
