"""The common result type every reordering strategy produces.

A `Reordering` is a pair of permutations plus provenance.  Conventions
(matching `partition.sort_rows_by_nnz`, which this subsystem absorbs):

    A'[i, j] = A[row_perm[i], col_perm[j]]

so `row_perm[i]` answers "which OLD row sits at NEW position i".  Under
that convention SpMV transports as

    x' = x[col_perm]          (permute_x)
    y' = A' @ x'
    y  = y'[inv_row_perm]     (restore_y)

and `spmv(A', x, reordering=r)` does the gather/scatter for you, returning
y in the ORIGINAL row order.  Reorderings compose with `then` (apply self
first, `other` second), which is what the `chain` combinator uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


def invert_permutation(perm: np.ndarray) -> np.ndarray:
    """inv[perm[i]] = i, O(n) (argsort-free)."""
    perm = np.asarray(perm, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size, dtype=np.int64)
    return inv


def is_permutation(perm: np.ndarray, n: int) -> bool:
    perm = np.asarray(perm)
    return perm.shape == (n,) and np.array_equal(np.sort(perm), np.arange(n))


@dataclasses.dataclass(frozen=True)
class Reordering:
    """Row/column permutation pair with provenance metadata.

    `strategy` names the producing strategy ("rcm", "degree-sort", ...,
    or "chain(a,b)"), `params` records its knobs, and `stats` records
    what the strategy measured while running (e.g. bandwidth before and
    after RCM) -- enough to reconstruct *why* this permutation exists.
    """

    row_perm: np.ndarray            # new row i holds old row row_perm[i]
    col_perm: np.ndarray            # new col j holds old col col_perm[j]
    strategy: str = "identity"
    params: Dict = dataclasses.field(default_factory=dict)
    stats: Dict = dataclasses.field(default_factory=dict)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.row_perm.size, self.col_perm.size)

    @property
    def inv_row_perm(self) -> np.ndarray:
        return invert_permutation(self.row_perm)

    @property
    def inv_col_perm(self) -> np.ndarray:
        return invert_permutation(self.col_perm)

    def validate(self) -> None:
        n_r, n_c = self.shape
        if not is_permutation(self.row_perm, n_r):
            raise ValueError(f"{self.strategy}: row_perm is not a permutation")
        if not is_permutation(self.col_perm, n_c):
            raise ValueError(f"{self.strategy}: col_perm is not a permutation")

    # -- application --------------------------------------------------------

    def apply(self, csr):
        """A' with A'[i, j] = A[row_perm[i], col_perm[j]]."""
        return csr.permute(self.row_perm, self.col_perm)

    def permute_x(self, x):
        """x' for the reordered multiply (x'[j] = x[col_perm[j]])."""
        import jax.numpy as jnp

        return jnp.take(jnp.asarray(x), jnp.asarray(self.col_perm), axis=0)

    def restore_y(self, y_perm):
        """Scatter y' back to the original row order (y = y'[inv_row_perm])."""
        import jax.numpy as jnp

        return jnp.take(jnp.asarray(y_perm), jnp.asarray(self.inv_row_perm),
                        axis=0)

    # -- composition --------------------------------------------------------

    def then(self, other: "Reordering") -> "Reordering":
        """The reordering equivalent to applying self, then `other`.

        (B = self.apply(A), C = other.apply(B))  =>  C = combined.apply(A):
        C[i] = B[other.row_perm[i]] = A[self.row_perm[other.row_perm[i]]].
        """
        return Reordering(
            row_perm=np.asarray(self.row_perm)[np.asarray(other.row_perm)],
            col_perm=np.asarray(self.col_perm)[np.asarray(other.col_perm)],
            strategy=f"{self.strategy}+{other.strategy}",
            params={**self.params, **other.params},
            stats={**self.stats, **other.stats},
        )

    def summary(self) -> str:
        extra = " ".join(f"{k}={v}" for k, v in sorted(self.stats.items()))
        return f"{self.strategy}: rows={self.shape[0]} cols={self.shape[1]}" \
               + (f" [{extra}]" if extra else "")


def identity_reordering(n_rows: int, n_cols: int | None = None) -> Reordering:
    n_cols = n_rows if n_cols is None else n_cols
    return Reordering(row_perm=np.arange(n_rows, dtype=np.int64),
                      col_perm=np.arange(n_cols, dtype=np.int64),
                      strategy="identity")
