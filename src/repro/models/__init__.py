"""Model zoo: unified pure-JAX implementations of the assigned architectures."""
from . import common, mamba, moe, registry, rwkv6, transformer, whisper
from .registry import ModelAPI, get_model, input_specs
