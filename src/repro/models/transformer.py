"""Unified decoder LM covering dense / MoE / hybrid / VLM architectures.

Layer layout is a (kind, is_moe) list derived from the config.  To keep
compile time O(1) in depth, layers are grouped as

    [prefix layers]  +  n_super x [period positions]

where the periodic tail is run under `jax.lax.scan` with per-position
parameter stacks (leading n_super dim).  Dense qwen2 has period 1; Jamba's
1:7 mamba:attn interleave with MoE-every-2 has period 8; Kimi's
dense-first-layer is a prefix of length 1.

Caches mirror the grouping: {'prefix': [...], 'stacks': (per-position
pytrees with leading n_super dim)}.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from . import mamba as _mamba
from . import moe as _moe
from . import rwkv6 as _rwkv
from .common import (apply_attention, apply_mlp, apply_norm, dtype_of,
                     embed_init, init_attention, init_mlp, init_norm, lm_loss)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------

def layer_layout(cfg: ModelConfig) -> List[Tuple[str, bool]]:
    return [(cfg.block_kind(i), cfg.is_moe_layer(i))
            for i in range(cfg.n_layers)]


def split_layout(cfg: ModelConfig):
    """-> (prefix_len, period, n_super); layout[prefix:] repeats `period`."""
    layout = layer_layout(cfg)
    n = len(layout)
    for prefix in range(0, 3):
        rem = n - prefix
        for period in range(1, 9):
            if rem % period:
                continue
            tail = layout[prefix:]
            if all(tail[i] == tail[i % period] for i in range(rem)):
                return prefix, period, rem // period
    return n, 1, 0   # fully irregular: all layers in prefix


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, is_moe: bool) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    elif kind == "mamba":
        p["mamba"] = _mamba.init_mamba(ks[0], cfg)
    elif kind == "rwkv":
        p["time"] = _rwkv.init_rwkv_time(ks[0], cfg)
    if is_moe:
        p["moe"] = _moe.init_moe(ks[1], cfg)
        if cfg.moe.dense_residual:
            p["mlp"] = init_mlp(ks[2], cfg)
    elif kind == "rwkv":
        p["channel"] = _rwkv.init_rwkv_channel(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def _residual_spec(x: jax.Array, cache) -> tuple:
    """Residual-stream sharding between blocks: sequence-parallel shards S
    over 'model' (Megatron-SP: per-layer all-reduces lower to
    reduce-scatter + all-gather and saved activations shrink by the
    model-axis factor).  TRAINING ONLY: prefill/decode have no backward
    residuals to save, and the measured prefill cells paid ~10% extra
    resharding under SP -- so cache-bearing passes stay batch-sharded."""
    from . import tuning
    if tuning.sequence_parallel and cache is None and x.shape[1] >= 64:
        return ("dp", "model", None)
    return ("dp", None, None)


def apply_block(p: Params, cfg: ModelConfig, kind: str, is_moe: bool,
                x: jax.Array, positions, cache: Optional[Params],
                cache_pos) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x, new_cache, aux_loss_scalar)."""
    aux = jnp.float32(0.0)
    h = apply_norm(p["norm1"], x)
    if kind == "attn":
        attn_cache = cache.get("kv") if cache else None
        out, new_kv = apply_attention(p["attn"], cfg, h, positions,
                                      cache=attn_cache, cache_pos=cache_pos)
        new_cache = {"kv": new_kv} if new_kv is not None else None
    elif kind == "mamba":
        out, new_ms = _mamba.apply_mamba(p["mamba"], cfg, h,
                                         state=cache.get("ssm") if cache
                                         else None)
        new_cache = {"ssm": new_ms} if new_ms is not None else None
    elif kind == "rwkv":
        out, new_ts = _rwkv.apply_rwkv_time(p["time"], cfg, h,
                                            state=cache.get("time") if cache
                                            else None)
        new_cache = {"time": new_ts} if new_ts is not None else None
    else:
        raise ValueError(kind)
    x = x + out
    x = constrain(x, *_residual_spec(x, cache))

    h2 = apply_norm(p["norm2"], x)
    if is_moe:
        mo, moe_aux = _moe.apply_moe_auto(p["moe"], cfg, h2)
        aux = aux + sum(moe_aux.values())
        if cfg.moe.dense_residual:
            mo = mo + apply_mlp(p["mlp"], cfg, h2)
        x = x + mo
    elif kind == "rwkv":
        co, new_cs = _rwkv.apply_rwkv_channel(p["channel"], cfg, h2,
                                              state=cache.get("channel")
                                              if cache else None)
        if new_cache is not None or new_cs is not None:
            new_cache = dict(new_cache or {})
            new_cache["channel"] = new_cs
        x = x + co
    else:
        x = x + apply_mlp(p["mlp"], cfg, h2)
    x = constrain(x, *_residual_spec(x, cache))
    return x, new_cache, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int,
                     max_len: int) -> Params:
    if kind == "attn":
        return {"kv": {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd),
                           dtype_of(cfg)),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd),
                           dtype_of(cfg)),
        }}
    if kind == "mamba":
        return {"ssm": _mamba.init_mamba_state(cfg, batch)}
    if kind == "rwkv":
        st = _rwkv.init_rwkv_state(cfg, batch)
        return {"time": st["time"], "channel": st["channel"]}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    prefix_len, period, n_super = split_layout(cfg)
    layout = layer_layout(cfg)
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 4 + prefix_len + period)
    p: Params = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dt),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_init(keys[1], cfg.vocab, cfg.d_model, dt).T
    p["prefix"] = [
        init_block(keys[4 + i], cfg, *layout[i]) for i in range(prefix_len)]
    stacks = []
    for pos in range(period):
        kind, is_moe = layout[prefix_len + pos]
        per_layer = [
            init_block(
                jax.random.fold_in(keys[4 + prefix_len + pos], u),
                cfg, kind, is_moe)
            for u in range(n_super)]
        stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
                      if n_super else None)
    p["stacks"] = stacks
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    prefix_len, period, n_super = split_layout(cfg)
    layout = layer_layout(cfg)
    cache: Params = {
        "prefix": [init_block_cache(cfg, layout[i][0], batch, max_len)
                   for i in range(prefix_len)],
        # per-slot positions: serving slots sit at different depths
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    stacks = []
    for pos in range(period):
        kind, _ = layout[prefix_len + pos]
        per_layer = [init_block_cache(cfg, kind, batch, max_len)
                     for _ in range(n_super)]
        stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
                      if n_super else None)
    cache["stacks"] = stacks
    return cache


def _cache_batch_dim(path) -> int:
    """Batch dim of a cache leaf: stacked leaves are (n_super, B, ...)."""
    for entry in path:
        if isinstance(entry, jax.tree_util.DictKey) and \
                str(entry.key) == "stacks":
            return 1
    return 0


def slice_cache(cache: Params, slot, width: int = 1) -> Params:
    """Extract `width` batch rows starting at `slot` (dynamic index)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [jax.lax.dynamic_slice_in_dim(leaf, slot, width,
                                        _cache_batch_dim(path))
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def merge_cache(cache: Params, sub: Params, slot) -> Params:
    """Write a sliced sub-cache back into the batch at `slot`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    sub_leaves = jax.tree_util.tree_leaves(sub)
    out = [jax.lax.dynamic_update_slice_in_dim(
        leaf, s.astype(leaf.dtype), slot, _cache_batch_dim(path))
        for (path, leaf), s in zip(flat, sub_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def forward(params: Params, cfg: ModelConfig, tokens=None, embeds=None,
            cache: Optional[Params] = None, remat: str = "full"
            ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """-> (hidden (B,S,d), new_cache, aux_loss).

    Training/prefill: cache None / zero-pos cache.  Decode: S==1.
    """
    prefix_len, period, n_super = split_layout(cfg)
    layout = layer_layout(cfg)

    if embeds is None:
        embeds = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(embeds, "dp", None, None)
    b, s, _ = x.shape

    cache_pos = cache["pos"] if cache is not None else None
    positions = (jnp.arange(s) if cache is None
                 else cache_pos[:, None] + jnp.arange(s)[None, :])

    aux_total = jnp.float32(0.0)
    new_prefix = []
    for i in range(prefix_len):
        kind, is_moe = layout[i]
        blk_cache = cache["prefix"][i] if cache is not None else None
        x, nc, aux = apply_block(params["prefix"][i], cfg, kind, is_moe,
                                 x, positions, blk_cache, cache_pos)
        new_prefix.append(nc)
        aux_total = aux_total + aux

    if n_super:
        def run_positions(x, aux_acc, stack_slices, cache_slices):
            new_caches = []
            for pos in range(period):
                kind, is_moe = layout[prefix_len + pos]
                blk_cache = (cache_slices[pos]
                             if cache_slices is not None else None)
                x, nc, aux = apply_block(stack_slices[pos], cfg, kind,
                                         is_moe, x, positions,
                                         blk_cache, cache_pos)
                new_caches.append(nc if nc is not None else blk_cache)
                aux_acc = aux_acc + aux
            return x, aux_acc, tuple(new_caches)

        def maybe_remat(fn):
            if remat == "full":
                return jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable)
            if remat == "dots":
                return jax.checkpoint(
                    fn,
                    policy=jax.checkpoint_policies
                    .checkpoint_dots_with_no_batch_dims)
            return fn

        if cache is None:
            def superblock(carry, stack_slices):
                x, aux_acc = carry
                x, aux_acc, _ = run_positions(x, aux_acc, stack_slices, None)
                return (x, aux_acc), None

            (x, aux_total), _ = jax.lax.scan(
                maybe_remat(superblock), (x, aux_total),
                tuple(params["stacks"]))
            new_stacks = ()
        else:
            def superblock(carry, xs):
                x, aux_acc = carry
                stack_slices, cache_slices = xs
                x, aux_acc, new_caches = run_positions(
                    x, aux_acc, stack_slices, cache_slices)
                return (x, aux_acc), new_caches

            (x, aux_total), new_stacks = jax.lax.scan(
                maybe_remat(superblock), (x, aux_total),
                (tuple(params["stacks"]), tuple(cache["stacks"])))
    else:
        new_stacks = ()

    x = apply_norm(params["final_norm"], x)
    new_cache = None
    if cache is not None:
        new_cache = {"prefix": new_prefix, "stacks": list(new_stacks),
                     "pos": cache_pos + s}
    return x, new_cache, aux_total


def head_matrix(params: Params, cfg: ModelConfig) -> jax.Array:
    return (params["embed"].T if cfg.tie_embeddings else params["head"])


# ---------------------------------------------------------------------------
# Task-level entry points
# ---------------------------------------------------------------------------

def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: str = "full") -> jax.Array:
    x, _, aux = forward(params, cfg, tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"), remat=remat)
    return lm_loss(head_matrix(params, cfg), x, batch["labels"]) + aux


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            max_len: int) -> Tuple[jax.Array, Params]:
    """Run the prompt, build the cache, return last-position logits."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    b = (tokens if tokens is not None else embeds).shape[0]
    cache = init_cache(cfg, b, max_len)
    x, new_cache, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                              cache=cache, remat="none")
    logits = x[:, -1:, :] @ head_matrix(params, cfg)
    return logits, new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jax.Array) -> Tuple[jax.Array, Params]:
    """tokens: (B, 1) -> (logits (B,1,V), new_cache)."""
    x, new_cache, _ = forward(params, cfg, tokens=tokens, cache=cache,
                              remat="none")
    logits = x @ head_matrix(params, cfg)
    return logits, new_cache
