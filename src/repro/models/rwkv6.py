"""RWKV-6 (Finch) block: attention-free time mix with data-dependent decay.

Paper tie-in: RWKV's state update is a sequential stream (structured access),
so decode cost is O(1) in context length -- the arch runs long_500k where
full attention cannot.  The paper's sparse-dispatch technique itself is
inapplicable (no sparse operator); noted in DESIGN.md §5.

Faithful-to-Finch pieces: token-shift lerp with learned mix, low-rank (LoRA)
data-dependent decay  w_t = exp(-exp(w0 + tanh(x A) B)),  per-head wkv state
S in R^{hd x hd} with bonus u, and squared-relu channel mix.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import dense_init, dtype_of

Params = Dict[str, Any]


def init_rwkv_time(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.hd
    h = d // hd
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 10)
    lora = max(32, d // 32)
    return {
        "mix_r": jnp.full((d,), 0.5, dt), "mix_k": jnp.full((d,), 0.5, dt),
        "mix_v": jnp.full((d,), 0.5, dt), "mix_w": jnp.full((d,), 0.5, dt),
        "mix_g": jnp.full((d,), 0.5, dt),
        "wr": dense_init(ks[0], d, d, dt), "wk": dense_init(ks[1], d, d, dt),
        "wv": dense_init(ks[2], d, d, dt), "wg": dense_init(ks[3], d, d, dt),
        "wo": dense_init(ks[4], d, d, dt),
        # data-dependent decay LoRA (the Finch novelty)
        "w0": jnp.zeros((d,), jnp.float32),
        "wA": dense_init(ks[5], d, lora, dt, scale=0.01),
        "wB": dense_init(ks[6], lora, d, dt, scale=0.01),
        "u": (jax.random.normal(ks[7], (h, hd)) * 0.1).astype(jnp.float32),
        "ln_x": {"scale": jnp.ones((d,), jnp.float32),
                 "bias": jnp.zeros((d,), jnp.float32)},
    }


def init_rwkv_channel(key, cfg: ModelConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((d,), 0.5, dt), "mix_r": jnp.full((d,), 0.5, dt),
        "wk": dense_init(ks[0], d, ff, dt),
        "wv": dense_init(ks[1], ff, d, dt),
        "wr": dense_init(ks[2], d, d, dt),
    }


def _group_norm(p, x, h, eps=1e-5):
    """per-head layernorm on (B, S, d) viewed as (B, S, H, hd)."""
    b, s, d = x.shape
    xf = x.reshape(b, s, h, -1).astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(b, s, d) * p["scale"] + p["bias"])


def _token_shift(x: jax.Array, last: jax.Array | None):
    """x_{t-1} stream: shift right by one; `last` supplies t=-1 (decode)."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None, :].astype(x.dtype),
                                x[:, :-1]], axis=1)
    return prev


def apply_rwkv_time(p: Params, cfg: ModelConfig, x: jax.Array,
                    state: Params | None = None
                    ) -> Tuple[jax.Array, Params | None]:
    """x: (B,S,d); state: {'S': (B,H,hd,hd), 'last': (B,d)} for decode."""
    b, s, d = x.shape
    hd = cfg.hd
    h = d // hd
    last = state["last"] if state is not None else None
    prev = _token_shift(x, last)

    def lerp(mix):
        return x + (prev - x) * mix

    r = (lerp(p["mix_r"]) @ p["wr"]).reshape(b, s, h, hd)
    k = (lerp(p["mix_k"]) @ p["wk"]).reshape(b, s, h, hd)
    v = (lerp(p["mix_v"]) @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(lerp(p["mix_g"]) @ p["wg"])
    # data-dependent decay in (0, 1):  w = exp(-exp(...))  (Finch eq. 4)
    w_log = p["w0"] + (jnp.tanh(lerp(p["mix_w"]) @ p["wA"]) @ p["wB"]) \
        .astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, s, h, hd)

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def step(s_carry, xs):
        rt, kt, vt, wt = xs                    # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        out = jnp.einsum("bhi,bhij->bhj", rt,
                         s_carry + p["u"][..., None] * kv)
        s_new = wt[..., None] * s_carry + kv
        return s_new, out

    s0 = (state["S"] if state is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))

    # Chunked (FLA-style) recurrence: the naive per-token scan both saves a
    # (B, H, hd, hd) state per TIMESTEP for backward (1.7 TB/chip at
    # train_4k -- the worst memory term in the baseline table) and runs
    # 131k sequential VPU steps.  Restructuring the stream into dense
    # chunks (the paper's banded/blocked argument applied to a recurrence)
    # turns the intra-chunk work into masked CxC matmuls on the MXU and
    # touches the state once per chunk.  §Perf cell 2.
    from . import tuning
    chunk = 256
    if tuning.rwkv_chunked_scan and s % chunk == 0 and s >= chunk:
        w_log_f = -jnp.exp(w_log.astype(jnp.float32)) \
            .reshape(b, s, h, hd)                          # log w_t < 0
        if tuning.rwkv_batch_shard:
            # 40 heads do not divide a 16-way model axis, so the recurrence
            # would replicate across 'model'.  There IS spare parallelism:
            # shard the BATCH over every mesh axis for the recurrence
            # (256 sequences over 256 chips) and let GSPMD all-to-all back.
            from repro.distributed.api import constrain
            rf, kf, vf, w_log_f = (
                constrain(t, "dpm", None, None, None)
                for t in (rf, kf, vf, w_log_f))
        s_last, out = _wkv_chunked(rf, kf, vf, w_log_f, p["u"], s0, chunk)
        out = out.reshape(b, s, d)
    else:
        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, w))
        s_last, outs = jax.lax.scan(step, s0, xs)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)   # (B,S,d)
    out = _group_norm(p["ln_x"], out.astype(jnp.float32), h)
    out = (out * g.astype(jnp.float32)).astype(x.dtype) @ p["wo"]
    new_state = None
    if state is not None:
        new_state = {"S": s_last, "last": x[:, -1, :]}
    return out, new_state


_SUB = 32          # factored sub-chunk length
_LW_CLIP = -2.6    # per-step log-decay floor for the FACTORED term only:
                   # e^(32 * 2.6) = e^83 stays inside f32; a step with
                   # w < e^-2.6 = 0.074 kills cross-position terms within
                   # two steps anyway, so the overestimate is <= e^-2.6-
                   # relative on already-dead contributions.


def _wkv_subchunk(s_carry, rc, kc, vc, lwc, u):
    """One factored sub-chunk (C = _SUB steps) of the RWKV-6 recurrence.

    Layout is (B, C, H, hd) THROUGHOUT -- the natural layout of the
    residual stream -- so no chunk<->head transposes are ever materialized
    (they were 30% of the memory term in the first lowering).

    With cumulative log-decay cum_t = sum_{i<=t} log w_i (per key dim):
        out_t = (r_t * exp(cum_{t-1})) @ S_0
              + sum_{i<t} <r_t * exp(cum_{t-1}), k_i * exp(-cum_i)> v_i
              + <r_t * u, k_t> v_t
        S_out = diag(exp(cum_C)) S_0 + (k * exp(cum_C - cum))^T V
    The two matmul factors are bounded by e^(C*|log w|); C=32 with the
    _LW_CLIP floor keeps them inside f32.  Exponents feeding the inter and
    state terms are exact (<= 0, no clipping needed).
    """
    lw_f = jnp.maximum(lwc, _LW_CLIP)
    cum = jnp.cumsum(lwc, axis=1)                          # exact, (B,C,H,d)
    cum_f = jnp.cumsum(lw_f, axis=1)
    c = rc.shape[1]
    mask = jnp.tril(jnp.ones((c, c), bool), -1)            # strict lower
    r_dec = rc * jnp.exp(cum_f - lw_f)                     # <= 1
    k_inv = kc * jnp.exp(-cum_f)                           # <= e^83
    scores = jnp.einsum("bthd,bihd->bhti", r_dec, k_inv)
    scores = jnp.where(mask[None, None], scores, 0.0)
    out = jnp.einsum("bhti,bihd->bthd", scores, vc)        # intra
    r_exact = rc * jnp.exp(cum - lwc)                      # exact cum_{t-1}
    out = out + jnp.einsum("bthd,bhde->bthe", r_exact, s_carry)   # inter
    bonus = (rc * u[None, None, :, :] * kc).sum(-1)        # (B,C,H)
    out = out + bonus[..., None] * vc
    total = cum[:, -1:]                                    # (B,1,H,hd)
    k2 = kc * jnp.exp(total - cum)                         # exact, <= 1
    s_new = (jnp.exp(total[:, 0])[..., None] * s_carry
             + jnp.einsum("bihd,bihe->bhde", k2, vc))
    return s_new, out


def _wkv_chunked(r, k, v, log_w, u, s0, chunk: int):
    """Two-level chunked RWKV-6 wkv recurrence.

    Outer: checkpointed scan over `chunk`-step super-chunks (backward
    recomputes interiors; only super-chunk boundary states are saved --
    the 1.7 TB/chip per-timestep residual problem becomes ~5 GB).
    Inner: scan over _SUB-step factored sub-chunks whose intra-chunk work
    is two (C x C) matmuls on the MXU instead of C sequential VPU steps.

    r/k/v/log_w: (B, S, H, hd) f32, log_w < 0; u: (H, hd);
    s0: (B, H, hd, hd).  Returns (s_last, out (B, S, H, hd)).
    """
    b, s, h, hd = r.shape
    n = s // chunk
    n_sub = chunk // _SUB

    def to_chunks(x):   # (B,S,H,hd) -> (n, B, chunk, H, hd): no transpose,
        return jnp.moveaxis(          # just the scan-dim split
            x.reshape(b, n, chunk, h, hd), 1, 0)

    rs, ks, vs, lws = map(to_chunks, (r, k, v, log_w))

    def super_chunk(s_carry, xs):
        rc, kc, vc, lwc = xs                       # (B, chunk, H, hd)

        def sub(s_c, xs_sub):
            return _wkv_subchunk(s_c, *xs_sub, u)

        subs = tuple(
            jnp.moveaxis(a.reshape(b, n_sub, _SUB, h, hd), 1, 0)
            for a in (rc, kc, vc, lwc))            # (n_sub, B, SUB, H, hd)
        s_new, outs = jax.lax.scan(sub, s_carry, subs)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, chunk, h, hd)
        return s_new, out

    super_chunk = jax.checkpoint(
        super_chunk, policy=jax.checkpoint_policies.nothing_saveable)
    s_last, outs = jax.lax.scan(super_chunk, s0, (rs, ks, vs, lws))
    # (n, B, chunk, H, hd) -> (B, S, H, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return s_last, out


def apply_rwkv_channel(p: Params, cfg: ModelConfig, x: jax.Array,
                       state: Params | None = None
                       ) -> Tuple[jax.Array, Params | None]:
    last = state["last"] if state is not None else None
    prev = _token_shift(x, last)
    xk = x + (prev - x) * p["mix_k"]
    xr = x + (prev - x) * p["mix_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    new_state = {"last": x[:, -1, :]} if state is not None else None
    return out, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int) -> Params:
    d, hd = cfg.d_model, cfg.hd
    h = d // hd
    return {
        "time": {"S": jnp.zeros((batch, h, hd, hd), jnp.float32),
                 "last": jnp.zeros((batch, d), dtype_of(cfg))},
        "channel": {"last": jnp.zeros((batch, d), dtype_of(cfg))},
    }
