"""Mamba (S6) block for the Jamba hybrid.

Paper tie-in: the selective-scan recurrence is the *perfectly structured*
streaming case -- state updates touch contiguous memory exactly once per
step (DIA-like), which is why SSM layers keep long_500k viable while full
attention cannot (DESIGN.md §5).

Sequence processing uses a chunked scan: `lax.scan` over chunks carries the
(B, d_inner, d_state) state; inside a chunk the recurrence is materialized
with `associative_scan` (parallel prefix), bounding the transient to
(B, chunk, d_inner, d_state).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SSMConfig
from .common import dense_init, dtype_of

Params = Dict[str, Any]

SCAN_CHUNK = 128


def init_mamba(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 7)
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, di)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dtype=dt),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * s.d_state, dt),
        "dt_proj": dense_init(ks[3], dt_rank, di, dt),
        "dt_bias": jnp.zeros((di,), dtype=jnp.float32),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
            (di, 1))),                                   # (di, ds)
        "D": jnp.ones((di,), dtype=jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dt),
    }


def _causal_conv(p: Params, x: jax.Array, state=None):
    """Depthwise causal conv1d.  x: (B, S, di).  state: (B, d_conv-1, di)."""
    dconv = p["conv_w"].shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (dconv - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(p["conv_w"][j] * xp[:, j: j + x.shape[1], :]
              for j in range(dconv))
    new_state = xp[:, -(dconv - 1):, :] if dconv > 1 else None
    return jax.nn.silu(out + p["conv_b"]), new_state


def _ssm_params(p: Params, cfg: ModelConfig, xc: jax.Array):
    """xc: (B, L, di) -> (dA (B,L,di,ds), dBx (B,L,di,ds), C (B,L,ds))."""
    s = cfg.ssm
    dt_rank = p["dt_proj"].shape[0]
    proj = xc @ p["x_proj"]
    dt_in, b_mat, c_mat = jnp.split(proj, [dt_rank, dt_rank + s.d_state],
                                    axis=-1)
    delta = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(jnp.float32)
                            + p["dt_bias"])               # (B, L, di)
    a = -jnp.exp(p["A_log"])                              # (di, ds)
    d_a = jnp.exp(delta[..., None] * a)                   # (B, L, di, ds)
    d_bx = (delta * xc.astype(jnp.float32))[..., None] \
        * b_mat.astype(jnp.float32)[..., None, :]         # (B, L, di, ds)
    return d_a, d_bx, c_mat.astype(jnp.float32)


def apply_mamba(p: Params, cfg: ModelConfig, x: jax.Array,
                state: Params | None = None
                ) -> Tuple[jax.Array, Params | None]:
    """x: (B, S, d).  state (decode): {'h': (B,di,ds), 'conv': (B,dc-1,di)}.

    Returns (out, new_state); new_state is None in training mode.
    """
    b, s_len, d = x.shape
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di) each

    if state is not None and s_len == 1:
        # ---- single-step decode ----
        xc, conv_state = _causal_conv(p, xi, state["conv"])
        d_a, d_bx, c_mat = _ssm_params(p, cfg, xc)
        h = state["h"] * d_a[:, 0] + d_bx[:, 0]           # (B, di, ds)
        y = jnp.einsum("bis,bs->bi", h, c_mat[:, 0])[:, None, :]
        new_state = {"h": h, "conv": conv_state}
    else:
        xc, _ = _causal_conv(p, xi)
        chunk = min(SCAN_CHUNK, s_len)
        if s_len % chunk != 0:
            chunk = s_len
        n_chunks = s_len // chunk
        ssm = cfg.ssm or SSMConfig()
        di = ssm.expand * d
        ds = ssm.d_state

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        def chunk_body(h0, xc_chunk):
            # the (B, chunk, di, ds) decay/input tensors are computed HERE,
            # inside the chunk, never for the full sequence: materializing
            # them at S=32k was 34 TB/chip and the whole of jamba's prefill
            # memory term (§Perf).  checkpointed so backward recomputes.
            da_c, dbx_c, c_c = _ssm_params(p, cfg, xc_chunk)
            acc_a, acc_b = jax.lax.associative_scan(
                combine, (da_c, dbx_c), axis=1)
            h_t = acc_a * h0[:, None] + acc_b             # (B,chunk,di,ds)
            y_c = jnp.einsum("blis,bls->bli", h_t, c_c)
            return h_t[:, -1], y_c

        from . import tuning
        if tuning.mamba_fused_params:
            chunk_body = jax.checkpoint(
                chunk_body, policy=jax.checkpoint_policies.nothing_saveable)

        h0 = (state["h"] if state is not None
              else jnp.zeros((b, di, ds), jnp.float32))
        if n_chunks == 1:
            h_last, y = chunk_body(h0, xc)
        else:
            xcs = jnp.moveaxis(
                xc.reshape(b, n_chunks, chunk, di), 1, 0)
            h_last, ys = jax.lax.scan(chunk_body, h0, xcs)
            y = jnp.moveaxis(ys, 0, 1).reshape(b, s_len, di)
        new_state = None
        if state is not None:
            dconv = p["conv_w"].shape[0]
            xp = jnp.pad(xi, ((0, 0), (dconv - 1, 0), (0, 0)))
            new_state = {"h": h_last, "conv": xp[:, -(dconv - 1):, :]}

    y = y + p["D"] * xc.astype(jnp.float32)
    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return out @ p["out_proj"], new_state


def init_mamba_state(cfg: ModelConfig, batch: int) -> Params:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, di), dtype_of(cfg)),
    }
