"""Mixture-of-Experts layer with structure-aware (sorted) dispatch.

Paper tie-in (DESIGN.md §4): the token->expert assignment matrix is an
unstructured sparse operator -- the R-MAT case.  Multiplying through it
directly would be a random gather per token (the paper's demand-miss
pathology).  We *permute into structure* instead: sort token slots by expert
id, making the dispatch block-diagonal (the FD case), then run dense
per-expert GEMMs.  This is the paper's row/column-permutation argument run
in reverse, and `core.structure.analyze` can quantify the before/after
(see tests/test_moe.py::test_dispatch_restructuring).

Expert-parallel sharding: expert weights carry a leading E dim sharded on
the 'model' mesh axis; the dispatch buffers get sharding constraints so the
token exchange lowers to an all-to-all inside the pod.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from repro.distributed.compat import shard_map
from .common import dense_init, dtype_of

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, ff, e = cfg.d_model, m.d_expert_ff, m.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], e * d, ff, dt).reshape(e, d, ff),
        "w_up": dense_init(ks[2], e * d, ff, dt).reshape(e, d, ff),
        "w_down": dense_init(ks[3], e * ff, d, dt).reshape(e, ff, d),
    }
    if m.n_shared_experts:
        se = m.n_shared_experts
        p["shared_gate"] = dense_init(ks[4], se * d, ff, dt).reshape(se, d, ff)
        p["shared_up"] = dense_init(ks[5], se * d, ff, dt).reshape(se, d, ff)
        p["shared_down"] = dense_init(ks[6], se * ff, d, dt).reshape(se, ff, d)
    return p


def apply_moe(p: Params, cfg: ModelConfig, x: jax.Array,
              capacity: Optional[int] = None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (y, aux_losses).

    Sorted-dispatch with fixed expert capacity (dropped tokens pass through
    the residual only, standard practice).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                   # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (load balance + router z-loss) ----
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * k))
    aux = {
        "moe_balance": e * jnp.sum(me * ce) * m.aux_loss_weight,
        "moe_zloss": (jax.nn.logsumexp(logits, -1) ** 2).mean()
        * m.router_z_loss,
    }

    # ---- restructuring: sort slots by expert (unstructured -> blocked) ----
    cap = capacity or int(-(-t * k // e) * m.capacity_factor)
    flat_e = top_e.reshape(-1)                               # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)                              # the permutation
    se_, sw_, st_ = flat_e[order], flat_w[order], flat_tok[order]
    # position of each slot within its expert's block
    pos_in_e = jnp.arange(t * k) - jnp.searchsorted(se_, se_, side="left")
    keep = pos_in_e < cap
    slot = se_ * cap + pos_in_e                              # (T*k,)
    slot = jnp.where(keep, slot, e * cap)                    # overflow slot

    # dispatch: (E*cap+1, d) buffer; one extra row swallows dropped tokens
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(xt[st_])
    buf = buf[: e * cap].reshape(e, cap, d)
    # expert dim on 'model' (EP), capacity on 'dp': the scatter above lowers
    # to the dispatch all-to-all between the token-sharded and expert-sharded
    # layouts (DESIGN.md §4.1)
    buf = constrain(buf, "model", "dp", None)

    # expert FFNs: dense per-expert GEMMs (the block-diagonal multiply)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])         # (E, cap, d)
    out = constrain(out, "model", "dp", None)

    # combine: weighted scatter-add back to token order
    out_flat = out.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], out_flat[jnp.minimum(slot, e * cap - 1)],
                         0.0)
    y = jnp.zeros((t, d), x.dtype).at[st_].add(
        (gathered * sw_[:, None]).astype(x.dtype))

    # shared experts (Kimi K2): always-on, added to every token
    if m.n_shared_experts:
        hs = jnp.einsum("td,edf->etf", xt, p["shared_gate"])
        hs = jax.nn.silu(hs) * jnp.einsum("td,edf->etf", xt, p["shared_up"])
        y = y + jnp.einsum("etf,efd->td", hs, p["shared_down"]).astype(x.dtype)

    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Sharded (EP) dispatch under shard_map
# ---------------------------------------------------------------------------
#
# The global sorted-scatter above is the *reference semantics*, but GSPMD
# cannot shard a data-dependent scatter across 1M tokens: the SPMD partition
# replicates the dispatch buffer (1.7 TB of temps for kimi-k2 at
# train_4k).  The scalable realization mirrors the paper's per-thread row
# blocks: every data shard restructures ITS tokens locally (local sort ->
# local capacity), every model shard owns E/M experts and multiplies only
# its slice, and one psum over 'model' recombines.  Dispatch itself moves
# zero bytes (tokens are already replicated over 'model'); the combine is
# the only collective.

def _dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def apply_moe_sharded(p: Params, cfg: ModelConfig, x: jax.Array
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """shard_map MoE: per-data-shard dispatch, per-model-shard experts."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.api import current_mesh

    mesh = current_mesh()
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    dp = _dp_axes(mesh)
    n_model = mesh.shape["model"]
    e_local = e // n_model

    def local_fn(xs, router, wg, wu, wd):
        # xs: (b_local, s, d); router: (d, E) replicated;
        # wg/wu/wd: (E/M, d, ff) local expert slice.
        bl = xs.shape[0]
        t = bl * s
        xt = xs.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router           # (t, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        # aux losses from globally-averaged stats (pmean over dp)
        me = jax.lax.pmean(probs.mean(axis=0), dp[0]) if len(dp) == 1 else \
            jax.lax.pmean(jax.lax.pmean(probs.mean(axis=0), dp[0]), dp[1])
        ce_local = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
            1.0 / (t * k))
        ce = jax.lax.pmean(ce_local, dp[0]) if len(dp) == 1 else \
            jax.lax.pmean(jax.lax.pmean(ce_local, dp[0]), dp[1])
        zloss = (jax.nn.logsumexp(logits, -1) ** 2).mean()
        zloss = jax.lax.pmean(zloss, dp[0]) if len(dp) == 1 else \
            jax.lax.pmean(jax.lax.pmean(zloss, dp[0]), dp[1])
        aux = {"moe_balance": e * jnp.sum(me * ce) * m.aux_loss_weight,
               "moe_zloss": zloss * m.router_z_loss}

        # local restructuring: sort this shard's slots by expert id
        cap = max(int(-(-t * k // e) * m.capacity_factor), 1)
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(flat_e)
        se_, sw_, st_ = flat_e[order], flat_w[order], flat_tok[order]
        pos_in_e = jnp.arange(t * k) - jnp.searchsorted(se_, se_, side="left")

        # this model shard's expert range
        j = jax.lax.axis_index("model")
        e0 = j * e_local
        le = se_ - e0
        in_range = (le >= 0) & (le < e_local) & (pos_in_e < cap)
        slot = jnp.where(in_range, le * cap + pos_in_e, e_local * cap)

        tok_buf = jnp.zeros((e_local * cap + 1,), jnp.int32) \
            .at[slot].set(st_.astype(jnp.int32), mode="drop")[:-1]
        wgt_buf = jnp.zeros((e_local * cap + 1,), jnp.float32) \
            .at[slot].set(jnp.where(in_range, sw_, 0.0), mode="drop")[:-1]

        # gather only the local experts' rows: (E/M * cap, d)
        gx = jnp.take(xt, tok_buf, axis=0).reshape(e_local, cap, d)
        h = jnp.einsum("ecd,edf->ecf", gx, wg)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", gx, wu)
        out = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_local * cap, d)

        y = jnp.zeros((t, d), jnp.float32).at[tok_buf].add(
            out.astype(jnp.float32) * wgt_buf[:, None])
        # combine across expert shards; bf16 halves the EP wire bytes and
        # only <= top_k shards contribute nonzero per token (knob: §Perf)
        from . import tuning
        if tuning.moe_combine_bf16:
            y = jax.lax.psum(y.astype(jnp.bfloat16), "model")
        else:
            y = jax.lax.psum(y, "model")
        return y.astype(xs.dtype).reshape(bl, s, d), aux

    shard = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp, None, None),
                   {"moe_balance": P(), "moe_zloss": P()}),
        check_vma=False,
    )
    y, aux = shard(x, p["router"].astype(jnp.float32),
                   p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared_experts:
        xt = x.reshape(b * s, d)
        hs = jnp.einsum("td,edf->etf", xt, p["shared_gate"])
        hs = jax.nn.silu(hs) * jnp.einsum("td,edf->etf", xt, p["shared_up"])
        y = y + jnp.einsum("etf,efd->td", hs, p["shared_down"]) \
            .astype(x.dtype).reshape(b, s, d)
    return y, aux


def apply_moe_a2a(p: Params, cfg: ModelConfig, x: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """All-to-all expert parallelism (the §Perf upgrade over the psum EP).

    Tokens stay sharded over EVERY mesh axis (batch over dp, sequence over
    'model'); each device routes only its own t_loc tokens.  Dispatch sends
    each token to the model-shard owning its expert via one all_to_all,
    expert FFNs run on (E/M, M*cap) blocks, and a second all_to_all returns
    finished outputs to the token's home device -- no psum, no all-gather
    of the token set.  Wire per MoE layer ~= 2 * t_loc*k*cf*d bytes versus
    the psum path's full-token all-gather + 2x f32 combine.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.api import current_mesh

    mesh = current_mesh()
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    dp = _dp_axes(mesh)
    n_model = mesh.shape["model"]
    e_local = e // n_model

    def local_fn(xs, router, wg, wu, wd):
        bl, sl = xs.shape[0], xs.shape[1]
        t = bl * sl
        xt = xs.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router           # (t, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        def gmean(v):
            for ax in dp + ("model",):
                v = jax.lax.pmean(v, ax)
            return v

        me = gmean(probs.mean(axis=0))
        ce = gmean(jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)]
                   .add(1.0 / (t * k)))
        zloss = gmean((jax.nn.logsumexp(logits, -1) ** 2).mean())
        aux = {"moe_balance": e * jnp.sum(me * ce) * m.aux_loss_weight,
               "moe_zloss": zloss * m.router_z_loss}

        # local restructure: sort MY slots by (global) expert id
        cap = max(int(-(-t * k // e) * m.capacity_factor), 1)
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(flat_e)
        se_, sw_, st_ = flat_e[order], flat_w[order], flat_tok[order]
        pos_in_e = jnp.arange(t * k) - jnp.searchsorted(se_, se_, "left")
        keep = pos_in_e < cap
        slot = jnp.where(keep, se_ * cap + pos_in_e, e * cap)

        send = jnp.zeros((e * cap + 1, d), xs.dtype) \
            .at[slot].set(jnp.take(xt, st_, axis=0))[:-1]
        send = send.reshape(n_model, e_local * cap, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv[src] = tokens from device `src` for MY experts:
        # (M, e_local, cap, d) -> (e_local, M*cap, d)
        gx = recv.reshape(n_model, e_local, cap, d) \
            .transpose(1, 0, 2, 3).reshape(e_local, n_model * cap, d)
        h = jnp.einsum("ecd,edf->ecf", gx, wg)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", gx, wu)
        out = jnp.einsum("ecf,efd->ecd", h, wd)
        # back to (M, e_local*cap, d) source-major, return home
        out = out.reshape(e_local, n_model, cap, d) \
            .transpose(1, 0, 2, 3).reshape(n_model, e_local * cap, d)
        back = jax.lax.all_to_all(out, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # back[j] = outputs from expert-shard j for MY tokens, laid out in
        # global-expert-major order == the `slot` indexing above
        back = back.reshape(e * cap, d)
        gathered = jnp.where(
            keep[:, None],
            jnp.take(back, jnp.minimum(slot, e * cap - 1), axis=0), 0.0)
        y = jnp.zeros((t, d), jnp.float32).at[st_].add(
            gathered.astype(jnp.float32) * sw_[:, None])
        return y.astype(xs.dtype).reshape(bl, sl, d), aux

    shard = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, "model", None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp, "model", None),
                   {"moe_balance": P(), "moe_zloss": P()}),
        check_vma=False,
    )
    y, aux = shard(x, p["router"].astype(jnp.float32),
                   p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared_experts:
        xt = x.reshape(b * s, d)
        hs = jnp.einsum("td,edf->etf", xt, p["shared_gate"])
        hs = jax.nn.silu(hs) * jnp.einsum("td,edf->etf", xt, p["shared_up"])
        y = y + jnp.einsum("etf,efd->td", hs, p["shared_down"]) \
            .astype(x.dtype).reshape(b, s, d)
    return y, aux


def apply_moe_decode(p: Params, cfg: ModelConfig, x: jax.Array
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Weight-stationary MoE for decode-sized token counts (§Perf cell 3).

    The train-time EP paths let GSPMD all-gather the FSDP(d)-shard of each
    expert's weights -- 235 MB f32 per weight per layer to multiply a
    handful of tokens.  Here the weights never move: they enter shard_map
    in their native P('model', 'data') placement; each (expert-shard,
    d-shard) device computes a partial GEMM on its d-slice and the psum
    runs over ACTIVATIONS (E/M * cap * ff floats -- kilobytes at decode
    batch sizes).  Wire per layer drops ~4000x for long_500k.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.api import current_mesh

    mesh = current_mesh()
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    dp = _dp_axes(mesh)
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"]
    e_local = e // n_model
    d_local = d // n_data

    def local_fn(xs, router, wg, wu, wd):
        # xs is the FULL (replicated) token set: at decode sizes it is a
        # few MB, and replicating it is what lets the d-contraction split
        # over 'data' (sharding batch over 'data' too would make the
        # activation psum mix different tokens' partial slices).
        bl = xs.shape[0]
        t = bl * s
        xt = xs.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        aux = {"moe_balance": jnp.float32(0.0),
               "moe_zloss": jnp.float32(0.0)}   # no aux losses at serve time

        cap = max(int(-(-t * k // e) * m.capacity_factor), 1)
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(flat_e)
        se_, sw_, st_ = flat_e[order], flat_w[order], flat_tok[order]
        pos_in_e = jnp.arange(t * k) - jnp.searchsorted(se_, se_, "left")
        j = jax.lax.axis_index("model")
        le = se_ - j * e_local
        in_range = (le >= 0) & (le < e_local) & (pos_in_e < cap)
        slot = jnp.where(in_range, le * cap + pos_in_e, e_local * cap)
        tok_buf = jnp.zeros((e_local * cap + 1,), jnp.int32) \
            .at[slot].set(st_.astype(jnp.int32))[:-1]
        wgt_buf = jnp.zeros((e_local * cap + 1,), jnp.float32) \
            .at[slot].set(jnp.where(in_range, sw_, 0.0))[:-1]

        gx = jnp.take(xt, tok_buf, axis=0)             # (E/M*cap, d)
        i = jax.lax.axis_index("data")
        gxs = jax.lax.dynamic_slice_in_dim(gx, i * d_local, d_local, 1) \
            .reshape(e_local, cap, d_local)
        # f32 partials: the d-contraction is split across 'data' shards, so
        # accumulate & reduce in f32 (the activation psums are kilobytes)
        hg = jax.lax.psum(jnp.einsum(
            "ecd,edf->ecf", gxs, wg,
            preferred_element_type=jnp.float32), "data")
        hu = jax.lax.psum(jnp.einsum(
            "ecd,edf->ecf", gxs, wu,
            preferred_element_type=jnp.float32), "data")
        hmid = jax.nn.silu(hg) * hu                    # (E/M, cap, ff) f32
        out_p = jnp.einsum("ecf,efd->ecd", hmid, wd)   # (E/M, cap, d/D)
        out = jax.lax.all_gather(out_p, "data", axis=2, tiled=True)
        out = out.reshape(e_local * cap, d)
        y = jnp.zeros((t, d), jnp.float32).at[tok_buf].add(
            out.astype(jnp.float32) * wgt_buf[:, None])
        y = jax.lax.psum(y.astype(jnp.bfloat16), "model")
        return y.astype(xs.dtype).reshape(bl, s, d), aux

    shard = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None, None), P(None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", None, "data")),
        out_specs=(P(None, None, None),
                   {"moe_balance": P(), "moe_zloss": P()}),
        check_vma=False,
    )
    y, aux = shard(x, p["router"].astype(jnp.float32),
                   p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared_experts:
        xt = x.reshape(b * s, d)
        hs = jnp.einsum("td,edf->etf", xt, p["shared_gate"])
        hs = jax.nn.silu(hs) * jnp.einsum("td,edf->etf", xt, p["shared_up"])
        y = y + jnp.einsum("etf,efd->td", hs, p["shared_down"]) \
            .astype(x.dtype).reshape(b, s, d)
    return y, aux


def apply_moe_auto(p: Params, cfg: ModelConfig, x: jax.Array
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Route to the shard_map EP path when a model-axis mesh is active and
    the expert count divides it; otherwise the global reference path."""
    from repro.distributed.api import current_mesh

    from . import tuning

    mesh = current_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or cfg.moe.n_experts % mesh.shape["model"] != 0):
        return apply_moe(p, cfg, x)
    # decode (one token per slot): weight-stationary path -- needs no
    # batch divisibility because the token set is replicated
    if (tuning.moe_decode_weight_stationary and x.shape[1] == 1
            and "data" in mesh.axis_names
            and cfg.d_model % mesh.shape["data"] == 0):
        return apply_moe_decode(p, cfg, x)
    if x.shape[0] % _dp_size(mesh) != 0:
        return apply_moe(p, cfg, x)
    if tuning.moe_all_to_all and x.shape[1] % mesh.shape["model"] == 0:
        return apply_moe_a2a(p, cfg, x)
    return apply_moe_sharded(p, cfg, x)


def _dp_size(mesh) -> int:
    n = mesh.shape.get("data", 1)
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def dispatch_structure_demo(top_e: jnp.ndarray, n_experts: int):
    """Build the (T, E) assignment matrix before/after sorting as CSR so
    core.structure.analyze can quantify the restructuring (used by examples
    and tests)."""
    import numpy as np

    from repro.core.formats import CSR

    t, k = top_e.shape
    rows = np.repeat(np.arange(t), k)
    cols = np.asarray(top_e).reshape(-1)
    vals = np.ones(t * k, np.float32)
    unsorted = CSR.from_coo(rows, cols, vals, t, n_experts)
    order = np.argsort(cols, kind="stable")
    sorted_m = CSR.from_coo(np.arange(t * k), cols[order], vals, t * k,
                            n_experts)
    return unsorted, sorted_m
