"""Uniform model API: every assigned architecture behind four functions.

    api = get_model(cfg)
    params = api.init(rng)
    loss   = api.loss_fn(params, batch)            # train shapes
    logits, cache = api.prefill(params, batch, max_len)
    logits, cache = api.decode_step(params, cache, tokens)

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every input
of the step function that the multi-pod dry-run lowers (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from . import transformer, whisper
from .common import dtype_of

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.is_encdec:
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: whisper.init_params(rng, cfg),
            loss_fn=functools.partial(_flip(whisper.loss_fn), cfg),
            prefill=functools.partial(_flip(whisper.prefill), cfg),
            decode_step=functools.partial(_flip(whisper.decode_step), cfg),
        )
    return ModelAPI(
        cfg=cfg,
        init=lambda rng: transformer.init_params(rng, cfg),
        loss_fn=functools.partial(_flip(transformer.loss_fn), cfg),
        prefill=functools.partial(_flip(transformer.prefill), cfg),
        decode_step=functools.partial(_flip(transformer.decode_step), cfg),
    )


def _flip(fn):
    """(params, cfg, ...) -> (cfg, params, ...) for partial application."""
    def wrapped(cfg, params, *a, **k):
        return fn(params, cfg, *a, **k)
    return wrapped


# ---------------------------------------------------------------------------
# Input specs for the dry-run (ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------

def _tok(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                           dtype_of(cfg)),
            "tokens": _tok((b, cfg.decoder_len)),
            "labels": _tok((b, cfg.decoder_len)),
        }
    if cfg.family == "vlm":
        # early-fusion VLM: the VQ tokenizer frontend is a stub per the
        # assignment -- input_specs provides precomputed patch-token embeds
        return {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                           dtype_of(cfg)),
            "labels": _tok((b, s)),
        }
    return {"tokens": _tok((b, s)), "labels": _tok((b, s))}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                           dtype_of(cfg)),
            "tokens": _tok((b, cfg.decoder_len)),
        }
    if cfg.family == "vlm":
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               dtype_of(cfg))}
    return {"tokens": _tok((b, s))}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Specs for (cache, tokens) of one serve_step with a seq_len-long
    context already in the cache."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        enc_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype_of(cfg))
        cache = jax.eval_shape(
            lambda e: whisper.init_cache(cfg, b, cfg.decoder_len, e),
            enc_spec)
        return {"cache": cache, "tokens": _tok((b, 1))}
    cache = jax.eval_shape(
        functools.partial(transformer.init_cache, cfg, b, s))
    return {"cache": cache, "tokens": _tok((b, 1))}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


# ---------------------------------------------------------------------------
# Random batches for smoke tests / examples (reduced configs only)
# ---------------------------------------------------------------------------

def random_train_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
                       ) -> Dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    if cfg.is_encdec:
        t = max(1, min(seq, cfg.decoder_len - 8))
        return {
            "frames": jnp.asarray(
                rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32),
                dtype=dtype_of(cfg)),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, t)), dtype=jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, t)), dtype=jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "embeds": jnp.asarray(
                rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32),
                dtype=dtype_of(cfg)),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq)), dtype=jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)),
                              dtype=jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)),
                              dtype=jnp.int32),
    }
