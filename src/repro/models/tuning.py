"""Performance knobs (the §Perf hillclimb switches).

Module-level so the dry-run / perf drivers can lower the SAME model code in
baseline and optimized configurations:

    from repro.models import tuning
    tuning.set_profile("baseline")   # paper-faithful first lowering
    tuning.set_profile("optimized")  # shipping defaults

Knobs:
  attn_chunk_remat   recompute attention scores/probs in backward
                     (flash-style memory) instead of saving per-chunk slabs
  sequence_parallel  shard the residual stream's sequence dim over 'model'
                     between blocks -> Megatron-SP: the per-layer
                     all-reduces become reduce-scatter + all-gather (half
                     the wire bytes) and saved activations shrink by the
                     model-axis factor
  moe_combine_bf16   psum the MoE combine in bf16 instead of f32 (half the
                     EP combine wire bytes; <=top_k shards contribute per
                     token so the accumulation error stays tiny)
"""
from __future__ import annotations

attn_chunk_remat: bool = True
sequence_parallel: bool = True
moe_combine_bf16: bool = True
moe_all_to_all: bool = True      # a2a expert parallelism (tokens stay
                                 # sharded on every axis; two all_to_alls
                                 # replace all-gather + psum combine)
moe_decode_weight_stationary: bool = True   # decode MoE: weights never
                                 # move; psum tiny activations instead
causal_chunk_unroll: bool = True  # static causal chunking: skip future KV
                                  # blocks + bias-only diagonal masking
mamba_fused_params: bool = True   # compute (B,chunk,di,ds) SSM tensors per
                                  # chunk + checkpoint (never full-sequence)
rwkv_chunked_scan: bool = True   # chunked-matmul wkv recurrence (FLA form)
rwkv_batch_shard: bool = True    # shard recurrence batch over ALL axes
kv_onehot_write: bool = True     # one-hot select KV write (vs vmapped DUS
                                 # that legalizes to f32 scatter)

_PROFILES = {
    "baseline": dict(attn_chunk_remat=False, sequence_parallel=False,
                     moe_combine_bf16=False, moe_all_to_all=False,
                     causal_chunk_unroll=False, rwkv_chunked_scan=False,
                     rwkv_batch_shard=False, kv_onehot_write=False,
                     moe_decode_weight_stationary=False,
                     mamba_fused_params=False),
    # rwkv_batch_shard measured WORSE on the dry-run (memory +7.2s for
    # collective -5.6s: GSPMD already extracts the batch parallelism and
    # the explicit constraint only forces resharding copies) -- kept as a
    # knob for the §Perf record, default off.
    # moe_all_to_all measured WORSE on the dominant (memory) term: its
    # full-E send/return buffers cost ~80s/step of HBM for a 7s collective
    # win (kimi train_4k).  Kept as a knob for the §Perf record.
    "optimized": dict(attn_chunk_remat=True, sequence_parallel=True,
                      moe_combine_bf16=True, moe_all_to_all=False,
                      causal_chunk_unroll=True, rwkv_chunked_scan=True,
                      rwkv_batch_shard=False, kv_onehot_write=True,
                      moe_decode_weight_stationary=True,
                      mamba_fused_params=True),
}


def set_profile(name: str) -> None:
    g = globals()
    for k, v in _PROFILES[name].items():
        g[k] = v


def set_knob(name: str, value: bool) -> None:
    if name not in _PROFILES["baseline"]:
        raise KeyError(name)
    globals()[name] = value


def snapshot() -> dict:
    return {k: globals()[k] for k in _PROFILES["baseline"]}
