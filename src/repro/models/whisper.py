"""Whisper-style encoder-decoder (audio backbone, conv frontend stubbed).

Per the assignment, the modality frontend is a STUB: `input_specs()`
provides precomputed frame embeddings (B, S_audio, d_model) as if the two
conv layers had already run.  The transformer backbone is faithful:
sinusoidal encoder positions, learned decoder positions, pre-LN blocks,
GELU MLPs, decoder with causal self-attention + cross-attention.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from .common import (apply_attention, apply_mlp, apply_norm, dtype_of,
                     embed_init, init_attention, init_mlp, init_norm, lm_loss)

Params = Dict[str, Any]


def sinusoids(length: int, d: int) -> jnp.ndarray:
    half = d // 2
    log_timescale = np.log(10000.0) / (half - 1)
    inv = np.exp(-log_timescale * np.arange(half))
    scaled = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1),
        dtype=jnp.float32)


def init_enc_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"norm1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
            "norm2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}


def init_dec_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {"norm1": init_norm(cfg), "self_attn": init_attention(ks[0], cfg),
            "norm_x": init_norm(cfg), "cross_attn": init_attention(ks[1], cfg),
            "norm2": init_norm(cfg), "mlp": init_mlp(ks[2], cfg)}


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    n_enc = cfg.n_encoder_layers
    n_dec = cfg.n_layers
    enc_blocks = [init_enc_block(jax.random.fold_in(ks[0], i), cfg)
                  for i in range(n_enc)]
    dec_blocks = [init_dec_block(jax.random.fold_in(ks[1], i), cfg)
                  for i in range(n_dec)]
    return {
        "tok_embed": embed_init(ks[2], cfg.vocab, cfg.d_model, dt),
        "dec_pos": embed_init(ks[3], cfg.decoder_len, cfg.d_model, dt),
        "enc_norm": init_norm(cfg),
        "dec_norm": init_norm(cfg),
        "enc_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
        "dec_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_blocks),
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           remat: str = "full") -> jax.Array:
    """frames: (B, S_audio, d) stub embeddings -> encoder states."""
    s = frames.shape[1]
    x = frames + sinusoids(s, cfg.d_model).astype(frames.dtype)
    x = constrain(x, "dp", None, None)
    positions = jnp.arange(s)

    def block(x, p):
        h = apply_norm(p["norm1"], x)
        out, _ = apply_attention(p["attn"], cfg, h, positions, causal=False)
        x = x + out
        x = x + apply_mlp(p["mlp"], cfg, apply_norm(p["norm2"], x))
        return constrain(x, "dp", None, None), None

    if remat == "full":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(block, x, params["enc_stack"])
    return apply_norm(params["enc_norm"], x)


def decode(params: Params, cfg: ModelConfig, tokens: jax.Array,
           enc_out: Optional[jax.Array],
           cache: Optional[Params] = None, remat: str = "full"
           ) -> Tuple[jax.Array, Optional[Params]]:
    """tokens: (B, T).  cache (decode): per-layer stacked self-KV +
    precomputed cross-KV."""
    b, t = tokens.shape
    cache_pos = cache["pos"] if cache is not None else None
    positions = (jnp.arange(t) if cache is None
                 else cache_pos[:, None] + jnp.arange(t)[None, :])
    x = jnp.take(params["tok_embed"], tokens, axis=0)
    x = x + jnp.take(params["dec_pos"], positions, axis=0, mode="clip")
    x = constrain(x, "dp", None, None)

    def block(carry, xs):
        x = carry
        p, kv_slice = xs
        h = apply_norm(p["norm1"], x)
        self_cache = kv_slice["kv"] if kv_slice is not None else None
        out, new_kv = apply_attention(p["self_attn"], cfg, h, positions,
                                      cache=self_cache, cache_pos=cache_pos)
        x = x + out
        hx = apply_norm(p["norm_x"], x)
        cross, _ = apply_attention(p["cross_attn"], cfg, hx, positions,
                                   kv_x=enc_out, causal=False)
        x = x + cross
        x = x + apply_mlp(p["mlp"], cfg, apply_norm(p["norm2"], x))
        x = constrain(x, "dp", None, None)
        new_slice = {"kv": new_kv} if new_kv is not None else kv_slice
        return x, new_slice

    if cache is None:
        def nb(c, p):
            c, _ = block(c, (p, None))
            return c, None
        if remat == "full":
            nb = jax.checkpoint(
                nb, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(nb, x, params["dec_stack"])
        new_cache = None
    else:
        x, new_kvs = jax.lax.scan(block, x,
                                  (params["dec_stack"], cache["kv_stack"]))
        new_cache = {"kv_stack": new_kvs, "pos": cache_pos + t,
                     "enc_out": cache["enc_out"]}
    return apply_norm(params["dec_norm"], x), new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_out: jax.Array) -> Params:
    dt = dtype_of(cfg)
    kv = {"kv": {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.hd), dt),
    }}
    return {"kv_stack": kv, "pos": jnp.zeros((batch,), jnp.int32),
            "enc_out": enc_out}


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: str = "full") -> jax.Array:
    enc_out = encode(params, cfg, batch["frames"], remat=remat)
    x, _ = decode(params, cfg, batch["tokens"], enc_out, remat=remat)
    return lm_loss(params["tok_embed"].T, x, batch["labels"])


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            max_len: int) -> Tuple[jax.Array, Params]:
    enc_out = encode(params, cfg, batch["frames"], remat="none")
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len, enc_out)
    x, new_cache = decode(params, cfg, batch["tokens"], enc_out,
                          cache=cache, remat="none")
    logits = x[:, -1:, :] @ params["tok_embed"].T
    return logits, new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jax.Array) -> Tuple[jax.Array, Params]:
    x, new_cache = decode(params, cfg, tokens, cache["enc_out"],
                          cache=cache, remat="none")
    logits = x @ params["tok_embed"].T
    return logits, new_cache
