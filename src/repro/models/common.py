"""Shared model building blocks (pure-JAX pytrees, no framework deps).

Conventions:
  * init_* functions return (params, ...) dicts of jnp arrays.
  * apply functions are pure; dtype policy: params in cfg.dtype, layernorm
    and softmax accumulate in fp32.
  * Attention is CHUNKED over queries (lax.scan) so 32k-sequence prefill
    never materializes an (S x S) score matrix -- the jnp analogue of the
    flash kernel, and what the dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

ATTN_CHUNK = 1024      # query-chunk size for chunked attention
ATTN_SCORE_BUDGET = 1 << 22   # target elements per (chunk x skv) score slab


def attn_chunk_for(skv: int) -> int:
    """Adapt the query-chunk so the transient score tensor stays bounded:
    32k-KV prefill uses 128-query chunks, 4k training keeps 1024."""
    return int(min(ATTN_CHUNK, max(128, ATTN_SCORE_BUDGET // max(skv, 1))))


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype=jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), dtype=jnp.float32)
    return p


def apply_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:   # LayerNorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:             # RMSNorm
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (partial rotary supported: StableLM rope_pct=0.25)
# ---------------------------------------------------------------------------

def rope_frequencies(hd_rot: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32)
                            / hd_rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rope_pct: float = 1.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if rope_pct <= 0.0:
        return x
    hd = x.shape[-1]
    hd_rot = int(hd * rope_pct)
    hd_rot -= hd_rot % 2
    if hd_rot == 0:
        return x
    freqs = rope_frequencies(hd_rot, theta)                    # (hd_rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (...,S,1,hr/2)
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :hd_rot], x[..., hd_rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    rotated = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, chunked, optional sliding window / cross / qk-norm)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype=dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype=dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype=dt)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype=jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype=jnp.float32)}
    return p


def _use_onehot_write() -> bool:
    from . import tuning
    return tuning.kv_onehot_write


def _qk_norm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * p["scale"]).astype(x.dtype)


def _sdpa_chunked(q, k, v, *, causal: bool, window: Optional[int],
                  q_offset, chunk: Optional[int] = None) -> jax.Array:
    """softmax(QK^T)V with queries chunked by lax.scan (flash-style memory).

    q: (B, Sq, H, hd)   k/v: (B, Skv, KVH, hd) with H = G*KVH
    q_offset: scalar -- position of q[0] within the kv timeline.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, sq, kvh, g, hd)
    k_idx = jnp.arange(skv)

    chunk = min(chunk if chunk is not None else attn_chunk_for(skv), sq)
    n_chunks = sq // chunk if sq % chunk == 0 else 1
    if sq % chunk != 0:
        chunk = sq

    # q_offset may be a scalar (train/prefill) or a (B,) vector (serving
    # slots at different depths); both broadcast to a (B|1, chunk) q_idx.
    q_off = jnp.asarray(q_offset)
    q_off = q_off.reshape(-1, 1)          # (B,1) or (1,1)

    def one_chunk(ci, qc):
        # qc: (B, chunk, KVH, G, hd)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qc.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        q_idx = q_off + ci * chunk + jnp.arange(chunk)[None, :]  # (B|1,chunk)
        mask = jnp.ones(q_idx.shape + (skv,), dtype=bool)
        if causal:
            mask &= q_idx[..., None] >= k_idx[None, None, :]
        if window is not None:
            mask &= (q_idx[..., None] - k_idx[None, None, :]) < window
        s = jnp.where(mask[:, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bqkgs,bskd->bqkgd", p,
                          v.astype(jnp.float32))

    # Flash-style memory behaviour: recompute scores/probs in the backward
    # pass instead of saving the (B, chunk, KVH, G, S) slabs per chunk --
    # without this, an L-layer model saves L*n_chunks probability tensors
    # (the dominant HBM term in the train-cell roofline).  Knob: §Perf.
    from . import tuning
    if tuning.attn_chunk_remat:
        one_chunk = jax.checkpoint(
            one_chunk, policy=jax.checkpoint_policies.nothing_saveable)

    # Causal-unrolled path (train-time self-attention): chunk ci only ever
    # sees keys < (ci+1)*chunk, so slice the KV prefix statically for the
    # score einsum -- future blocks are skipped outright (the flash
    # kernel's block-skip on the QK^T half) and the boolean where() mask
    # collapses to an additive bias on the diagonal block alone.  The
    # scores are then padded back to the full KV length with -1e30 before
    # softmax, so the softmax denominator and the PV accumulation reduce
    # over the SAME extent (and order) as the fori path above: the two
    # knob settings are bitwise-identical, not merely close.
    if (tuning.causal_chunk_unroll and causal and window is None
            and isinstance(q_offset, int) and q_offset == 0
            and n_chunks > 1 and n_chunks <= 16):
        tri_bias = jnp.where(
            jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :],
            0.0, -1e30).astype(jnp.float32)        # (chunk, chunk)

        def causal_chunk(ci, qc):
            hi = (ci + 1) * chunk
            kc = k[:, :hi]
            s_ci = jnp.einsum("bqkgd,bskd->bqkgs", qc.astype(jnp.float32),
                              kc.astype(jnp.float32)) * scale
            bias = jnp.concatenate(
                [jnp.zeros((chunk, ci * chunk), jnp.float32), tri_bias],
                axis=1)                            # (chunk, hi)
            s_ci = s_ci + bias[None, :, None, None, :]
            # pad the skipped future blocks as -1e30 (exactly what the
            # masked path stores there): exp underflows to 0.0, and the
            # full-width softmax/PV reductions match the fori path bitwise
            s_full = jnp.pad(s_ci, ((0, 0),) * 4 + ((0, skv - hi),),
                             constant_values=-1e30)
            p_ci = jax.nn.softmax(s_full, axis=-1)
            return jnp.einsum("bqkgs,bskd->bqkgd", p_ci,
                              v.astype(jnp.float32))

        if tuning.attn_chunk_remat:
            causal_chunk = jax.checkpoint(
                causal_chunk, policy=jax.checkpoint_policies
                .nothing_saveable, static_argnums=(0,))
        qcs = qg.reshape(b, n_chunks, chunk, kvh, g, hd)
        outs = [causal_chunk(ci, qcs[:, ci]) for ci in range(n_chunks)]
        out = jnp.stack(outs, axis=1).reshape(b, sq, kvh, g, hd)
        return out.reshape(b, sq, h, hd)

    if n_chunks == 1:
        out = one_chunk(0, qg)
    else:
        qcs = qg.reshape(b, n_chunks, chunk, kvh, g, hd)
        qcs = jnp.moveaxis(qcs, 1, 0)               # (n, B, chunk, KVH, G, hd)

        def body(_, xs):
            ci, qc = xs
            return None, one_chunk(ci, qc)

        _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qcs))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kvh, g, hd)
    return out.reshape(b, sq, h, hd)


def apply_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, *,
                    kv_x: Optional[jax.Array] = None,
                    causal: bool = True,
                    cache: Optional[Params] = None,
                    cache_pos=None):
    """Returns (out, new_cache).  Self-attention unless kv_x given (cross).

    cache: {'k','v'}: (B, S_max, KVH, hd); cache_pos: scalar write index.
    """
    b, s, d = x.shape
    hd = cfg.hd
    src = kv_x if kv_x is not None else x
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = _qk_norm(p["q_norm"], q)
        k = _qk_norm(p["k_norm"], k)
    if kv_x is None and cfg.rope_pct > 0:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)

    new_cache = None
    q_offset = 0
    if cache is not None:
        # decode / incremental: write new kv at cache_pos, attend over the
        # prefix.  cache_pos is a scalar (uniform batch) or a (B,) vector
        # (serving slots at different depths).
        #
        # The per-slot single-token write uses a one-hot select, NOT a
        # vmapped dynamic-update-slice: vmapped DUS lowers to scatter,
        # which XLA legalizes for bf16 via f32 round-trips of the whole
        # stacked cache (measured: ~0.5 TB/step of pure convert traffic on
        # the decode_32k cells).  The select is the TPU-idiomatic pattern
        # (cf. MaxText decode) and stays a fused bf16 elementwise op.
        # NOTE(§Perf): a B==1 scalar-DUS special case was tried for
        # long_500k and measured WORSE (98->111 ms): a dynamic index into
        # the sequence-SHARDED cache dim makes GSPMD reshard, while the
        # one-hot select below stays shard-local.
        pos = jnp.asarray(cache_pos)
        dt = cache["k"].dtype
        if pos.ndim == 0:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(dt), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(dt), (0, pos, 0, 0))
        elif s == 1 and _use_onehot_write():
            s_max = cache["k"].shape[1]
            oh = (jnp.arange(s_max)[None, :] == pos[:, None]
                  )[:, :, None, None]                       # (B, S, 1, 1)
            ck = jnp.where(oh, k.astype(dt), cache["k"])
            cv = jnp.where(oh, v.astype(dt), cache["v"])
        elif s == 1:
            upd = jax.vmap(
                lambda c, u, pp: jax.lax.dynamic_update_slice(
                    c, u, (pp, 0, 0)))
            ck = upd(cache["k"], k.astype(dt), pos)
            cv = upd(cache["v"], v.astype(dt), pos)
        else:
            # batched multi-token prefill: slots share the write offset
            # (the serving engine prefills one slot at a time, so this
            # branch only sees aligned offsets)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(dt), (0, pos[0], 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(dt), (0, pos[0], 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        q_offset = cache_pos
        # mask out beyond cache_pos + s via causal indexing
        causal = True
    out = _sdpa_chunked(q, k, v, causal=causal and kv_x is None,
                        window=cfg.attn_window, q_offset=q_offset)
    out = out.astype(x.dtype).reshape(b, s, cfg.n_heads * hd)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, ff, dt),
         "w_down": dense_init(ks[1], ff, d, dt)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[2], d, ff, dt)
    return p


def apply_mlp(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    up = x @ p["w_up"]
    if cfg.act == "swiglu":
        up = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return up @ p["w_down"]


# ---------------------------------------------------------------------------
# Loss (chunked over sequence; vocab stays shardable on 'model')
# ---------------------------------------------------------------------------

def lm_loss(head: jax.Array, x: jax.Array, labels: jax.Array,
            n_chunks: int = 8) -> jax.Array:
    """Cross-entropy( x @ head , labels ) without materializing full logits.

    x: (B, S, d), head: (d, V), labels: (B, S) int32 (-1 = masked).
    Chunked over S: transient logits are (B, S/n_chunks, V).
    """
    b, s, d = x.shape
    if s % n_chunks != 0:
        n_chunks = 1
    cs = s // n_chunks
    xc = jnp.moveaxis(x.reshape(b, n_chunks, cs, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n_chunks, cs), 1, 0)

    def body(carry, xs):
        xi, li = xs
        logits = (xi @ head).astype(jnp.float32)      # (B, cs, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        loss = ((logz - gold) * valid).sum()
        return (carry[0] + loss, carry[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)
