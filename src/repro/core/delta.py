"""Batched edge deltas: the mutation container for streaming matrices.

The plan pipeline freezes a matrix at compile time; a live graph does
not hold still.  `EdgeDelta` is the bridge: a batch of edge inserts and
deletes expressed against one *base* CSR, small enough to apply as a
COO correction pass after the planned SpMV (`repro.plan.overlay`) and
to materialize cheaply when the plan must be rebuilt
(`CSR.apply_delta`).

Overlay algebra
---------------
Under plus_times, SpMV is linear: (A + Δ)x = Ax + Δx, so an insert is
a COO entry with its value and a *delete* is the same entry negated --
both exact (no float cancellation issues arise for the bit-exactness
contract because the subtraction removes precisely the term the base
kernel added only in exact arithmetic; the property suite therefore
pins bit-identity on integer-valued matrices, where every f32 sum is
exact).  The other semirings have no ⊕-inverse: an insert still
overlays (y' = y ⊕ (Δ ⊗ x) is exact because ⊕ is idempotent or the
coordinate was absent from the base), but a delete cannot be undone
after the base reduction -- `has_deletes` under a non-invertible
semiring marks the delta *overlay-ineligible* and forces
materialization (`repro.plan.overlay.overlay_eligible`).

Contract
--------
Coordinates are unique per operation: an insert targets a coordinate
absent from the effective matrix, a delete targets a present one, and
"change this value" is a delete plus an insert of the same coordinate
in one batch (deletes apply first).  This keeps every semiring
unambiguous -- a duplicate-summing insert would be plus_times-specific.
Base CSRs must be canonical (built via `CSR.from_coo`, duplicate-free);
non-canonical bases are refused rather than silently corrupted.

`EdgeDelta` is host-side numpy, deliberately NOT a pytree: deltas live
on the mutation path (plan lifecycle bookkeeping), and only the small
arrays the overlay pass needs are shipped to the device by
`OverlaidPlan`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

import numpy as np

from .formats import CSR


def _canonical_keys(csr: CSR, who: str) -> np.ndarray:
    """Flattened (row * n_cols + col) keys of a canonical CSR, strictly
    ascending.  Raises on unsorted or duplicate coordinates."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    keys = rows * csr.n_cols + np.asarray(csr.indices, dtype=np.int64)
    if keys.size and not np.all(np.diff(keys) > 0):
        raise ValueError(
            f"{who} requires a canonically (row, col)-sorted, duplicate-free "
            "CSR (build via CSR.from_coo with unique coordinates)")
    return keys


def _member(query_keys: np.ndarray, base_keys: np.ndarray
            ) -> Tuple[np.ndarray, np.ndarray]:
    """(found mask, position) of each query key in sorted `base_keys`."""
    if base_keys.size == 0:
        z = np.zeros(query_keys.shape, dtype=np.int64)
        return np.zeros(query_keys.shape, dtype=bool), z
    pos = np.searchsorted(base_keys, query_keys)
    pos_c = np.minimum(pos, base_keys.size - 1)
    return (pos < base_keys.size) & (base_keys[pos_c] == query_keys), pos_c


def csr_lookup(csr: CSR, rows, cols) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized coordinate lookup: (values, found mask) for each
    (rows[i], cols[i]) in a canonical CSR.  Absent coordinates report
    value 0.0 and found=False."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    keys = _canonical_keys(csr, "csr_lookup")
    found, pos = _member(rows * csr.n_cols + cols, keys)
    data = np.asarray(csr.data)
    vals = np.where(found, data[pos] if data.size else 0.0, 0.0)
    return vals.astype(data.dtype if data.size else np.float32), found


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """A canonical batch of edge mutations against one base matrix.

    Entries are (row, col, value, is_delete), sorted by (row, col) with
    a coordinate's delete ordered before its re-insert; at most one
    delete and one insert may name a coordinate.  Delete values record
    the base value being removed (that is what the plus_times overlay
    negates).  Build through `from_updates` / `csr_diff` / `merge`; the
    raw constructor is an implementation detail shared with `_build`.
    """

    rows: np.ndarray       # (nnz,) int64
    cols: np.ndarray       # (nnz,) int64
    vals: np.ndarray       # (nnz,) float32; for deletes, the removed value
    deletes: np.ndarray    # (nnz,) bool
    n_rows: int
    n_cols: int

    # -- geometry -----------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_cols)

    @property
    def nnz(self) -> int:
        return int(self.rows.size)

    @property
    def n_deletes(self) -> int:
        return int(self.deletes.sum())

    @property
    def n_inserts(self) -> int:
        return self.nnz - self.n_deletes

    @property
    def has_deletes(self) -> bool:
        return bool(self.deletes.any())

    # -- construction -------------------------------------------------------

    @staticmethod
    def _build(rows, cols, vals, deletes, n_rows: int, n_cols: int
               ) -> "EdgeDelta":
        rows = np.asarray(rows, dtype=np.int64).ravel()
        cols = np.asarray(cols, dtype=np.int64).ravel()
        vals = np.asarray(vals, dtype=np.float32).ravel()
        deletes = np.asarray(deletes, dtype=bool).ravel()
        if not (rows.shape == cols.shape == vals.shape == deletes.shape):
            raise ValueError("rows/cols/vals/deletes must be equal-length")
        if rows.size:
            if (rows.min() < 0 or rows.max() >= n_rows
                    or cols.min() < 0 or cols.max() >= n_cols):
                raise ValueError(
                    f"delta coordinates out of range for {n_rows}x{n_cols}")
            order = np.lexsort((~deletes, cols, rows))
            rows, cols = rows[order], cols[order]
            vals, deletes = vals[order], deletes[order]
            keys = rows * n_cols + cols
            same = keys[1:] == keys[:-1]
            pair_ok = deletes[:-1] & ~deletes[1:]       # delete then insert
            if (same & ~pair_ok).any() or (same[1:] & same[:-1]).any():
                raise ValueError(
                    "a coordinate may carry at most one delete and one "
                    "insert per delta batch")
        return EdgeDelta(rows=rows, cols=cols, vals=vals, deletes=deletes,
                         n_rows=int(n_rows), n_cols=int(n_cols))

    @staticmethod
    def empty(n_rows: int, n_cols: int) -> "EdgeDelta":
        z = np.zeros(0, dtype=np.int64)
        return EdgeDelta(rows=z, cols=z.copy(),
                         vals=np.zeros(0, dtype=np.float32),
                         deletes=np.zeros(0, dtype=bool),
                         n_rows=int(n_rows), n_cols=int(n_cols))

    @staticmethod
    def from_updates(base: CSR, inserts: Iterable = (),
                     deletes: Iterable = ()) -> "EdgeDelta":
        """Validated delta from user-level updates against `base`.

        `inserts` are (row, col, value) triples naming coordinates absent
        from `base`; `deletes` are (row, col) pairs naming present ones
        (the removed value is looked up here -- callers never supply it).
        Changing a stored value = delete + insert of the same coordinate
        in one batch.  Violations raise instead of producing a delta
        whose overlay and materialization would disagree.
        """
        ins = np.asarray(list(inserts), dtype=np.float64).reshape(-1, 3)
        dels = np.asarray(list(deletes), dtype=np.int64).reshape(-1, 2)
        ir = ins[:, 0].astype(np.int64)
        ic = ins[:, 1].astype(np.int64)
        iv = ins[:, 2].astype(np.float32)
        dr, dc = dels[:, 0], dels[:, 1]
        dvals, found = csr_lookup(base, dr, dc)
        if not found.all():
            missing = [(int(r), int(c)) for r, c in
                       zip(dr[~found][:5], dc[~found][:5])]
            raise ValueError(f"deletes name absent coordinates: {missing}")
        _, present = csr_lookup(base, ir, ic)
        if present.any():
            del_keys = dr * base.n_cols + dc
            bad = present & ~np.isin(ir * base.n_cols + ic, del_keys)
            if bad.any():
                clash = [(int(r), int(c)) for r, c in
                         zip(ir[bad][:5], ic[bad][:5])]
                raise ValueError(
                    f"inserts target stored coordinates {clash}; delete "
                    "first (delete+insert in one batch updates the value)")
        return EdgeDelta._build(
            np.concatenate([dr, ir]), np.concatenate([dc, ic]),
            np.concatenate([dvals.astype(np.float32), iv]),
            np.concatenate([np.ones(dr.size, bool), np.zeros(ir.size, bool)]),
            base.n_rows, base.n_cols)

    def merge(self, other: "EdgeDelta") -> "EdgeDelta":
        """Net effect of `self` followed by `other` (chained against the
        same lineage: `other` was built against `self` applied to the
        base).  Insert-then-delete of the same coordinate annihilates;
        delete-then-reinsert folds to a value change.  The result is a
        single delta against the original base."""
        if self.shape != other.shape:
            raise ValueError(f"shape mismatch: {self.shape} vs {other.shape}")
        state: "dict[int, tuple]" = {}
        for d in (self, other):
            for r, c, v, is_del in zip(d.rows, d.cols, d.vals, d.deletes):
                key = int(r) * self.n_cols + int(c)
                dv, iv = state.get(key, (None, None))
                if is_del:
                    if iv is not None:
                        iv = None          # deleting our own insert: net zero
                    elif dv is None:
                        dv = float(v)      # deleting a base edge
                    else:
                        raise ValueError(
                            f"coordinate ({r}, {c}) deleted twice without an "
                            "intervening insert")
                else:
                    if iv is not None:
                        raise ValueError(
                            f"coordinate ({r}, {c}) inserted twice without an "
                            "intervening delete")
                    iv = float(v)
                state[key] = (dv, iv)
        rows, cols, vals, dels = [], [], [], []
        for key, (dv, iv) in state.items():
            r, c = divmod(key, self.n_cols)
            if dv is not None:
                rows.append(r); cols.append(c); vals.append(dv)
                dels.append(True)
            if iv is not None:
                rows.append(r); cols.append(c); vals.append(iv)
                dels.append(False)
        return EdgeDelta._build(rows, cols, vals, dels,
                                self.n_rows, self.n_cols)

    # -- overlay views ------------------------------------------------------

    def signed_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rows, cols, values) with delete values negated -- the
        plus_times overlay stream: (A + Δ)x = Ax + Δx."""
        vals = np.where(self.deletes, -self.vals, self.vals)
        return self.rows, self.cols, vals.astype(np.float32)

    def insert_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rows, cols, values) of the inserts only -- the overlay stream
        for ⊕-only semirings.  Refuses a delta with deletes: those have
        no overlay under a non-invertible ⊕ (materialize instead)."""
        if self.has_deletes:
            raise ValueError(
                "delta carries deletes, which are overlay-ineligible "
                "outside plus_times; materialize via CSR.apply_delta")
        return self.rows, self.cols, self.vals

    def column_order(self) -> np.ndarray:
        """Permutation sorting entries by (col, row) -- the ascending-x
        stream order the delta address trace replays (same discipline as
        the HYB heavy partition)."""
        return np.lexsort((self.rows, self.cols))

    def summary(self) -> str:
        return (f"EdgeDelta[{self.n_rows}x{self.n_cols}] "
                f"+{self.n_inserts} -{self.n_deletes}")


def csr_diff(old: CSR, new: CSR) -> EdgeDelta:
    """The delta turning `old` into `new`: `old.apply_delta(csr_diff(old,
    new))` reproduces `new` exactly.  A changed stored value appears as a
    delete of the old value plus an insert of the new one.  This is how
    the serving engine derives *operand* deltas (stochastic transpose,
    patterns) generically from an adjacency mutation, without per-analytic
    delta calculus."""
    if old.shape != new.shape:
        raise ValueError(f"shape mismatch: {old.shape} vs {new.shape}")
    ok = _canonical_keys(old, "csr_diff")
    nk = _canonical_keys(new, "csr_diff")
    ov = np.asarray(old.data)
    nv = np.asarray(new.data)
    in_new, pn = _member(ok, nk)
    in_old, po = _member(nk, ok)
    diff_old = in_new & (np.where(in_new, nv[pn] if nv.size else 0.0, 0.0)
                         != ov) if ok.size else np.zeros(0, bool)
    diff_new = in_old & (np.where(in_old, ov[po] if ov.size else 0.0, 0.0)
                         != nv) if nk.size else np.zeros(0, bool)
    del_mask = (~in_new) | diff_old
    ins_mask = (~in_old) | diff_new
    dk, ik = ok[del_mask], nk[ins_mask]
    return EdgeDelta._build(
        np.concatenate([dk // old.n_cols, ik // old.n_cols]),
        np.concatenate([dk % old.n_cols, ik % old.n_cols]),
        np.concatenate([ov[del_mask], nv[ins_mask]]),
        np.concatenate([np.ones(dk.size, bool), np.zeros(ik.size, bool)]),
        old.n_rows, old.n_cols)


def apply_delta(base: CSR, delta: EdgeDelta) -> CSR:
    """Materialize `base` + `delta` as a fresh canonical CSR: deleted
    coordinates removed structurally (even when the stored value is 0.0
    -- the cc operand's zero weights stay intact for everything else),
    inserts appended, the whole rebuilt through `CSR.from_coo`."""
    if delta.shape != base.shape:
        raise ValueError(f"shape mismatch: {base.shape} vs {delta.shape}")
    bk = _canonical_keys(base, "apply_delta")
    vals = np.asarray(base.data)
    dmask = delta.deletes
    del_keys = delta.rows[dmask] * base.n_cols + delta.cols[dmask]
    found, pos = _member(del_keys, bk)
    if not found.all():
        missing = del_keys[~found][:5]
        raise ValueError(
            "delta deletes coordinates absent from the base: "
            f"{[(int(k // base.n_cols), int(k % base.n_cols)) for k in missing]}")
    keep = np.ones(bk.size, dtype=bool)
    keep[pos[found]] = False
    ins = ~dmask
    clash, _ = _member(delta.rows[ins] * base.n_cols + delta.cols[ins],
                       bk[keep])
    if clash.any():
        raise ValueError("delta inserts coordinates already stored in the "
                         "base (delete first to change a value)")
    rows = np.concatenate([bk[keep] // base.n_cols, delta.rows[ins]])
    cols = np.concatenate([bk[keep] % base.n_cols, delta.cols[ins]])
    v = np.concatenate([vals[keep], delta.vals[ins].astype(vals.dtype)])
    return CSR.from_coo(rows, cols, v, base.n_rows, base.n_cols,
                        dtype=vals.dtype)


__all__ = ["EdgeDelta", "csr_lookup", "csr_diff", "apply_delta"]
