"""Matrix generators from the paper.

Two families (paper §II-A):

  * FD    -- 2-D 9-point-stencil finite-difference matrices: three diagonal
             bands of three nonzeros each, exactly 9 nnz/row (periodic
             boundaries, matching the paper's nnz = 9 * 2^k accounting).
  * R-MAT -- recursive power-law graphs (Chakrabarti et al.), 8 nnz/row on
             average, rows+columns randomly permuted to remove load imbalance
             (exactly as the paper does).

Plus auxiliary generators (uniform-random, variable-bandwidth banded) used by
structure sweeps and property tests.  All generation is host-side numpy.
"""
from __future__ import annotations

import numpy as np

from .formats import CSR

# Graph500-style R-MAT quadrant probabilities.
RMAT_A, RMAT_B, RMAT_C, RMAT_D = 0.57, 0.19, 0.19, 0.05


def fd_matrix(n_rows: int, dtype=np.float32, seed: int = 0) -> CSR:
    """2-D 9-point-stencil FD matrix with periodic boundaries.

    The grid is g x g with g = floor(sqrt(n_rows)) rounded so that g*g is
    close to n_rows; we use exactly n_rows = g*g when possible, otherwise a
    g x h grid with g*h == n_rows (h = n_rows // g).  Every row has exactly
    nine nonzeros: itself and its eight (periodic) grid neighbours, which
    yields the paper's three bands of three adjacent elements.
    """
    g = int(np.sqrt(n_rows))
    while n_rows % g != 0:
        g -= 1
    h = n_rows // g  # grid is g rows x h cols, row-major node numbering
    rng = np.random.default_rng(seed)

    node = np.arange(n_rows, dtype=np.int64)
    gi, gj = node // h, node % h
    rows, cols = [], []
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            ni = (gi + di) % g
            nj = (gj + dj) % h
            rows.append(node)
            cols.append(ni * h + nj)
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    vals = rng.uniform(0.5, 1.5, size=rows.shape[0]).astype(dtype)
    return CSR.from_coo(rows, cols, vals, n_rows, n_rows, dtype=dtype)


def rmat_edges(n_rows: int, n_edges: int, seed: int = 0,
               a: float = RMAT_A, b: float = RMAT_B,
               c: float = RMAT_C) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized R-MAT edge generation (levels = log2 n)."""
    assert n_rows & (n_rows - 1) == 0, "R-MAT needs power-of-two dimension"
    levels = int(np.log2(n_rows))
    rng = np.random.default_rng(seed)
    rows = np.zeros(n_edges, dtype=np.int64)
    cols = np.zeros(n_edges, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for _ in range(levels):
        r = rng.random(n_edges)
        go_down = (r >= ab).astype(np.int64)          # quadrants c, d
        go_right = ((r >= a) & (r < ab)) | (r >= abc)  # quadrants b, d
        rows = (rows << 1) | go_down
        cols = (cols << 1) | go_right.astype(np.int64)
    return rows, cols


def rmat_matrix(n_rows: int, nnz_per_row: int = 8, dtype=np.float32,
                seed: int = 0, permute: bool = True) -> CSR:
    """R-MAT matrix with ~nnz_per_row average nonzeros/row.

    Duplicate edges are summed (dedup keeps avg-nnz close to the target).
    Rows and columns are randomly permuted (paper §II-A) so the power-law
    hubs do not create thread-level load imbalance.
    """
    n_edges = n_rows * nnz_per_row
    rows, cols = rmat_edges(n_rows, n_edges, seed=seed)
    if permute:
        rng = np.random.default_rng(seed + 1)
        rperm = rng.permutation(n_rows)
        cperm = rng.permutation(n_rows)
        rows = rperm[rows]
        cols = cperm[cols]
    rng2 = np.random.default_rng(seed + 2)
    vals = rng2.uniform(0.5, 1.5, size=n_edges).astype(dtype)
    # merge duplicates by (row, col)
    key = rows * n_rows + cols
    order = np.argsort(key, kind="stable")
    key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
    uniq_mask = np.empty(len(key), dtype=bool)
    uniq_mask[0] = True
    np.not_equal(key[1:], key[:-1], out=uniq_mask[1:])
    seg_id = np.cumsum(uniq_mask) - 1
    merged_vals = np.zeros(int(seg_id[-1]) + 1, dtype=dtype)
    np.add.at(merged_vals, seg_id, vals)
    return CSR.from_coo(rows[uniq_mask], cols[uniq_mask], merged_vals,
                        n_rows, n_rows, dtype=dtype)


def banded_matrix(n_rows: int, bandwidth: int, nnz_per_row: int = 9,
                  dtype=np.float32, seed: int = 0) -> CSR:
    """Banded matrix with nonzeros uniform inside |c - r| <= bandwidth.

    Interpolates between FD-like (tiny bandwidth) and R-MAT-like (bandwidth
    ~ n) structure: the knob used by the structure-sweep benchmarks.
    """
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), nnz_per_row)
    offs = rng.integers(-bandwidth, bandwidth + 1, size=rows.shape[0])
    cols = np.clip(rows + offs, 0, n_rows - 1)
    vals = rng.uniform(0.5, 1.5, size=rows.shape[0]).astype(dtype)
    # dedup (row, col)
    key = rows * n_rows + cols
    order = np.argsort(key, kind="stable")
    key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
    uniq = np.ones(len(key), dtype=bool)
    uniq[1:] = key[1:] != key[:-1]
    seg = np.cumsum(uniq) - 1
    mvals = np.zeros(int(seg[-1]) + 1, dtype=dtype)
    np.add.at(mvals, seg, vals)
    return CSR.from_coo(rows[uniq], cols[uniq], mvals, n_rows, n_rows,
                        dtype=dtype)


def uniform_random_matrix(n_rows: int, nnz_per_row: int = 8,
                          dtype=np.float32, seed: int = 0) -> CSR:
    """Uniform-random sparse matrix (no power law): control case."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), nnz_per_row)
    cols = rng.integers(0, n_rows, size=rows.shape[0])
    vals = rng.uniform(0.5, 1.5, size=rows.shape[0]).astype(dtype)
    return CSR.from_coo(rows, cols, vals, n_rows, n_rows, dtype=dtype)


def paper_sizes(max_log2_rows: int = 26, min_log2_rows: int = 11):
    """The paper's size sweep: 2^11 .. 2^26 rows (§II-C)."""
    return [2 ** k for k in range(min_log2_rows, max_log2_rows + 1)]
