"""repro.core — the paper's contribution, productized.

Quantifying (and then exploiting) the effect of matrix structure on sparse
matrix-vector multiply performance:

  formats      CSR / ELL / BELL / DIA / HYB sparse containers (pytrees)
  delta        EdgeDelta batched edge mutations for streaming matrices
  generators   FD 9-point stencil + R-MAT (paper §II-A) + sweep helpers
  structure    structure metrics: bandedness, locality, block density
  cache_model  Sandy Bridge L2/L3+prefetcher model -> the paper's 5 metrics
  traffic      TPU HBM<->VMEM movement model (hardware adaptation)
  partition    row-blocking (threads/chips) + column-blocking (VMEM cache)
  spmv         structure-aware dispatcher + jnp reference kernels
"""
from . import cache_model, delta, formats, generators, partition, spmv, structure, traffic
from .cache_model import SANDY_BRIDGE, CacheMetrics, MachineModel, analytic_metrics
from .delta import EdgeDelta, csr_diff, csr_lookup
from .formats import BELL, CSR, DIA, ELL, HYB
from .generators import banded_matrix, fd_matrix, rmat_matrix, uniform_random_matrix
from .spmv import auto_format, spmv
from .structure import StructureReport, analyze
from .traffic import TPU_V5E, TPUModel

__all__ = [
    "cache_model", "delta", "formats", "generators", "partition", "spmv",
    "structure", "traffic", "SANDY_BRIDGE", "CacheMetrics", "MachineModel",
    "analytic_metrics", "BELL", "CSR", "DIA", "ELL", "HYB", "EdgeDelta",
    "csr_diff", "csr_lookup", "banded_matrix",
    "fd_matrix", "rmat_matrix", "uniform_random_matrix", "auto_format",
    "analyze", "StructureReport", "TPU_V5E", "TPUModel",
]
