"""Structure metrics for sparse matrices.

The paper's central variable is *matrix structure*: FD matrices produce
sequential + reused x-accesses, R-MAT matrices produce random ones.  This
module turns that qualitative axis into numbers the framework can act on
(format dispatch, partitioning, traffic prediction).

All metrics are computed host-side from the CSR column stream -- the exact
stream of x-indices the SpMV kernel will issue (paper Fig. 2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .formats import CSR


@dataclasses.dataclass(frozen=True)
class StructureReport:
    n_rows: int
    nnz: int
    avg_nnz_per_row: float
    row_nnz_cv: float           # coefficient of variation: load-balance proxy
    bandwidth: int              # max |col - row|
    bandwidth_p95: int          # 95th percentile |col - row|
    n_distinct_offsets: int     # diagonals present (DIA viability)
    n_band_groups: int          # contiguous diagonal groups (FD: 3)
    spatial_locality: float     # frac of consecutive x-accesses within 1 line
    temporal_locality: float    # frac of x-accesses re-touching a recent line
    stream_servable: float      # frac servable by a K-stream next-line prefetcher
    block_density_8x128: float  # density within touched 8x128 blocks
    kind: str                   # 'banded' | 'blocked' | 'unstructured'

    def summary(self) -> str:
        return (
            f"{self.kind}: n={self.n_rows} nnz={self.nnz} "
            f"bw={self.bandwidth} bw95={self.bandwidth_p95} "
            f"bands={self.n_band_groups} "
            f"spatial={self.spatial_locality:.3f} "
            f"temporal={self.temporal_locality:.3f} "
            f"stream={self.stream_servable:.3f} "
            f"blockdens={self.block_density_8x128:.4f}"
        )


LINE_ELEMS = 8          # 64-byte line of f64 (paper) -- locality window
RECENT_WINDOW = 64      # lines considered "recent" for temporal locality
STREAM_WINDOW = 24      # accesses a 16-stream prefetcher can look back over


def x_access_stream(csr: CSR) -> np.ndarray:
    """The exact sequence of x-indices touched by CSR SpMV (row-major)."""
    return np.asarray(csr.indices, dtype=np.int64)


def analyze(csr: CSR, sample_rows: int | None = 65536,
            reordering=None) -> StructureReport:
    """Structure metrics of `csr` (optionally after applying `reordering`,
    a `repro.reorder.Reordering` -- the "after" half of a before/after
    comparison; see `analyze_reorder`)."""
    if reordering is not None:
        csr = reordering.apply(csr)
    indptr = np.asarray(csr.indptr)
    lengths = np.diff(indptr)
    n_rows = csr.n_rows

    if sample_rows is not None and n_rows > sample_rows:
        # contiguous row windows (stream metrics need the true sequence)
        n_chunks = 8
        chunk = sample_rows // n_chunks
        starts = np.linspace(0, n_rows - chunk, n_chunks).astype(np.int64)
        sel = np.concatenate([np.arange(s, s + chunk) for s in starts])
    else:
        sel = np.arange(n_rows, dtype=np.int64)

    cols_all = np.asarray(csr.indices, dtype=np.int64)
    lo = indptr[sel]
    hi = indptr[sel + 1]
    seg_len = (hi - lo).astype(np.int64)
    # vectorized extraction of the sampled rows' nonzeros
    total = int(seg_len.sum())
    pos = np.repeat(lo, seg_len) + (
        np.arange(total) - np.repeat(np.cumsum(seg_len) - seg_len, seg_len))
    cols = cols_all[pos] if total else np.zeros(0, np.int64)
    rows_rep = np.repeat(sel, seg_len) if total else np.zeros(0, np.int64)

    offs = cols - rows_rep
    bandwidth = int(np.abs(offs).max()) if offs.size else 0
    bandwidth_p95 = int(np.percentile(np.abs(offs), 95)) if offs.size else 0
    uniq_offs = np.unique(offs) if offs.size else np.zeros(0, np.int64)
    n_offsets = int(len(uniq_offs))
    if n_offsets:
        gaps = np.diff(np.sort(uniq_offs))
        n_band_groups = int(1 + np.sum(gaps > 2 * LINE_ELEMS))
    else:
        n_band_groups = 0

    # --- spatial locality: consecutive accesses land in the same/adjacent line
    lines = cols // LINE_ELEMS
    if lines.size > 1:
        d = np.abs(np.diff(lines))
        spatial = float(np.mean(d <= 1))
    else:
        spatial = 1.0

    # --- temporal locality: access re-touches one of the last RECENT_WINDOW
    #     distinct lines (cheap windowed approximation of reuse distance)
    temporal = _windowed_reuse(lines, RECENT_WINDOW)

    # --- stream servability: access line is within +-1 of one of the last
    #     STREAM_WINDOW accesses -> a K-stream next-line prefetcher (or the
    #     line already resident from that neighbour's fill) covers it.
    stream = _stream_servable(lines, STREAM_WINDOW)

    # --- density inside touched 8x128 blocks (BELL viability)
    br = rows_rep // 8
    bc = cols // 128
    key = br * ((csr.n_cols // 128) + 2) + bc
    n_blocks = len(np.unique(key)) if key.size else 1
    block_density = float(cols.size) / (n_blocks * 8 * 128)

    avg_nnz = float(lengths.mean()) if lengths.size else 0.0
    cv = float(lengths.std() / max(avg_nnz, 1e-9)) if lengths.size else 0.0

    if n_offsets <= 32 and bandwidth_p95 <= 4 * LINE_ELEMS * 16:
        kind = "banded"
    elif block_density >= 0.05:
        kind = "blocked"
    else:
        kind = "unstructured"

    return StructureReport(
        n_rows=n_rows, nnz=csr.nnz, avg_nnz_per_row=avg_nnz, row_nnz_cv=cv,
        bandwidth=bandwidth, bandwidth_p95=bandwidth_p95,
        n_distinct_offsets=n_offsets, n_band_groups=n_band_groups,
        spatial_locality=spatial, temporal_locality=temporal,
        stream_servable=stream, block_density_8x128=block_density,
        kind=kind,
    )


@dataclasses.dataclass(frozen=True)
class StructureDelta:
    """Before/after structure comparison for one reordering."""

    strategy: str
    before: StructureReport
    after: StructureReport

    # the metrics a reordering is supposed to move, with the sign of "better"
    COMPARED = (("bandwidth", -1), ("bandwidth_p95", -1),
                ("n_distinct_offsets", -1), ("spatial_locality", +1),
                ("temporal_locality", +1), ("stream_servable", +1))

    def changes(self) -> dict:
        """metric -> (before, after) for every compared metric."""
        return {name: (getattr(self.before, name), getattr(self.after, name))
                for name, _ in self.COMPARED}

    def improved(self) -> bool:
        """Did any compared metric move in the better direction?"""
        for name, sign in self.COMPARED:
            b, a = getattr(self.before, name), getattr(self.after, name)
            if sign * (a - b) > 0:
                return True
        return False

    def summary(self) -> str:
        parts = []
        for name, _ in self.COMPARED:
            b, a = getattr(self.before, name), getattr(self.after, name)
            fmt = "{:.0f}" if isinstance(b, (int, np.integer)) else "{:.3f}"
            parts.append(f"{name} {fmt.format(b)}->{fmt.format(a)}")
        return (f"{self.strategy}: kind {self.before.kind}->{self.after.kind} "
                + " ".join(parts))


def analyze_reorder(csr: CSR, reordering,
                    sample_rows: int | None = 65536) -> StructureDelta:
    """Before/after structure report pair for one reordering -- quantifies
    how much FD-likeness the permutation recovers before any simulation."""
    return StructureDelta(
        strategy=getattr(reordering, "strategy", "?"),
        before=analyze(csr, sample_rows=sample_rows),
        after=analyze(csr, sample_rows=sample_rows, reordering=reordering),
    )


def _stream_servable(lines: np.ndarray, window: int) -> float:
    """Fraction of accesses whose line is within +-1 of one of the previous
    `window` accesses' lines -- i.e. coverable by a multi-stream next-line
    prefetcher or already resident from the neighbouring access's fill.

    Vectorized: O(window * m) numpy comparisons.
    """
    if lines.size < 2:
        return 1.0
    served = np.zeros(lines.size, dtype=bool)
    for k in range(1, window + 1):
        d = np.abs(lines[k:] - lines[:-k])
        served[k:] |= d <= 1
    served[0] = True
    return float(np.mean(served))


def _windowed_reuse(lines: np.ndarray, window: int) -> float:
    """Fraction of accesses whose line was seen within the last `window`
    *accesses* (vectorized lower bound on LRU-of-`window`-lines hits)."""
    if lines.size < 2:
        return 1.0
    # position of previous access to the same line
    order = np.argsort(lines, kind="stable")
    sorted_lines = lines[order]
    same = sorted_lines[1:] == sorted_lines[:-1]
    prev_pos = np.full(lines.size, -10 ** 12, dtype=np.int64)
    prev_pos[order[1:][same]] = order[:-1][same]
    idx = np.arange(lines.size, dtype=np.int64)
    return float(np.mean((idx - prev_pos) <= window))


def reuse_distance_histogram(lines: np.ndarray, max_bits: int = 30):
    """Exact LRU stack distances via a Fenwick tree (O(m log m)).

    Returns (distances, counts) where distance is the number of *distinct*
    lines touched since the previous access to the same line (inf -> cold).
    Used by the cache model for exact small/medium-size simulation.
    """
    m = lines.size
    tree = np.zeros(m + 1, dtype=np.int64)

    def bit_add(i, v):
        i += 1
        while i <= m:
            tree[i] += v
            i += i & (-i)

    def bit_sum(i):  # sum of [0, i)
        s = 0
        while i > 0:
            s += tree[i]
            i -= i & (-i)
        return s

    last = {}
    dists = np.empty(m, dtype=np.int64)
    for t in range(m):
        ln = lines[t]
        p = last.get(ln, -1)
        if p < 0:
            dists[t] = -1  # cold miss
        else:
            dists[t] = bit_sum(t) - bit_sum(p + 1)
            bit_add(p, -1)
        bit_add(t, 1)
        last[ln] = t
    return dists
