"""Structure-aware SpMV: a thin client over `repro.plan`.

This is the paper's conclusion turned into a library: *structure determines
performance*, so the stack measures structure (core.structure) and routes
to the format whose TPU access pattern matches it:

    banded        -> DIA   (streaming x windows; FD fast path)
    blocked       -> BELL  (dense 8x128 tiles; useful-byte gathers)
    unstructured  -> CSR   (column-blocked scalar-prefetch kernel)

The decision machinery itself lives in `repro.plan` (compile-once:
analyze -> reorder -> convert -> pre-padded kernel layout, frozen into a
cached `SpmvPlan`).  This module keeps the pure-jnp implementations (the
oracles the Pallas kernels in `repro.kernels` are validated against) and
two thin entry points: `auto_format` delegates the format decision to
`plan.choose_format`/`plan.convert`, and `spmv(..., use_pallas=True)`
fetches the matrix's plan from the process-wide `plan.DEFAULT_CACHE`
(compiling a minimal container plan on first touch), so repeated
multiplies of the same matrix skip all per-call layout prep.  The jnp
path stays direct — it is already jit-cached by XLA.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import structure
from .formats import BELL, CSR, DIA, ELL, HYB


# ---------------------------------------------------------------------------
# Pure-jnp reference implementations (one per format)
# ---------------------------------------------------------------------------

@jax.jit
def spmv_csr_jnp(csr: CSR, x: jax.Array) -> jax.Array:
    """y = A @ x via gather + segment-sum (row ids from indptr)."""
    nnz = csr.data.shape[0]
    lengths = jnp.diff(csr.indptr)
    row_ids = jnp.repeat(jnp.arange(csr.n_rows), lengths,
                         total_repeat_length=nnz)
    prods = csr.data * jnp.take(x, csr.indices, axis=0)
    return jax.ops.segment_sum(prods, row_ids, num_segments=csr.n_rows)


@jax.jit
def spmv_ell_jnp(ell: ELL, x: jax.Array) -> jax.Array:
    return (ell.data * jnp.take(x, ell.indices, axis=0)).sum(axis=1)


@jax.jit
def spmv_bell_jnp(bell: BELL, x: jax.Array) -> jax.Array:
    nbc = -(-bell.n_cols // bell.bn)
    xp = jnp.pad(x, (0, nbc * bell.bn - bell.n_cols))
    x_tiles = xp.reshape(nbc, bell.bn)
    gathered = jnp.take(x_tiles, bell.block_cols, axis=0)  # (nbr, bpr, bn)
    y = jnp.einsum("rkmn,rkn->rm", bell.data, gathered)
    return y.reshape(-1)[: bell.n_rows]


@jax.jit
def spmv_dia_jnp(dia: DIA, x: jax.Array) -> jax.Array:
    n = dia.n_rows
    xp = jnp.pad(x, (n, n))  # zero halo so every window slice is in-range

    def one_diag(band, off):
        window = jax.lax.dynamic_slice(xp, (n + off,), (n,))
        return band * window

    contrib = jax.vmap(one_diag)(dia.data, dia.offsets)
    return contrib.sum(axis=0)


@jax.jit
def spmv_hyb_jnp(hyb: HYB, x: jax.Array) -> jax.Array:
    """Light ELL partial plus heavy COO segment-sum (heavy rows are
    all-padding in the light slab, so the + join is exact)."""
    y = (hyb.data * jnp.take(x, hyb.indices, axis=0)).sum(axis=1)
    prods = hyb.hvals * jnp.take(x, hyb.hcols, axis=0)
    return y + jax.ops.segment_sum(prods, hyb.hrows,
                                   num_segments=hyb.n_rows)


def spmv_dense_jnp(a: jax.Array, x: jax.Array) -> jax.Array:
    return a @ x


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def auto_format(csr: CSR, report: structure.StructureReport | None = None,
                reordering=None, threads: int = 1):
    """Pick the TPU-friendly format for this matrix's structure.

    Thin client of `repro.plan`: the decision rule is
    `plan.choose_format` and the conversion `plan.convert` (one-shot --
    compile a `plan.SpmvPlan` instead to also freeze the kernel layout,
    or `plan.compile(csr, predictor='auto')` to let the learned cost
    model pick the reordering too).

    With `reordering` (a `repro.reorder.Reordering`), the permutation is
    applied first and the structure re-analyzed on the permuted matrix, so
    the format decision reflects the post-reorder structure -- an RCM'd
    scrambled-banded matrix becomes DIA-eligible again.  Pass the same
    reordering to `spmv` to multiply in the original row order.
    `threads` biases dispersed unstructured matrices toward the
    nnz-balanced segmented layout, exactly as plan compilation would.
    """
    from repro import plan as _plan

    if reordering is not None:
        csr = reordering.apply(csr)
        report = None
    rep = report or structure.analyze(csr)
    return _plan.convert(csr, _plan.choose_format(rep, threads=threads))


def spmv(matrix, x: jax.Array, use_pallas: bool = False,
         interpret: bool | None = None, reordering=None) -> jax.Array:
    """Multiply any supported sparse container by x.

    use_pallas=True routes through the matrix's cached execution plan
    (`repro.plan.DEFAULT_CACHE`): the first call on a given container
    compiles a minimal plan (one-time kernel layout prep), subsequent
    calls replay it with zero matrix-side work.  On CPU the kernels run
    in interpret mode, on TPU as compiled Mosaic kernels.  Inside a jit
    trace (tracer containers cannot be fingerprinted) the call falls
    back to the per-call `repro.kernels.ops` wrappers.

    `reordering` declares that `matrix` is the REORDERED operand (built via
    `reordering.apply` / `auto_format(..., reordering=...)`) while x and the
    returned y stay in the ORIGINAL order: x is gathered through col_perm
    before the multiply and y scattered back through inv_row_perm after.
    """
    if reordering is not None:
        y = spmv(matrix, reordering.permute_x(x), use_pallas=use_pallas,
                 interpret=interpret)
        return reordering.restore_y(y)
    if use_pallas:
        from repro import plan as _plan
        from repro.kernels import ops as kops
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if isinstance(matrix, (CSR, ELL, BELL, DIA, HYB)):
            if _plan.is_concrete(matrix):
                p = _plan.DEFAULT_CACHE.get_or_build(
                    _plan.matrix_fingerprint(matrix) + "|container",
                    lambda: _plan.plan_for_container(matrix))
                return p.execute(x, interpret=interpret)
            # tracer fallback: per-call wrappers (prep under jit where the
            # format permits it)
            direct = {DIA: kops.spmv_dia, BELL: kops.spmv_bell,
                      CSR: kops.spmv_csr, ELL: kops.spmv_ell,
                      HYB: kops.spmv_hyb}
            return direct[type(matrix)](matrix, x, interpret=interpret)
    if isinstance(matrix, CSR):
        return spmv_csr_jnp(matrix, x)
    if isinstance(matrix, ELL):
        return spmv_ell_jnp(matrix, x)
    if isinstance(matrix, HYB):
        return spmv_hyb_jnp(matrix, x)
    if isinstance(matrix, BELL):
        return spmv_bell_jnp(matrix, x)
    if isinstance(matrix, DIA):
        return spmv_dia_jnp(matrix, x)
    if isinstance(matrix, jax.Array) and matrix.ndim == 2:
        return spmv_dense_jnp(matrix, x)
    raise TypeError(f"unsupported matrix container: {type(matrix)}")


@partial(jax.jit, static_argnames=("n_iters",))
def power_iteration(matrix, x0: jax.Array, n_iters: int = 16):
    """Example composite analytic from the paper's motivation (§I): repeated
    SpMV drives eigensolvers for graph anomaly detection.  Returns the
    dominant eigenvalue estimate and final vector."""
    def body(carry, _):
        x, _ = carry
        y = spmv(matrix, x)
        norm = jnp.linalg.norm(y)
        y = y / jnp.maximum(norm, 1e-30)
        return (y, norm), None

    (x, lam), _ = jax.lax.scan(body, (x0, jnp.array(0.0, x0.dtype)),
                               None, length=n_iters)
    return lam, x


def pagerank(csr: CSR, damping: float = 0.85, n_iters: int = 32):
    """PageRank via repeated SpMV (network-analysis example, paper §I).

    Compatibility wrapper over `repro.graph.pagerank` (the full driver:
    compile-once semiring plan, convergence checks, per-iteration
    telemetry): this entry historically scaled A's *columns* in place,
    which equals the graph driver's documented A[i,j] = edge i->j
    convention applied to A^T — so it delegates on the transpose and
    keeps the fixed iteration count.  Prefer `repro.graph.pagerank`.
    """
    from repro.graph.drivers import pagerank as _graph_pagerank
    from repro.graph.drivers import transpose_csr

    res = _graph_pagerank(transpose_csr(csr), damping=damping, tol=0.0,
                          max_iters=n_iters)
    return jnp.asarray(res.values)
