"""Analytic cache model + machine constants (the paper's VTune metrics).

The paper measures five compound metrics (L2/L3 miss rate per kilo-
instruction, prefetch miss rate, L2 stall cycles, GFLOPS) on a dual
Xeon E5-2690 (Sandy Bridge).  This container has no Sandy Bridge and no
VTune, so the repo reproduces the *methodology* in two places:

  1. Trace-driven simulation lives in `repro.telemetry` -- the pluggable
     hierarchy (`telemetry.hierarchy`: set-associative caches, prefetcher,
     the §V victim/miss-cache/stream-buffer mechanisms), the sweep harness
     and topdown reports.  `simulate_exact` below is only a thin
     compatibility shim over `telemetry.hierarchy.Hierarchy.default`
     preserving the original counter dictionary (bit-exact parity is
     pinned by tests/test_telemetry.py).
  2. THIS module owns the machine description (`MachineModel`,
     `SANDY_BRIDGE`) and the *analytic* model (Che/working-set
     approximation over the empirical line-popularity distribution) used
     across the paper's full size sweep 2^11..2^26 rows where trace
     simulation is intractable.

The analytic model captures the effect the paper measures: FD's sequential
banded accesses are served by the (modelled) stream prefetcher -> near-zero
demand misses at every size; R-MAT's random accesses miss once the x working
set outgrows each level, *modulated by power-law hub columns that stay
cache-resident* (the permutation shuffles which columns are hubs but not the
popularity distribution).  Shared-L3 vs. per-core-L2 semantics reproduce the
paper's serial==parallel miss-rate finding (F2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .formats import CSR


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Sandy Bridge E5-2690 x2 (paper §II-B) -- all sizes in bytes."""

    name: str = "2x Xeon E5-2690 (Sandy Bridge)"
    freq_ghz: float = 2.9
    cores_per_socket: int = 8
    sockets: int = 2
    line_bytes: int = 64
    l2_bytes: int = 256 * 1024          # per core
    l3_bytes: int = 20 * 1024 * 1024    # per socket, shared
    l3_hit_cycles: float = 31.0
    dram_cycles: float = 200.0
    dram_bw_gbs: float = 51.2           # per socket (4ch DDR3-1600)
    elem_bytes: int = 8                 # f64 values (paper uses doubles)
    idx_bytes: int = 4
    # calibration constants (documented in EXPERIMENTS.md §Paper-validation)
    instr_per_nnz: float = 35.0         # CSR inner loop (compiled -O2, f64;
                                        # includes loop control + addr calc --
                                        # calibrated so the R-MAT L2 plateau
                                        # lands at the paper's ~26/kinst
    mlp: float = 6.0                    # avg outstanding misses (OOO window)
    x_cache_frac: float = 0.85          # cache fraction holding x lines
    prefetch_streams: int = 16          # trackable sequential streams / core
    pf_shutoff_util: float = 0.65       # DRAM utilization that kills the
                                        # prefetcher (paper §II-B, §IV-C)


SANDY_BRIDGE = MachineModel()


@dataclasses.dataclass(frozen=True)
class CacheMetrics:
    """The paper's five compound metrics (Eqs. 1-5) + raw components."""

    l2_miss_rate: float        # demand misses / kilo-instruction  (Eq. 1)
    l3_miss_rate: float        # demand misses / kilo-instruction  (Eq. 2)
    prefetch_miss_rate: float  # prefetch L2 fills / kinst          (Eq. 3)
    l2_stall_frac: float       # stalled cycles / total cycles      (Eq. 4)
    gflops: float              # 2*nnz / runtime / 1e9              (Eq. 5)
    # components
    x_miss_l2_per_access: float
    x_miss_l3_per_access: float
    dram_utilization: float
    threads: int
    nnz: int


# ---------------------------------------------------------------------------
# Exact trace-driven simulator (small/medium sizes; tests cross-validate
# the analytic model against this).  The simulator itself lives in
# repro.telemetry.hierarchy -- this is the legacy entry point, preserved
# with its original counter dictionary.
# ---------------------------------------------------------------------------

def simulate_exact(csr: CSR, machine: MachineModel = SANDY_BRIDGE,
                   sweeps: int = 2) -> dict:
    """Trace-driven simulation of one core running CSR SpMV.

    Replays the full demand stream (matrix values+indices, row pointers, x
    gathers, y writes) through L2 -> L3 with a stream prefetcher filling L2.
    Returns per-sweep counters for the final (warm) sweep.

    Delegates to `repro.telemetry.hierarchy.Hierarchy.default`, which
    reproduces the historical fully-associative LRU + next-line-prefetcher
    configuration; richer geometries and the paper's §V mechanisms are
    available through that module directly.
    """
    from repro.telemetry import events as tev
    from repro.telemetry.hierarchy import Hierarchy

    c = Hierarchy.default(machine).run_spmv(csr, machine, sweeps=sweeps)
    return {
        "l2_demand": c[tev.L2_DEMAND_MISS],
        "l3_demand": c[tev.L3_DEMAND_MISS],
        "pf_fills": c[tev.L2_PREFETCH_FILL],
        "accesses": c[tev.ACCESS],
    }


# ---------------------------------------------------------------------------
# Analytic model (Che approximation over empirical line popularity)
# ---------------------------------------------------------------------------

def _che_hit_rate(counts: np.ndarray, capacity_lines: float,
                  stream_rate: float = 0.0) -> float:
    """LRU hit rate under the independent-reference model with empirical
    per-line access counts, via the Che characteristic-time approximation.

    `stream_rate` models cache pollution by streaming (use-once) lines
    inserted at `stream_rate` lines per x-access: they occupy `stream_rate*T`
    slots of the capacity (the paper's finding F1 -- "the L3 rarely contains
    relevant data" -- emerges from exactly this competition).

    hit = sum_i p_i * (1 - exp(-p_i * T)),  where T solves
          sum_i (1 - exp(-p_i * T)) + stream_rate * T = C.
    """
    counts = counts[counts > 0].astype(np.float64)
    n_lines = counts.size
    if n_lines == 0:
        return 1.0
    if capacity_lines >= n_lines and stream_rate <= 0.0:
        return 1.0
    # compress to (distinct value, multiplicity): popularity arrays hold
    # millions of lines but only O(100) distinct counts -- the Che sums
    # collapse to weighted sums, making the 2^26 sweep cheap
    vals, wts = np.unique(counts, return_counts=True)
    total = float((vals * wts).sum())
    p = vals / total
    w = wts.astype(np.float64)
    # T is measured in x-accesses; one x-access per count unit.
    lo, hi = 1.0, 1e18
    for _ in range(80):
        mid = np.sqrt(lo * hi)
        filled = float(np.sum(w * (-np.expm1(-p * mid)))) + stream_rate * mid
        if filled > capacity_lines:
            hi = mid
        else:
            lo = mid
    T = np.sqrt(lo * hi)
    return float(min(1.0, np.sum(w * p * (-np.expm1(-p * T)))))


def x_line_popularity(csr: CSR, machine: MachineModel = SANDY_BRIDGE
                      ) -> np.ndarray:
    """Empirical access counts per 64B line of x (the gathered operand)."""
    per_line = machine.line_bytes // machine.elem_bytes
    lines = np.asarray(csr.indices, dtype=np.int64) // per_line
    return np.bincount(lines, minlength=-(-csr.n_cols // per_line))


@dataclasses.dataclass(frozen=True)
class MatrixProfile:
    """Everything the analytic model needs, detached from a concrete CSR --
    enables the paper's full 2^11..2^26 sweep without materializing the
    5x10^8-nnz matrices."""
    n_rows: int
    n_cols: int
    nnz: int
    line_counts: np.ndarray      # x-access counts per 64B line
    stream_servable: float       # fraction of prefetcher-servable accesses
    n_band_groups: int


def profile_of(csr: CSR, machine: MachineModel = SANDY_BRIDGE
               ) -> MatrixProfile:
    from . import structure as _structure

    rep = _structure.analyze(csr)
    return MatrixProfile(
        n_rows=csr.n_rows, n_cols=csr.n_cols, nnz=csr.nnz,
        line_counts=x_line_popularity(csr, machine),
        stream_servable=rep.stream_servable,
        n_band_groups=rep.n_band_groups,
    )


def profile_fd(n_rows: int, nnz_per_row: int = 9,
               machine: MachineModel = SANDY_BRIDGE) -> MatrixProfile:
    """Synthetic FD profile: banded accesses are uniform over x lines and
    ~fully stream-servable (calibrated against empirical profiles in
    tests/test_cache_model.py)."""
    per_line = machine.line_bytes // machine.elem_bytes
    n_lines = -(-n_rows // per_line)
    nnz = n_rows * nnz_per_row
    counts = np.full(n_lines, nnz / max(n_lines, 1))
    return MatrixProfile(n_rows=n_rows, n_cols=n_rows, nnz=nnz,
                         line_counts=counts, stream_servable=0.995,
                         n_band_groups=3)


def profile_rmat(n_rows: int, nnz_per_row: int = 8,
                 machine: MachineModel = SANDY_BRIDGE,
                 a: float = 0.57, b: float = 0.19, c: float = 0.19
                 ) -> MatrixProfile:
    """Synthetic R-MAT profile via the exact column-marginal argument.

    The marginal probability of column j is a product of per-level Bernoulli
    factors with P(right) = b + d; rows analogously with P(down) = c + d.
    Summing 8 adjacent columns (one 64B f64 line) marginalizes the bottom 3
    column levels away, so LINE popularity classes are indexed by the number
    of set high bits.  Duplicate-edge dedup is applied at CELL level: a
    (row, col) cell with Poisson(m * p_row * p_col) draws contributes
    1 - exp(-m p_r p_c) distinct nonzeros -- this is what clips the hub
    columns that a flat dedup factor would overweight (and what makes the
    paper's "every L2 miss also misses L3" emerge at the top of the sweep).
    """
    import math as _math

    levels = int(np.log2(n_rows))
    high = max(levels - 3, 1)
    q_col = b + (1.0 - a - b - c)          # P(right) = b + d
    q_row = c + (1.0 - a - b - c)          # P(down)  = c + d
    m_draws = float(n_rows) * nnz_per_row

    k_r = np.arange(levels + 1)
    row_sizes = np.array([_math.comb(levels, int(k)) for k in k_r],
                         dtype=np.float64)
    p_r = q_row ** k_r * (1 - q_row) ** (levels - k_r)

    def dedup_count(p_col: float) -> float:
        """Expected distinct nonzeros in one column of marginal p_col."""
        lam = m_draws * p_r * p_col
        return float(np.sum(row_sizes * (-np.expm1(-lam))))

    # Column-count distribution after dedup, by class (k set bits).
    k_c = np.arange(levels + 1)
    col_sizes = np.array([_math.comb(levels, int(k)) for k in k_c],
                         dtype=np.float64)
    col_vals = np.array([dedup_count(
        q_col ** int(k) * (1 - q_col) ** (levels - int(k))) for k in k_c])
    nnz = float(np.sum(col_sizes * col_vals))

    # The paper PERMUTES rows and columns, so a 64B line holds 8 columns
    # drawn ~uniformly from the column-count multiset (NOT 8 R-MAT
    # siblings).  Sample line counts as sums of 8 Poisson draws; chunked to
    # bound memory at 2^26 (67M columns).
    rng = np.random.default_rng(12345)
    probs = col_sizes / col_sizes.sum()
    cdf = np.cumsum(probs)
    n_lines = n_rows // 8
    counts = np.empty(n_lines, dtype=np.float64)
    chunk = min(n_lines, 1 << 20)
    for lo in range(0, n_lines, chunk):
        hi = min(lo + chunk, n_lines)
        u = rng.random((hi - lo) * 8)
        cls = np.searchsorted(cdf, u).clip(0, levels)
        lam = col_vals[cls].astype(np.float64)
        counts[lo:hi] = rng.poisson(lam).reshape(-1, 8).sum(axis=1)
    return MatrixProfile(n_rows=n_rows, n_cols=n_rows, nnz=int(nnz),
                         line_counts=counts, stream_servable=0.02,
                         n_band_groups=1)


def analytic_metrics(csr: CSR, machine: MachineModel = SANDY_BRIDGE,
                     threads: int = 1,
                     structured_frac: float | None = None) -> CacheMetrics:
    """The paper's five metrics for `csr` (empirical profile)."""
    return analytic_metrics_from_profile(
        profile_of(csr, machine), machine, threads=threads,
        structured_frac=structured_frac)


def analytic_metrics_from_profile(
        prof: MatrixProfile, machine: MachineModel = SANDY_BRIDGE,
        threads: int = 1,
        structured_frac: float | None = None) -> CacheMetrics:
    """The paper's five metrics from a (possibly synthetic) profile."""
    nnz = prof.nnz
    n = prof.n_rows
    lb = machine.line_bytes
    instr = nnz * machine.instr_per_nnz

    if structured_frac is None:
        # stream-servable accesses are handled by the prefetcher / adjacent
        # fills; only the remainder behaves like random demand traffic.
        structured_frac = prof.stream_servable
    # a prefetcher can only track `prefetch_streams` concurrent bands
    n_bands = min(max(prof.n_band_groups, 1), machine.prefetch_streams)

    # ---- problem working set (Table I accounting: 2m+n+1 matrix + 2 vectors)
    ws_bytes = (nnz * (machine.elem_bytes + machine.idx_bytes)
                + (n + 1) * machine.idx_bytes + 2 * n * machine.elem_bytes)
    ws_lines = ws_bytes / lb
    fits_l2 = ws_lines <= machine.l2_bytes / lb
    sockets_used = 1 if threads <= machine.cores_per_socket else machine.sockets
    fits_l3 = ws_lines <= (machine.l3_bytes * sockets_used) / lb

    # ---- streaming traffic (matrix arrays + y + structured x) --------------
    # structured x bytes: each trackable band group streams its x window once
    x_stream_bytes_per_nnz = (
        structured_frac * n_bands * prof.n_cols * machine.elem_bytes
        / max(nnz, 1))
    stream_bytes_per_nnz = (
        machine.elem_bytes + machine.idx_bytes                    # val + idx
        + machine.idx_bytes * (n + 1) / max(nnz, 1)               # rowptr
        + 2 * machine.elem_bytes * n / max(nnz, 1)                # y rd+wr
        + x_stream_bytes_per_nnz                                  # x windows
    )
    stream_lines_per_nnz = stream_bytes_per_nnz / lb
    # streams pollute the caches only when they do not fit (use-once lines)
    stream_rate_l2 = 0.0 if fits_l2 else stream_lines_per_nnz
    stream_rate_l3 = 0.0 if fits_l3 else stream_lines_per_nnz

    # ---- x-gather demand misses (per access) --------------------------------
    counts = prof.line_counts
    # per-core L2: each thread sees 1/threads of the rows; popularity
    # distribution is unchanged by the random permutation, counts scale down.
    l2_cap = machine.x_cache_frac * machine.l2_bytes / lb
    per_core_counts = counts / max(threads, 1)
    hit_l2_rand = _che_hit_rate(per_core_counts, l2_cap, stream_rate_l2)
    # if the whole problem fits in L2, everything hits after warmup
    if fits_l2:
        hit_l2_rand = 1.0
    x_miss_l2 = (1.0 - structured_frac) * (1.0 - hit_l2_rand)

    # shared L3 (per socket): threads on a socket share hub lines, and the
    # streaming matrix data competes for the same capacity (finding F1).
    l3_cap = machine.x_cache_frac * machine.l3_bytes * sockets_used / lb
    hit_l3_rand = _che_hit_rate(counts, l3_cap,
                                stream_rate_l3 * max(threads, 1))
    if fits_l3:
        hit_l3_rand = 1.0
    # L3 miss given L2 miss (inclusive hierarchy, IRM): conditional ratio
    x_miss_l3 = x_miss_l2 * (1.0 - hit_l3_rand) / max(1.0 - hit_l2_rand, 1e-12) \
        if hit_l2_rand < 1.0 else 0.0
    x_miss_l3 = min(x_miss_l3, x_miss_l2)

    # ---- two-pass solve: prefetcher state depends on *demand* DRAM traffic
    # (Intel manual / paper §II-B: the prefetcher stays off when the DRAM
    # link is congested with demand requests -- FD generates none, so its
    # prefetcher keeps running; R-MAT's gather misses shut it down).
    threads_per_socket = min(threads, machine.cores_per_socket)
    bw_bytes_per_cyc_core = (machine.dram_bw_gbs * 1e9 /
                             (machine.freq_ghz * 1e9)) / threads_per_socket
    compute_cpn = 2.9   # load-port bound: 3 loads / 2 ports + fma + loop ctl

    pf_on = True
    for _ in range(4):  # fixed-point: pf state <-> DRAM demand utilization
        if fits_l2:
            pf_fills_per_nnz = 0.0
            stream_demand_l2 = 0.0
        elif pf_on:
            pf_fills_per_nnz = stream_lines_per_nnz
            stream_demand_l2 = 0.005 * stream_lines_per_nnz
        else:
            # paper §IV-C: congestion shuts the prefetcher off; stream lines
            # become demand misses
            pf_fills_per_nnz = 0.0
            stream_demand_l2 = stream_lines_per_nnz
        stream_demand_l3 = 0.0 if fits_l3 else 0.9 * stream_demand_l2

        l2_miss_per_nnz = x_miss_l2 + stream_demand_l2
        l3_miss_per_nnz = x_miss_l3 + stream_demand_l3

        demand_bytes_per_nnz = l3_miss_per_nnz * lb
        dram_lines_per_nnz = (
            l3_miss_per_nnz + (0.0 if fits_l3 else pf_fills_per_nnz))
        dram_bytes_per_nnz = dram_lines_per_nnz * lb

        stall_cpn = (
            (l2_miss_per_nnz - l3_miss_per_nnz) * machine.l3_hit_cycles
            + l3_miss_per_nnz * machine.dram_cycles
        ) / machine.mlp

        bw_cpn = dram_bytes_per_nnz / max(bw_bytes_per_cyc_core, 1e-12)
        eff_cpn = max(compute_cpn + stall_cpn, bw_cpn)
        dram_util = min(1.0, bw_cpn / eff_cpn) if eff_cpn > 0 else 0.0
        demand_util = min(
            1.0, (demand_bytes_per_nnz / max(bw_bytes_per_cyc_core, 1e-12))
            / max(eff_cpn, 1e-12))
        new_pf_on = demand_util < machine.pf_shutoff_util
        if new_pf_on == pf_on:
            break
        pf_on = new_pf_on

    # when DRAM saturates, queueing delay raises stalls further
    if dram_util > 0.8:
        stall_cpn *= 1.0 / max(1e-3, (1.05 - dram_util)) ** 0.5
        eff_cpn = max(compute_cpn + stall_cpn, bw_cpn)

    stall_frac = stall_cpn / max(eff_cpn, 1e-12)
    # bandwidth-bound cycles also show up as L2-pending stalls (paper Fig 4:
    # parallel FD stalls rise from prefetch-induced DRAM congestion even
    # though demand miss rates stay low)
    if not fits_l3:
        stall_frac = max(stall_frac,
                         (eff_cpn - compute_cpn) / max(eff_cpn, 1e-12))
    stall_frac = min(stall_frac, 0.95)

    # ---- compose the paper's metrics ---------------------------------------
    kinst = instr / 1e3
    runtime_s = eff_cpn * nnz / (machine.freq_ghz * 1e9) / max(threads, 1)
    gflops = 2.0 * nnz / runtime_s / 1e9

    return CacheMetrics(
        l2_miss_rate=l2_miss_per_nnz * nnz / kinst,
        l3_miss_rate=l3_miss_per_nnz * nnz / kinst,
        prefetch_miss_rate=pf_fills_per_nnz * nnz / kinst,
        l2_stall_frac=stall_frac,
        gflops=gflops,
        x_miss_l2_per_access=x_miss_l2,
        x_miss_l3_per_access=x_miss_l3,
        dram_utilization=dram_util,
        threads=threads,
        nnz=nnz,
    )


def table1_capacity(machine: MachineModel = SANDY_BRIDGE,
                    nnz_per_row: float = 9.0, parallel: bool = False) -> dict:
    """Paper Table I: max nnz such that the whole problem fits a cache level.

    problem bytes = nnz*(8+4) + (rows+1)*4 + 2*rows*8, rows = nnz/nnz_per_row.
    """
    def solve(cap_bytes):
        per_nnz = (machine.elem_bytes + machine.idx_bytes
                   + (machine.idx_bytes + 2 * machine.elem_bytes) / nnz_per_row)
        return int(cap_bytes / per_nnz)

    l2 = machine.l2_bytes * (16 if parallel else 1)
    l3 = machine.l3_bytes * (2 if parallel else 1)
    return {"L2": solve(l2), "L3": solve(l3)}
