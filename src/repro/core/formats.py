"""Sparse-matrix containers used throughout the framework.

The paper stores matrices in CSR (values / col-indices / row-pointers,
2m + n + 1 elements).  On TPU we additionally provide formats whose access
pattern is *structurally* friendly to the HBM->VMEM DMA engine:

  * CSR   -- the paper's format; row-pointer driven, good for scalar-prefetch
             Pallas grids.
  * ELL   -- fixed nnz/row, row-major padded; vectorizes on the VPU.
  * BELL  -- blocked-ELL: (bm x bn) dense blocks, fixed blocks per row-block.
             The TPU-native unstructured format (blocks are lane-aligned, so
             every gather moves a useful 2-D tile instead of 8 wasted lanes).
  * DIA   -- diagonal/banded storage; the FD fast path (x-windows contiguous).
  * HYB   -- hybrid row split for power-law matrices: rows above an nnz
             threshold move to a column-sorted COO heavy partition (hub
             rows stream x instead of thrashing it), the structured
             remainder stays ELL with a small width.

All containers are registered pytrees of jnp arrays so they pass through
jit/pjit unharmed; construction happens host-side in numpy.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _register(cls):
    """Register a dataclass as a pytree (arrays = leaves, ints = static)."""
    fields = [f.name for f in dataclasses.fields(cls)]
    array_fields = [f for f in fields if f not in cls._static]
    static_fields = [f for f in fields if f in cls._static]

    def flatten(obj):
        return (
            tuple(getattr(obj, f) for f in array_fields),
            tuple(getattr(obj, f) for f in static_fields),
        )

    def unflatten(static, arrays):
        kwargs = dict(zip(array_fields, arrays))
        kwargs.update(dict(zip(static_fields, static)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@_register
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row.  2m + n + 1 stored elements (paper §II-A)."""

    _static = ("n_rows", "n_cols")

    data: Array        # (nnz,) values
    indices: Array     # (nnz,) column index per nonzero
    indptr: Array      # (n_rows + 1,) offsets into data
    n_rows: int
    n_cols: int

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_cols)

    def storage_bytes(self) -> int:
        return (
            self.data.size * self.data.dtype.itemsize
            + self.indices.size * self.indices.dtype.itemsize
            + self.indptr.size * self.indptr.dtype.itemsize
        )

    @staticmethod
    def from_coo(rows, cols, vals, n_rows, n_cols, dtype=np.float32) -> "CSR":
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = np.asarray(vals, dtype=dtype)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        indptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr, dtype=np.int64)
        if indptr[-1] < np.iinfo(np.int32).max:
            indptr = indptr.astype(np.int32)
        return CSR(
            data=jnp.asarray(vals),
            indices=jnp.asarray(cols.astype(np.int32)),
            indptr=jnp.asarray(indptr),
            n_rows=int(n_rows),
            n_cols=int(n_cols),
        )

    def to_dense(self) -> Array:
        out = np.zeros(self.shape, dtype=np.asarray(self.data).dtype)
        indptr = np.asarray(self.indptr)
        cols = np.asarray(self.indices)
        vals = np.asarray(self.data)
        for r in range(self.n_rows):
            lo, hi = int(indptr[r]), int(indptr[r + 1])
            np.add.at(out[r], cols[lo:hi], vals[lo:hi])
        return jnp.asarray(out)

    def row_lengths(self) -> np.ndarray:
        indptr = np.asarray(self.indptr)
        return np.diff(indptr)

    def apply_delta(self, delta) -> "CSR":
        """Materialize this matrix with an `repro.core.delta.EdgeDelta`
        applied: deleted coordinates removed structurally, inserts
        appended, result rebuilt canonically through `from_coo`.  The
        streaming plan lifecycle calls this when a delta outgrows its
        overlay budget and the plan re-compiles."""
        from .delta import apply_delta as _apply
        return _apply(self, delta)

    def permute(self, row_perm=None, col_perm=None) -> "CSR":
        """A' with A'[i, j] = A[row_perm[i], col_perm[j]].

        `row_perm[i]` names the OLD row placed at NEW position i (the
        convention of `repro.reorder.Reordering`); either perm may be None
        for identity.  Raises ValueError on a non-permutation (duplicate or
        out-of-range index), which would otherwise corrupt silently.
        Rebuilds through `from_coo`, so the result is canonically
        (row, col)-sorted.
        """
        def invert(perm, n, name):
            perm = np.asarray(perm, dtype=np.int64)
            if perm.shape != (n,) or \
                    not np.array_equal(np.bincount(perm, minlength=n),
                                       np.ones(n, dtype=np.int64)):
                raise ValueError(f"{name} is not a permutation of range({n})")
            inv = np.empty(n, dtype=np.int64)
            inv[perm] = np.arange(n, dtype=np.int64)
            return inv

        indptr = np.asarray(self.indptr, dtype=np.int64)
        cols = np.asarray(self.indices, dtype=np.int64)
        vals = np.asarray(self.data)
        rows = np.repeat(np.arange(self.n_rows, dtype=np.int64),
                         np.diff(indptr))
        if row_perm is not None:
            rows = invert(row_perm, self.n_rows, "row_perm")[rows]
        if col_perm is not None:
            cols = invert(col_perm, self.n_cols, "col_perm")[cols]
        return CSR.from_coo(rows, cols, vals, self.n_rows, self.n_cols,
                            dtype=vals.dtype)


@_register
@dataclasses.dataclass(frozen=True)
class ELL:
    """ELLPACK: every row padded to `max_nnz` entries (pad col = 0, val = 0)."""

    _static = ("n_rows", "n_cols", "max_nnz")

    data: Array        # (n_rows, max_nnz)
    indices: Array     # (n_rows, max_nnz) int32; padding points at col 0
    n_rows: int
    n_cols: int
    max_nnz: int

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_cols)

    @staticmethod
    def from_csr(csr: CSR, max_nnz: int | None = None,
                 fill: float = 0.0) -> "ELL":
        """`fill` is the padding value for short rows -- 0.0 for plus-times
        SpMV, the semiring's absorbing element (`Semiring.pad_value`, e.g.
        +inf for min-plus) when the container feeds a semiring kernel."""
        lengths = csr.row_lengths()
        width = (int(lengths.max()) if len(lengths) else 0) \
            if max_nnz is None else int(max_nnz)
        data = np.full((csr.n_rows, width), fill,
                       dtype=np.asarray(csr.data).dtype)
        idx = np.zeros((csr.n_rows, width), dtype=np.int32)
        indptr = np.asarray(csr.indptr)
        cols = np.asarray(csr.indices)
        vals = np.asarray(csr.data)
        for r in range(csr.n_rows):
            lo, hi = int(indptr[r]), int(indptr[r + 1])
            k = min(hi - lo, width)
            data[r, :k] = vals[lo:lo + k]
            idx[r, :k] = cols[lo:lo + k]
        return ELL(
            data=jnp.asarray(data),
            indices=jnp.asarray(idx),
            n_rows=csr.n_rows,
            n_cols=csr.n_cols,
            max_nnz=width,
        )

    def storage_bytes(self) -> int:
        return (
            self.data.size * self.data.dtype.itemsize
            + self.indices.size * self.indices.dtype.itemsize
        )


@_register
@dataclasses.dataclass(frozen=True)
class BELL:
    """Blocked-ELL: (bm, bn) dense blocks, fixed `blocks_per_row` per block-row.

    This is the TPU-native unstructured format: each gathered unit is a dense
    (bm, bn) tile whose bn is lane-aligned, so a "random access" still moves a
    fully-useful 2-D tile through the DMA engine.  Padding blocks have
    block_col 0 and all-zero data.
    """

    _static = ("n_rows", "n_cols", "bm", "bn", "blocks_per_row")

    data: Array        # (n_block_rows, blocks_per_row, bm, bn)
    block_cols: Array  # (n_block_rows, blocks_per_row) int32 block-col index
    n_rows: int
    n_cols: int
    bm: int
    bn: int
    blocks_per_row: int

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_cols)

    @property
    def n_block_rows(self) -> int:
        return -(-self.n_rows // self.bm)

    @staticmethod
    def from_csr(csr: CSR, bm: int = 8, bn: int = 128,
                 blocks_per_row: int | None = None) -> "BELL":
        nbr = -(-csr.n_rows // bm)
        nbc = -(-csr.n_cols // bn)
        indptr = np.asarray(csr.indptr)
        cols = np.asarray(csr.indices)
        vals = np.asarray(csr.data)
        # bucket nonzeros by (block_row, block_col)
        from collections import defaultdict
        buckets: dict = defaultdict(list)
        for r in range(csr.n_rows):
            br = r // bm
            for p in range(int(indptr[r]), int(indptr[r + 1])):
                c = int(cols[p])
                buckets[(br, c // bn)].append((r % bm, c % bn, vals[p]))
        per_row: dict = defaultdict(list)
        for (br, bc), entries in buckets.items():
            per_row[br].append((bc, entries))
        width = blocks_per_row or max(
            (len(v) for v in per_row.values()), default=1)
        width = max(width, 1)
        data = np.zeros((nbr, width, bm, bn), dtype=vals.dtype)
        bcols = np.zeros((nbr, width), dtype=np.int32)
        for br, blocks in per_row.items():
            blocks.sort(key=lambda t: t[0])
            for k, (bc, entries) in enumerate(blocks[:width]):
                bcols[br, k] = bc
                for (ri, ci, v) in entries:
                    data[br, k, ri, ci] += v
        del nbc
        return BELL(
            data=jnp.asarray(data),
            block_cols=jnp.asarray(bcols),
            n_rows=csr.n_rows, n_cols=csr.n_cols,
            bm=bm, bn=bn, blocks_per_row=width,
        )

    def storage_bytes(self) -> int:
        return (
            self.data.size * self.data.dtype.itemsize
            + self.block_cols.size * self.block_cols.dtype.itemsize
        )

    def density(self) -> float:
        """Fraction of stored block entries that are true nonzeros."""
        return float(np.count_nonzero(np.asarray(self.data))) / self.data.size


@_register
@dataclasses.dataclass(frozen=True)
class DIA:
    """Diagonal (banded) storage: the FD fast path.

    `data[k, i]` is A[i, i + offsets[k]].  Out-of-range entries are zero.
    x-accesses for diagonal k are the contiguous window x[off_k : off_k + n] --
    the structurally perfect case from the paper's Fig. 2.
    """

    _static = ("n_rows", "n_cols")

    data: Array      # (n_diags, n_rows)
    offsets: Array   # (n_diags,) int32, column offset of each diagonal
    n_rows: int
    n_cols: int

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_cols)

    @staticmethod
    def from_csr(csr: CSR) -> "DIA":
        indptr = np.asarray(csr.indptr)
        cols = np.asarray(csr.indices)
        vals = np.asarray(csr.data)
        rows = np.repeat(np.arange(csr.n_rows), np.diff(indptr))
        offs = cols.astype(np.int64) - rows
        uniq = np.unique(offs)
        data = np.zeros((len(uniq), csr.n_rows), dtype=vals.dtype)
        pos = {int(o): k for k, o in enumerate(uniq)}
        for r, c, v in zip(rows, cols, vals):
            data[pos[int(c) - int(r)], r] += v
        return DIA(
            data=jnp.asarray(data),
            offsets=jnp.asarray(uniq.astype(np.int32)),
            n_rows=csr.n_rows, n_cols=csr.n_cols,
        )

    @property
    def n_diags(self) -> int:
        return int(self.offsets.shape[0])

    def storage_bytes(self) -> int:
        return (
            self.data.size * self.data.dtype.itemsize
            + self.offsets.size * self.offsets.dtype.itemsize
        )


def hyb_auto_threshold(row_lengths) -> int:
    """Default heavy-row cutoff: the median nnz/row (>= 2).

    The cut is the *typical* row, not the mean: power-law matrices have
    median ≪ mean (most rows are near-empty, hubs carry the mass), so
    everything past the typical row -- the hubs and the heavy tail that
    hold most nonzeros -- moves to the column-sorted heavy stream whose
    x gathers ascend, and the light ELL slab stays narrow instead of
    being sized by outliers.  Near-uniform matrices have median ≈ max,
    so no row crosses the cut and the split degenerates to plain ELL.
    (A mean-based cut keeps the tail rows in the slab and its width
    balloons: at 2^12 R-MAT a 2x-mean cut doubles the streamed slab
    footprint and costs ~2x the warm cycles of this cut.)"""
    lens = np.asarray(row_lengths)
    if lens.size == 0:
        return 2
    return max(2, int(np.median(lens)))


@_register
@dataclasses.dataclass(frozen=True)
class HYB:
    """Hybrid row split: ELL light partition + column-sorted COO heavy tail.

    Rows with more than `threshold` nonzeros are routed whole to the heavy
    partition, stored as flat COO sorted by (column, row): hub-row x
    gathers become one ascending streaming pass over x instead of a
    random walk, and the few hub y rows stay resident.  Remaining rows
    keep ELL layout over the FULL row range (heavy rows are all-padding
    there), so the light width is bounded by `threshold` instead of the
    hub-row maximum.  `fill` pads short light rows -- 0.0 for plus-times,
    the semiring's absorbing element otherwise (same contract as ELL).
    """

    _static = ("n_rows", "n_cols", "threshold", "light_width")

    data: Array        # (n_rows, light_width) light values; padding `fill`
    indices: Array     # (n_rows, light_width) int32; padding points at col 0
    hvals: Array       # (heavy_nnz,) heavy values, column-sorted
    hrows: Array       # (heavy_nnz,) int32 global row per heavy nonzero
    hcols: Array       # (heavy_nnz,) int32 column per heavy nonzero, ascending
    n_rows: int
    n_cols: int
    threshold: int
    light_width: int

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_cols)

    @property
    def heavy_nnz(self) -> int:
        return int(self.hvals.shape[0])

    def heavy_row_ids(self) -> np.ndarray:
        return np.unique(np.asarray(self.hrows))

    @staticmethod
    def from_csr(csr: CSR, threshold: int | None = None,
                 fill: float = 0.0) -> "HYB":
        lengths = csr.row_lengths()
        thr = hyb_auto_threshold(lengths) if threshold is None \
            else int(threshold)
        heavy_rows = np.flatnonzero(lengths > thr)
        heavy_set = np.zeros(csr.n_rows, dtype=bool)
        heavy_set[heavy_rows] = True

        indptr = np.asarray(csr.indptr, dtype=np.int64)
        cols = np.asarray(csr.indices, dtype=np.int64)
        vals = np.asarray(csr.data)
        rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64),
                         np.diff(indptr))
        is_heavy = heavy_set[rows] if len(rows) else \
            np.zeros(0, dtype=bool)

        hr, hc, hv = rows[is_heavy], cols[is_heavy], vals[is_heavy]
        order = np.lexsort((hr, hc))          # ascending column, then row
        hr, hc, hv = hr[order], hc[order], hv[order]

        lr, lc, lv = rows[~is_heavy], cols[~is_heavy], vals[~is_heavy]
        light_lens = np.where(heavy_set, 0, lengths) if len(lengths) else \
            lengths
        width = int(light_lens.max()) if light_lens.size else 0
        data = np.full((csr.n_rows, width), fill, dtype=vals.dtype)
        idx = np.zeros((csr.n_rows, width), dtype=np.int32)
        if len(lr):
            light_ptr = np.zeros(csr.n_rows + 1, dtype=np.int64)
            np.add.at(light_ptr, lr + 1, 1)
            light_ptr = np.cumsum(light_ptr)
            inner = np.arange(len(lr), dtype=np.int64) - light_ptr[lr]
            data[lr, inner] = lv
            idx[lr, inner] = lc.astype(np.int32)
        return HYB(
            data=jnp.asarray(data), indices=jnp.asarray(idx),
            hvals=jnp.asarray(hv),
            hrows=jnp.asarray(hr.astype(np.int32)),
            hcols=jnp.asarray(hc.astype(np.int32)),
            n_rows=csr.n_rows, n_cols=csr.n_cols,
            threshold=thr, light_width=width,
        )

    def storage_bytes(self) -> int:
        return (
            self.data.size * self.data.dtype.itemsize
            + self.indices.size * self.indices.dtype.itemsize
            + self.hvals.size * self.hvals.dtype.itemsize
            + self.hrows.size * self.hrows.dtype.itemsize
            + self.hcols.size * self.hcols.dtype.itemsize
        )
