"""Partitioners: row-blocking (thread/chip parallelism) and column-stripe
splitting (the paper's software-managed-cache technique, P2+P3).

The paper randomly permutes R-MAT rows/columns *to equalize thread load*;
`rowblock_balanced` provides the same guarantee deterministically by
splitting on the nnz CDF instead of on row count.

Structure-changing permutations live in `repro.reorder` (RCM, degree
sorting, cache blocking, chains); `sort_rows_by_nnz` below is kept as a
thin compatibility wrapper over `repro.reorder.degree_sort`.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .formats import CSR


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Row ranges [starts[i], starts[i+1]) per worker + their nnz counts."""
    starts: np.ndarray     # (parts+1,)
    nnz_per_part: np.ndarray

    @property
    def n_parts(self) -> int:
        return len(self.starts) - 1

    def imbalance(self) -> float:
        """max/mean nnz ratio -- 1.0 is perfect."""
        m = self.nnz_per_part.mean()
        return float(self.nnz_per_part.max() / max(m, 1e-9))


def rowblock_equal(csr: CSR, parts: int) -> RowPartition:
    """Equal row counts (what the paper's permuted matrices make safe).

    Every part is non-empty: row counts differ by at most one (exact
    integer split, not float linspace, whose truncation used to produce
    empty parts), and `parts > n_rows` is capped at one row per part
    (`n_parts` reports the effective count).
    """
    parts = max(1, min(int(parts), csr.n_rows))
    starts = (np.arange(parts + 1, dtype=np.int64) * csr.n_rows) // parts
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    nnz = indptr[starts[1:]] - indptr[starts[:-1]]
    return RowPartition(starts=starts, nnz_per_part=nnz)


def rowblock_balanced(csr: CSR, parts: int) -> RowPartition:
    """Equal nnz counts via CDF split (robust to unpermuted power laws)."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    targets = np.linspace(0, indptr[-1], parts + 1)
    starts = np.searchsorted(indptr, targets, side="left").astype(np.int64)
    starts[0], starts[-1] = 0, csr.n_rows
    starts = np.maximum.accumulate(starts)
    nnz = indptr[starts[1:]] - indptr[starts[:-1]]
    return RowPartition(starts=starts, nnz_per_part=nnz)


@dataclasses.dataclass(frozen=True)
class NnzPartition:
    """Flat-nonzero ranges [cuts[i], cuts[i+1]) per worker (merge-CSR
    style): cuts may fall mid-row, so a row crossing a boundary is shared
    and its partials reconciled by a carry-out merge.  Duck-typed with
    `RowPartition` where only `nnz_per_part` matters
    (`parallel.simulate_parallel`)."""
    cuts: np.ndarray       # (parts+1,) positions in the nonzero stream

    @property
    def n_parts(self) -> int:
        return len(self.cuts) - 1

    @property
    def nnz_per_part(self) -> np.ndarray:
        return np.diff(self.cuts)

    def imbalance(self) -> float:
        """max/mean nnz ratio -- by construction within 1 nonzero of 1.0."""
        m = self.nnz_per_part.mean()
        return float(self.nnz_per_part.max() / max(m, 1e-9))


def nnz_split(csr: CSR, parts: int) -> NnzPartition:
    """Equal nonzero segments regardless of row boundaries -- the
    partition the merge/segmented CSR kernel executes.  Unlike
    `rowblock_balanced` (which can still be skewed by a single hub row
    heavier than the target share), segment loads differ by at most one
    nonzero."""
    parts = max(1, min(int(parts), max(csr.nnz, 1)))
    cuts = (np.arange(parts + 1, dtype=np.int64) * csr.nnz) // parts
    return NnzPartition(cuts=cuts)


def col_stripes(csr: CSR, n_stripes: int) -> List[CSR]:
    """Split A into column stripes A = [A_0 | A_1 | ... ]; SpMV becomes
    y = sum_s A_s @ x_s with x_s pinned in VMEM (paper P2+P3 on TPU).

    Column indices inside each stripe are rebased to the stripe, so each
    stripe is a standalone (n_rows x stripe_width) CSR.
    """
    stripe_w = -(-csr.n_cols // n_stripes)
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cols = np.asarray(csr.indices, dtype=np.int64)
    vals = np.asarray(csr.data)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    out = []
    for s in range(n_stripes):
        lo, hi = s * stripe_w, min((s + 1) * stripe_w, csr.n_cols)
        m = (cols >= lo) & (cols < hi)
        out.append(CSR.from_coo(rows[m], cols[m] - lo, vals[m],
                                csr.n_rows, hi - lo,
                                dtype=vals.dtype))
    return out


def sort_rows_by_nnz(csr: CSR) -> tuple[CSR, np.ndarray]:
    """Row permutation descending by nnz (SELL-style): groups similar-length
    rows so ELL padding within blocks is minimal.  Returns (A', perm) with
    A'[i] = A[perm[i]]; y' = A' x  =>  y = y'[inv_perm].

    Compatibility wrapper: the strategy now lives in
    `repro.reorder.degree_sort`, which returns the richer `Reordering`.
    """
    from repro.reorder import degree_sort

    r = degree_sort(csr, descending=True)
    return r.apply(csr), np.asarray(r.row_perm)
