"""TPU HBM<->VMEM data-movement model for SpMV (hardware adaptation).

The paper's CPU metrics (cache miss rates) have no direct TPU counterpart:
v5e has no demand caches and no hardware prefetcher.  What *does* transfer is
the underlying quantity the misses proxy for -- bytes moved per nonzero --
and the paper's proposals P1-P3 become explicit software policies:

  stream    : matrix tiles stream HBM->VMEM once (P1: no cache to pollute)
  gather    : each x access is a DMA of `gather_granularity` bytes (the
              pathology; analogue of the R-MAT demand-miss plateau)
  col-block : partition A into column stripes; pin each stripe's x slice in
              VMEM and sweep the matrix once per stripe (P2+P3: software-
              managed cache + kernel-directed placement)

This model predicts bytes/nnz and a bandwidth-roofline GFLOP/s for each
policy, quantifying on TPU the structured-vs-unstructured gap the paper
measured on Sandy Bridge.  `benchmarks/traffic_bench.py` tabulates it.
"""
from __future__ import annotations

import dataclasses

from .formats import CSR


@dataclasses.dataclass(frozen=True)
class TPUModel:
    name: str = "TPU v5e"
    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9                 # bytes/s per chip
    vmem_bytes: int = 128 * 1024 * 1024   # per core (v5e: 128 MiB)
    lane_bytes: int = 512                 # min useful 2nd-minor DMA width
    gather_granularity: int = 512         # bytes moved per random x gather
    ici_bw_per_link: float = 50e9         # bytes/s/link (given constant)
    elem_bytes: int = 4                   # f32 values on TPU path
    idx_bytes: int = 4


TPU_V5E = TPUModel()


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    policy: str
    bytes_per_nnz: float
    hbm_bytes: float
    arithmetic_intensity: float      # flop / HBM byte
    roofline_gflops: float           # min(peak, AI * BW) / 1e9
    vmem_resident_bytes: int
    x_reload_factor: float           # times each x byte crosses HBM->VMEM

    def summary(self) -> str:
        return (f"{self.policy:>10}: {self.bytes_per_nnz:7.2f} B/nnz  "
                f"AI={self.arithmetic_intensity:6.4f}  "
                f"roofline={self.roofline_gflops:8.2f} GFLOP/s  "
                f"x_reload={self.x_reload_factor:5.2f}")


def _matrix_stream_bytes(csr: CSR, tpu: TPUModel) -> float:
    """CSR arrays + y, streamed exactly once (P1)."""
    return (csr.nnz * (tpu.elem_bytes + tpu.idx_bytes)
            + (csr.n_rows + 1) * tpu.idx_bytes
            + 2 * csr.n_rows * tpu.elem_bytes)


def gather_policy(csr: CSR, tpu: TPUModel = TPU_V5E) -> TrafficReport:
    """Naive port of CPU SpMV: per-nonzero random gather of x from HBM.

    Each gather moves `gather_granularity` bytes of which 4 are useful --
    the TPU analogue of the paper's R-MAT demand-miss regime, but worse
    (512B DMA tile vs 64B cache line).
    """
    mat = _matrix_stream_bytes(csr, tpu)
    x_bytes = csr.nnz * tpu.gather_granularity
    total = mat + x_bytes
    ai = 2.0 * csr.nnz / total
    return TrafficReport(
        policy="gather",
        bytes_per_nnz=total / csr.nnz,
        hbm_bytes=total,
        arithmetic_intensity=ai,
        roofline_gflops=min(tpu.peak_flops_bf16, ai * tpu.hbm_bw) / 1e9,
        vmem_resident_bytes=0,
        x_reload_factor=x_bytes / max(csr.n_cols * tpu.elem_bytes, 1),
    )


def stream_policy(csr: CSR, bandwidth: int, tpu: TPUModel = TPU_V5E
                  ) -> TrafficReport:
    """Banded/DIA policy (FD fast path): x windows stream alongside the
    matrix; each x byte crosses HBM once per diagonal *band group* that
    cannot share a window.  For the FD 9-point matrix there are 3 bands ->
    x streams ~3x (grid-row window reuse covers the 3 in-band diagonals)."""
    n_windows = max(1, min(3, bandwidth // max(1, int(csr.n_rows ** 0.5))
                           + 1)) if bandwidth > 0 else 1
    mat = _matrix_stream_bytes(csr, tpu)
    x_bytes = n_windows * csr.n_cols * tpu.elem_bytes
    total = mat + x_bytes
    ai = 2.0 * csr.nnz / total
    return TrafficReport(
        policy="stream",
        bytes_per_nnz=total / csr.nnz,
        hbm_bytes=total,
        arithmetic_intensity=ai,
        roofline_gflops=min(tpu.peak_flops_bf16, ai * tpu.hbm_bw) / 1e9,
        vmem_resident_bytes=3 * int(csr.n_rows ** 0.5) * tpu.elem_bytes,
        x_reload_factor=float(n_windows),
    )


def col_blocked_policy(csr: CSR, n_stripes: int | None = None,
                       tpu: TPUModel = TPU_V5E) -> TrafficReport:
    """Column-blocked SpMV: the paper's P2+P3 realized in software.

    A is split into `n_stripes` column stripes; stripe s's x-slice
    (n_cols/n_stripes * 4 bytes) is DMA'd into VMEM once and *pinned* while
    the stripe's nonzeros stream through.  x crosses HBM exactly once per
    full sweep; matrix bytes stream once (partial y accumulators stay in
    VMEM for the current row block, spilling adds the n_stripes y factor
    only when rows are also blocked -- we keep y in VMEM, stripes iterate
    inner, so y spills n_stripes times for very large n).
    """
    if n_stripes is None:
        x_bytes_total = csr.n_cols * tpu.elem_bytes
        n_stripes = max(1, -(-x_bytes_total // int(tpu.vmem_bytes * 0.5)))
    mat = _matrix_stream_bytes(csr, tpu)
    x_bytes = csr.n_cols * tpu.elem_bytes           # once: stripes partition x
    y_spill = (n_stripes - 1) * 2 * csr.n_rows * tpu.elem_bytes
    total = mat + x_bytes + y_spill
    ai = 2.0 * csr.nnz / total
    return TrafficReport(
        policy="col-block",
        bytes_per_nnz=total / csr.nnz,
        hbm_bytes=total,
        arithmetic_intensity=ai,
        roofline_gflops=min(tpu.peak_flops_bf16, ai * tpu.hbm_bw) / 1e9,
        vmem_resident_bytes=csr.n_cols * tpu.elem_bytes // n_stripes,
        x_reload_factor=1.0,
    )


def bell_policy(density: float, csr: CSR, tpu: TPUModel = TPU_V5E
                ) -> TrafficReport:
    """Blocked-ELL: random block-gathers move useful 2-D tiles.

    bytes/nnz = block bytes / (true nnz per block) for both matrix and the
    gathered x tile (bn columns * 4B each).
    """
    bm, bn = 8, 128
    block_bytes = bm * bn * tpu.elem_bytes
    nnz_per_block = max(density * bm * bn, 1e-9)
    mat = (block_bytes + tpu.idx_bytes) / nnz_per_block * csr.nnz
    x_bytes = (bn * tpu.elem_bytes) / nnz_per_block * csr.nnz
    y_bytes = 2 * csr.n_rows * tpu.elem_bytes
    total = mat + x_bytes + y_bytes
    ai = 2.0 * csr.nnz / total
    return TrafficReport(
        policy="bell",
        bytes_per_nnz=total / csr.nnz,
        hbm_bytes=total,
        arithmetic_intensity=ai,
        roofline_gflops=min(tpu.peak_flops_bf16, ai * tpu.hbm_bw) / 1e9,
        vmem_resident_bytes=block_bytes * 2,
        x_reload_factor=x_bytes / max(csr.n_cols * tpu.elem_bytes, 1),
    )
