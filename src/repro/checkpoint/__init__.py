"""repro.checkpoint — fault-tolerant sharded checkpointing.

  manager   CheckpointManager: async committed-step save/restore
            (msgpack manifest + zstd/zlib shards), schema-free
            `restore_any` for string-keyed dict trees

Consumed by `repro.telemetry.runner` (incremental sweep-cell
checkpoints behind `--workers/--resume`) and by training/serving state
elsewhere in the repo.
"""
from .manager import (DEFAULT_CODEC, CheckpointManager, compress_payload,
                      decompress_payload, shard_filename)

__all__ = [
    "CheckpointManager", "DEFAULT_CODEC", "compress_payload",
    "decompress_payload", "shard_filename",
]
