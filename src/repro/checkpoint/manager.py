"""Fault-tolerant sharded checkpointing (msgpack + zstd/zlib, async commit).

Layout (one directory per step):

    ckpt_dir/
      step_000123/
        manifest.msgpack        # tree structure, shapes, dtypes, shard map,
                                # compression codec
        shard_00000.bin.zst     # concatenated leaf buffers for host 0
        ...                     # (.bin.zlib when zstandard is unavailable)
        COMMITTED               # written LAST -> crash-safe commit marker

Design points for the 1000+-node story:
  * every host writes only its own shard file (no cross-host traffic);
  * `COMMITTED` marker is written by host 0 after all shards exist, so a
    restart never reads a torn checkpoint (restore() picks the newest
    committed step);
  * async: `save()` snapshots device arrays to host memory synchronously
    (cheap) and does compression+IO in a background thread -- training
    continues; `wait()` joins before the next save or exit;
  * elastic restore: the manifest stores the *global* array metadata, so a
    restart with a different host count re-shards by reading whichever
    shard files contain the needed byte ranges (here: single-process CPU,
    so the degenerate 1-shard case is exercised for real and the resharding
    path is unit-tested with synthetic multi-shard manifests);
  * data-pipeline state and RNG are checkpointed alongside params so
    restart is bitwise deterministic.
"""
from __future__ import annotations

import os
import re
import struct
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ModuleNotFoundError:          # optional dep (requirements-dev.txt)
    zstd = None

import zlib

Params = Any

# Codec registry: name -> (compress, decompress).  The codec used at save
# time is recorded in the manifest so restore works regardless of which
# codecs the restoring host has installed (zlib is always available).
_CODECS: Dict[str, Tuple[Any, Any]] = {
    "zlib": (lambda b: zlib.compress(b, 3), zlib.decompress),
}
if zstd is not None:
    _CODECS["zstd"] = (
        lambda b: zstd.ZstdCompressor(level=3).compress(b),
        lambda b: zstd.ZstdDecompressor().decompress(b),
    )

# shard-file extensions are fixed per codec name, independent of whether the
# codec is importable here (restore must locate files it cannot decompress
# in order to raise a useful error)
_EXTS = {"zstd": "zst", "zlib": "zlib"}

DEFAULT_CODEC = "zstd" if zstd is not None else "zlib"


def compress_payload(payload: bytes, codec: str = DEFAULT_CODEC) -> bytes:
    return _CODECS[codec][0](payload)


def decompress_payload(buf: bytes, codec: str) -> bytes:
    if codec not in _CODECS:
        raise ModuleNotFoundError(
            f"checkpoint was written with codec {codec!r}, which is not "
            f"available here (have: {sorted(_CODECS)})")
    return _CODECS[codec][1](buf)


def shard_filename(shard_id: int, codec: str) -> str:
    return f"shard_{shard_id:05d}.bin.{_EXTS.get(codec, codec)}"

_FLOAT_KINDS = {"bfloat16"}


def _leaf_to_bytes(x: np.ndarray) -> bytes:
    if x.dtype == jnp.bfloat16:
        return np.asarray(x).view(np.uint16).tobytes()
    return np.asarray(x).tobytes()


def _bytes_to_leaf(buf: bytes, shape, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        arr = np.frombuffer(buf, np.uint16).reshape(shape)
        return jnp.asarray(arr.view(jnp.bfloat16))
    return np.frombuffer(buf, np.dtype(dtype)).reshape(shape).copy()


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class CheckpointManager:
    def __init__(self, ckpt_dir: str, host_id: int = 0, n_hosts: int = 1,
                 keep: int = 3, codec: str = DEFAULT_CODEC):
        if codec not in _CODECS:
            raise ValueError(f"unknown codec {codec!r} (have {sorted(_CODECS)})")
        self.dir = ckpt_dir
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.keep = keep
        self.codec = codec
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Params, blocking: bool = False) -> str:
        """Snapshot now, write in background.  Returns the step dir."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        step_dir = os.path.join(self.dir, f"step_{step:09d}")

        def _write():
            os.makedirs(step_dir, exist_ok=True)
            flat = _flatten_with_paths(host_tree)
            treedef = jax.tree.structure(tree)
            entries = []
            payload = bytearray()
            for key in sorted(flat):
                leaf = flat[key]
                buf = _leaf_to_bytes(leaf)
                entries.append({
                    "key": key, "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "offset": len(payload), "nbytes": len(buf),
                    "shard": self.host_id,
                })
                payload.extend(buf)
            comp = compress_payload(bytes(payload), self.codec)
            shard_path = os.path.join(
                step_dir, shard_filename(self.host_id, self.codec))
            with open(shard_path + ".tmp", "wb") as f:
                f.write(comp)
            os.replace(shard_path + ".tmp", shard_path)
            manifest = {
                "step": step, "n_hosts": self.n_hosts,
                "codec": self.codec,
                "treedef": str(treedef), "entries": entries,
            }
            mpath = os.path.join(step_dir, "manifest.msgpack")
            with open(mpath + ".tmp", "wb") as f:
                f.write(msgpack.packb(manifest))
            os.replace(mpath + ".tmp", mpath)
            # commit marker last (host 0 in multi-host; here host 0 == us)
            if self.host_id == 0:
                with open(os.path.join(step_dir, "COMMITTED"), "w") as f:
                    f.write(str(step))
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()
        return step_dir

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            sd = os.path.join(self.dir, f"step_{s:09d}")
            for fn in os.listdir(sd):
                os.unlink(os.path.join(sd, fn))
            os.rmdir(sd)

    # -- restore --------------------------------------------------------------

    def committed_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def load_manifest(self, step: Optional[int] = None) -> Dict:
        """Read the manifest of a committed step (newest when None)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        step_dir = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(step_dir, "manifest.msgpack"), "rb") as f:
            return msgpack.unpackb(f.read())

    # keystr of a string-keyed nested dict path: "['a']['b']..."
    _DICT_KEY = re.compile(r"\['([^']*)'\]")

    def restore_any(self, step: Optional[int] = None) -> Tuple[Params, int]:
        """Schema-free restore: rebuild the tree from the manifest alone,
        with no caller-supplied target.

        Only string-keyed nested-dict trees are supported (every manifest
        key must be a chain of `['k']` segments) — enough for state that
        must be loadable before its structure is known, e.g. a serialized
        `repro.plan.SpmvPlan` restored at process start.  Trees with list
        or attribute nodes still need `restore(step, target)`.
        """
        if step is None:
            step = self.latest_step()
        manifest = self.load_manifest(step)
        target: Dict = {}
        for e in manifest["entries"]:
            key = e["key"]
            parts = self._DICT_KEY.findall(key)
            if "".join(f"['{p}']" for p in parts) != key:
                raise ValueError(
                    f"restore_any supports string-keyed dict trees only; "
                    f"cannot rebuild node {key!r} — use restore() with a "
                    f"target tree")
            node = target
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = np.zeros((0,))   # placeholder; restore()
                                               # reads shape/dtype from the
                                               # manifest, not the target
        return self.restore(step, target)

    def restore(self, step: Optional[int], target: Params) -> Tuple[Params, int]:
        """Restore into the structure of `target` (elastic: shard count may
        differ from save time -- byte ranges are reassembled per leaf)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        step_dir = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(step_dir, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        # manifests written before the codec field default to zstd
        codec = manifest.get("codec", "zstd")
        shards: Dict[int, bytes] = {}

        def shard_bytes(i: int) -> bytes:
            if i not in shards:
                path = os.path.join(step_dir, shard_filename(i, codec))
                with open(path, "rb") as f:
                    shards[i] = decompress_payload(f.read(), codec)
            return shards[i]

        by_key = {e["key"]: e for e in manifest["entries"]}
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for path, tgt in flat_t:
            key = jax.tree_util.keystr(path)
            e = by_key[key]
            buf = shard_bytes(e["shard"])[e["offset"]: e["offset"] + e["nbytes"]]
            leaf = _bytes_to_leaf(buf, e["shape"], e["dtype"])
            leaves.append(jnp.asarray(leaf))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
