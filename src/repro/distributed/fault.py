"""Fault tolerance: heartbeats, straggler detection, elastic rescale plans.

On a real cluster these hook the launcher's control plane (GRPC/etcd); the
logic is identical on one host, so it is implemented and unit-tested here
and wired into launch/train.py's supervisor loop:

  * HeartbeatMonitor  -- declares workers dead after `timeout_s` silence;
  * StragglerDetector -- flags workers whose step time exceeds
    k x rolling-median; emits a mitigation (re-balance rows for SpMV jobs,
    shrink microbatch or evict for LM jobs);
  * plan_elastic_rescale -- maps a committed checkpoint onto a new device
    count (data-axis resize only: model-parallel degree is part of the
    lowered program and never resized in place);
  * Supervisor -- restart-on-failure wrapper with bounded retries and
    deterministic data replay (resume step comes from the checkpoint).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class WorkerState:
    last_seen: float
    last_step: int


class HeartbeatMonitor:
    def __init__(self, n_workers: int, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(last_seen=-1.0, last_step=-1)
            for i in range(n_workers)}

    def beat(self, worker: int, step: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self.workers[worker] = WorkerState(last_seen=now, last_step=step)

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [w for w, st in self.workers.items()
                if st.last_seen >= 0 and now - st.last_seen > self.timeout_s]

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_workers(now)


class StragglerDetector:
    """Rolling-median step-time watchdog (paper analogy: the permuted R-MAT
    rows equalize *work*; stragglers come from the *machine*, so detection
    is temporal, not structural)."""

    def __init__(self, k: float = 2.0, window: int = 32):
        self.k = k
        self.times: Dict[int, deque] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, worker: int, step_time_s: float):
        self.times[worker].append(step_time_s)

    def medians(self) -> Dict[int, float]:
        out = {}
        for w, ts in self.times.items():
            s = sorted(ts)
            out[w] = s[len(s) // 2] if s else 0.0
        return out

    def stragglers(self) -> List[int]:
        med = self.medians()
        if not med:
            return []
        global_med = sorted(med.values())[len(med) // 2]
        if global_med <= 0:
            return []
        return [w for w, m in med.items() if m > self.k * global_med]

    def mitigation(self, worker: int) -> str:
        return (f"worker {worker}: reassign its row-block via "
                f"partition.rowblock_balanced excluding it, or evict and "
                f"elastic-rescale the data axis")


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_mesh: tuple
    new_mesh: tuple
    data_resize: float          # new_data / old_data
    batch_per_host_change: float
    notes: str


def plan_elastic_rescale(old_mesh: Dict[str, int], n_devices_now: int
                         ) -> RescalePlan:
    """Resize the data axis to fit the surviving device count.

    model (and pod) degrees are fixed by the compiled program; the data axis
    shrinks to the largest size that divides the survivors.  Checkpoints
    restore unchanged (params are sharded over model; the data axis only
    replicates/FSDP-shards them, and the CheckpointManager reshards byte
    ranges on read).
    """
    model = old_mesh.get("model", 1)
    pod = old_mesh.get("pod", 1)
    per_pod = n_devices_now // pod
    new_data = max(per_pod // model, 1)
    # data axes prefer powers of two (collective efficiency)
    while new_data & (new_data - 1):
        new_data -= 1
    old = tuple(old_mesh.values())
    new = (pod, new_data, model) if "pod" in old_mesh else (new_data, model)
    old_data = old_mesh.get("data", 1)
    return RescalePlan(
        old_mesh=old, new_mesh=new, data_resize=new_data / old_data,
        batch_per_host_change=old_data / new_data,
        notes=(f"global batch kept constant: per-device batch scales by "
               f"{old_data / new_data:.2f}; grad-accumulation steps scale "
               f"inversely; dataset replay deterministic from step counter"),
    )


class Supervisor:
    """Run a step loop with bounded restart-on-failure."""

    def __init__(self, max_restarts: int = 3):
        self.max_restarts = max_restarts
        self.restarts = 0
        self.failures: List[str] = []

    def run(self, make_state: Callable[[], dict],
            step_fn: Callable[[dict, int], dict],
            n_steps: int, start_step: int = 0,
            fail_injector: Optional[Callable[[int], None]] = None) -> dict:
        """`make_state()` must restore from the latest checkpoint."""
        while True:
            state = make_state()
            step = int(state.get("step", start_step))
            try:
                while step < n_steps:
                    if fail_injector is not None:
                        fail_injector(step)
                    state = step_fn(state, step)
                    step = int(state.get("step", step + 1))
                return state
            except Exception as e:  # noqa: BLE001 -- supervisor boundary
                self.restarts += 1
                self.failures.append(f"step {step}: {type(e).__name__}: {e}")
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts; "
                        f"failures={self.failures}") from e
