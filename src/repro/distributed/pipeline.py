"""Pipeline parallelism (GPipe-style) over a mesh axis, as a shard_map.

Not used by the production dry-run meshes (scan-over-layers + FSDP + TP
dominates at 512 chips -- DESIGN.md §6); provided and unit-tested at toy
scale as the stage-over-`pod` variant for scaling beyond ICI domains,
where activations crossing the slow axis once per stage beat gradient
all-reduces crossing it every step.

Model: `n_stages` devices along `axis_name`, each owning `layers/n_stages`
consecutive layers (stacked leading dim on its param shard).  A microbatch
enters stage 0, and each step every stage processes one microbatch and
ppermutes its activation to the next stage.  With M microbatches the
schedule runs M + n_stages - 1 ticks (the classic bubble); utilization =
M / (M + S - 1).

The implementation is deliberately jnp-pure (runs under jit on any mesh)
and avoids host control flow over ticks: a lax.scan over the schedule with
a rotating buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    axis_name: str = "stage"

    @property
    def n_ticks(self) -> int:
        return self.n_microbatches + self.n_stages - 1

    @property
    def bubble_fraction(self) -> float:
        return (self.n_stages - 1) / self.n_ticks


def pipeline_apply(stage_fn: Callable, cfg: PipelineConfig,
                   stage_params, x_microbatches: jax.Array) -> jax.Array:
    """Run microbatches through the pipeline inside shard_map.

    stage_fn(params_slice, x) -> x : one stage's computation.
    stage_params: this device's parameter shard (layers of its stage).
    x_microbatches: (M, mb, ...) -- every stage receives the same input
    array; only stage 0 actually consumes it (others ignore, standard
    GPipe data feeding).

    Returns (M, mb, ...) outputs, valid on the LAST stage (other stages
    return zeros -- caller selects stage n-1's shard).
    """
    axis = cfg.axis_name
    s = cfg.n_stages
    idx = jax.lax.axis_index(axis)
    m = cfg.n_microbatches
    mb_shape = x_microbatches.shape[1:]

    def tick(carry, t):
        held, outputs = carry
        # stage 0 ingests microbatch t (if in range), others use held
        feed = jax.lax.dynamic_index_in_dim(
            x_microbatches, jnp.clip(t, 0, m - 1), axis=0, keepdims=False)
        x_in = jnp.where(idx == 0,
                         jnp.where(t < m, feed, jnp.zeros(mb_shape,
                                                          feed.dtype)),
                         held)
        y = stage_fn(stage_params, x_in)
        # last stage emits microbatch (t - (s-1)) at tick t
        out_slot = t - (s - 1)
        outputs = jax.lax.cond(
            (idx == s - 1) & (out_slot >= 0),
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, jnp.clip(out_slot, 0, m - 1), axis=0),
            lambda o: o, outputs)
        # rotate activations forward one stage
        nxt = jax.lax.ppermute(
            y, axis, perm=[(i, (i + 1) % s) for i in range(s)])
        return (nxt, outputs), None

    held0 = jnp.zeros(mb_shape, x_microbatches.dtype)
    outs0 = jnp.zeros_like(x_microbatches)
    (_, outputs), _ = jax.lax.scan(tick, (held0, outs0),
                                   jnp.arange(cfg.n_ticks))
    return outputs


def make_pipelined_mlp(cfg: PipelineConfig, layer_widths, key):
    """Toy stage model for tests: each stage holds layers/n_stages dense
    layers; returns (per-stage params stacked on axis 0, stage_fn)."""
    n_layers = len(layer_widths) - 1
    assert n_layers % cfg.n_stages == 0
    per = n_layers // cfg.n_stages
    keys = jax.random.split(key, n_layers)
    ws = [jax.random.normal(keys[i], (layer_widths[i], layer_widths[i + 1]))
          / jnp.sqrt(layer_widths[i]) for i in range(n_layers)]
    # uniform widths required for stacking; tests use equal widths
    stacked = jnp.stack(ws).reshape(cfg.n_stages, per, *ws[0].shape)

    def stage_fn(params_slice, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, params_slice)
        return y

    return stacked, stage_fn


def reference_apply(stacked, x):
    """Sequential oracle for the toy pipelined MLP."""
    s, per = stacked.shape[:2]
    y = x
    for i in range(s):
        for j in range(per):
            y = jnp.tanh(y @ stacked[i, j])
    return y
