"""Distribution layer: mesh context, sharding rules, collectives, fault
tolerance, and the row-parallel SpMV execution path (`distributed.spmv`,
the hardware counterpart of the `repro.parallel` scaling simulation)."""
from . import api
from .spmv import row_mesh, spmv_row_sharded

__all__ = ["api", "row_mesh", "spmv_row_sharded"]
