"""Distribution layer: mesh context, sharding rules, collectives, fault tolerance."""
from . import api
