"""Parameter/activation sharding rules (FSDP on 'data', TP on 'model').

Rules are *logical* (axis names resolved against whatever mesh is active) and
divisibility-checked: a dim that does not divide evenly falls back to
replication rather than failing to lower -- e.g. RWKV's 40 heads on a
16-way model axis, or GQA kv-projections when kv_heads < model.

Megatron-style layout:
    embed (V, d)            -> (model, data)     vocab-sharded
    head  (d, V)            -> (data, model)
    attn  wq/wk/wv (d, out) -> (data, model)     column parallel
    attn  wo (out, d)       -> (model, data)     row parallel
    mlp   up/gate (d, ff)   -> (data, model)
    mlp   down (ff, d)      -> (model, data)
    moe   experts (E, d, f) -> (model, data, -)  expert parallel + FSDP
    scalars / norms         -> replicated

The 'pod' axis is deliberately absent here: parameters are replicated
across pods (pure DP); only gradients cross the DCN (DESIGN.md §6).
Leaves under a scan stack ('stacks', 'enc_stack', 'dec_stack', 'prefix')
get a leading None for the layer dimension.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from .api import resolve_axis

Params = Any

_STACK_MARKERS = ("stacks", "enc_stack", "dec_stack")

# (name-suffix, logical spec per trailing dims)
_RULES_2D = {
    "embed": ("model", "data"),
    "tok_embed": ("model", "data"),
    "head": ("data", "model"),
    "wq": ("data", "model"),
    "wk": ("data", "kv_model"),      # kv_model: model iff kv divisible
    "wv": ("data", "kv_model"),
    "wo": ("model", "data"),
    "wg": ("data", "model"),
    "wr": ("data", "model"),
    "w_up": ("data", "model"),
    "w_gate": ("data", "model"),
    "w_down": ("model", "data"),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "A_log": ("model", None),
    "conv_w": (None, "model"),
    "wA": ("data", None),
    "wB": (None, "model"),
    "router": ("data", None),
    "dec_pos": (None, "data"),
}

_RULES_3D = {
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
    "shared_gate": (None, "data", "model"),
    "shared_up": (None, "data", "model"),
    "shared_down": (None, "model", "data"),
}

_RULES_1D = {
    "bq": ("model",),
    "bk": ("kv_model",),
    "bv": ("kv_model",),
    "conv_b": ("model",),
    "dt_bias": ("model",),
    "D": ("model",),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
    return ""


def _in_stack(path) -> bool:
    for entry in path:
        if isinstance(entry, jax.tree_util.DictKey) and \
                str(entry.key) in _STACK_MARKERS:
            return True
    return False


def _axis_size(mesh: Mesh, logical: Optional[str]) -> int:
    axis = resolve_axis(mesh, logical)
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def spec_for_leaf(path, shape: Tuple[int, ...], cfg: ModelConfig,
                  mesh: Mesh) -> P:
    name = _leaf_name(path)
    stacked = _in_stack(path)
    dims = shape[1:] if stacked else shape
    rank = len(dims)
    table = {1: _RULES_1D, 2: _RULES_2D, 3: _RULES_3D}.get(rank, {})
    logical = table.get(name)
    if logical is None and rank >= 2:
        # fallback: biggest-dims heuristic (covers future additions)
        logical = tuple([None] * (rank - 2) + ["data", "model"])
    if logical is None:
        logical = (None,) * rank

    resolved = []
    for dim_size, lax_name in zip(dims, logical):
        if lax_name == "kv_model":
            lax_name = "model" if cfg.n_kv_heads % _axis_size(
                mesh, "model") == 0 else None
        if lax_name is None:
            resolved.append(None)
            continue
        if dim_size % max(_axis_size(mesh, lax_name), 1) != 0:
            resolved.append(None)        # not divisible -> replicate
            continue
        resolved.append(resolve_axis(mesh, lax_name))
    if stacked:
        resolved = [None] + resolved
    return P(*resolved)


def param_specs(params_shape: Params, cfg: ModelConfig, mesh: Mesh) -> Params:
    """ShapeDtypeStruct tree (from eval_shape) -> PartitionSpec tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [spec_for_leaf(path, leaf.shape, cfg, mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_shape: Params, cfg: ModelConfig,
                    mesh: Mesh) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, cfg, mesh))


# ---------------------------------------------------------------------------
# Optimizer-state shardings (derived from the param specs, never re-derived
# from leaf names: moment tensors must be axis-aligned with their parameter
# or every optimizer step pays a resharding collective)
# ---------------------------------------------------------------------------

def opt_state_specs(opt_state_shape: Params, params_shape: Params,
                    cfg: ModelConfig, mesh: Mesh) -> Params:
    """PartitionSpecs for AdamWState / AdafactorState, built by construction.

    mu/nu mirror the param spec exactly (axis-aligned moments -> no
    resharding in the update).  Adafactor's factored stats drop the last
    (vr) / second-to-last (vc) dim of the param spec.  Scalars and the
    step counter are replicated.
    """
    from repro.optim.adamw import AdafactorState, AdamWState

    pspecs = param_specs(params_shape, cfg, mesh)
    if isinstance(opt_state_shape, AdamWState):
        return AdamWState(step=P(), mu=pspecs, nu=pspecs)
    if not isinstance(opt_state_shape, AdafactorState):
        raise TypeError(f"unknown optimizer state {type(opt_state_shape)}")

    is_p = lambda s: isinstance(s, P)  # noqa: E731
    spec_leaves, spec_def = jax.tree_util.tree_flatten(pspecs, is_leaf=is_p)
    param_leaves = jax.tree_util.tree_flatten(params_shape)[0]

    def _fit(axes, leaf_shape):
        axes = tuple(axes)[: len(leaf_shape)]
        axes = axes + (None,) * (len(leaf_shape) - len(axes))
        return P(*axes)

    vr_leaves, vc_leaves = [], []
    for spec, p in zip(spec_leaves, param_leaves):
        t = tuple(spec) + (None,) * (len(p.shape) - len(tuple(spec)))
        if len(p.shape) >= 2:
            vr_leaves.append(_fit(t[:-1], p.shape[:-1]))
            vc_leaves.append(_fit(t[:-2] + t[-1:],
                                  p.shape[:-2] + p.shape[-1:]))
        else:   # <2-D params use v_full; vr/vc are scalars
            vr_leaves.append(P())
            vc_leaves.append(P())
    vr = jax.tree_util.tree_unflatten(spec_def, vr_leaves)
    vc = jax.tree_util.tree_unflatten(spec_def, vc_leaves)
    vf = jax.tree_util.tree_unflatten(spec_def, [P()] * len(spec_leaves))
    return AdafactorState(step=P(), vr=vr, vc=vc, v_full=vf)


# ---------------------------------------------------------------------------
# Data / cache shardings
# ---------------------------------------------------------------------------

def batch_specs(batch_shape: Params, mesh: Mesh) -> Params:
    """Shard the leading (global-batch) dim of every input on dp."""
    dp = resolve_axis(mesh, "dp")

    def one(leaf):
        dims = [None] * len(leaf.shape)
        total_dp = _axis_size(mesh, "dp")
        if leaf.shape and leaf.shape[0] % max(total_dp, 1) == 0:
            dims[0] = dp
        return P(*dims)

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: Params, cfg: ModelConfig, mesh: Mesh) -> Params:
    """KV caches: batch on dp AND sequence on model (both where divisible).

    A 32k-deep qwen2-72b cache is 1.4 TB -- it only fits 16 GiB chips with
    full 256-way sharding, so the batch dim shards on dp and the KV length
    dim on model (GQA kv=8 heads cannot take a 16-way axis).  GSPMD lowers
    attention over seq-sharded KV as partial-softmax + small all-reduce.
    long_500k (B=1) gets sequence sharding only.  Non-KV state (SSM/RWKV
    states, enc_out) shards its batch dim and, for enc_out, sequence too.
    """
    dp = resolve_axis(mesh, "dp")
    dp_size = _axis_size(mesh, "dp")
    model = resolve_axis(mesh, "model")
    model_size = _axis_size(mesh, "model")

    def one(path, leaf):
        name = _leaf_name(path)
        dims: list = [None] * len(leaf.shape)
        stacked = _in_stack(path)
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        # find the batch dim: stacked caches are (L, B, ...), prefix (B, ...)
        b_dim = 1 if (stacked and rank >= 2) else 0
        if b_dim >= rank:
            return P(*dims)
        if leaf.shape[b_dim] % max(dp_size, 1) == 0 and leaf.shape[b_dim] > 1:
            dims[b_dim] = dp
        if name in ("k", "v", "enc_out") and rank >= b_dim + 2:
            # sequence dim: (L, B, S, KV, hd) / (B, S, KV, hd) / (B, S, d)
            s_dim = b_dim + 1
            if (leaf.shape[s_dim] % max(model_size, 1) == 0
                    and leaf.shape[s_dim] >= 4 * model_size):
                dims[s_dim] = model
        return P(*dims)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = [one(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
