"""Collective helpers: overlap-friendly patterns on jax.lax primitives.

pjit/GSPMD schedules most collectives automatically; these helpers cover the
cases where an explicit schedule beats the default:

  * ring_allgather_matmul -- shard_map pattern that overlaps the per-step
    `ppermute` of weight shards with the partial matmul (the classic
    "all-gather overlap" used for FSDP prefetch; the dry-run HLO shows
    collective-permute interleaved with dots instead of one blocking
    all-gather);
  * lse_merge_attention   -- merges per-shard attention partials computed
    over a sequence-sharded KV cache (decode with 500k contexts) with one
    tiny psum instead of all-gathering KV;
  * crosspod_psum_compressed -- re-export of the int8 error-feedback
    all-reduce from optim.grad_compress.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.grad_compress import crosspod_allreduce_compressed  # noqa: F401


def _axis_size(axis_name: str) -> int:
    # jax.lax.axis_size is newer jax; psum of 1 is the portable spelling
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_allgather_matmul(x: jax.Array, w_shard: jax.Array, axis_name: str
                          ) -> jax.Array:
    """Inside shard_map: y = x @ all_gather(w, axis) without a blocking
    all-gather.  w_shard: (d_in/n, d_out) local shard; x: (..., d_in).

    Each of the n steps multiplies the currently-held shard while
    ppermute-ing shards around the ring -- compute hides the permute
    latency (XLA overlaps independent ops).
    """
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    d_in = x.shape[-1]
    chunk = d_in // n

    def body(i, carry):
        acc, w_cur = carry
        src = (idx + i) % n
        x_chunk = jax.lax.dynamic_slice_in_dim(x, src * chunk, chunk, -1)
        acc = acc + x_chunk @ w_cur
        w_nxt = jax.lax.ppermute(
            w_cur, axis_name,
            perm=[(j, (j - 1) % n) for j in range(n)])
        return acc, w_nxt

    acc0 = jnp.zeros(x.shape[:-1] + (w_shard.shape[-1],),
                     jnp.promote_types(x.dtype, w_shard.dtype))
    acc, _ = jax.lax.fori_loop(0, n, body, (acc0, w_shard))
    return acc


def lse_merge_attention(q: jax.Array, k_shard: jax.Array, v_shard: jax.Array,
                        axis_name: str, positions_valid: jax.Array
                        ) -> jax.Array:
    """Decode attention over sequence-sharded KV without gathering KV.

    q: (B, H, 1, hd); k/v_shard: (B, S/n, KVH, hd) local slice;
    positions_valid: (B, S/n) bool mask for the local slice.
    Each shard computes its partial softmax numerator/denominator; the merge
    is a psum of (exp-shifted) partials -- O(B*H*hd) bytes on the wire
    instead of O(B*S*KVH*hd).
    """
    b, h, _, hd = q.shape
    kvh = k_shard.shape[2]
    g = h // kvh
    qf = q.reshape(b, kvh, g, hd).astype(jnp.float32)
    kf = k_shard.astype(jnp.float32)
    vf = v_shard.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kf) / (hd ** 0.5)
    s = jnp.where(positions_valid[:, None, None, :], s, -1e30)
    m_local = s.max(axis=-1, keepdims=True)
    m_global = jax.lax.pmax(m_local, axis_name)
    p = jnp.exp(s - m_global)
    num = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    den = p.sum(axis=-1, keepdims=True)
    num = jax.lax.psum(num, axis_name)
    den = jax.lax.psum(den, axis_name)
    out = num / jnp.maximum(den, 1e-30)
    return out.reshape(b, h, 1, hd)


def reduce_scatter_grads(grads, axis_name: str):
    """ZeRO-2: each worker keeps 1/n of the (summed) gradient."""
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)

    def one(g):
        if g.shape and g.shape[0] % n == 0:
            scattered = jax.lax.psum_scatter(
                g, axis_name, scatter_dimension=0, tiled=True)
            return scattered
        return jax.lax.psum(g, axis_name)

    del idx
    return jax.tree.map(one, grads)
