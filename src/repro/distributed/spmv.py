"""Row-parallel SpMV over a device mesh — the hardware side of the
`repro.parallel` simulation.

The simulated engine partitions rows across threads sharing an LLC; this
module executes the same `RowPartition` across real devices with
`shard_map`: every device runs the Pallas ELL kernel on its row slab
(x replicated, like the threads sharing one x working set), and y comes
back row-sharded.  On CPU the kernel runs in interpret mode, on TPU as
compiled Mosaic — the same dispatch contract as `repro.kernels.ops`.

Shard preparation is part of the matrix's execution plan:
`spmv_row_sharded` fetches a row-sharded `SpmvPlan` from
`repro.plan.DEFAULT_CACHE` (packing the ELL slabs only on first touch),
or build one yourself with `repro.plan.compile(csr, mesh=mesh)` to also
control reordering and serialize the planned shards.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.core.formats import CSR
from repro.core.partition import RowPartition, rowblock_equal
from repro.kernels import spmv_ell as _ell
from repro.kernels._layout import (ShardedELL, round_up,           # noqa: F401
                                   prepare_ell_shards)  # re-exported for
                                                        # pre-plan callers

from .compat import shard_map

_AXIS = "shards"


def row_mesh(devices=None) -> Mesh:
    """A 1-D mesh over all (or the given) devices, axis name 'shards'."""
    devices = list(devices) if devices is not None else jax.devices()
    return Mesh(np.array(devices), (_AXIS,))


def default_row_partition(csr: CSR, mesh: Mesh) -> RowPartition:
    """`rowblock_equal` over the mesh's shard axis, padded with trailing
    empty parts when there are more devices than rows (`rowblock_equal`
    caps its part count, but `shard_map` needs exactly one slab per
    device)."""
    n_shards = mesh.shape[_AXIS]
    if n_shards <= csr.n_rows:
        return rowblock_equal(csr, n_shards)
    starts = np.minimum(np.arange(n_shards + 1, dtype=np.int64), csr.n_rows)
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    return RowPartition(starts=starts,
                        nnz_per_part=indptr[starts[1:]] - indptr[starts[:-1]])


def spmv_row_sharded(csr: CSR, x: jax.Array, mesh: Optional[Mesh] = None,
                     partition: Optional[RowPartition] = None,
                     bm: int = 128, interpret: Optional[bool] = None,
                     reorder: str = "none", predictor: str = "auto"
                     ) -> jax.Array:
    """y = A @ x with rows sharded across the mesh's 'shards' axis.

    `partition` defaults to `default_row_partition`; a
    `rowblock_balanced` partition is accepted too (shards are padded to
    the largest part, so balance trades padding for equal work).  The
    packed shard slabs are cached in `repro.plan.DEFAULT_CACHE` keyed by
    matrix contents + partition, so repeated multiplies pay the ELL
    packing once.

    `reorder` defaults to 'none' (keeping historical cache keys);
    `reorder='auto'` lets the compiler's candidate scoring pick the
    shard-local ordering, scored by the learned cost model when one is
    shipped (`predictor='auto'`) -- a cheap decision even on the first
    touch of a large matrix.
    """
    from repro import plan as _plan

    mesh = mesh if mesh is not None else row_mesh()
    n_shards = mesh.shape[_AXIS]
    if partition is None:
        partition = default_row_partition(csr, mesh)
    if partition.n_parts != n_shards:
        raise ValueError(f"partition has {partition.n_parts} parts for "
                         f"{n_shards} devices on axis '{_AXIS}'")
    if reorder == "none":
        predictor = "none"     # nothing to score; keep historical keys
    p = _plan.DEFAULT_CACHE.get_or_compile(
        csr, mesh=mesh, partition=partition, bm=bm, reorder=reorder,
        predictor=predictor, keep_csr=False)
    return p.execute(x, interpret=interpret)


def spmv_row_sharded_prepared(prep: ShardedELL, x: jax.Array, mesh: Mesh,
                              interpret: Optional[bool] = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm = prep.bm
    _, rows_pad, w = prep.data.shape
    xp = jnp.pad(x, (0, round_up(prep.n_cols, 128) - prep.n_cols))

    def one_shard(data, idx, xv):
        # data/idx arrive as this device's (1, rows_pad, w) slab
        b_dim = rows_pad // bm
        y = _ell.spmv_ell_pallas(data.reshape(b_dim, bm, w),
                                 idx.reshape(b_dim, bm, w),
                                 xv, interpret=interpret)
        return y.reshape(1, rows_pad)

    sharded = shard_map(
        one_shard, mesh=mesh,
        in_specs=(PartitionSpec(_AXIS, None, None),
                  PartitionSpec(_AXIS, None, None),
                  PartitionSpec()),
        out_specs=PartitionSpec(_AXIS, None),
        check_vma=False)
    y_slabs = jax.jit(sharded)(prep.data, prep.idx, xp)   # (parts, rows_pad)
    parts = [y_slabs[p, : int(prep.starts[p + 1] - prep.starts[p])]
             for p in range(y_slabs.shape[0])]
    return jnp.concatenate(parts)[: prep.n_rows]
