"""Version shim for shard_map.

Newer jax exposes `jax.shard_map` with a `check_vma` kwarg; older
releases have `jax.experimental.shard_map.shard_map` with the same
semantics under `check_rep`.  Import `shard_map` from here so model and
test code runs on both sides of the rename.
"""
from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _CHECK_KWARG = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KWARG: check_vma})
