"""Mesh context + sharding-constraint helper used inside model code.

Model code calls `constrain(x, "dp", None, "model")` with *logical* axis
names; if the launch layer has installed a mesh context, this becomes a
`with_sharding_constraint`, otherwise it is a no-op (CPU smoke tests).

Logical axes:
  dp     -> ("pod", "data") when the mesh has a pod axis, else ("data",)
  data   -> "data"
  model  -> "model"
  None   -> replicated
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def resolve_axis(mesh: Mesh, logical: Optional[str]):
    if logical is None:
        return None
    if logical == "dp":
        return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if logical == "dpm":   # every axis: fully shard one dim (e.g. batch
        return tuple(mesh.axis_names)  # for attention-free recurrences)
    if logical in mesh.axis_names:
        return logical
    return None   # axis absent on this mesh -> replicate


def logical_spec(mesh: Mesh, *logical_axes) -> P:
    return P(*[resolve_axis(mesh, a) for a in logical_axes])


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(mesh, *logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
