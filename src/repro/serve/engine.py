"""Decode engine: continuous batching over the registry model API.

The engine owns a fixed-capacity slot batch (static shapes -> one compiled
decode step, reused forever) and drives the Scheduler:

    loop:
      admit_waiting()  -> prefill new slots (per-slot prefill, padded)
      pre_decode()     -> extend block tables / preempt
      decode_step      -> one token for every active slot (inactive masked)
      post_decode()    -> sampling, EOS bookkeeping, slot recycling

Sampling is greedy or temperature-based (per-request).  The per-slot cache
positions added to the model layer (cache['pos'] is a (B,) vector) are what
make mixed-depth batches correct.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry, transformer
from .kv_blocks import PoolConfig
from .scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8
    max_context: int = 512
    block_size: int = 16
    pool_blocks: Optional[int] = None   # default: 75% of dense worst case
    temperature: float = 0.0            # 0 => greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        worst = ecfg.max_batch * (ecfg.max_context // ecfg.block_size)
        pool_cfg = PoolConfig(
            n_blocks=ecfg.pool_blocks or max(int(0.75 * worst), 1),
            block_size=ecfg.block_size,
            max_blocks_per_seq=ecfg.max_context // ecfg.block_size,
        )
        self.sched = Scheduler(pool_cfg, ecfg.max_batch)
        self.cache = transformer.init_cache(cfg, ecfg.max_batch,
                                            ecfg.max_context)
        self.rng = jax.random.PRNGKey(ecfg.seed)
        self._decode = jax.jit(
            lambda p, c, t: transformer.decode_step(p, cfg, c, t))
        self._prefill_cache = {}

    # -- per-slot prefill -----------------------------------------------------

    def _prefill_one(self, slot_id: int, prompt: List[int]) -> None:
        """Run the prompt through the model into this slot's cache rows.

        Prompts are bucketed to power-of-two lengths so only O(log L)
        prefill programs ever compile."""
        plen = len(prompt)
        bucket = 1
        while bucket < plen:
            bucket *= 2
        bucket = min(bucket, self.ecfg.max_context)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = prompt

        if bucket not in self._prefill_cache:
            cfg = self.cfg
            max_ctx = self.ecfg.max_context

            def prefill_fn(params, tokens, cache, slot, true_len):
                # fresh width-1 cache, run the (padded) prompt, stamp the
                # true length, merge into the batch cache at `slot`.
                sub = transformer.init_cache(cfg, 1, max_ctx)
                x, new_sub, _ = transformer.forward(
                    params, cfg, tokens=tokens, cache=sub, remat="none")
                new_sub = _restamp_pos(new_sub, true_len[None])
                merged = transformer.merge_cache(cache, new_sub, slot)
                logits = x @ transformer.head_matrix(params, cfg)
                return logits, merged

            self._prefill_cache[bucket] = jax.jit(prefill_fn)

        logits, self.cache = self._prefill_cache[bucket](
            self.params, jnp.asarray(toks), self.cache,
            jnp.int32(slot_id), jnp.int32(plen))
        # next-token logits come from the last REAL prompt position
        self._pending_logits[slot_id] = np.asarray(
            logits[0, plen - 1], np.float32)

    # -- main loop ------------------------------------------------------------

    def run(self, requests: List[Request], max_steps: int = 10_000
            ) -> Dict[int, List[int]]:
        for r in requests:
            self.sched.submit(r)
        self._pending_logits: Dict[int, np.ndarray] = {}

        steps = 0
        while not self.sched.idle and steps < max_steps:
            steps += 1
            self.sched.tick()

            for slot in self.sched.admit_waiting():
                self._prefill_one(slot.slot_id, slot.req.prompt)
                tok = self._sample(self._pending_logits.pop(slot.slot_id))
                self.sched.post_decode(slot, tok)

            active = self.sched.pre_decode()
            if not active:
                continue
            tokens = np.zeros((self.ecfg.max_batch, 1), np.int32)
            for slot in active:
                seq = slot.req.prompt + slot.req.generated
                tokens[slot.slot_id, 0] = seq[-1]
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens))
            logits = np.asarray(logits[:, 0], np.float32)
            for slot in list(active):
                tok = self._sample(logits[slot.slot_id])
                self.sched.post_decode(slot, tok)

        return {r.req_id: r.generated for r in self.sched.finished}

    def _sample(self, logits: np.ndarray) -> int:
        if self.ecfg.temperature <= 0.0:
            return int(np.argmax(logits))
        self.rng, sub = jax.random.split(self.rng)
        return int(jax.random.categorical(
            sub, jnp.asarray(logits) / self.ecfg.temperature))


def _restamp_pos(cache, pos):
    out = dict(cache)
    out["pos"] = pos
    return out


def make_engine(cfg: ModelConfig, params=None, rng=None,
                ecfg: Optional[EngineConfig] = None) -> Engine:
    ecfg = ecfg or EngineConfig()
    if params is None:
        api = registry.get_model(cfg)
        params = api.init(rng if rng is not None else jax.random.PRNGKey(0))
    return Engine(cfg, params, ecfg)
