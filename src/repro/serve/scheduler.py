"""Continuous-batching scheduler (vLLM-style) with block-pool admission.

Policy:
  * requests queue FIFO; a request is admitted when (a) a batch slot is
    free and (b) the allocator can cover its prompt + one decode block;
  * every decode step extends each running sequence by one token; if the
    pool is exhausted the *youngest* running sequence is preempted back to
    the queue (its blocks freed, prompt re-queued) -- strict FIFO progress
    for the oldest work, no deadlock;
  * finished sequences (EOS or max_new_tokens) release immediately.

The scheduler is deliberately host-side and deterministic: identical
request traces produce identical schedules, which the tests rely on.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

from .kv_blocks import BlockAllocator, PoolConfig


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: list                 # token ids
    max_new_tokens: int
    arrived_step: int = 0
    generated: list = dataclasses.field(default_factory=list)

    @property
    def context_len(self) -> int:
        return len(self.prompt) + len(self.generated)


@dataclasses.dataclass
class Slot:
    slot_id: int
    req: Optional[Request] = None

    @property
    def free(self) -> bool:
        return self.req is None


class Scheduler:
    def __init__(self, pool_cfg: PoolConfig, max_batch: int,
                 eos_id: int = -1):
        self.alloc = BlockAllocator(pool_cfg)
        self.slots = [Slot(i) for i in range(max_batch)]
        self.queue: Deque[Request] = deque()
        self.eos_id = eos_id
        self.finished: List[Request] = []
        self.step_count = 0
        self.preemptions = 0

    # -- intake ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.arrived_step = self.step_count
        self.queue.append(req)

    # -- scheduling -----------------------------------------------------------

    def admit_waiting(self) -> List[Slot]:
        """Fill free slots from the queue while blocks allow.  Returns the
        slots that need a prefill this step."""
        newly = []
        for slot in self.slots:
            if not slot.free or not self.queue:
                continue
            req = self.queue[0]
            if not self.alloc.can_admit(req.context_len + 1):
                break     # FIFO: do not skip ahead of the head request
            self.queue.popleft()
            self.alloc.admit((slot.slot_id, req.req_id), req.context_len)
            slot.req = req
            newly.append(slot)
        return newly

    def _seq_key(self, slot: Slot):
        # (slot, request) tuple: additive schemes collide (slot 4 + req 0
        # == slot 0 + req 4) and corrupt the allocator's tables
        return (slot.slot_id, slot.req.req_id)

    def running(self) -> List[Slot]:
        return [s for s in self.slots if not s.free]

    def pre_decode(self) -> List[Slot]:
        """Extend every running sequence by one token; preempt youngest on
        pool exhaustion.  Returns slots participating in this decode step."""
        run = self.running()
        # youngest-first preemption order
        by_age = sorted(run, key=lambda s: s.req.arrived_step)
        for slot in run:
            ok = self.alloc.extend(self._seq_key(slot), 1)
            if not ok:
                victim = by_age[-1]
                self._preempt(victim)
                by_age.pop()
                if victim is slot:
                    continue
                if not self.alloc.extend(self._seq_key(slot), 1):
                    self._preempt(slot)
        return self.running()

    def _preempt(self, slot: Slot) -> None:
        req = slot.req
        self.alloc.release(self._seq_key(slot))
        # restart from scratch (prompt + already-generated become the prompt)
        req.prompt = list(req.prompt) + list(req.generated)
        req.generated = []
        self.queue.appendleft(req)
        slot.req = None
        self.preemptions += 1

    def post_decode(self, slot: Slot, token: int) -> None:
        req = slot.req
        req.generated.append(int(token))
        done = (token == self.eos_id
                or len(req.generated) >= req.max_new_tokens)
        if done:
            self.alloc.release(self._seq_key(slot))
            self.finished.append(req)
            slot.req = None

    def tick(self) -> None:
        self.step_count += 1

    @property
    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)

    def stats(self) -> Dict[str, float]:
        return {
            "queued": len(self.queue),
            "running": len(self.running()),
            "finished": len(self.finished),
            "pool_utilization": self.alloc.utilization(),
            "preemptions": self.preemptions,
            "steps": self.step_count,
        }
