"""Serving: paged KV blocks, continuous-batching scheduler, decode engine."""
from .engine import Engine, EngineConfig, make_engine
from .kv_blocks import BlockAllocator, PoolConfig, gather_kv, init_pool, write_token
from .scheduler import Request, Scheduler
