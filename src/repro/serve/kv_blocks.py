"""Paged KV-cache block allocator (vLLM-style), tied to the paper.

The KV pool is carved into fixed-size blocks; a sequence's cache is a list
of block ids (its *block table*).  Serving-time attention then reads KV
through a data-dependent block-index indirection -- structurally the same
access pattern as the paper's unstructured SpMV: the block table is the
column-index array, the pool is x, and the block-gather is exactly what
`kernels/spmv_bell.py` does with scalar-prefetched block columns (paper P3:
the kernel directs placement).  On TPU the pool blocks are (block, kv, hd)
tiles whose last dim is lane-aligned, so every gather moves a fully useful
tile -- the BELL argument applied to serving.

This module is the host-side allocator: free-list, per-sequence tables,
admission accounting.  `engine.py` consumes it; the device-side assembly is
`gather_kv` below (pure jnp; the Pallas path reuses the BELL kernel's
pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    n_blocks: int            # total physical blocks in the pool
    block_size: int          # tokens per block
    max_blocks_per_seq: int  # static bound: ceil(max_context / block_size)


class BlockAllocator:
    """Free-list allocator over the physical pool.  O(1) alloc/free."""

    def __init__(self, cfg: PoolConfig):
        self.cfg = cfg
        self.free: List[int] = list(range(cfg.n_blocks - 1, -1, -1))
        self.tables: Dict[int, List[int]] = {}      # seq_id -> block ids
        self.lengths: Dict[int, int] = {}           # seq_id -> tokens used

    @property
    def n_free(self) -> int:
        return len(self.free)

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.cfg.block_size)

    def can_admit(self, n_tokens: int) -> bool:
        return (self.blocks_needed(n_tokens) <= self.n_free)

    def admit(self, seq_id: int, n_tokens: int) -> List[int]:
        need = self.blocks_needed(max(n_tokens, 1))
        if need > self.n_free or need > self.cfg.max_blocks_per_seq:
            raise MemoryError(
                f"seq {seq_id}: need {need} blocks, free {self.n_free}")
        blocks = [self.free.pop() for _ in range(need)]
        self.tables[seq_id] = blocks
        self.lengths[seq_id] = n_tokens
        return blocks

    def extend(self, seq_id: int, n_new_tokens: int = 1) -> bool:
        """Grow a sequence; returns False when the pool is exhausted
        (caller must preempt -- scheduler policy, not allocator policy)."""
        new_len = self.lengths[seq_id] + n_new_tokens
        need = self.blocks_needed(new_len)
        table = self.tables[seq_id]
        while len(table) < need:
            if not self.free or len(table) >= self.cfg.max_blocks_per_seq:
                return False
            table.append(self.free.pop())
        self.lengths[seq_id] = new_len
        return True

    def release(self, seq_id: int) -> None:
        for b in self.tables.pop(seq_id, []):
            self.free.append(b)
        self.lengths.pop(seq_id, None)

    def table_array(self, seq_id: int) -> np.ndarray:
        """Fixed-width block table (padded with 0) for device code."""
        t = self.tables.get(seq_id, [])
        out = np.zeros((self.cfg.max_blocks_per_seq,), np.int32)
        out[: len(t)] = t
        return out

    def utilization(self) -> float:
        return 1.0 - self.n_free / self.cfg.n_blocks


# ---------------------------------------------------------------------------
# Device-side paged KV (pure jnp; BELL-pattern block gather)
# ---------------------------------------------------------------------------

def init_pool(cfg: PoolConfig, n_kv_heads: int, head_dim: int, n_layers: int,
              dtype=jnp.bfloat16):
    """Physical pool: (L, n_blocks, block, KVH, hd) for k and v."""
    shape = (n_layers, cfg.n_blocks, cfg.block_size, n_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_token(pool, layer: int, block_ids: jax.Array, offsets: jax.Array,
                k_new: jax.Array, v_new: jax.Array):
    """Scatter one token's KV for a batch of slots.

    block_ids/offsets: (B,) physical block + within-block offset per slot;
    k_new/v_new: (B, KVH, hd).
    """
    k = pool["k"].at[layer, block_ids, offsets].set(
        k_new.astype(pool["k"].dtype))
    v = pool["v"].at[layer, block_ids, offsets].set(
        v_new.astype(pool["v"].dtype))
    return {"k": k, "v": v}


def gather_kv(pool, layer: int, tables: jax.Array):
    """Assemble per-slot contiguous KV views from the pool.

    tables: (B, max_blocks) physical block ids (0-padded).
    Returns k, v: (B, max_blocks * block, KVH, hd).

    This is the BELL block-gather: a data-dependent index per (slot, block)
    selects a dense lane-aligned tile.  The Pallas realization is
    `kernels/spmv_bell.py`'s scalar-prefetch index_map with KV tiles in
    place of matrix blocks.
    """
    kb = jnp.take(pool["k"][layer], tables, axis=0)  # (B, mb, blk, KVH, hd)
    vb = jnp.take(pool["v"][layer], tables, axis=0)
    b, mb, blk, kvh, hd = kb.shape
    return (kb.reshape(b, mb * blk, kvh, hd),
            vb.reshape(b, mb * blk, kvh, hd))
