"""Optimizers (pure-JAX, pytree-native): AdamW and factored Adafactor.

AdamW keeps fp32 master weights + two fp32 moments (12 bytes/param) -- fine
up to ~100B params on the production mesh.  The trillion-parameter MoE
(kimi-k2) uses Adafactor with factored second moment and bf16 accumulators
(~2.5 bytes/param), selected per-arch by the launcher (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # schedule
    warmup_steps: int = 2000
    total_steps: int = 100_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


class AdafactorState(NamedTuple):
    step: jax.Array
    # per-leaf: either (row, col) factored stats or a full `nu` for <2D
    vr: Params
    vc: Params
    v_full: Params


def cosine_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: Params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(cfg: OptimizerConfig, grads: Params, state: AdamWState,
                 params: Params) -> Tuple[Params, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / (1 - b1 ** step)
        vhat = v2 / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, bf16 accumulators)
# ---------------------------------------------------------------------------

def adafactor_init(params: Params) -> AdafactorState:
    def vr(p):
        return (jnp.zeros(p.shape[:-1], jnp.bfloat16) if p.ndim >= 2
                else jnp.zeros((), jnp.bfloat16))

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.bfloat16)
                if p.ndim >= 2 else jnp.zeros((), jnp.bfloat16))

    def vf(p):
        return (jnp.zeros((), jnp.bfloat16) if p.ndim >= 2
                else jnp.zeros(p.shape, jnp.bfloat16))

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params),
                          v_full=jax.tree.map(vf, params))


def adafactor_update(cfg: OptimizerConfig, grads: Params,
                     state: AdafactorState, params: Params
                     ) -> Tuple[Params, AdafactorState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1) ** -0.8

    def upd(g, vr, vc, vf, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if p.ndim >= 2:
            vr2 = decay * vr.astype(jnp.float32) + (1 - decay) * g2.mean(-1)
            vc2 = decay * vc.astype(jnp.float32) + (1 - decay) * g2.mean(-2)
            denom = (vr2[..., None] * vc2[..., None, :]
                     / jnp.maximum(vr2.mean(-1)[..., None, None], 1e-30))
            delta = gf / (jnp.sqrt(denom) + cfg.eps)
            vf2 = vf
        else:
            vf2 = decay * vf.astype(jnp.float32) + (1 - decay) * g2
            delta = gf / (jnp.sqrt(vf2) + cfg.eps)
            vr2, vc2 = vr, vc
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(delta * delta) + 1e-30)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, vr2.astype(jnp.bfloat16), vc2.astype(jnp.bfloat16), \
            (vf2.astype(jnp.bfloat16) if p.ndim < 2 else vf)

    out = jax.tree.map(upd, grads, state.vr, state.vc, state.v_full, params)
    pick = lambda i: jax.tree.map(  # noqa: E731
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return pick(0), AdafactorState(step, pick(1), pick(2), pick(3)), metrics


# ---------------------------------------------------------------------------
# Uniform facade
# ---------------------------------------------------------------------------

def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(cfg, g, s, p)
    if cfg.name == "adafactor":
        return adafactor_init, lambda g, s, p: adafactor_update(cfg, g, s, p)
    raise ValueError(cfg.name)


def optimizer_bytes_per_param(name: str) -> float:
    return {"adamw": 8.0, "adafactor": 2.1}[name]
