"""Optimizers: AdamW, factored Adafactor, cosine schedule, int8 grad compression."""
from . import grad_compress
from .adamw import (AdamWState, AdafactorState, OptimizerConfig, adamw_init,
                    adamw_update, adafactor_init, adafactor_update, cosine_lr,
                    make_optimizer, optimizer_bytes_per_param)
