"""Gradient compression for the slow (cross-pod) all-reduce axis.

int8 error-feedback quantization: each pod quantizes its local gradient to
int8 with a per-tensor scale, all-reduces the int8 payload (8.5x fewer DCN
bytes than fp32 + scale exchange), dequantizes, and feeds the quantization
residual back into the next step's gradient (error feedback keeps the
scheme unbiased in the long run; Karimireddy et al. 2019).

Applied ONLY across 'pod' -- within-pod reduce-scatter stays full precision
(DESIGN.md §6).  Pure-jnp so it lowers in the dry-run; the collective is an
ordinary psum over the pod axis under shard_map, or implicit under pjit.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class CompressionState(NamedTuple):
    residual: Params     # error-feedback memory, same structure as grads


def compress_init(grads_shape: Params) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape))


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Params, state: CompressionState
                   ) -> Tuple[Params, Params, CompressionState]:
    """-> (int8_payload, scales, new_state).  Residual folded in first."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        new_r = gf - dequantize_int8(q, s)
        return q, s, new_r

    out = jax.tree.map(one, grads, state.residual)
    is_t = lambda t: isinstance(t, tuple)  # noqa: E731
    payload = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
    scales = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
    resid = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
    return payload, scales, CompressionState(residual=resid)


def decompress_grads(payload: Params, scales: Params) -> Params:
    return jax.tree.map(dequantize_int8, payload, scales)


def crosspod_allreduce_compressed(grads: Params, state: CompressionState,
                                  axis_name: str = "pod"
                                  ) -> Tuple[Params, CompressionState]:
    """Inside shard_map: quantize -> psum(int8 as int32) -> dequantize.

    int8 payloads are summed in int32 (no overflow for <= 2^23 pods) and the
    scales are averaged -- a standard approximation that keeps one collective.
    """
    payload, scales, new_state = compress_grads(grads, state)
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), payload)
    mean_scale = jax.tree.map(
        lambda s: jax.lax.pmean(s, axis_name), scales)
    n = jax.lax.psum(1, axis_name)
    reduced = jax.tree.map(
        lambda q, s: (q.astype(jnp.float32) * s / n), summed, mean_scale)
    return reduced, new_state
