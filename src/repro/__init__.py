"""repro — jax/Pallas reproduction of "Quantifying the Effect of Matrix
Structure on Multithreaded Performance of the SpMV Kernel".

Subpackages (see README.md's package map):

  core        generators, structure metrics, formats, cache model, SpMV
  plan        compile-once execution plans (the repeated-traffic surface)
  graph       semiring SpMV + iterative graph analytics on plans
  kernels     Pallas TPU kernels + prepared layouts
  reorder     structure-recovering permutations
  parallel    multithreaded shared-LLC scaling engine
  telemetry   trace-driven hierarchy simulation + topdown reports
  distributed meshes, collectives, row-sharded SpMV
  serve_graph analytics serving: continuous batching over the plan cache
  serve / models / train / optim / data / checkpoint / launch / roofline
              the production scaffolding

The plan API is re-exported at top level (`repro.compile`,
`repro.SpmvPlan`, ...) because it is the front door for repeated SpMV
traffic.  Imports are lazy: `import repro` stays cheap, and each
subpackage loads on first attribute access.
"""
from __future__ import annotations

import importlib

_SUBPACKAGES = (
    "checkpoint", "configs", "core", "data", "distributed", "graph",
    "kernels", "launch", "models", "optim", "parallel", "plan", "reorder",
    "roofline", "serve", "serve_graph", "telemetry", "train",
)

# plan API re-exported at top level (lazily, via __getattr__)
_PLAN_EXPORTS = (
    "SpmvPlan", "compile", "compile_plan", "PlanCache", "DEFAULT_CACHE",
    "get_plan", "matrix_fingerprint", "save_plan", "load_plan",
)

__all__ = list(_SUBPACKAGES) + list(_PLAN_EXPORTS)


def __getattr__(name: str):
    if name in _PLAN_EXPORTS:
        return getattr(importlib.import_module(".plan", __name__), name)
    if name in _SUBPACKAGES:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
