"""Public jit'd wrappers for the Pallas kernels.

These are what the rest of the framework calls.  Each wrapper:
  * does host-side layout prep (padding, stripe splitting),
  * runs the Pallas kernel (interpret=True on CPU, Mosaic on TPU),
  * restores the caller's shapes.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BELL, CSR, DIA, ELL
from . import flash_attention as _fa
from . import spmv_bell as _bell
from . import spmv_csr as _csr
from . import spmv_dia as _dia
from . import spmv_ell as _ell


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _reordered(kernel_fn):
    """Give a `fn(matrix, x, ..)` wrapper an optional `reordering` kwarg:
    the matrix is the reordered operand, x/y stay in the original order
    (gather x through col_perm in, scatter y through inv_row_perm out) --
    same contract as `repro.core.spmv.spmv`."""
    @functools.wraps(kernel_fn)
    def run(matrix, x, *args, reordering=None, **kwargs):
        if reordering is None:
            return kernel_fn(matrix, x, *args, **kwargs)
        y = kernel_fn(matrix, reordering.permute_x(x), *args, **kwargs)
        return reordering.restore_y(y)
    return run


# ---------------------------------------------------------------------------
# DIA
# ---------------------------------------------------------------------------

@_reordered
def spmv_dia(dia: DIA, x: jax.Array, bn: int = 512,
             interpret: bool = True) -> jax.Array:
    n = dia.n_rows
    n_pad = _round_up(n, bn)
    band = jnp.pad(dia.data, ((0, 0), (0, n_pad - n)))
    xp = jnp.pad(x, (0, n_pad - n))
    y = _dia.spmv_dia_pallas(band, dia.offsets, xp, bn=bn,
                             interpret=interpret)
    return y[:n]


# ---------------------------------------------------------------------------
# BELL
# ---------------------------------------------------------------------------

@_reordered
def spmv_bell(bell: BELL, x: jax.Array, interpret: bool = True) -> jax.Array:
    nbc = -(-bell.n_cols // bell.bn)
    xp = jnp.pad(x, (0, nbc * bell.bn - bell.n_cols))
    y = _bell.spmv_bell_pallas(bell.data, bell.block_cols, xp,
                               interpret=interpret)
    return y[: bell.n_rows]


# ---------------------------------------------------------------------------
# ELL (row-blocked, fixed width)
# ---------------------------------------------------------------------------

@_reordered
def spmv_ell(ell: ELL, x: jax.Array, bm: int = 128,
             interpret: bool = True) -> jax.Array:
    """Row-block the (n_rows, max_nnz) ELL arrays to (B, bm, W) and run the
    Pallas kernel; padding rows index col 0 with value 0."""
    n, w = ell.data.shape
    n_pad = _round_up(n, bm)
    w_pad = _round_up(max(w, 1), 128)
    data = jnp.pad(ell.data, ((0, n_pad - n), (0, w_pad - w)))
    idx = jnp.pad(ell.indices, ((0, n_pad - n), (0, w_pad - w)))
    b_dim = n_pad // bm
    xp = jnp.pad(x, (0, _round_up(ell.n_cols, 128) - ell.n_cols))
    y = _ell.spmv_ell_pallas(data.reshape(b_dim, bm, w_pad),
                             idx.reshape(b_dim, bm, w_pad).astype(jnp.int32),
                             xp, interpret=interpret)
    return y.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# ELL row shards (host prep for the shard_map row-parallel path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedELL:
    """Row-partitioned ELL layout: one (rows, width) slab per shard,
    stacked so `shard_map` can split the leading axis across devices.
    Column indices stay global (x is replicated); padding slots index
    col 0 with value 0."""
    data: jax.Array      # (parts, rows_pad, W)
    idx: jax.Array       # (parts, rows_pad, W) int32, global columns
    n_rows: int
    n_cols: int
    starts: np.ndarray   # (parts+1,) row range per shard
    bm: int              # row-block size the kernel tiles rows_pad into


def prepare_ell_shards(csr: CSR, partition, bm: int = 128,
                       pad_mult: int = 128) -> ShardedELL:
    """Pack each `RowPartition` part into one padded ELL slab.

    All shards share the global max row width (padded to `pad_mult`) and
    the max part row count (padded to `bm`), so the stacked arrays are
    rectangular -- the price of `shard_map`-compatible layout is padding,
    exactly like `prepare_csr`'s per-cell padding.
    """
    starts = np.asarray(partition.starts, dtype=np.int64)
    n_parts = len(starts) - 1
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    row_len = np.diff(indptr)
    w = _round_up(max(int(row_len.max()) if len(row_len) else 1, 1), pad_mult)
    rows_pad = _round_up(max(int(np.diff(starts).max()), 1), bm)

    D = np.zeros((n_parts, rows_pad, w), dtype=np.asarray(csr.data).dtype)
    C = np.zeros((n_parts, rows_pad, w), dtype=np.int32)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), row_len)
    part_of = np.searchsorted(starts, rows, side="right") - 1
    inner = np.arange(csr.nnz, dtype=np.int64) - indptr[rows]
    D[part_of, rows - starts[part_of], inner] = np.asarray(csr.data)
    C[part_of, rows - starts[part_of], inner] = \
        np.asarray(csr.indices).astype(np.int32)
    return ShardedELL(data=jnp.asarray(D), idx=jnp.asarray(C),
                      n_rows=csr.n_rows, n_cols=csr.n_cols,
                      starts=starts, bm=bm)


# ---------------------------------------------------------------------------
# CSR (column-blocked, padded)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PaddedCSR:
    """Host-prepped column-blocked layout for the spmv_csr kernel."""
    vals: jax.Array    # (S, B, W)
    cols: jax.Array    # (S, B, W) stripe-rebased
    rowin: jax.Array   # (S, B, W) row within block
    n_rows: int
    n_cols: int
    stripe_w: int
    bm: int


def prepare_csr(csr: CSR, n_stripes: int = 1, bm: int = 128,
                pad_mult: int = 128) -> PaddedCSR:
    """Pad each (stripe x row-block) cell to the max nonzero count."""
    stripe_w = _round_up(-(-csr.n_cols // n_stripes), 128)
    n_blocks = -(-csr.n_rows // bm)
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cols = np.asarray(csr.indices, dtype=np.int64)
    vals = np.asarray(csr.data)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    s_of = cols // stripe_w
    b_of = rows // bm
    cell = s_of * n_blocks + b_of
    order = np.argsort(cell, kind="stable")
    cell_s, rows_s, cols_s, vals_s = (cell[order], rows[order], cols[order],
                                      vals[order])
    counts = np.bincount(cell_s, minlength=n_stripes * n_blocks)
    w = max(int(counts.max()), 1)
    w = _round_up(w, pad_mult)
    V = np.zeros((n_stripes, n_blocks, w), dtype=vals.dtype)
    C = np.zeros((n_stripes, n_blocks, w), dtype=np.int32)
    R = np.zeros((n_stripes, n_blocks, w), dtype=np.int32)
    # position within cell
    cell_start = np.zeros(n_stripes * n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=cell_start[1:])
    inner = np.arange(len(cell_s)) - cell_start[cell_s]
    s_idx = cell_s // n_blocks
    b_idx = cell_s % n_blocks
    V[s_idx, b_idx, inner] = vals_s
    C[s_idx, b_idx, inner] = (cols_s % stripe_w).astype(np.int32)
    R[s_idx, b_idx, inner] = (rows_s % bm).astype(np.int32)
    return PaddedCSR(
        vals=jnp.asarray(V), cols=jnp.asarray(C), rowin=jnp.asarray(R),
        n_rows=csr.n_rows, n_cols=csr.n_cols, stripe_w=stripe_w, bm=bm,
    )


def spmv_csr_prepared(prep: PaddedCSR, x: jax.Array,
                      interpret: bool = True) -> jax.Array:
    s_dim = prep.vals.shape[0]
    xp = jnp.pad(x, (0, s_dim * prep.stripe_w - prep.n_cols))
    x_stripes = xp.reshape(s_dim, prep.stripe_w)
    partials = _csr.spmv_csr_pallas(prep.vals, prep.cols, prep.rowin,
                                    x_stripes, interpret=interpret)
    y = partials.sum(axis=0).reshape(-1)      # reduce over stripes
    return y[: prep.n_rows]


@_reordered
def spmv_csr(csr: CSR, x: jax.Array, n_stripes: int = 1,
             interpret: bool = True) -> jax.Array:
    """Convenience wrapper: preps layout per call (cache PaddedCSR via
    prepare_csr for repeated multiplies)."""
    return spmv_csr_prepared(prepare_csr(csr, n_stripes=n_stripes), x,
                             interpret=interpret)


# ---------------------------------------------------------------------------
# Paged attention (decode over block-table KV, GQA broadcast here)
# ---------------------------------------------------------------------------

def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    tables: jax.Array, lengths: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, hd); pools: (n_blocks, block, KVH, hd) with KVH | H;
    tables: (B, max_blocks) int32; lengths: (B,) -> (B, H, hd)."""
    from . import paged_attention as _pa

    b, h, hd = q.shape
    kvh = k_pool.shape[2]
    if kvh != h:                      # GQA: broadcast KV heads to H
        g = h // kvh
        k_pool = jnp.repeat(k_pool, g, axis=2)
        v_pool = jnp.repeat(v_pool, g, axis=2)
    return _pa.paged_attention_pallas(q, k_pool, v_pool, tables, lengths,
                                      interpret=interpret)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int | None = None,
                    interpret: bool = True) -> jax.Array:
    """q/k/v: (batch, heads, seq, head_dim); GQA callers broadcast kv first."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)
    of = _fa.flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                    interpret=interpret)
    return of.reshape(b, h, sq, d)
