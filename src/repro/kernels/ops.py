"""Public jit'd wrappers for the Pallas kernels.

Two layers, split so repeated traffic never repeats host work:

  * `repro.kernels._layout` owns ALL matrix-side preparation (padding,
    stripe splitting, row blocking) as `prepare_*` functions returning
    `Prepared*` containers, plus `spmv_*_prepared` runners that do zero
    matrix-side work per call.  `repro.plan` calls `prepare_*` once at
    plan-compile time and replays `spmv_*_prepared` forever after.
  * THIS module keeps the per-call convenience wrappers (`spmv_dia`,
    `spmv_bell`, `spmv_ell`, `spmv_csr`): each is just
    `prepare_*` + `spmv_*_prepared` composed, for one-shot callers and
    oracle tests.  Repeated multiplies should go through a compiled
    `repro.plan.SpmvPlan` (or `core.spmv.spmv`, which caches plans).

The prepared-layout containers (`PaddedCSR`, `ShardedELL`, ...) are
re-exported here for backwards compatibility.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import BELL, CSR, DIA, ELL, HYB
from . import flash_attention as _fa
from ._layout import (PaddedCSR, PreparedBELL, PreparedDIA, PreparedELL,
                      PreparedHYB, PreparedSegCSR, ShardedELL, prepare_bell,
                      prepare_csr, prepare_csr_seg, prepare_dia, prepare_ell,
                      prepare_ell_shards, prepare_hyb, round_up,
                      spmv_bell_prepared, spmv_csr_prepared,
                      spmv_csr_seg_prepared, spmv_dia_prepared,
                      spmv_ell_prepared, spmv_hyb_prepared)

# Backwards-compatible alias; new code should use `_layout.round_up`.
_round_up = round_up


def _reordered(kernel_fn):
    """Give a `fn(matrix, x, ..)` wrapper an optional `reordering` kwarg:
    the matrix is the reordered operand, x/y stay in the original order
    (gather x through col_perm in, scatter y through inv_row_perm out) --
    same contract as `repro.core.spmv.spmv`."""
    @functools.wraps(kernel_fn)
    def run(matrix, x, *args, reordering=None, **kwargs):
        if reordering is None:
            return kernel_fn(matrix, x, *args, **kwargs)
        y = kernel_fn(matrix, reordering.permute_x(x), *args, **kwargs)
        return reordering.restore_y(y)
    return run


# ---------------------------------------------------------------------------
# Per-call SpMV wrappers: prepare + run (cache the prep via repro.plan for
# repeated multiplies)
# ---------------------------------------------------------------------------

@_reordered
def spmv_dia(dia: DIA, x: jax.Array, bn: int = 512,
             interpret: bool = True) -> jax.Array:
    return spmv_dia_prepared(prepare_dia(dia, bn=bn), x, interpret=interpret)


@_reordered
def spmv_bell(bell: BELL, x: jax.Array, interpret: bool = True) -> jax.Array:
    return spmv_bell_prepared(prepare_bell(bell), x, interpret=interpret)


def _check_ell_padding_absorbing(ell: ELL, semiring) -> None:
    """An ELL built with the default `fill=0.0` pads short rows with
    (value 0.0, col 0) slots.  Under a semiring whose absorbing element is
    not 0.0 (min-plus: +inf) those slots read as real weight-0 edges to
    vertex 0 and silently corrupt every short row — so refuse any
    container holding such ambiguous slots and point at the fix.  (The
    check is conservative: a genuine explicit-zero entry in column 0
    trips it too; store it as the CSR path does, or nudge it off 0.0.)"""
    if isinstance(ell.data, jax.core.Tracer) or \
            isinstance(ell.indices, jax.core.Tracer):
        return                         # can't inspect under tracing
    import numpy as np

    data, idx = np.asarray(ell.data), np.asarray(ell.indices)
    if data.size and bool(np.any((data == 0.0) & (idx == 0))):
        raise ValueError(
            f"ELL container has (value 0.0, col 0) slots, which the "
            f"{semiring.name!r} semiring (pad_value="
            f"{semiring.pad_value!r}) would treat as real edges; build it "
            f"with ELL.from_csr(csr, fill=semiring.pad_value) so padding "
            "is absorbing, or use spmv_csr(csr, x, semiring=...)")


@_reordered
def spmv_ell(ell: ELL, x: jax.Array, bm: int = 128,
             interpret: bool = True, semiring=None) -> jax.Array:
    """Row-block the (n_rows, max_nnz) ELL arrays to (B, bm, W) and run the
    Pallas kernel; padding rows index col 0 with the absorbing pad value
    (0 for the default plus-times `semiring`).

    Non-plus-times semirings require the CONTAINER's own short-row
    padding to be absorbing too: build it with
    `ELL.from_csr(csr, fill=semiring.pad_value)` (checked when the pad
    value is not 0.0)."""
    pad = 0.0 if semiring is None else semiring.pad_value
    if semiring is not None and semiring.pad_value != 0.0:
        _check_ell_padding_absorbing(ell, semiring)
    return spmv_ell_prepared(prepare_ell(ell, bm=bm, pad_value=pad), x,
                             interpret=interpret, semiring=semiring)


@_reordered
def spmv_csr(csr: CSR, x: jax.Array, n_stripes: int = 1,
             interpret: bool = True, semiring=None) -> jax.Array:
    """Convenience wrapper: preps layout per call (compile a
    `repro.plan.SpmvPlan` to cache the `PaddedCSR` for repeated
    multiplies)."""
    pad = 0.0 if semiring is None else semiring.pad_value
    return spmv_csr_prepared(
        prepare_csr(csr, n_stripes=n_stripes, pad_value=pad), x,
        interpret=interpret, semiring=semiring)


@_reordered
def spmv_csr_seg(csr: CSR, x: jax.Array, seg_len: int = 512,
                 interpret: bool = True, semiring=None) -> jax.Array:
    """nnz-balanced segmented (merge) CSR: equal-nonzero segments over a
    static grid with a carry-out merge across segment boundaries.
    Convenience wrapper; compile a `repro.plan.SpmvPlan` with
    `format="csr-seg"` to cache the `PreparedSegCSR` layout."""
    pad = 0.0 if semiring is None else semiring.pad_value
    return spmv_csr_seg_prepared(
        prepare_csr_seg(csr, seg_len=seg_len, pad_value=pad), x,
        interpret=interpret, semiring=semiring)


def _check_hyb_padding_absorbing(hyb: HYB, semiring) -> None:
    """Same contract as `_check_ell_padding_absorbing`, applied to the
    HYB light partition: `fill=0.0` padding reads as real weight-0 edges
    to vertex 0 under semirings whose absorbing element is not 0.0."""
    if isinstance(hyb.data, jax.core.Tracer) or \
            isinstance(hyb.indices, jax.core.Tracer):
        return                         # can't inspect under tracing
    import numpy as np

    data, idx = np.asarray(hyb.data), np.asarray(hyb.indices)
    if data.size and bool(np.any((data == 0.0) & (idx == 0))):
        raise ValueError(
            f"HYB light partition has (value 0.0, col 0) slots, which the "
            f"{semiring.name!r} semiring (pad_value="
            f"{semiring.pad_value!r}) would treat as real edges; build it "
            f"with HYB.from_csr(csr, fill=semiring.pad_value) so padding "
            "is absorbing")


@_reordered
def spmv_hyb(hyb: HYB, x: jax.Array, seg_len: int = 512,
             interpret: bool = True, semiring=None) -> jax.Array:
    """Hybrid row split: one ELL launch over the light rows, one
    segmented launch over the column-sorted heavy stream, joined by ⊕.
    Non-plus-times semirings require the container's light padding to be
    absorbing: build it with `HYB.from_csr(csr, fill=semiring.pad_value)`
    (checked when the pad value is not 0.0)."""
    pad = 0.0 if semiring is None else semiring.pad_value
    if semiring is not None and semiring.pad_value != 0.0:
        _check_hyb_padding_absorbing(hyb, semiring)
    return spmv_hyb_prepared(
        prepare_hyb(hyb, seg_len=seg_len, pad_value=pad), x,
        interpret=interpret, semiring=semiring)


# ---------------------------------------------------------------------------
# Paged attention (decode over block-table KV, GQA broadcast here)
# ---------------------------------------------------------------------------

def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    tables: jax.Array, lengths: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, hd); pools: (n_blocks, block, KVH, hd) with KVH | H;
    tables: (B, max_blocks) int32; lengths: (B,) -> (B, H, hd)."""
    from . import paged_attention as _pa

    b, h, hd = q.shape
    kvh = k_pool.shape[2]
    if kvh != h:                      # GQA: broadcast KV heads to H
        g = h // kvh
        k_pool = jnp.repeat(k_pool, g, axis=2)
        v_pool = jnp.repeat(v_pool, g, axis=2)
    return _pa.paged_attention_pallas(q, k_pool, v_pool, tables, lengths,
                                      interpret=interpret)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int | None = None,
                    interpret: bool = True) -> jax.Array:
    """q/k/v: (batch, heads, seq, head_dim); GQA callers broadcast kv first."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)
    of = _fa.flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                    interpret=interpret)
    return of.reshape(b, h, sq, d)


__all__ = [
    "spmv_dia", "spmv_bell", "spmv_ell", "spmv_csr", "spmv_csr_seg",
    "spmv_hyb",
    "paged_attention", "flash_attention",
    # prepared-layout API (lives in _layout; re-exported for compatibility)
    "PaddedCSR", "prepare_csr", "spmv_csr_prepared",
    "PreparedDIA", "prepare_dia", "spmv_dia_prepared",
    "PreparedBELL", "prepare_bell", "spmv_bell_prepared",
    "PreparedELL", "prepare_ell", "spmv_ell_prepared",
    "ShardedELL", "prepare_ell_shards",
    "PreparedSegCSR", "prepare_csr_seg", "spmv_csr_seg_prepared",
    "PreparedHYB", "prepare_hyb", "spmv_hyb_prepared",
]
