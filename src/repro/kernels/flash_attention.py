"""Flash attention Pallas kernel with causal + sliding-window (banded) masks.

Paper tie-in: a sliding-window attention matrix IS a banded sparse matrix --
the FD structure applied to attention.  The same streaming property that
makes DIA SpMV roofline-friendly makes banded attention sub-quadratic: each
query block touches a contiguous KV window, so KV tiles stream HBM->VMEM
with no gathers and out-of-band blocks are skipped entirely.

Grid = (batch*heads, n_q_blocks, n_kv_blocks), online-softmax accumulators
in VMEM scratch, fp32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, bq, bk, nk):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = i * bq
    k_lo = j * bk
    # block-level skip: entire KV block out of the (causal, window) band
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, k_lo <= q_lo + bq - 1)
    if window is not None:
        relevant = jnp.logical_and(relevant, k_lo + bk - 1 >= q_lo - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        q_idx = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_idx >= k_idx)
        if window is not None:
            mask = jnp.logical_and(mask, q_idx - k_idx < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = l_scr[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, d)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, :] = jnp.where(
            l == 0.0, 0.0, acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, window: int | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (bh, sq, d), k/v: (bh, skv, d) -> (bh, sq, d).

    `window`: sliding-window size (None = full attention).  fp32 accumulate.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max m
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(q, k, v)
