"""Shared host-side layout preparation for the Pallas SpMV kernels.

One home for the padding / stripe-splitting / row-blocking arithmetic that
used to be copied between `kernels.ops` and the per-format kernel modules.
Every format gets a `prepare_*` function that does ALL matrix-side work
(padding, reshaping, stripe bucketing) once, returning a `Prepared*`
container, and a `spmv_*_prepared` runner that performs zero matrix-side
work per call -- only the per-call x pad/reshape plus the Pallas kernel.

This split is what `repro.plan` builds on: `prepare_*` runs at plan-compile
time, `spmv_*_prepared` is the amortized hot path.  The per-call wrappers in
`kernels.ops` are now just `prepare_*` + `spmv_*_prepared` composed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BELL, CSR, DIA, ELL, HYB

from . import spmv_bell as _bell
from . import spmv_csr as _csr
from . import spmv_csr_seg as _seg
from . import spmv_dia as _dia
from . import spmv_ell as _ell


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(v: int, m: int) -> int:
    return ceil_div(v, m) * m


# ---------------------------------------------------------------------------
# DIA
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreparedDIA:
    """Pre-padded banded layout: band rows padded to a bn multiple."""
    band: jax.Array      # (n_diags, n_pad)
    offsets: jax.Array   # (n_diags,) int32
    n_rows: int
    n_cols: int
    bn: int


def prepare_dia(dia: DIA, bn: int = 512) -> PreparedDIA:
    n_pad = round_up(dia.n_rows, bn)
    band = jnp.pad(dia.data, ((0, 0), (0, n_pad - dia.n_rows)))
    offsets = dia.offsets
    if band.shape[0] == 0:
        # nnz=0 matrix: DIA.from_csr stores zero diagonals, which the
        # Pallas grid (n_diags as a grid axis, scalar-prefetched offsets)
        # cannot represent.  Synthesize one explicit zero main diagonal --
        # zeros are the plus-times identity, so y is exactly zeros.
        band = jnp.zeros((1, n_pad), dia.data.dtype)
        offsets = jnp.zeros((1,), jnp.int32)
    return PreparedDIA(band=band, offsets=offsets, n_rows=dia.n_rows,
                       n_cols=dia.n_cols, bn=bn)


def spmv_dia_prepared(prep: PreparedDIA, x: jax.Array,
                      interpret: bool = True) -> jax.Array:
    xp = jnp.pad(x, (0, prep.band.shape[1] - x.shape[0]))
    y = _dia.spmv_dia_pallas(prep.band, prep.offsets, xp, bn=prep.bn,
                             interpret=interpret)
    return y[: prep.n_rows]


# ---------------------------------------------------------------------------
# BELL (already kernel-shaped; prep only records the x pad width)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreparedBELL:
    data: jax.Array        # (nbr, bpr, bm, bn)
    block_cols: jax.Array  # (nbr, bpr) int32
    n_rows: int
    n_cols: int
    x_pad: int             # padded x length (nbc * bn)


def prepare_bell(bell: BELL) -> PreparedBELL:
    nbc = ceil_div(bell.n_cols, bell.bn)
    return PreparedBELL(data=bell.data, block_cols=bell.block_cols,
                        n_rows=bell.n_rows, n_cols=bell.n_cols,
                        x_pad=nbc * bell.bn)


def spmv_bell_prepared(prep: PreparedBELL, x: jax.Array,
                       interpret: bool = True) -> jax.Array:
    xp = jnp.pad(x, (0, prep.x_pad - prep.n_cols))
    y = _bell.spmv_bell_pallas(prep.data, prep.block_cols, xp,
                               interpret=interpret)
    return y[: prep.n_rows]


# ---------------------------------------------------------------------------
# ELL (row-blocked, fixed width)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreparedELL:
    """Row-blocked (B, bm, W) ELL arrays; padding rows index col 0 val 0."""
    data: jax.Array      # (B, bm, W)
    idx: jax.Array       # (B, bm, W) int32
    n_rows: int
    n_cols: int
    x_pad: int           # padded x length


def prepare_ell(ell: ELL, bm: int = 128, pad_mult: int = 128,
                pad_value: float = 0.0) -> PreparedELL:
    """`pad_value` fills the width/row padding slots: 0.0 for plus-times,
    the semiring's absorbing element (`Semiring.pad_value`) otherwise.
    The container itself must already use the same fill
    (`ELL.from_csr(..., fill=...)`) for its own short-row padding."""
    n, w = ell.data.shape
    # max(n, 1): a 0-row container still needs one (all-padding) row
    # block -- a zero-length Pallas grid is not representable.
    n_pad = round_up(max(n, 1), bm)
    w_pad = round_up(max(w, 1), pad_mult)
    data = jnp.pad(ell.data, ((0, n_pad - n), (0, w_pad - w)),
                   constant_values=pad_value)
    idx = jnp.pad(ell.indices, ((0, n_pad - n), (0, w_pad - w)))
    b_dim = n_pad // bm
    return PreparedELL(
        data=data.reshape(b_dim, bm, w_pad),
        idx=idx.reshape(b_dim, bm, w_pad).astype(jnp.int32),
        n_rows=n, n_cols=ell.n_cols,
        x_pad=round_up(ell.n_cols, pad_mult))


def spmv_ell_prepared(prep: PreparedELL, x: jax.Array,
                      interpret: bool = True, semiring=None) -> jax.Array:
    xp = jnp.pad(x, (0, prep.x_pad - prep.n_cols))
    y = _ell.spmv_ell_pallas(prep.data, prep.idx, xp, interpret=interpret,
                             semiring=semiring)
    return y.reshape(-1)[: prep.n_rows]


# ---------------------------------------------------------------------------
# ELL row shards (host prep for the shard_map row-parallel path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedELL:
    """Row-partitioned ELL layout: one (rows, width) slab per shard,
    stacked so `shard_map` can split the leading axis across devices.
    Column indices stay global (x is replicated); padding slots index
    col 0 with value 0."""
    data: jax.Array      # (parts, rows_pad, W)
    idx: jax.Array       # (parts, rows_pad, W) int32, global columns
    n_rows: int
    n_cols: int
    starts: np.ndarray   # (parts+1,) row range per shard
    bm: int              # row-block size the kernel tiles rows_pad into


def prepare_ell_shards(csr: CSR, partition, bm: int = 128,
                       pad_mult: int = 128) -> ShardedELL:
    """Pack each `RowPartition` part into one padded ELL slab.

    All shards share the global max row width (padded to `pad_mult`) and
    the max part row count (padded to `bm`), so the stacked arrays are
    rectangular -- the price of `shard_map`-compatible layout is padding,
    exactly like `prepare_csr`'s per-cell padding.
    """
    starts = np.asarray(partition.starts, dtype=np.int64)
    n_parts = len(starts) - 1
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    row_len = np.diff(indptr)
    w = round_up(max(int(row_len.max()) if len(row_len) else 1, 1), pad_mult)
    rows_pad = round_up(max(int(np.diff(starts).max()), 1), bm)

    D = np.zeros((n_parts, rows_pad, w), dtype=np.asarray(csr.data).dtype)
    C = np.zeros((n_parts, rows_pad, w), dtype=np.int32)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), row_len)
    part_of = np.searchsorted(starts, rows, side="right") - 1
    inner = np.arange(csr.nnz, dtype=np.int64) - indptr[rows]
    D[part_of, rows - starts[part_of], inner] = np.asarray(csr.data)
    C[part_of, rows - starts[part_of], inner] = \
        np.asarray(csr.indices).astype(np.int32)
    return ShardedELL(data=jnp.asarray(D), idx=jnp.asarray(C),
                      n_rows=csr.n_rows, n_cols=csr.n_cols,
                      starts=starts, bm=bm)


# ---------------------------------------------------------------------------
# CSR (column-blocked, padded)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PaddedCSR:
    """Host-prepped column-blocked layout for the spmv_csr kernel."""
    vals: jax.Array    # (S, B, W)
    cols: jax.Array    # (S, B, W) stripe-rebased
    rowin: jax.Array   # (S, B, W) row within block
    n_rows: int
    n_cols: int
    stripe_w: int
    bm: int


def prepare_csr(csr: CSR, n_stripes: int = 1, bm: int = 128,
                pad_mult: int = 128, pad_value: float = 0.0) -> PaddedCSR:
    """Pad each (stripe x row-block) cell to the max nonzero count.

    `pad_value` fills the value padding slots (cols/rowin pad to 0): 0.0
    for plus-times, the semiring's absorbing element otherwise, so the
    kernel's segment-⊕ treats padding as the empty contribution."""
    stripe_w = round_up(ceil_div(csr.n_cols, n_stripes), 128)
    n_blocks = ceil_div(csr.n_rows, bm)
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cols = np.asarray(csr.indices, dtype=np.int64)
    vals = np.asarray(csr.data)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    s_of = cols // stripe_w
    b_of = rows // bm
    cell = s_of * n_blocks + b_of
    order = np.argsort(cell, kind="stable")
    cell_s, rows_s, cols_s, vals_s = (cell[order], rows[order], cols[order],
                                      vals[order])
    counts = np.bincount(cell_s, minlength=n_stripes * n_blocks)
    w = max(int(counts.max()), 1)
    w = round_up(w, pad_mult)
    V = np.full((n_stripes, n_blocks, w), pad_value, dtype=vals.dtype)
    C = np.zeros((n_stripes, n_blocks, w), dtype=np.int32)
    R = np.zeros((n_stripes, n_blocks, w), dtype=np.int32)
    # position within cell
    cell_start = np.zeros(n_stripes * n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=cell_start[1:])
    inner = np.arange(len(cell_s)) - cell_start[cell_s]
    s_idx = cell_s // n_blocks
    b_idx = cell_s % n_blocks
    V[s_idx, b_idx, inner] = vals_s
    C[s_idx, b_idx, inner] = (cols_s % stripe_w).astype(np.int32)
    R[s_idx, b_idx, inner] = (rows_s % bm).astype(np.int32)
    return PaddedCSR(
        vals=jnp.asarray(V), cols=jnp.asarray(C), rowin=jnp.asarray(R),
        n_rows=csr.n_rows, n_cols=csr.n_cols, stripe_w=stripe_w, bm=bm,
    )


def spmv_csr_prepared(prep: PaddedCSR, x: jax.Array,
                      interpret: bool = True, semiring=None) -> jax.Array:
    s_dim = prep.vals.shape[0]
    xp = jnp.pad(x, (0, s_dim * prep.stripe_w - prep.n_cols))
    x_stripes = xp.reshape(s_dim, prep.stripe_w)
    partials = _csr.spmv_csr_pallas(prep.vals, prep.cols, prep.rowin,
                                    x_stripes, interpret=interpret,
                                    semiring=semiring)
    if semiring is None or semiring.name == "plus_times":
        y = partials.sum(axis=0).reshape(-1)  # reduce over stripes
    else:
        y = semiring.reduce(partials, axis=0).reshape(-1)
    return y[: prep.n_rows]


# ---------------------------------------------------------------------------
# Segmented CSR (nnz-balanced flat stream; the merge-CSR layout)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreparedSegCSR:
    """Flat nonzero stream cut into equal-nnz segments for spmv_csr_seg.

    `rid` holds the per-segment-dense row rank of each nonzero and
    `row_ids[s, r]` maps rank r of segment s back to its global row (pad
    ranks park on the dummy row n_rows, sliced off after the carry
    merge).  `nonempty` marks rows with at least one nonzero so the
    non-plus-times combine can restore the ⊕-identity on empty rows."""
    vals: jax.Array      # (S, L)
    cols: jax.Array      # (S, L) int32
    rid: jax.Array       # (S, L) int32 rank within segment
    row_ids: jax.Array   # (S, R) int32 global row per rank; pad -> n_rows
    nonempty: jax.Array  # (n_rows,) bool
    n_rows: int
    n_cols: int
    rwin: int            # R: static rank-window width
    seg_len: int         # L: padded nonzeros per segment
    x_pad: int


def _seg_arrays(rows, cols, vals, n_rows: int, seg_len: int, pad_mult: int,
                pad_value: float):
    """Cut a (rows, cols, vals) nonzero stream -- in whatever order the
    caller chose (row-major for merge-CSR, column-sorted for the HYB
    heavy partition) -- into S equal segments of L slots, ranking rows
    densely within each segment."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    seg = round_up(max(int(seg_len), 1), pad_mult)
    nnz = len(vals)
    n_segs = max(ceil_div(nnz, seg), 1)
    total = n_segs * seg
    v = np.full(total, pad_value, dtype=vals.dtype)
    c = np.zeros(total, dtype=np.int32)
    r = np.full(total, n_rows, dtype=np.int64)   # pads on the dummy row
    v[:nnz], c[:nnz], r[:nnz] = vals, cols.astype(np.int32), rows
    v2, c2, r2 = v.reshape(n_segs, seg), c.reshape(n_segs, seg), \
        r.reshape(n_segs, seg)
    rid = np.zeros((n_segs, seg), dtype=np.int32)
    uniques = []
    for s in range(n_segs):
        uniq, inv = np.unique(r2[s], return_inverse=True)
        rid[s] = inv.astype(np.int32)
        uniques.append(uniq)
    rwin = round_up(max(len(u) for u in uniques), pad_mult)
    row_ids = np.full((n_segs, rwin), n_rows, dtype=np.int32)
    for s, uniq in enumerate(uniques):
        row_ids[s, : len(uniq)] = uniq
    return v2, c2, rid, row_ids, rwin, seg


def prepare_csr_seg(csr: CSR, seg_len: int = 512, pad_mult: int = 128,
                    pad_value: float = 0.0) -> PreparedSegCSR:
    """Flatten the CSR nonzero stream row-major and cut it into
    equal-nnz segments.  `pad_value` fills the tail slots: 0.0 for
    plus-times, the semiring's absorbing element otherwise."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    lengths = np.diff(indptr)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), lengths)
    v2, c2, rid, row_ids, rwin, seg = _seg_arrays(
        rows, np.asarray(csr.indices), np.asarray(csr.data), csr.n_rows,
        seg_len, pad_mult, pad_value)
    return PreparedSegCSR(
        vals=jnp.asarray(v2), cols=jnp.asarray(c2), rid=jnp.asarray(rid),
        row_ids=jnp.asarray(row_ids), nonempty=jnp.asarray(lengths > 0),
        n_rows=csr.n_rows, n_cols=csr.n_cols, rwin=rwin, seg_len=seg,
        x_pad=round_up(max(csr.n_cols, 1), pad_mult))


def spmv_csr_seg_prepared(prep: PreparedSegCSR, x: jax.Array,
                          interpret: bool = True, semiring=None) -> jax.Array:
    xp = jnp.pad(x, (0, prep.x_pad - prep.n_cols))
    partials = _seg.spmv_csr_seg_pallas(prep.vals, prep.cols, prep.rid, xp,
                                        rwin=prep.rwin, interpret=interpret,
                                        semiring=semiring)
    flat, ids = partials.reshape(-1), prep.row_ids.reshape(-1)
    if semiring is None or semiring.name == "plus_times":
        # carry-out merge: rows straddling a segment boundary have one
        # rank in each segment; the segment sum stitches them together.
        return jax.ops.segment_sum(flat, ids,
                                   num_segments=prep.n_rows + 1)[: prep.n_rows]
    y = semiring.segment(flat, ids,
                         num_segments=prep.n_rows + 1)[: prep.n_rows]
    # jax's segment_min/max fill empty segments with +/-inf, which is only
    # the ⊕-identity for min_plus -- restore it for the rest.
    return jnp.where(prep.nonempty, y, jnp.asarray(semiring.identity,
                                                   y.dtype))


# ---------------------------------------------------------------------------
# HYB (ELL light partition + column-sorted COO heavy tail)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreparedHYB:
    """Two fused launches per SpMV: the ELL kernel over the light rows
    and the segmented kernel over the column-sorted heavy stream, joined
    by one ⊕.  Heavy rows are all-padding in the light slab (identity)
    and light rows never appear in the heavy stream (identity via
    `heavy.nonempty`), so the join is exact for every semiring."""
    light: PreparedELL
    heavy: PreparedSegCSR
    n_rows: int
    n_cols: int


def prepare_hyb(hyb: HYB, seg_len: int = 512, bm: int = 128,
                pad_mult: int = 128, pad_value: float = 0.0) -> PreparedHYB:
    light_ell = ELL(data=hyb.data, indices=hyb.indices, n_rows=hyb.n_rows,
                    n_cols=hyb.n_cols, max_nnz=hyb.light_width)
    light = prepare_ell(light_ell, bm=bm, pad_mult=pad_mult,
                        pad_value=pad_value)
    v2, c2, rid, row_ids, rwin, seg = _seg_arrays(
        np.asarray(hyb.hrows), np.asarray(hyb.hcols), np.asarray(hyb.hvals),
        hyb.n_rows, seg_len, pad_mult, pad_value)
    heavy_mask = np.zeros(hyb.n_rows, dtype=bool)
    heavy_mask[hyb.heavy_row_ids()] = True
    heavy = PreparedSegCSR(
        vals=jnp.asarray(v2), cols=jnp.asarray(c2), rid=jnp.asarray(rid),
        row_ids=jnp.asarray(row_ids), nonempty=jnp.asarray(heavy_mask),
        n_rows=hyb.n_rows, n_cols=hyb.n_cols, rwin=rwin, seg_len=seg,
        x_pad=round_up(max(hyb.n_cols, 1), pad_mult))
    return PreparedHYB(light=light, heavy=heavy, n_rows=hyb.n_rows,
                       n_cols=hyb.n_cols)


def spmv_hyb_prepared(prep: PreparedHYB, x: jax.Array,
                      interpret: bool = True, semiring=None) -> jax.Array:
    y_light = spmv_ell_prepared(prep.light, x, interpret=interpret,
                                semiring=semiring)
    y_heavy = spmv_csr_seg_prepared(prep.heavy, x, interpret=interpret,
                                    semiring=semiring)
    if semiring is None or semiring.name == "plus_times":
        return y_light + y_heavy
    return semiring.add(y_light, y_heavy)


__all__ = [
    "ceil_div", "round_up",
    "PreparedDIA", "prepare_dia", "spmv_dia_prepared",
    "PreparedBELL", "prepare_bell", "spmv_bell_prepared",
    "PreparedELL", "prepare_ell", "spmv_ell_prepared",
    "ShardedELL", "prepare_ell_shards",
    "PaddedCSR", "prepare_csr", "spmv_csr_prepared",
    "PreparedSegCSR", "prepare_csr_seg", "spmv_csr_seg_prepared",
    "PreparedHYB", "prepare_hyb", "spmv_hyb_prepared",
]
