"""Pallas TPU kernels for the paper's compute hot spots.

  spmv_dia         banded SpMV (FD fast path): pure streaming, no gathers
  spmv_ell         fixed-width ELL: dense tiles, whole x pinned in VMEM
  spmv_csr         column-blocked CSR: x stripes pinned in VMEM (paper P2+P3)
  spmv_bell        blocked-ELL: data-dependent block-tile gathers (paper P3)
  _layout          shared host-side layout prep: `prepare_*` (run once,
                   at plan-compile time) + `spmv_*_prepared` (zero
                   matrix-side work per call)
  ops              per-call wrappers composing prepare + run, plus the
                   attention entry points
  flash_attention  causal + sliding-window (banded) attention
  paged_attention  decode over block-table KV (BELL pattern on the cache)

Validated with interpret=True on CPU against the jnp oracles in ref.py;
compiled by Mosaic on real TPUs.
"""
