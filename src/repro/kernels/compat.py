"""Version shims for the Pallas TPU API.

The `compiler_params` container class was renamed across jax releases
(`TPUCompilerParams` -> `CompilerParams`); resolve whichever the installed
jax provides so the kernels run on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
