"""Blocked-ELL SpMV Pallas kernel -- the TPU-native unstructured path.

Paper mapping: R-MAT's random x-gathers are the pathology (demand-miss
plateau, prefetcher shutoff).  On TPU a per-element gather would move a
full DMA tile per nonzero; instead we restructure the matrix into dense
(bm x bn) blocks so every "random access" fetches a *fully useful* 2-D x
tile, chosen by a scalar-prefetched block-column index -- the paper's P3
("let the kernel direct placement") as an index_map.

Layout:
  data       : (n_block_rows, blocks_per_row, bm, bn)  dense blocks
  block_cols : (n_block_rows, blocks_per_row) int32     scalar-prefetched
  x tiles    : (n_col_blocks, bn)
  y          : (n_block_rows, bm)

Grid = (n_block_rows, blocks_per_row); the x tile index_map dereferences
block_cols -- a data-dependent DMA schedule, which is exactly the
"prefetcher that can predict non-sequential accesses" the paper asks
hardware for (§V).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(bc_ref, data_ref, x_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    block = data_ref[0, 0]                       # (bm, bn)
    tile = x_ref[0, :]                           # (bn,)
    out_ref[0, :] += jax.lax.dot_general(
        block, tile[:, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_bell_pallas(data: jax.Array, block_cols: jax.Array, x: jax.Array,
                     interpret: bool = True) -> jax.Array:
    """y = A @ x for A in blocked-ELL layout.

    data       : (nbr, bpr, bm, bn)
    block_cols : (nbr, bpr) int32
    x          : (n_cols,) with n_cols a multiple of bn
    returns y  : (nbr * bm,)
    """
    nbr, bpr, bm, bn = data.shape
    assert x.shape[0] % bn == 0
    x_tiles = x.reshape(-1, bn)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nbr, bpr),
            in_specs=[
                pl.BlockSpec((1, 1, bm, bn), lambda b, k, bc: (b, k, 0, 0)),
                pl.BlockSpec((1, bn), lambda b, k, bc: (bc[b, k], 0)),
            ],
            out_specs=pl.BlockSpec((1, bm), lambda b, k, bc: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nbr, bm), data.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(block_cols.astype(jnp.int32), data, x_tiles)
    return out.reshape(-1)
