"""ELLPACK SpMV Pallas kernel -- fixed-width rows, VPU-friendly gathers.

ELL pads every row to `max_nnz` entries, so the kernel is a dense (bm, W)
elementwise multiply over a gathered x tile -- no row pointers, no
segment sum.  That regular shape is what makes ELL the natural middle
ground between DIA (pure streaming) and CSR (scalar-prefetch indirection):
the value/index arrays stream block by block (paper P1) while x stays
pinned in VMEM across the whole grid (paper P2), mirroring the
column-stripe pinning of `spmv_csr`.

Layout (host prep in ops.py):

  data : (B, bm, W)  f32   rows padded to bm row-blocks, W = max_nnz
  idx  : (B, bm, W)  int32 column per slot; padding points at col 0 with
                           data 0.0, so gathered garbage multiplies to 0
  x    : (1, n_pad)  f32   whole operand vector, block-constant -> pinned

Grid = (B,).  Each step gathers x at (bm * W) indices, multiplies by the
value tile, and row-sums into y's (1, bm) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams


def _kernel(data_ref, idx_ref, x_ref, y_ref, *, semiring=None):
    idx = idx_ref[0]                                       # (bm, W)
    flat = jnp.take(x_ref[0, :], idx.reshape(-1), axis=0)  # VMEM gather
    xg = flat.reshape(idx.shape)
    if semiring is None:                                   # plus-times
        y_ref[0, :] = (data_ref[0] * xg).sum(axis=1)
    else:
        # generalized inner loop: ⊗ elementwise, ⊕-reduce over slots.
        # Padding slots hold semiring.pad_value (absorbing), so they
        # vanish under the reduction exactly like 0.0 does under sum.
        y_ref[0, :] = semiring.reduce(semiring.mul(data_ref[0], xg), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "semiring"))
def spmv_ell_pallas(data: jax.Array, idx: jax.Array, x: jax.Array,
                    interpret: bool = True, semiring=None) -> jax.Array:
    """y = A (⊕,⊗) x for A in row-blocked ELL layout.

    data / idx : (B, bm, W)
    x          : (n_pad,) -- padded so every idx is in range
    semiring   : None or a `repro.graph.semiring.Semiring`; None (and
                 plus_times) takes the byte-identical historical path
    returns    : (B, bm)
    """
    if semiring is not None and semiring.name == "plus_times":
        semiring = None                 # one compiled path, bit-identical
    b_dim, bm, w = data.shape
    xp = x.reshape(1, -1)
    y = pl.pallas_call(
        functools.partial(_kernel, semiring=semiring),
        grid=(b_dim,),
        in_specs=[
            pl.BlockSpec((1, bm, w), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, bm, w), lambda b: (b, 0, 0)),
            # whole x pinned: block index constant across the grid
            pl.BlockSpec((1, xp.shape[1]), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((b_dim, bm), data.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
    )(data, idx, xp)
    return y
