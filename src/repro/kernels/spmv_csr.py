"""Column-blocked padded-CSR SpMV Pallas kernel -- the paper's P2+P3.

This is the software realization of the paper's proposed architecture fixes:
partition A into column stripes whose x slice fits VMEM, *pin* the slice
(P2: dedicate cache to x), and let the row-pointer metadata drive the DMA
schedule (P3: kernel-directed placement).  The matrix arrays stream exactly
once (P1: no cache to pollute).

Host-side prep (ops.py) pads each (row_block x stripe) cell to a fixed
nonzero count W so shapes are static:

  vals  : (S, B, W)  f32   padding value 0.0
  cols  : (S, B, W)  int32 stripe-rebased column, padding 0
  rowin : (S, B, W)  int32 row-within-block, padding 0

Grid = (S, B) with the stripe dimension OUTER so the x stripe block index is
constant across the inner sweep -- Mosaic keeps it resident in VMEM (the
"pin").  Each (s, b) cell writes a partial y block; a cheap dense reduction
over S finishes the sum (the y-spill term of core.traffic.col_blocked_policy).

In-kernel accumulation uses a one-hot matmul (rows x W @ W) so the segment
sum runs on the MXU instead of a scatter -- scatters don't exist in the TPU
memory model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(vals_ref, cols_ref, rowin_ref, x_ref, part_ref, *, bm,
            semiring=None):
    xg = jnp.take(x_ref[0, :], cols_ref[0, 0, :], axis=0)      # VMEM gather
    rows = rowin_ref[0, 0, :]                                  # (W,)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (bm, rows.shape[0]), 0)
              == rows[None, :])
    if semiring is None:                                       # plus-times
        prods = vals_ref[0, 0, :] * xg                         # (W,)
        part_ref[0, 0, :] = jax.lax.dot_general(
            onehot.astype(prods.dtype), prods[:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[:, 0].astype(part_ref.dtype)
    else:
        # generalized segment-⊕: min/max have no matmul form, so select
        # each row's slots with the same one-hot mask (identity
        # elsewhere) and ⊕-reduce on the VPU instead of the MXU.
        prods = semiring.mul(vals_ref[0, 0, :], xg)            # (W,)
        masked = jnp.where(onehot, prods[None, :],
                           jnp.asarray(semiring.identity, prods.dtype))
        part_ref[0, 0, :] = semiring.reduce(masked,
                                            axis=1).astype(part_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "semiring"))
def spmv_csr_pallas(vals: jax.Array, cols: jax.Array, rowin: jax.Array,
                    x_stripes: jax.Array, interpret: bool = True,
                    semiring=None) -> jax.Array:
    """Partial-product pass: returns (S, B, bm) partials; ⊕ over S outside.

    vals/cols/rowin : (S, B, W)
    x_stripes       : (S, stripe_w)
    semiring        : None or a `repro.graph.semiring.Semiring`; None
                      (and plus_times) takes the byte-identical
                      historical MXU one-hot path
    """
    if semiring is not None and semiring.name == "plus_times":
        semiring = None
    s_dim, b_dim, w = vals.shape
    bm = 128  # rows per block (fixed by ops.py prep)

    partials = pl.pallas_call(
        functools.partial(_kernel, bm=bm, semiring=semiring),
        grid=(s_dim, b_dim),
        in_specs=[
            pl.BlockSpec((1, 1, w), lambda s, b: (s, b, 0)),
            pl.BlockSpec((1, 1, w), lambda s, b: (s, b, 0)),
            pl.BlockSpec((1, 1, w), lambda s, b: (s, b, 0)),
            # stripe pinned: block index depends only on the OUTER dim
            pl.BlockSpec((1, x_stripes.shape[1]), lambda s, b: (s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bm), lambda s, b: (s, b, 0)),
        out_shape=jax.ShapeDtypeStruct((s_dim, b_dim, bm), vals.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(vals, cols, rowin, x_stripes)
    return partials
