"""Banded (DIA) SpMV Pallas kernel -- the FD fast path.

Paper mapping: FD matrices have three bands of three adjacent diagonals; the
x-window for a diagonal is a *contiguous* slice (Fig. 2's red-A pattern), so
the TPU realization is pure streaming: the grid walks row blocks, Mosaic
double-buffers the band and x tiles HBM->VMEM, and no gather ever happens.
This is proposal P1 (stream, don't cache) made structural.

Layout:
  band data : (n_diags, n)           one row per diagonal
  offsets   : (n_diags,) int32       scalar-prefetched; drives x index_map
  x padded  : (1, n + 2*halo)        zero halo so every window is in-range
  y         : (1, n)

Grid = (n/bn, n_diags); out block (1, bn) is revisited across the inner
(diagonal) dimension and accumulated in VMEM.  Misaligned windows are read
as two adjacent bn-blocks and shifted in-register (dynamic_slice), keeping
every HBM access block-aligned -- the DMA engine never sees a misaligned
request.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(offs_ref, band_ref, xlo_ref, xhi_ref, out_ref, *, halo, bn):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    off = offs_ref[j]
    rem = (off + halo) % bn          # block-internal shift (i*bn drops out)
    window2 = jnp.concatenate([xlo_ref[0, :], xhi_ref[0, :]], axis=0)
    window = jax.lax.dynamic_slice(window2, (rem,), (bn,))
    out_ref[0, :] += band_ref[0, :] * window


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def spmv_dia_pallas(band: jax.Array, offsets: jax.Array, x: jax.Array,
                    bn: int = 512, interpret: bool = True) -> jax.Array:
    """y = A @ x for A in DIA layout.

    band     : (n_diags, n) float -- band[k, i] = A[i, i + offsets[k]]
    offsets  : (n_diags,) int32
    x        : (n,) float
    """
    d, n = band.shape
    assert n % bn == 0, f"n={n} must be a multiple of bn={bn}"
    # halo covers the largest |offset|, rounded up to a block multiple
    halo_blocks = 1 + (n - 1) // bn          # offsets bounded by |off| < n
    halo = halo_blocks * bn
    xp = jnp.pad(x, (halo, halo)).reshape(1, -1)

    grid = (n // bn, d)

    def xlo_map(i, j, offs):
        return (0, (i * bn + offs[j] + halo) // bn)

    def xhi_map(i, j, offs):
        return (0, (i * bn + offs[j] + halo) // bn + 1)

    out = pl.pallas_call(
        functools.partial(_kernel, halo=halo, bn=bn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bn), lambda i, j, offs: (j, i)),   # band
                pl.BlockSpec((1, bn), xlo_map),                     # x low
                pl.BlockSpec((1, bn), xhi_map),                     # x high
            ],
            out_specs=pl.BlockSpec((1, bn), lambda i, j, offs: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, n), band.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(offsets.astype(jnp.int32), band, xp, xp)
    return out[0]
