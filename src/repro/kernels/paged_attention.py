"""Paged-attention decode Pallas kernel -- the BELL pattern applied to KV.

Serving keeps the KV cache as a pool of fixed-size blocks; a sequence's
cache is the list of block ids in its block table (serve/kv_blocks.py).
Decode attention must therefore gather KV through a data-dependent block
indirection -- structurally identical to blocked-ELL SpMV: the block table
is the block-column index array, the pool is the gathered operand, and the
scalar-prefetched index_map (paper P3: the kernel directs placement) turns
each "random access" into a fully-useful lane-aligned tile DMA.

Layout (one query token per sequence, GQA folded by the wrapper):
  q        : (B, H, hd)
  k_pool   : (n_blocks, block, KVH, hd)   physical pool
  v_pool   : (n_blocks, block, KVH, hd)
  tables   : (B, max_blocks) int32        physical block id per logical blk
  lengths  : (B,) int32                   tokens in each sequence
  out      : (B, H, hd)

Grid = (B, max_blocks): for each sequence the kernel walks its logical
blocks; the BlockSpec index_map dereferences tables[b, j] so the DMA
engine prefetches exactly the needed pool block (never the whole pool).
Flash-style online softmax accumulates across blocks in VMEM scratch;
positions >= length are masked.  Interpret-mode validated vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, block, n_blocks, scale):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    base = j * block
    # skip blocks entirely beyond the sequence (paper P1: never touch them)
    @pl.when(base < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # (H, hd)
        k = k_ref[0].astype(jnp.float32)               # (H, block, hd)
        v = v_ref[0].astype(jnp.float32)
        # per-head scores: batched dot over H -> (H, block)
        s = jax.lax.dot_general(
            q, k, (((1,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        # mask positions past the sequence length
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # (H, block)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            l_scr[:, :1] * corr + p.sum(axis=1, keepdims=True), l_scr.shape)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)         # (H, hd)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = jnp.where(l == 0.0, 0.0,
                             acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(q, k_pool, v_pool, tables, lengths,
                           interpret: bool = True):
    """q: (B, H, hd); pools: (n_blocks, block, H, hd) (GQA pre-broadcast);
    tables: (B, max_blocks) int32; lengths: (B,) int32 -> (B, H, hd)."""
    bsz, h, hd = q.shape
    _, block, hp, _ = k_pool.shape
    assert hp == h, "wrapper must broadcast KV heads to query heads"
    max_blocks = tables.shape[1]
    scale = 1.0 / (hd ** 0.5)

    # pool laid out (n_blocks, H, block, hd) so the kernel sees (block..)
    kp = jnp.swapaxes(k_pool, 1, 2)     # (n_blocks, H, block, hd)
    vp = jnp.swapaxes(v_pool, 1, 2)

    grid = (bsz, max_blocks)

    out = pl.pallas_call(
        functools.partial(_kernel, block=block, n_blocks=max_blocks,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # tables, lengths
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, h, hd), lambda b, j, tbl, ln: (b, 0, 0)),
                # the BELL move: block index derefs the table (paper P3)
                pl.BlockSpec((1, h, block, hd),
                             lambda b, j, tbl, ln: (tbl[b, j], 0, 0, 0)),
                pl.BlockSpec((1, h, block, hd),
                             lambda b, j, tbl, ln: (tbl[b, j], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, h, hd),
                                   lambda b, j, tbl, ln: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((h, 128), jnp.float32),
                pltpu.VMEM((h, 128), jnp.float32),
                pltpu.VMEM((h, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, h, hd), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, kp, vp)
    return out
