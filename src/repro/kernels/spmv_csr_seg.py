"""Segmented (merge-style) CSR SpMV Pallas kernel -- nnz-balanced grid.

The row-blocked kernels (`spmv_csr`, `spmv_ell`) partition work by ROWS,
so a power-law matrix hands one grid step a 4000-nonzero hub row and its
neighbor eight -- the load-imbalance half of the paper's R-MAT penalty.
This kernel partitions the FLAT nonzero stream instead (Bergmans et al.'s
merge-based CSR, PAPERS.md): every grid step owns exactly `seg_len`
nonzeros regardless of how rows fall, and rows that straddle a segment
boundary are finished by a carry-out merge after the grid.

Layout (host prep in `_layout.prepare_csr_seg`):

  vals : (S, L)  f32   flat row-major nonzero stream cut into S segments,
                       padded with the semiring's absorbing element
  cols : (S, L)  int32 column per nonzero, padding 0
  rid  : (S, L)  int32 LOCAL row rank within the segment (dense: 0..R-1 in
                       stream order), padding R-1
  x    : (1, n_pad)    whole operand vector, block-constant -> pinned

Ranks are per-segment-dense rather than row offsets so the partial window
R is bounded by L even when empty rows interleave; `row_ids[s, r]` (host
side) maps rank r back to the global row, with pad ranks parked on a
dummy row n_rows.  A row crossing segments s and s+1 appears as the last
rank of s and rank 0 of s+1; the host-side segment-⊕ over `row_ids` is
the merge that stitches those partials back together.

In-segment accumulation reuses the one-hot matmul trick of `spmv_csr`
(segment sum on the MXU; TPU has no scatter), or a masked ⊕-reduce on the
VPU for non-plus-times semirings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams


def _kernel(vals_ref, cols_ref, rid_ref, x_ref, part_ref, *, rwin,
            semiring=None):
    xg = jnp.take(x_ref[0, :], cols_ref[0, :], axis=0)         # VMEM gather
    ranks = rid_ref[0, :]                                      # (L,)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (rwin, ranks.shape[0]), 0)
              == ranks[None, :])
    if semiring is None:                                       # plus-times
        prods = vals_ref[0, :] * xg                            # (L,)
        part_ref[0, :] = jax.lax.dot_general(
            onehot.astype(prods.dtype), prods[:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[:, 0].astype(part_ref.dtype)
    else:
        # generalized segment-⊕: mask each rank's slots (identity
        # elsewhere) and ⊕-reduce on the VPU; absorbing pad slots
        # contribute the identity wherever their rank lands.
        prods = semiring.mul(vals_ref[0, :], xg)               # (L,)
        masked = jnp.where(onehot, prods[None, :],
                           jnp.asarray(semiring.identity, prods.dtype))
        part_ref[0, :] = semiring.reduce(masked,
                                         axis=1).astype(part_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rwin", "interpret", "semiring"))
def spmv_csr_seg_pallas(vals: jax.Array, cols: jax.Array, rid: jax.Array,
                        x: jax.Array, rwin: int, interpret: bool = True,
                        semiring=None) -> jax.Array:
    """Partial pass: returns (S, rwin) per-segment rank partials.

    vals/cols/rid : (S, L) -- equal-nnz segments of the flat stream
    x             : (n_pad,) padded so every col index is in range
    rwin          : static rank-window width (max distinct rows touched by
                    any one segment, rounded up to a lane multiple)
    semiring      : None or a `repro.graph.semiring.Semiring`; None (and
                    plus_times) takes the byte-identical MXU one-hot path

    The caller finishes with a segment-⊕ of the partials at
    `row_ids[s, r]` -- the carry-out merge across segment boundaries.
    """
    if semiring is not None and semiring.name == "plus_times":
        semiring = None                 # one compiled path, bit-identical
    s_dim, seg_len = vals.shape
    xp = x.reshape(1, -1)
    partials = pl.pallas_call(
        functools.partial(_kernel, rwin=rwin, semiring=semiring),
        grid=(s_dim,),
        in_specs=[
            pl.BlockSpec((1, seg_len), lambda s: (s, 0)),
            pl.BlockSpec((1, seg_len), lambda s: (s, 0)),
            pl.BlockSpec((1, seg_len), lambda s: (s, 0)),
            # whole x pinned: block index constant across the grid
            pl.BlockSpec((1, xp.shape[1]), lambda s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rwin), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((s_dim, rwin), vals.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
    )(vals, cols, rid, xp)
    return partials
