"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function computes the same mathematical result as its kernel without
Pallas, so tests can `assert_allclose(kernel(...), ref(...))` across shape
and dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# SpMV oracles are the format-level jnp implementations in core.spmv
from repro.core.spmv import (  # noqa: F401  (re-exported oracles)
    spmv_bell_jnp,
    spmv_csr_jnp,
    spmv_dia_jnp,
    spmv_ell_jnp,
)


def spmv_dia_ref(band: jax.Array, offsets: jax.Array, x: jax.Array
                 ) -> jax.Array:
    """y[i] = sum_k band[k, i] * x[i + offsets[k]] (zero outside range)."""
    n = band.shape[1]
    xp = jnp.pad(x, (n, n))

    def one(bk, off):
        return bk * jax.lax.dynamic_slice(xp, (n + off,), (n,))

    return jax.vmap(one)(band, offsets).sum(axis=0)


def spmv_bell_ref(data: jax.Array, block_cols: jax.Array, x: jax.Array
                  ) -> jax.Array:
    nbr, bpr, bm, bn = data.shape
    x_tiles = x.reshape(-1, bn)
    gathered = jnp.take(x_tiles, block_cols, axis=0)     # (nbr, bpr, bn)
    y = jnp.einsum("rkmn,rkn->rm", data.astype(jnp.float32),
                   gathered.astype(jnp.float32))
    return y.reshape(-1).astype(data.dtype)


def spmv_csr_padded_ref(vals: jax.Array, cols: jax.Array, rowin: jax.Array,
                        x_stripes: jax.Array) -> jax.Array:
    """Oracle for the padded column-blocked layout: (S,B,W) -> (B*bm,)."""
    s_dim, b_dim, w = vals.shape
    bm = 128
    xg = jax.vmap(lambda c, xs: jnp.take(xs, c, axis=0),
                  in_axes=(0, 0))(cols.reshape(s_dim, -1),
                                  x_stripes)             # (S, B*W)
    prods = vals.reshape(s_dim, -1) * xg                 # (S, B*W)
    prods = prods.reshape(s_dim, b_dim, w)
    seg = jax.nn.one_hot(rowin, bm, dtype=prods.dtype)   # (S, B, W, bm)
    y = jnp.einsum("sbw,sbwm->bm", prods, seg)
    return y.reshape(-1)


def paged_attention_ref(q, k_pool, v_pool, tables, lengths):
    """Oracle for the paged decode kernel.

    q: (B, H, hd); pools: (n_blocks, block, H, hd);
    tables: (B, max_blocks); lengths: (B,) -> (B, H, hd)."""
    bsz, h, hd = q.shape
    block = k_pool.shape[1]
    max_blocks = tables.shape[1]
    kb = jnp.take(k_pool, tables, axis=0)      # (B, mb, blk, H, hd)
    vb = jnp.take(v_pool, tables, axis=0)
    kf = kb.reshape(bsz, max_blocks * block, h, hd).astype(jnp.float32)
    vf = vb.reshape(bsz, max_blocks * block, h, hd).astype(jnp.float32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf)
    s = s / (hd ** 0.5)
    pos = jnp.arange(max_blocks * block)[None, None, :]
    s = jnp.where(pos < lengths[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, vf).astype(q.dtype)


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array,
            causal: bool = True, window: int | None = None) -> jax.Array:
    """Masked softmax attention oracle. q:(bh,sq,d) k/v:(bh,skv,d)."""
    sq, skv = q.shape[1], k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_idx = jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows that are fully masked produce uniform softmax over -1e30; zero them
    any_valid = mask.any(axis=1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)
