"""Learned cost model: structural features -> predicted SpMV throughput.

The paper's thesis is that matrix *structure* determines SpMV
performance; SpChar and Mpakos et al. (PAPERS.md) show a handful of
structural characteristics predict throughput well enough to *rank*
execution choices.  This module operationalizes that for the plan
compiler: a small gradient-boosted ensemble of regression trees (pure
numpy -- no new dependency) maps a candidate's `structure.analyze`
report plus geometry/thread-count to predicted contended-LLC throughput
(log2 GFLOPS), so `plan.compile` can score (format, reordering)
candidates in microseconds instead of replaying full address traces
through the cache simulator.

The replay predictor stays as the *oracle*: it labels the training
corpus (`run_label_cell` mirrors `compiler._predict`'s replay branch
bit-for-bit) and remains the fallback scoring mode when no model is
loaded (`plan.compile(predictor='oracle')`).

Everything here is deterministic: exact greedy splits with fixed
tie-breaks (first feature, first threshold), stable sorts, float64
prefix sums -- refitting from the checked-in corpus reproduces the
shipped model byte-for-byte (`model_bytes` / `model_digest`, compared
in CI's `costmodel` job).

Training pipeline (the CLI):

    python -m repro.plan.costmodel --harvest --corpus corpus.json \
        --ckpt /tmp/labels            # replay-label the sweep grid
    python -m repro.plan.costmodel --fit --corpus corpus.json \
        --out src/repro/plan/_data/costmodel   # deterministic refit
    python -m repro.plan.costmodel --eval --corpus corpus.json
    python -m repro.plan.costmodel --check --corpus corpus.json
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import msgpack
import numpy as np

from repro.core.cache_model import SANDY_BRIDGE, MachineModel
from repro.core.structure import StructureReport

_VERSION = 1

# ---------------------------------------------------------------------------
# Features: one vector per (candidate structure, geometry, thread count)
# ---------------------------------------------------------------------------
# Counts and byte sizes enter as log2(v + 1) so trees split on orders of
# magnitude; the locality fractions and nnz/row dispersion enter raw.
# The candidate's *permuted* report is featurized -- the model scores
# exactly the stream the chosen format will exploit, the same contract
# the replay oracle has.

FEATURE_NAMES: Tuple[str, ...] = (
    "log2_rows", "log2_nnz", "avg_nnz_per_row", "row_nnz_cv",
    "log2_bandwidth", "log2_bandwidth_p95", "log2_distinct_offsets",
    "log2_band_groups", "spatial_locality", "temporal_locality",
    "stream_servable", "block_density_8x128",
    "kind_banded", "kind_blocked", "kind_unstructured",
    "log2_threads", "log2_nnz_per_thread",
    "log2_l2_bytes", "log2_llc_bytes",
)


def _lg(v) -> float:
    return math.log2(max(float(v), 0.0) + 1.0)


def features_for(report: StructureReport, threads: int = 1, *,
                 l2_bytes: Optional[int] = None,
                 llc_bytes: Optional[int] = None,
                 machine: MachineModel = SANDY_BRIDGE) -> np.ndarray:
    """Feature vector (float64, `FEATURE_NAMES` order) for one candidate.

    `l2_bytes`/`llc_bytes` take the simulated geometry when the caller
    scores a scaled cell (`ParallelSpec(l2_bytes=..., llc_bytes=...)`);
    `None` falls back to the machine's real private-L2 / shared-L3 sizes,
    matching `ParallelSpec`'s own defaulting.
    """
    t = max(int(threads), 1)
    l2 = float(l2_bytes) if l2_bytes else float(machine.l2_bytes)
    llc = float(llc_bytes) if llc_bytes else float(machine.l3_bytes)
    return np.array([
        _lg(report.n_rows), _lg(report.nnz),
        float(report.avg_nnz_per_row), float(report.row_nnz_cv),
        _lg(report.bandwidth), _lg(report.bandwidth_p95),
        _lg(report.n_distinct_offsets), _lg(report.n_band_groups),
        float(report.spatial_locality), float(report.temporal_locality),
        float(report.stream_servable), float(report.block_density_8x128),
        1.0 if report.kind == "banded" else 0.0,
        1.0 if report.kind == "blocked" else 0.0,
        1.0 if report.kind == "unstructured" else 0.0,
        math.log2(t), _lg(report.nnz / t),
        math.log2(l2), math.log2(llc),
    ], dtype=np.float64)


# ---------------------------------------------------------------------------
# Regression trees + gradient boosting (numpy, exact greedy, deterministic)
# ---------------------------------------------------------------------------

DEFAULT_CONFIG: Dict[str, float] = {
    "n_trees": 150, "max_depth": 3, "learning_rate": 0.1,
    "min_leaf": 2, "seed": 0,
}


@dataclasses.dataclass(frozen=True)
class _Tree:
    """One regression tree as parallel node arrays (feat < 0 marks a
    leaf; children index into the same arrays)."""

    feat: np.ndarray      # int32 (n_nodes,)
    thresh: np.ndarray    # float64
    left: np.ndarray      # int32
    right: np.ndarray     # int32
    value: np.ndarray     # float64

    def predict(self, X: np.ndarray) -> np.ndarray:
        node = np.zeros(X.shape[0], dtype=np.int32)
        for _ in range(64):                      # depth-bounded walk
            f = self.feat[node]
            active = f >= 0
            if not active.any():
                break
            rows = np.nonzero(active)[0]
            goes_left = X[rows, f[rows]] <= self.thresh[node[rows]]
            nxt = np.where(goes_left, self.left[node[rows]],
                           self.right[node[rows]])
            node = node.copy()
            node[rows] = nxt
        return self.value[node]


def _fit_tree(X: np.ndarray, y: np.ndarray, max_depth: int,
              min_leaf: int) -> _Tree:
    """Exact greedy least-squares tree.  Deterministic: features scanned
    in index order, stable sorts, a split must *strictly* beat the
    incumbent (first feature / first threshold wins ties)."""
    nodes: List[Tuple[int, float, int, int, float]] = []

    def build(idx: np.ndarray, depth: int) -> int:
        i = len(nodes)
        nodes.append((-1, 0.0, -1, -1, 0.0))     # placeholder
        ysub = y[idx]
        val = float(ysub.mean())
        best = None                              # (gain, feat, thr, lidx, ridx)
        if depth < max_depth and idx.size >= 2 * min_leaf:
            sse_parent = float(((ysub - val) ** 2).sum())
            n = idx.size
            for f in range(X.shape[1]):
                xs = X[idx, f]
                order = np.argsort(xs, kind="stable")
                xo, yo = xs[order], ysub[order]
                csum = np.cumsum(yo)
                csq = np.cumsum(yo * yo)
                p = np.arange(1, n)
                valid = (xo[1:] != xo[:-1]) & (p >= min_leaf) \
                    & (n - p >= min_leaf)
                if not valid.any():
                    continue
                pl = p[valid]
                nl = pl.astype(np.float64)
                nr = float(n) - nl
                sl, sql = csum[pl - 1], csq[pl - 1]
                sse = (sql - sl * sl / nl) \
                    + ((csq[-1] - sql) - (csum[-1] - sl) ** 2 / nr)
                j = int(np.argmin(sse))          # first minimum wins
                gain = sse_parent - float(sse[j])
                if gain > 1e-12 and (best is None or gain > best[0] + 1e-12):
                    cut = int(pl[j])
                    thr = 0.5 * (float(xo[cut - 1]) + float(xo[cut]))
                    best = (gain, f, thr, idx[order[:cut]], idx[order[cut:]])
            del n
        if best is None:
            nodes[i] = (-1, 0.0, -1, -1, val)
        else:
            _, f, thr, lidx, ridx = best
            lchild = build(lidx, depth + 1)
            rchild = build(ridx, depth + 1)
            nodes[i] = (f, thr, lchild, rchild, val)
        return i

    build(np.arange(y.shape[0]), 0)
    feat, thr, left, right, value = zip(*nodes)
    return _Tree(feat=np.asarray(feat, np.int32),
                 thresh=np.asarray(thr, np.float64),
                 left=np.asarray(left, np.int32),
                 right=np.asarray(right, np.int32),
                 value=np.asarray(value, np.float64))


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Gradient-boosted ensemble over `FEATURE_NAMES`, predicting
    log2(GFLOPS) of the contended-LLC replay oracle."""

    base: float
    learning_rate: float
    trees: Tuple[_Tree, ...]
    feature_names: Tuple[str, ...] = FEATURE_NAMES
    config: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_CONFIG))
    meta: Mapping[str, object] = dataclasses.field(default_factory=dict)

    def predict(self, X) -> np.ndarray:
        """log2-GFLOPS predictions for feature rows `X` (n, n_features)."""
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        if X.shape[1] != len(self.feature_names):
            raise ValueError(
                f"feature mismatch: model wants {len(self.feature_names)} "
                f"features, got {X.shape[1]}")
        out = np.full(X.shape[0], self.base, dtype=np.float64)
        for t in self.trees:
            out += self.learning_rate * t.predict(X)
        return out

    def predict_gflops(self, report: StructureReport, threads: int = 1, *,
                       l2_bytes: Optional[int] = None,
                       llc_bytes: Optional[int] = None,
                       machine: MachineModel = SANDY_BRIDGE) -> float:
        """Predicted throughput for one candidate structure (the
        `plan.compile` fast-path entry)."""
        f = features_for(report, threads, l2_bytes=l2_bytes,
                         llc_bytes=llc_bytes, machine=machine)
        return float(2.0 ** self.predict(f[None, :])[0])


def fit(rows: Sequence["LabelPoint"],
        config: Optional[Mapping[str, float]] = None) -> CostModel:
    """Deterministic refit from a label corpus.  The label is
    log2(GFLOPS): multiplicative throughput error is what candidate
    *ranking* cares about, and the margin rule operates on ratios."""
    cfg = dict(DEFAULT_CONFIG)
    if config:
        cfg.update(config)
    if not rows:
        raise ValueError("empty corpus")
    X = np.asarray([r.features for r in rows], dtype=np.float64)
    if X.shape[1] != len(FEATURE_NAMES):
        raise ValueError(
            f"corpus features have width {X.shape[1]}, expected "
            f"{len(FEATURE_NAMES)} (stale corpus? re-run --harvest)")
    y = np.log2(np.maximum([r.gflops for r in rows], 1e-12))
    base = float(y.mean())
    pred = np.full(y.shape, base)
    trees: List[_Tree] = []
    for _ in range(int(cfg["n_trees"])):
        t = _fit_tree(X, y - pred, int(cfg["max_depth"]),
                      int(cfg["min_leaf"]))
        pred += float(cfg["learning_rate"]) * t.predict(X)
        trees.append(t)
    meta = {"n_rows": len(rows), "corpus_digest": corpus_digest(rows),
            "label": "log2_gflops"}
    return CostModel(base=base, learning_rate=float(cfg["learning_rate"]),
                     trees=tuple(trees), feature_names=FEATURE_NAMES,
                     config=cfg, meta=meta)


# ---------------------------------------------------------------------------
# Canonical bytes + digest (what CI byte-compares)
# ---------------------------------------------------------------------------


def model_bytes(model: CostModel) -> bytes:
    """Canonical msgpack encoding (fixed key order, float64 exact) --
    stable across processes and platforms, unlike a checkpoint
    directory's on-disk layout."""
    payload = {
        "version": _VERSION,
        "feature_names": list(model.feature_names),
        "config": [[k, model.config[k]] for k in sorted(model.config)],
        "base": float(model.base),
        "learning_rate": float(model.learning_rate),
        "meta": [[k, model.meta[k]] for k in sorted(model.meta)],
        "trees": [{
            "feat": t.feat.tolist(), "thresh": t.thresh.tolist(),
            "left": t.left.tolist(), "right": t.right.tolist(),
            "value": t.value.tolist(),
        } for t in model.trees],
    }
    return msgpack.packb(payload)


def model_digest(model: CostModel) -> str:
    return hashlib.blake2b(model_bytes(model), digest_size=16).hexdigest()


# ---------------------------------------------------------------------------
# Labeling: replay-oracle corpus rows through the sweep runner
# ---------------------------------------------------------------------------

# Simulated-geometry axis for label cells (a `SweepCell` carries the
# label in its free `mechanism` field; `SweepCell` fields are pinned by
# the resume contract, so the spec rides an existing axis).
LABEL_SPECS: Dict[str, Dict[str, Optional[int]]] = {
    "default": {"l2_bytes": None, "llc_bytes": None},
    "scaled": {"l2_bytes": 16 * 1024, "llc_bytes": 64 * 1024},
}

LABEL_KINDS = ("banded", "fd", "rmat", "scrambled", "uniform")


@dataclasses.dataclass(frozen=True)
class LabelPoint:
    """One labeled corpus row: the feature vector of a (matrix, reorder,
    threads, geometry) candidate and its replay-oracle throughput."""

    kind: str
    log2n: int
    seed: int
    reorder: str
    threads: int
    spec: str                     # LABEL_SPECS key
    nnz: int
    gflops: float                 # ParallelMetrics.gflops_est() (the label)
    time_s: float
    features: Tuple[float, ...]   # FEATURE_NAMES order


def label_matrix(kind: str, n: int, seed: int):
    """Deterministic matrix for a label cell.  'scrambled' is a banded
    matrix under a random symmetric permutation -- the case where RCM
    recovers the band and reordering genuinely wins."""
    from repro.core.generators import (banded_matrix, fd_matrix, rmat_matrix,
                                       uniform_random_matrix)

    if kind == "fd":
        return fd_matrix(n, seed=seed)
    if kind == "rmat":
        return rmat_matrix(n, seed=seed)
    if kind == "uniform":
        return uniform_random_matrix(n, seed=seed)
    if kind in ("banded", "scrambled"):
        csr = banded_matrix(n, bandwidth=max(8, n // 32), seed=seed)
        if kind == "banded":
            return csr
        from repro.reorder import Reordering

        perm = np.random.default_rng(seed + 9173).permutation(n) \
            .astype(np.int64)
        scramble = Reordering(row_perm=perm, col_perm=perm,
                              strategy="scramble", params={}, stats={})
        return scramble.apply(csr)
    raise ValueError(f"unknown label kind {kind!r}")


def run_label_cell(kind: str, log2n: int, reorder: str, threads: int,
                   spec_label: str = "scaled", *,
                   machine: MachineModel = SANDY_BRIDGE, seed: int = 0,
                   sweeps: int = 2) -> LabelPoint:
    """Execute one label cell (pure, deterministic): permute, featurize
    the permuted structure, replay the permuted stream.  This mirrors
    `plan.compiler._predict`'s replay branch exactly, so the corpus
    labels are the same numbers `predictor='replay'` would score."""
    from repro.core import structure
    from repro.core.partition import rowblock_balanced
    from repro.parallel import ParallelSpec, simulate_parallel
    from repro.reorder import STRATEGIES

    geo = LABEL_SPECS[spec_label]
    spec = ParallelSpec(l2_bytes=geo["l2_bytes"], llc_bytes=geo["llc_bytes"])
    csr = label_matrix(kind, 2 ** log2n, seed)
    r = STRATEGIES[reorder](csr) if reorder != "none" else None
    perm = r.apply(csr) if r is not None else csr
    rep = structure.analyze(perm)
    feats = features_for(rep, threads, l2_bytes=geo["l2_bytes"],
                         llc_bytes=geo["llc_bytes"], machine=machine)
    part = rowblock_balanced(perm, threads)
    _, m = simulate_parallel(perm, part, machine, spec, sweeps=sweeps)
    return LabelPoint(kind=kind, log2n=int(log2n), seed=int(seed),
                      reorder=reorder, threads=int(threads), spec=spec_label,
                      nnz=int(perm.nnz), gflops=float(m.gflops_est()),
                      time_s=float(m.time_s),
                      features=tuple(float(v) for v in feats))


def label_cells(kinds: Sequence[str] = LABEL_KINDS,
                log2ns: Sequence[int] = (8, 9, 10),
                threads_list: Sequence[int] = (1, 2, 4, 8),
                reorders: Sequence[str] = ("none", "rcm"),
                specs: Sequence[str] = ("default", "scaled")) -> List:
    """The label grid as runner `SweepCell`s (sweep='label'; the spec
    label rides the free `mechanism` field).  Seeds are not a cell axis:
    they come from `SweepConfig.seed`, one `execute_cells` pass per seed."""
    from repro.telemetry.runner import SweepCell, sort_cells

    return sort_cells([
        SweepCell(sweep="label", kind=k, log2n=int(n), reorder=r,
                  threads=int(t), mechanism=s)
        for k in kinds for n in log2ns for r in reorders
        for t in threads_list for s in specs])


def harvest(kinds: Sequence[str] = LABEL_KINDS,
            log2ns: Sequence[int] = (8, 9, 10),
            threads_list: Sequence[int] = (1, 2, 4, 8),
            reorders: Sequence[str] = ("none", "rcm"),
            specs: Sequence[str] = ("default", "scaled"),
            seeds: Sequence[int] = (0, 1, 2),
            workers: int = 1, ckpt_dir: Optional[str] = None,
            sweeps: int = 2) -> List[LabelPoint]:
    """Replay-label the grid through the sharded resumable runner, one
    checkpointed pass per seed (`ckpt_dir/seed<N>` -- a seed is config,
    not a cell axis, so each seed gets its own resume domain)."""
    from repro.telemetry.runner import SweepConfig, execute_cells

    cells = label_cells(kinds, log2ns, threads_list, reorders, specs)
    rows: List[LabelPoint] = []
    for seed in seeds:
        cfg = SweepConfig(seed=int(seed), sweeps=sweeps)
        sub = os.path.join(ckpt_dir, f"seed{seed}") if ckpt_dir else None
        rows.extend(execute_cells(cells, cfg, workers=workers,
                                  ckpt_dir=sub))
    return sort_rows(rows)


# ---------------------------------------------------------------------------
# Corpus I/O: canonical JSON (exact float round-trip, sorted keys)
# ---------------------------------------------------------------------------


def sort_rows(rows: Sequence[LabelPoint]) -> List[LabelPoint]:
    return sorted(rows, key=lambda r: (r.kind, r.log2n, r.seed, r.spec,
                                       r.reorder, r.threads))


def save_corpus(rows: Sequence[LabelPoint], path: str) -> None:
    doc = {"version": _VERSION, "feature_names": list(FEATURE_NAMES),
           "rows": [dataclasses.asdict(r) for r in sort_rows(rows)]}
    blob = json.dumps(doc, sort_keys=True, indent=1)
    with open(path, "w") as f:
        f.write(blob + "\n")


def load_corpus(path: str) -> List[LabelPoint]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != _VERSION:
        raise ValueError(f"unknown corpus version {doc.get('version')!r}")
    names = tuple(doc.get("feature_names", ()))
    if names != FEATURE_NAMES:
        raise ValueError(
            "corpus feature names do not match this build's FEATURE_NAMES; "
            "re-run --harvest")
    return [LabelPoint(kind=d["kind"], log2n=int(d["log2n"]),
                       seed=int(d["seed"]), reorder=d["reorder"],
                       threads=int(d["threads"]), spec=d["spec"],
                       nnz=int(d["nnz"]), gflops=float(d["gflops"]),
                       time_s=float(d["time_s"]),
                       features=tuple(float(v) for v in d["features"]))
            for d in doc["rows"]]


def corpus_digest(rows: Sequence[LabelPoint]) -> str:
    blob = json.dumps([dataclasses.asdict(r) for r in sort_rows(rows)],
                      sort_keys=True).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


# ---------------------------------------------------------------------------
# Evaluation: does the model pick the replay winner?
# ---------------------------------------------------------------------------


def pick_winner(scores: Mapping[str, float]) -> str:
    """The compiler's candidate-selection rule over reorder labels:
    sorted order, strict > to displace, and a reordered winner must beat
    'none' by `REORDER_MARGIN` (transport overhead bar)."""
    from .compiler import REORDER_MARGIN

    ordered = sorted(scores)
    chosen = ordered[0]
    for lab in ordered[1:]:
        if scores[lab] > scores[chosen]:
            chosen = lab
    if chosen != "none" and "none" in scores:
        if scores[chosen] <= scores["none"] * (1.0 + REORDER_MARGIN):
            chosen = "none"
    return chosen


def evaluate(model: CostModel, rows: Sequence[LabelPoint]) -> Dict:
    """Agreement of model-picked vs replay-picked reordering per cell
    group (kind, log2n, seed, spec, threads), plus regression quality."""
    X = np.asarray([r.features for r in rows], dtype=np.float64)
    y = np.log2(np.maximum([r.gflops for r in rows], 1e-12))
    yhat = model.predict(X)
    groups: Dict[Tuple, Dict[str, Tuple[float, float]]] = {}
    for r, t, p in zip(rows, y, yhat):
        gk = (r.kind, r.log2n, r.seed, r.spec, r.threads)
        groups.setdefault(gk, {})[r.reorder] = (2.0 ** t, 2.0 ** p)
    n_groups = agree = 0
    by_kind: Dict[str, List[int]] = {}
    for gk, cand in groups.items():
        if len(cand) < 2:
            continue
        n_groups += 1
        w_true = pick_winner({k: v[0] for k, v in cand.items()})
        w_pred = pick_winner({k: v[1] for k, v in cand.items()})
        ok = int(w_true == w_pred)
        agree += ok
        by_kind.setdefault(gk[0], []).append(ok)
    resid = y - yhat
    ss_res = float((resid ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    return {
        "n_rows": len(rows), "n_groups": n_groups,
        "agreement": agree / n_groups if n_groups else 1.0,
        "mae_log2": float(np.abs(resid).mean()),
        "r2": 1.0 - ss_res / ss_tot if ss_tot else 1.0,
        "by_kind": {k: sum(v) / len(v) for k, v in sorted(by_kind.items())},
    }


# ---------------------------------------------------------------------------
# The shipped default model (what `plan.compile(predictor='auto')` uses)
# ---------------------------------------------------------------------------

DEFAULT_MODEL_DIR = os.path.join(os.path.dirname(__file__), "_data",
                                 "costmodel")
_UNSET = object()
_default_model = _UNSET


def default_model() -> Optional[CostModel]:
    """The in-repo pretrained model, loaded lazily once per process
    (None when no artifact ships / loading fails -- callers fall back to
    the replay oracle)."""
    global _default_model
    if _default_model is _UNSET:
        try:
            from .serial import load_model

            _default_model = load_model(DEFAULT_MODEL_DIR)[0]
        except Exception:
            _default_model = None
    return _default_model


def set_default_model(model: Optional[CostModel]):
    """Swap the process default (tests use this to force fallback or pin
    a fixture model).  Returns the previous value; pass the sentinel-free
    previous value back to restore."""
    global _default_model
    prev = None if _default_model is _UNSET else _default_model
    _default_model = model
    return prev


# ---------------------------------------------------------------------------
# CLI: harvest / fit / eval / check
# ---------------------------------------------------------------------------


def _int_list(s: str) -> List[int]:
    return [int(v) for v in s.split(",") if v]


def _str_list(s: str) -> List[str]:
    return [v for v in s.split(",") if v]


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="learned plan-compiler cost model: harvest replay "
                    "labels, fit, evaluate, or verify the shipped artifact")
    ap.add_argument("--harvest", action="store_true",
                    help="replay-label the grid into --corpus")
    ap.add_argument("--fit", action="store_true",
                    help="deterministic refit from --corpus into --out")
    ap.add_argument("--eval", action="store_true",
                    help="agreement/regression metrics of --model on --corpus")
    ap.add_argument("--check", action="store_true",
                    help="refit from --corpus and byte-compare against the "
                         "shipped artifact (exit 1 on drift)")
    ap.add_argument("--corpus", default=os.path.join(
        os.path.dirname(__file__), "_data", "costmodel_corpus.json"))
    ap.add_argument("--out", default=DEFAULT_MODEL_DIR,
                    help="checkpoint directory the fitted model is saved to")
    ap.add_argument("--model", default=DEFAULT_MODEL_DIR,
                    help="checkpoint directory --eval loads from")
    ap.add_argument("--kinds", default=",".join(LABEL_KINDS))
    ap.add_argument("--log2ns", default="8,9,10")
    ap.add_argument("--threads", default="1,2,4,8")
    ap.add_argument("--reorders", default="none,rcm")
    ap.add_argument("--specs", default="default,scaled")
    ap.add_argument("--seeds", default="0,1,2")
    ap.add_argument("--sweeps", type=int, default=2)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--ckpt", default=None,
                    help="harvest checkpoint directory (resumable)")
    args = ap.parse_args(argv)

    if not (args.harvest or args.fit or args.eval or args.check):
        ap.error("pick at least one of --harvest/--fit/--eval/--check")

    if args.harvest:
        rows = harvest(kinds=_str_list(args.kinds),
                       log2ns=_int_list(args.log2ns),
                       threads_list=_int_list(args.threads),
                       reorders=_str_list(args.reorders),
                       specs=_str_list(args.specs),
                       seeds=_int_list(args.seeds),
                       workers=args.workers, ckpt_dir=args.ckpt,
                       sweeps=args.sweeps)
        save_corpus(rows, args.corpus)
        print(f"[costmodel] harvested {len(rows)} rows -> {args.corpus} "
              f"(digest {corpus_digest(rows)})")

    if args.fit:
        from .serial import save_model

        rows = load_corpus(args.corpus)
        model = fit(rows)
        save_model(model, args.out)
        print(f"[costmodel] fit {len(model.trees)} trees on {len(rows)} "
              f"rows -> {args.out} (digest {model_digest(model)})")

    if args.eval:
        from .serial import load_model

        rows = load_corpus(args.corpus)
        model, _ = load_model(args.model)
        m = evaluate(model, rows)
        print(f"[costmodel] eval on {m['n_rows']} rows / {m['n_groups']} "
              f"cells: agreement={m['agreement']:.3f} "
              f"mae_log2={m['mae_log2']:.4f} r2={m['r2']:.4f}")
        for kind, rate in m["by_kind"].items():
            print(f"[costmodel]   {kind}: agreement={rate:.3f}")

    if args.check:
        from .serial import load_model

        rows = load_corpus(args.corpus)
        refit = fit(rows)
        shipped, _ = load_model(DEFAULT_MODEL_DIR)
        ok = model_bytes(refit) == model_bytes(shipped)
        print(f"[costmodel] refit digest {model_digest(refit)} vs shipped "
              f"{model_digest(shipped)}: {'OK' if ok else 'MISMATCH'}")
        if not ok:
            return 1
        m = evaluate(shipped, rows)
        print(f"[costmodel] shipped-model agreement on checked-in corpus: "
              f"{m['agreement']:.3f} over {m['n_groups']} cells")
        if m["agreement"] < 0.9:
            print("[costmodel] agreement below the 0.9 floor")
            return 1
    return 0


# package-level alias: `plan.fit_cost_model` (a bare `plan.fit` would
# read ambiguously next to `plan.compile`)
fit_cost_model = fit

__all__ = [
    "FEATURE_NAMES", "DEFAULT_CONFIG", "CostModel", "LabelPoint",
    "fit_cost_model",
    "LABEL_KINDS", "LABEL_SPECS", "features_for", "fit", "evaluate",
    "pick_winner", "model_bytes", "model_digest", "label_matrix",
    "label_cells", "run_label_cell", "harvest", "save_corpus",
    "load_corpus", "corpus_digest", "default_model", "set_default_model",
    "DEFAULT_MODEL_DIR",
]

if __name__ == "__main__":
    raise SystemExit(main())
