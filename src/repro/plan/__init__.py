"""repro.plan — compile-once SpMV: cached, serializable execution plans.

The paper's workloads call SpMV thousands of times on the *same* matrix
(graph analytics: eigensolvers, PageRank), so everything the per-call
stack decides — structure analysis, reordering, format conversion,
partitioning, Pallas layout padding — is pure overhead on the hot path.
This package freezes that decision chain once per matrix:

  fingerprint  content digests (a plan is valid while the bytes match)
  compiler     `compile(matrix, ...)` -> SpmvPlan: candidate reorderings
               scored by predicted contended-LLC throughput, winning
               format converted, kernel layout pre-padded; `semiring=`
               builds absorbing-padded plans for `repro.graph` analytics
  plan         SpmvPlan: execute / execute_many (SpMM) /
               power_iteration / address_trace
  overlay      OverlaidPlan: a frozen plan + edge delta served warm
               (streaming matrices; staleness-budgeted re-plan)
  cache        PlanCache + the process-wide DEFAULT_CACHE behind the
               thin-client call paths (core.spmv, distributed.spmv)
  costmodel    the learned candidate scorer (structural features ->
               predicted throughput) that replaces trace replay on the
               default compile path, plus its replay-labeled training
               pipeline (`python -m repro.plan.costmodel`)
  serial       save_plan / load_plan (and save_model / load_model)
               through repro.checkpoint

Quick use:

    from repro import plan
    p = plan.compile(csr, threads=8)       # slow: analyze+predict+convert
    y = p.execute(x)                       # fast: zero per-call prep
    Y = p.execute_many(X)                  # batched SpMM
    lam, v = p.power_iteration(x0)         # amortized iterative driver
    plan.save_plan(p, "ckpt/")             # survives restart
"""
from .cache import DEFAULT_CACHE, PlanCache, get_plan
from .compiler import (REPLAY_NNZ_MAX, choose_format, compile, convert,
                       plan_for_container)
from .costmodel import (CostModel, default_model, fit_cost_model,
                        set_default_model)
from .fingerprint import (chain_fingerprint, delta_fingerprint,
                          fingerprint_arrays, is_concrete, matrix_fingerprint)
from .overlay import (DEFAULT_STALENESS_BUDGET, OverlaidPlan, overlay,
                      overlay_eligible)
from .plan import SpmvPlan
from .serial import (load_model, load_plan, model_from_state, model_state,
                     plan_from_state, plan_state, save_model, save_plan)

# alias for callers who prefer not to shadow the builtin
compile_plan = compile

__all__ = [
    "SpmvPlan", "compile", "compile_plan", "plan_for_container",
    "choose_format", "convert", "REPLAY_NNZ_MAX",
    "PlanCache", "DEFAULT_CACHE", "get_plan",
    "CostModel", "fit_cost_model", "default_model", "set_default_model",
    "OverlaidPlan", "overlay", "overlay_eligible",
    "DEFAULT_STALENESS_BUDGET",
    "matrix_fingerprint", "fingerprint_arrays", "is_concrete",
    "delta_fingerprint", "chain_fingerprint",
    "save_plan", "load_plan", "plan_state", "plan_from_state",
    "save_model", "load_model", "model_state", "model_from_state",
]
