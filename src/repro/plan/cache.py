"""`PlanCache` — content-addressed storage of compiled plans.

Keys are matrix fingerprints salted with the compile options that change
the produced plan, so the cache is self-invalidating: mutate one stored
value and the digest (hence the key) changes, and the stale plan simply
stops being found and ages out of the LRU.  A module-level
`DEFAULT_CACHE` backs the thin-client call paths (`core.spmv.spmv`,
`distributed.spmv.spmv_row_sharded`), so repeated per-call traffic on
the same matrix amortizes to one compile.
"""
from __future__ import annotations

import functools
import hashlib
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict

import numpy as np

from .fingerprint import fingerprint_arrays, matrix_fingerprint


def _fn_token(v) -> str:
    """Distinguish callables beyond module+name: two lambdas (or closures
    over different constants) must not collide, or a sweep passing
    `lambda c: cache_block(c, 4)` and `lambda c: cache_block(c, 8)` would
    silently share one cached plan."""
    code = getattr(v, "__code__", None)
    if code is not None:
        h = hashlib.blake2b(digest_size=8)
        h.update(code.co_code)
        h.update(repr(code.co_consts).encode())
        for cell in (getattr(v, "__closure__", None) or ()):
            h.update(_opt_token(cell.cell_contents).encode())
        h.update(repr(getattr(v, "__defaults__", None)).encode())
        return (f"fn:{getattr(v, '__module__', '?')}."
                f"{getattr(v, '__qualname__', '?')}:{h.hexdigest()}")
    if isinstance(v, functools.partial):
        kw = sorted((v.keywords or {}).items())
        return f"partial:{_fn_token(v.func)}:{v.args!r}:{kw!r}"
    return f"callable:{type(v).__module__}.{type(v).__qualname__}:{v!r}"


def _opt_token(v) -> str:
    """Stable string for one compile option (participates in cache keys)."""
    from repro.reorder import Reordering

    if isinstance(v, Reordering):
        return f"Reordering:{v.strategy}:" + fingerprint_arrays(
            np.asarray(v.row_perm), np.asarray(v.col_perm))
    if callable(v):
        return _fn_token(v)
    if isinstance(v, np.ndarray):
        return "nd:" + fingerprint_arrays(v)
    if hasattr(v, "devices") and hasattr(v, "shape"):      # a jax Mesh
        return f"mesh:{v.shape}:{[getattr(d, 'id', d) for d in np.ravel(v.devices)]}"
    if hasattr(v, "starts"):                               # a RowPartition
        return "part:" + fingerprint_arrays(np.asarray(v.starts))
    return repr(v)


class PlanCache:
    """LRU cache of compiled `SpmvPlan`s keyed by matrix content + options."""

    def __init__(self, max_plans: int = 32):
        self.max_plans = max_plans
        self._plans: "OrderedDict[str, object]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compiles = 0
        self.compile_s = 0.0
        # compile counters split by the plan's resolved scoring mode
        # ('model' -> predictor_*; 'replay'/'analytic' -> oracle_*;
        # unscored compiles count only in the totals above), so serving
        # reports don't average microsecond model compiles into the
        # oracle's seconds
        self.predictor_compiles = 0
        self.predictor_compile_s = 0.0
        self.oracle_compiles = 0
        self.oracle_compile_s = 0.0
        # streaming plan lifecycle (repro.plan.overlay): overlaid plans
        # installed, atomic base swaps landed, re-plans forced by a
        # past-budget (or overlay-ineligible) delta
        self.overlays = 0
        self.swaps = 0
        self.delta_recompiles = 0

    def __len__(self) -> int:
        return len(self._plans)

    @staticmethod
    def key_for(matrix, **opts) -> str:
        salt = ";".join(f"{k}={_opt_token(v)}" for k, v in sorted(opts.items()))
        return f"{matrix_fingerprint(matrix)}|{salt}"

    def contains(self, key: str) -> bool:
        """Warm-pool probe: True iff `key` is resident.  Does NOT touch
        LRU order or hit/miss counters -- admission controllers call this
        every scheduling step, and a probe is not a serve."""
        with self._lock:
            return key in self._plans

    def peek(self, key: str):
        """The resident value for `key`, or None.  Like `contains`, a
        probe: no LRU promotion, no hit/miss accounting."""
        with self._lock:
            return self._plans.get(key)

    @staticmethod
    def chained_key(old_key: str, fingerprint: str) -> str:
        """Re-key an entry under a new (chained) fingerprint, preserving
        the option salt -- the streaming lifecycle's key derivation, with
        no matrix re-hash (`plan.fingerprint.chain_fingerprint` supplies
        the digest)."""
        _, salt = old_key.split("|", 1)
        return f"{fingerprint}|{salt}"

    def install_overlay(self, key: str, overlaid, supersedes: str | None = None
                        ) -> None:
        """Insert an `OverlaidPlan` under its chained key.  The
        superseded generation (previous overlay, or the base plan's key
        when the base should no longer be served directly) is dropped in
        the same critical section, so no scheduling step ever observes
        both generations as warm."""
        with self._lock:
            self._plans[key] = overlaid
            self._plans.move_to_end(key)
            self.overlays += 1
            if supersedes is not None and supersedes != key:
                self._plans.pop(supersedes, None)
            while len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)
                self.evictions += 1

    def swap(self, key: str, builder: Callable[[], object],
             supersedes: str | None = None):
        """Atomic re-plan landing: build (or reuse) the plan for `key`
        and retire the superseded generation.  The drop and the counter
        bump share one critical section -- after `swap` returns, probes
        see exactly one generation."""
        value = self.get_or_build(key, builder)
        with self._lock:
            if supersedes is not None and supersedes != key:
                self._plans.pop(supersedes, None)
            self.swaps += 1
        return value

    def note_delta_recompile(self) -> None:
        """Count one delta-forced re-plan (past staleness budget, or an
        overlay-ineligible delete) -- bumped when the re-plan is
        *scheduled*, so reports show pressure even while the compile is
        still queued."""
        with self._lock:
            self.delta_recompiles += 1

    def get_or_build(self, key: str, builder: Callable[[], object]):
        """Low-level entry: return the cached value for `key` or build,
        insert (evicting LRU past `max_plans`), and return it."""
        with self._lock:
            if key in self._plans:
                self._plans.move_to_end(key)
                self.hits += 1
                return self._plans[key]
        t0 = time.perf_counter()
        value = builder()          # build outside the lock (can be slow)
        elapsed = time.perf_counter() - t0
        with self._lock:
            if key not in self._plans:
                self.misses += 1
                self.compiles += 1
                self.compile_s += elapsed
                scoring = (getattr(value, "compile_stats", None)
                           or {}).get("scoring")
                if scoring == "model":
                    self.predictor_compiles += 1
                    self.predictor_compile_s += elapsed
                elif scoring in ("replay", "analytic"):
                    self.oracle_compiles += 1
                    self.oracle_compile_s += elapsed
                self._plans[key] = value
                while len(self._plans) > self.max_plans:
                    self._plans.popitem(last=False)
                    self.evictions += 1
            else:
                self.hits += 1
            self._plans.move_to_end(key)
            return self._plans[key]

    def get_or_compile(self, matrix, **opts):
        """The main entry: `compile`d plan for (matrix contents, opts),
        cached.  Same signature as `repro.plan.compile`."""
        from .compiler import compile as _compile

        key = self.key_for(matrix, **opts)
        return self.get_or_build(key, lambda: _compile(matrix, **opts))

    def invalidate(self, matrix_or_fingerprint) -> int:
        """Drop every plan for the given matrix (any options).  Returns the
        number of entries removed.

        Accepts a fingerprint string, or the container itself.  Passing
        the container is what makes invalidation after IN-PLACE mutation
        work: `matrix_fingerprint` memoizes its digest per object, so a
        mutated container would otherwise keep resolving to the
        pre-mutation digest (and the cache would keep serving the stale
        plan).  Here the memo entry is evicted first and plans under BOTH
        digests -- the stale memoized one and the re-hash of the current
        bytes -- are dropped.  Rarely needed for immutable containers,
        where content addressing invalidates implicitly.
        """
        if isinstance(matrix_or_fingerprint, str):
            fps = {matrix_or_fingerprint}
        else:
            from .fingerprint import forget_fingerprint
            stale_fp = forget_fingerprint(matrix_or_fingerprint)
            fps = {matrix_fingerprint(matrix_or_fingerprint)}
            if stale_fp is not None:
                fps.add(stale_fp)
        with self._lock:
            stale = [k for k in self._plans
                     if k.split("|", 1)[0] in fps]
            for k in stale:
                del self._plans[k]
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0
            self.compiles = 0
            self.compile_s = 0.0
            self.predictor_compiles = 0
            self.predictor_compile_s = 0.0
            self.oracle_compiles = 0
            self.oracle_compile_s = 0.0
            self.overlays = 0
            self.swaps = 0
            self.delta_recompiles = 0

    def stats(self) -> Dict[str, float]:
        """Counter snapshot.  `hit_rate` is hits/(hits+misses) over the
        cache's lifetime (0.0 before any traffic); callers wanting a
        windowed rate diff two snapshots (`telemetry.plan_cache_report`
        does exactly that for the serving benchmark's measured phase)."""
        with self._lock:
            served = self.hits + self.misses
            return {"plans": len(self._plans), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "compiles": self.compiles,
                    "compile_s": round(self.compile_s, 6),
                    "predictor_compiles": self.predictor_compiles,
                    "predictor_compile_s": round(self.predictor_compile_s, 6),
                    "oracle_compiles": self.oracle_compiles,
                    "oracle_compile_s": round(self.oracle_compile_s, 6),
                    "overlays": self.overlays,
                    "swaps": self.swaps,
                    "delta_recompiles": self.delta_recompiles,
                    "hit_rate": self.hits / served if served else 0.0}


DEFAULT_CACHE = PlanCache()


def get_plan(matrix, **opts):
    """`compile` through the process-wide `DEFAULT_CACHE`."""
    return DEFAULT_CACHE.get_or_compile(matrix, **opts)
