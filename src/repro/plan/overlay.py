"""`OverlaidPlan` — a frozen plan plus a small edge delta, served warm.

The streaming-graph answer to "any mutation recompiles from scratch":
keep serving the *frozen* base plan and correct its output with a COO
pass over the delta.  Execution is

    y = base.execute(x)            # the planned SpMV, untouched
    y = y (⊕) delta-pass(x)        # O(delta nnz) correction

which is exact (see `repro.core.delta` for the algebra): under
plus_times both inserts and deletes overlay (deletes as negated
values); under the ⊕-only semirings inserts overlay and deletes force
materialization (`overlay_eligible`).

Plan lifecycle (the state machine `serve_graph` drives):

    FRESH --mutation--> OVERLAID --mutation--> OVERLAID (merged delta)
      ^                     |
      |     past budget / ineligible delete: re-plan materialized
      +--------------- atomic swap ----------------------+

The staleness budget is `delta.nnz / base.nnz`: the overlay pass costs
O(delta) extra per multiply and the base plan's format/reordering
choices go stale as structure drifts (SpChar's drift observation), so
once the delta outgrows `staleness_budget` the lifecycle recompiles the
materialized matrix in the background and swaps atomically
(`PlanCache.swap`).  Cache keys chain fingerprints
(`fingerprint.chain_fingerprint`): no overlay generation ever re-hashes
the base matrix.

An `OverlaidPlan` is plan-shaped: `execute` / `execute_many` /
`address_trace` / `summary` and the geometry properties delegate or
wrap, so steppers, the serving engine, and `graph.telemetry` never
branch on plan vs overlay.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delta import EdgeDelta

from .fingerprint import chain_fingerprint, delta_fingerprint

#: Default re-plan threshold: delta nnz over base nnz.  5% keeps the
#: overlay pass a rounding error next to the base SpMV while bounding
#: how far structure can drift from what the plan's format/reordering
#: decisions saw (`benchmarks/stream_bench.py` measures both sides).
DEFAULT_STALENESS_BUDGET = 0.05


def overlay_eligible(delta: EdgeDelta, semiring: str) -> bool:
    """True when `delta` can be served as an overlay under `semiring`:
    always for plus_times (⊕ has inverses -- deletes are negations),
    insert-only otherwise (min/max/or have no way to retract a term
    already folded into the base reduction)."""
    return semiring == "plus_times" or not delta.has_deletes


@dataclasses.dataclass
class OverlaidPlan:
    """A base `SpmvPlan` plus an accumulated `EdgeDelta`, plan-shaped.

    `base_matrix` is the ORIGINAL-ORDER CSR the base plan froze (the
    matrix `delta` is expressed against); `fingerprint` is the chained
    digest distinguishing this generation in the `PlanCache`.  Build via
    `overlay(...)`, which handles fingerprint chaining and delta merging
    across generations.
    """

    base: Any                        # the frozen SpmvPlan
    base_matrix: Any                 # original-order CSR the delta targets
    delta: EdgeDelta
    fingerprint: str
    staleness_budget: float = DEFAULT_STALENESS_BUDGET
    _delta_fn: Any = dataclasses.field(default=None, repr=False)
    _many_fn: Any = dataclasses.field(default=None, repr=False)
    _materialized: Any = dataclasses.field(default=None, repr=False)
    _traces: Dict = dataclasses.field(default_factory=dict, repr=False)

    # -- geometry / plan-shape delegation -----------------------------------

    @property
    def n_rows(self) -> int:
        return self.base.n_rows

    @property
    def n_cols(self) -> int:
        return self.base.n_cols

    @property
    def csr(self):
        return self.base.csr

    @property
    def container(self):
        return self.base.container

    @property
    def format_name(self) -> str:
        return self.base.format_name

    @property
    def semiring(self) -> str:
        return self.base.semiring

    @property
    def threads(self) -> int:
        return self.base.threads

    @property
    def reordering(self):
        return self.base.reordering

    @property
    def report(self):
        return self.base.report

    @property
    def compile_stats(self) -> Dict:
        return self.base.compile_stats

    # -- lifecycle state ----------------------------------------------------

    @property
    def staleness(self) -> float:
        """Delta size relative to the base: the quantity the budget caps."""
        return self.delta.nnz / max(self.base_matrix.nnz, 1)

    @property
    def eligible(self) -> bool:
        return overlay_eligible(self.delta, self.semiring)

    @property
    def stale(self) -> bool:
        """True when the lifecycle must re-plan instead of (or despite)
        overlaying: budget exceeded, or a delete under a non-invertible
        semiring."""
        return self.staleness > self.staleness_budget or not self.eligible

    def materialize(self):
        """base_matrix + delta as a fresh canonical CSR (cached): the
        matrix a past-budget re-plan compiles, and the reference the
        exactness tests compare against."""
        if self._materialized is None:
            self._materialized = self.base_matrix.apply_delta(self.delta)
        return self._materialized

    # -- execution ----------------------------------------------------------

    def _build_delta_fn(self):
        """The jitted O(delta) correction pass (y, x) -> y'."""
        n = self.n_rows
        if self.semiring == "plus_times":
            rows_np, cols_np, vals_np = self.delta.signed_coo()
        else:
            if not self.eligible:
                raise ValueError(
                    f"delta carries deletes under semiring "
                    f"{self.semiring!r}: overlay-ineligible, materialize "
                    "and re-plan instead")
            rows_np, cols_np, vals_np = self.delta.insert_coo()
        rows = jnp.asarray(rows_np.astype(np.int32))
        cols = jnp.asarray(cols_np.astype(np.int32))
        vals = jnp.asarray(vals_np.astype(np.float32))
        if self.semiring == "plus_times":
            def fn(y, x):
                terms = vals * jnp.take(x, cols, axis=0)
                return y + jax.ops.segment_sum(terms, rows, num_segments=n)
            return jax.jit(fn)
        from repro.graph.semiring import resolve
        sr = resolve(self.semiring)

        def fn(y, x):
            prods = sr.mul(vals, jnp.take(x, cols, axis=0))
            h = sr.segment(prods, rows, num_segments=n)
            counts = jax.ops.segment_sum(jnp.ones_like(prods), rows,
                                         num_segments=n)
            h = jnp.where(counts > 0, h, jnp.asarray(sr.identity, h.dtype))
            return sr.add(y, h)
        return jax.jit(fn)

    def execute(self, x: jax.Array, interpret: Optional[bool] = None
                ) -> jax.Array:
        """y = (base + delta) @ x: the planned SpMV then the delta pass."""
        y = self.base.execute(x, interpret=interpret)
        if self.delta.nnz == 0:
            return y
        if self._delta_fn is None:
            self._delta_fn = self._build_delta_fn()
        return self._delta_fn(y, jnp.asarray(x))

    __call__ = execute

    def execute_many(self, X: jax.Array) -> jax.Array:
        """Batched (k, n) path: base SpMM then the delta pass vmapped
        over lanes, jitted once per overlay generation."""
        Y = self.base.execute_many(X)
        if self.delta.nnz == 0:
            return Y
        if self._many_fn is None:
            if self._delta_fn is None:
                self._delta_fn = self._build_delta_fn()
            self._many_fn = jax.jit(jax.vmap(self._delta_fn))
        return self._many_fn(Y, jnp.asarray(X))

    # -- telemetry ----------------------------------------------------------

    def address_trace(self, machine):
        """Base plan trace plus the overlay pass priced as a
        column-sorted COO stream (ascending x gathers, same discipline
        as the HYB heavy partition).  Cached per machine, like
        `SpmvPlan.address_trace`."""
        if machine not in self._traces:
            from repro.telemetry.hierarchy import overlay_address_trace
            rows, cols = self.delta.rows, self.delta.cols
            if self.base.reordering is not None:
                irp = np.asarray(self.base.reordering.inv_row_perm)
                icp = np.asarray(self.base.reordering.inv_col_perm)
                rows, cols = irp[rows], icp[cols]
            self._traces[machine] = overlay_address_trace(
                self.base.csr, self.base.format_name, rows, cols, machine,
                container=self.base.container)
        return self._traces[machine]

    # -- reporting ----------------------------------------------------------

    def summary(self) -> str:
        return (f"OverlaidPlan[{self.fingerprint[:8]}] "
                f"+{self.delta.n_inserts} -{self.delta.n_deletes} "
                f"staleness={self.staleness:.3f}/{self.staleness_budget:g} "
                f"over {self.base.summary()}")


def overlay(plan_or_overlaid, delta: EdgeDelta, *, base_matrix=None,
            staleness_budget: Optional[float] = None) -> OverlaidPlan:
    """Extend a plan (or an existing overlay) with one more delta batch.

    Wrapping a fresh `SpmvPlan` starts a lineage: `base_matrix` defaults
    to the plan's retained CSR, un-permuted back to original order when
    the plan reordered (the delta's coordinates are original-order).
    Wrapping an `OverlaidPlan` merges the new batch into the accumulated
    delta and chains the fingerprint -- only the new batch is hashed.
    """
    if isinstance(plan_or_overlaid, OverlaidPlan):
        prev = plan_or_overlaid
        merged = prev.delta.merge(delta)
        return OverlaidPlan(
            base=prev.base, base_matrix=prev.base_matrix, delta=merged,
            fingerprint=chain_fingerprint(prev.fingerprint,
                                          delta_fingerprint(delta)),
            staleness_budget=(prev.staleness_budget if staleness_budget is None
                              else float(staleness_budget)))
    plan = plan_or_overlaid
    if base_matrix is None:
        if plan.csr is None:
            raise ValueError(
                "plan was compiled with keep_csr=False; pass base_matrix= "
                "explicitly to overlay it")
        base_matrix = plan.csr
        if plan.reordering is not None:
            base_matrix = base_matrix.permute(plan.reordering.inv_row_perm,
                                              plan.reordering.inv_col_perm)
    if (delta.n_rows, delta.n_cols) != (base_matrix.n_rows,
                                        base_matrix.n_cols):
        raise ValueError(f"delta shape {delta.shape} does not match the "
                         f"base matrix {base_matrix.shape}")
    return OverlaidPlan(
        base=plan, base_matrix=base_matrix, delta=delta,
        fingerprint=chain_fingerprint(plan.fingerprint,
                                      delta_fingerprint(delta)),
        staleness_budget=(DEFAULT_STALENESS_BUDGET if staleness_budget is None
                          else float(staleness_budget)))


__all__ = ["OverlaidPlan", "overlay", "overlay_eligible",
           "DEFAULT_STALENESS_BUDGET"]
