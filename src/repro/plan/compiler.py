"""`compile` — turn a matrix into a frozen `SpmvPlan`.

The slow half of the compile-once split.  One call runs the whole
decision chain the per-call stack used to repeat on every multiply:

  fingerprint -> candidate reorderings -> predicted contended-LLC
  throughput (per candidate) -> winning reordering ->
  structure.analyze -> format -> conversion -> pre-padded kernel
  layout -> SpmvPlan.

Candidates are (format, reordering) pairs, enumerated in sorted name
order so plan choice is deterministic across runs.  Each reordering
candidate's *permuted access stream* is scored by the same models the
telemetry/parallel subsystems report with, and its format is read off
its permuted structure (DIA for recovered bands, BELL for block density,
HYB/segmented-CSR for power-law nnz dispersion, CSR otherwise) — so what
the predictor scored is exactly the stream that format will exploit.
Forcing `format=` skips the O(nnz) structure analysis altogether.

Predictors (`predictor=`):

  * 'model'     the learned cost model (`plan.costmodel`): each
                candidate's permuted structure report is featurized and
                scored by the shipped gradient-boosted ensemble in
                microseconds — no trace replay.  Falls back to 'oracle'
                (recorded in `compile_stats`) when no model is loaded.
  * 'oracle'    the simulation-backed scorer the model was trained
                against: 'replay' when nnz <= REPLAY_NNZ_MAX, else
                'analytic'.
  * 'replay'    `repro.parallel.simulate_parallel` — per-thread trace
                replay through private caches + the shared contended LLC,
                scored by `ParallelMetrics.gflops_est()`.  Exact but
                Python-speed; right for small/medium matrices.
  * 'analytic'  `core.cache_model.analytic_metrics(..., threads=)` — the
                Che-approximation model (with its shared-LLC thread
                scaling), scored by `CacheMetrics.gflops`.  O(distinct
                line counts); right for the 2^26 regime.
  * 'auto'      'model' when a pretrained model ships in-repo
                (`costmodel.default_model()`), else 'oracle' — the
                default: plan-cache misses on the serving path score in
                microseconds instead of seconds.
  * 'none'      no scoring: keeps the single given candidate (used by
                sweep harnesses that pin the reordering themselves);
                with reorder='auto' it degenerates to the identity
                ordering — no candidate work is done at all.

`compile_stats['scoring']` records the *resolved* mode ('model',
'replay', 'analytic', or 'none'), which is what `PlanCache` buckets its
predictor-vs-oracle compile counters by.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from repro.core import structure
from repro.core.cache_model import SANDY_BRIDGE, MachineModel
from repro.core.formats import BELL, CSR, DIA, ELL, HYB
from repro.kernels import _layout as kl

from .fingerprint import matrix_fingerprint
from .plan import SpmvPlan

# 'auto' predictor switches from trace replay to the analytic model above
# this nnz (replay is Python-speed: ~5 trace entries per nonzero per sweep).
REPLAY_NNZ_MAX = 16384

# A reordered candidate must beat the identity ordering by this fraction of
# predicted throughput to win: executing under a reordering pays an x-gather
# and y-scatter per multiply that the stream-level predictors do not model,
# so a sub-margin "win" is a loss in practice.
REORDER_MARGIN = 0.02


# Power-law detection for the nnz-balanced formats: above this nnz/row
# coefficient of variation an unstructured matrix routes to the hybrid
# row split (R-MAT sits at 1.7-3.2 across 2^8..2^12; uniform random at
# ~0.37, FD at 0.0).  Between SEG_MIN_CV and HYB_MIN_CV, a multithreaded
# plan takes the segmented (merge) CSR layout: rows are dispersed enough
# that row partitions imbalance but not enough to justify a row split.
HYB_MIN_CV = 1.0
SEG_MIN_CV = 0.5

# Semiring plans need absorbing padding, which the dense-footprint
# formats (DIA bands, BELL tiles) cannot express -- see graph.semiring.
SEMIRING_FORMATS = ("csr", "csr-seg", "ell", "hyb")


def choose_format(report, threads: int = 1,
                  semiring_safe: bool = False) -> str:
    """Format name for a structure report (the dispatch rule that used to
    live inline in `core.spmv.auto_format`).

    `threads` biases unstructured dispersion toward the nnz-balanced
    segmented layout (row partitions imbalance at scale);
    `semiring_safe` restricts the choice to absorbing-pad formats
    (`SEMIRING_FORMATS`), with ELL replacing the dense-footprint picks.
    """
    if not semiring_safe:
        if report.kind == "banded" and report.n_distinct_offsets <= 64:
            return "dia"
        if report.kind == "blocked":
            return "bell"
    if report.kind == "unstructured":
        if report.row_nnz_cv >= HYB_MIN_CV:
            return "hyb"                # power-law: split the hub rows off
        if threads > 1 and report.row_nnz_cv >= SEG_MIN_CV:
            return "csr-seg"            # dispersed: balance by nonzeros
    return "ell" if semiring_safe else "csr"


def convert(csr: CSR, format_name: str, fill: float = 0.0):
    """Convert a CSR to the named storage format.  `fill` is the padding
    value for layouts that materialize padding slots (ELL, the HYB light
    partition): 0.0 for plus-times, the semiring's absorbing element
    otherwise.  'csr-seg' is a kernel layout over the CSR container, not
    a distinct storage format, so it converts to the CSR itself."""
    if format_name == "dia":
        return DIA.from_csr(csr)
    if format_name == "bell":
        return BELL.from_csr(csr)
    if format_name == "ell":
        return ELL.from_csr(csr, fill=fill)
    if format_name == "hyb":
        return HYB.from_csr(csr, fill=fill)
    if format_name in ("csr", "csr-seg"):
        return csr
    raise ValueError(f"unknown format {format_name!r}")


def _prepare(container, format_name: str, *, bn: int, bm: int,
             n_stripes: int, seg_len: int = 512, pad_value: float = 0.0):
    """Pre-padded kernel layout for the chosen container (plan-build time;
    `SpmvPlan.execute` replays it with zero matrix-side work)."""
    if format_name == "dia":
        return kl.prepare_dia(container, bn=bn)
    if format_name == "bell":
        return kl.prepare_bell(container)
    if format_name == "ell":
        return kl.prepare_ell(container, bm=bm, pad_value=pad_value)
    if format_name == "csr":
        return kl.prepare_csr(container, n_stripes=n_stripes, bm=bm,
                              pad_value=pad_value)
    if format_name == "csr-seg":
        return kl.prepare_csr_seg(container, seg_len=seg_len,
                                  pad_value=pad_value)
    if format_name == "hyb":
        return kl.prepare_hyb(container, seg_len=seg_len, bm=bm,
                              pad_value=pad_value)
    raise ValueError(f"unknown format {format_name!r}")


def _candidates(csr: CSR, reorder) -> Dict[str, object]:
    """label -> Reordering|None for the `reorder=` argument forms:
    'auto' (none + rcm), 'none'/None, a strategy name, a strategy
    callable, or a concrete Reordering."""
    from repro.reorder import STRATEGIES, Reordering

    if reorder is None or reorder == "none":
        return {"none": None}
    if reorder == "auto":
        return {"none": None, "rcm": STRATEGIES["rcm"](csr)}
    if isinstance(reorder, str):
        return {reorder: STRATEGIES[reorder](csr)}
    if isinstance(reorder, Reordering):
        return {reorder.strategy: reorder}
    if callable(reorder):
        r = reorder(csr)
        return {getattr(r, "strategy", getattr(reorder, "__name__", "custom")): r}
    raise TypeError(f"unsupported reorder argument: {reorder!r}")


def _predict(csr: CSR, threads: int, machine: MachineModel,
             parallel_spec, predictor: str) -> Dict:
    """Predicted contended-LLC throughput of one candidate's stream."""
    if predictor == "auto":
        predictor = "replay" if csr.nnz <= REPLAY_NNZ_MAX else "analytic"
    if predictor == "replay":
        from repro.core.partition import rowblock_balanced
        from repro.parallel import ParallelSpec, simulate_parallel

        spec = parallel_spec if parallel_spec is not None else ParallelSpec()
        part = rowblock_balanced(csr, threads)
        _, m = simulate_parallel(csr, part, machine, spec, sweeps=2)
        return {"predictor": "replay", "gflops": m.gflops_est(),
                "time_s": m.time_s, "dram_util": m.dram_util,
                "l2_mpki": m.l2_mpki_mean}
    if predictor == "analytic":
        from repro.core.cache_model import analytic_metrics

        m = analytic_metrics(csr, machine, threads=threads)
        return {"predictor": "analytic", "gflops": m.gflops,
                "l2_mpki": m.l2_miss_rate,
                "dram_util": m.dram_utilization}
    raise ValueError(f"unknown predictor {predictor!r}")


def compile(matrix: CSR, *,                       # noqa: A001 (plan.compile)
            threads: int = 1,
            mesh=None,
            partition=None,
            reorder="auto",
            machine: MachineModel = SANDY_BRIDGE,
            parallel_spec=None,
            predictor: str = "auto",
            format: Optional[str] = None,         # noqa: A002
            use_pallas: bool = True,
            interpret: Optional[bool] = None,
            semiring: str = "plus_times",
            bn: int = 512, bm: int = 128, n_stripes: int = 1,
            seg_len: int = 512,
            keep_csr: bool = True,
            sample_rows: Optional[int] = 65536) -> SpmvPlan:
    """Compile a CSR matrix into a frozen `SpmvPlan`.

    threads    target thread count the predictor scores contention at
    mesh       a device mesh: build a row-sharded plan (`shard_map` ELL
               path) over `partition` (default `rowblock_equal`)
    reorder    'auto' (predictor picks none-vs-RCM) | 'none'/None | a
               strategy name/callable | a concrete Reordering
    format     force a storage format
               ('dia'|'bell'|'ell'|'csr'|'csr-seg'|'hyb'); default reads
               it off each candidate's permuted structure -- power-law
               dispersion (row_nnz_cv) routes to the nnz-balanced 'hyb'
               and 'csr-seg' layouts, see `choose_format`
    seg_len    nonzeros per segment for the 'csr-seg'/'hyb' layouts
    semiring   name (or `Semiring`) of the (⊕, ⊗) pair the plan executes
               under ('plus_times' default).  Non-plus-times plans are
               restricted to the absorbing-pad formats
               (`SEMIRING_FORMATS`); the reordering/predictor machinery
               is semiring-independent (same access stream)
    keep_csr   retain the permuted CSR on the plan (needed for
               `execute_many`'s SpMM path and telemetry trace replay)
    """
    fp = matrix_fingerprint(matrix)
    stats: Dict[str, object] = {}   # timings + the resolved scoring mode

    sr = None
    if semiring != "plus_times":
        from repro.graph.semiring import SEMIRINGS, resolve
        sr = resolve(semiring)
        if SEMIRINGS.get(sr.name) is not sr:
            # plans store the semiring by NAME (it must survive
            # serialization and cache keys), so an unregistered instance
            # would compile fine and KeyError on the first execute
            raise ValueError(
                f"semiring {sr.name!r} is not registered in "
                "repro.graph.semiring.SEMIRINGS; plans resolve semirings "
                "by name, so add custom semirings to the registry first")
        if sr.name == "plus_times":
            sr = None
        semiring = sr.name if sr is not None else "plus_times"
    pad_value = sr.pad_value if sr is not None else 0.0
    if sr is not None:
        if mesh is not None:
            raise ValueError("sharded plans are plus-times only")
        if format is not None and format not in SEMIRING_FORMATS:
            raise ValueError(
                f"semiring {semiring!r} requires a format in "
                f"{SEMIRING_FORMATS} (dense-footprint {format!r} stores "
                "absent entries as 0.0, which is only absorbing under "
                "plus_times)")

    if predictor == "none" and reorder == "auto":
        # no scoring requested, so don't build candidates that could only
        # be chosen by a score: 'auto' degenerates to the identity order
        reorder = "none"

    # Resolve 'auto'/'model'/'oracle' to a concrete scorer up front so the
    # candidate loop below is mode-free and the cache can bucket compile
    # counters by what actually ran.
    model = None
    if predictor in ("auto", "model"):
        from .costmodel import default_model

        model = default_model()
        if model is None:
            if predictor == "model":
                stats["model_fallback"] = 1.0
            predictor = "oracle"
        else:
            predictor = "model"
    if predictor == "oracle":
        predictor = "replay" if matrix.nnz <= REPLAY_NNZ_MAX else "analytic"

    t0 = time.perf_counter()
    cands = _candidates(matrix, reorder)
    permuted_by = {label: (r.apply(matrix) if r is not None else matrix)
                   for label, r in cands.items()}
    stats["reorder_s"] = time.perf_counter() - t0

    # Candidate enumeration: one (format, reordering) pair per reordering
    # candidate, the format read off that candidate's permuted structure
    # (forcing `format=` skips the O(nnz) analysis and pins the pair's
    # format).  The list is sorted by (format, reordering) name so the
    # enumeration -- and every tie-break below -- is deterministic across
    # runs and processes, keeping fingerprint-salted cache entries stable.
    fmt_by: Dict[str, str] = {}
    report_by: Dict[str, object] = {}
    t0 = time.perf_counter()
    for label in sorted(cands):
        if format is not None:
            fmt_by[label], report_by[label] = format, None
        else:
            rep = structure.analyze(permuted_by[label],
                                    sample_rows=sample_rows)
            report_by[label] = rep
            fmt_by[label] = choose_format(rep, threads=threads,
                                          semiring_safe=sr is not None)
    if format is None:
        stats["analyze_s"] = time.perf_counter() - t0
    ordered = sorted(cands, key=lambda lab: (fmt_by[lab], lab))

    if len(ordered) > 1:
        # Drop candidates whose (permuted bytes, format) duplicates an
        # earlier one -- RCM on an already-banded matrix returns the
        # identity permutation, and scoring it would replay the exact
        # stream 'none' already covers.  'none' is preferred as survivor
        # (no x-gather/y-scatter at execute time); a compile this leaves
        # with one candidate skips scoring entirely below.
        pref = [lab for lab in ("none",) if lab in cands] + \
            [lab for lab in ordered if lab != "none"]
        seen: Dict[object, str] = {}
        for label in pref:
            sig = (matrix_fingerprint(permuted_by[label]), fmt_by[label])
            seen.setdefault(sig, label)
        keep = set(seen.values())
        ordered = [lab for lab in ordered if lab in keep]

    t0 = time.perf_counter()
    predicted: Dict[str, Dict] = {}
    if predictor == "none" or len(ordered) == 1:
        chosen = ordered[0]
        stats["scoring"] = "none"
    else:
        if predictor == "model":
            import numpy as _np

            from .costmodel import features_for

            l2b = getattr(parallel_spec, "l2_bytes", None)
            llcb = getattr(parallel_spec, "llc_bytes", None)
            feats = []
            for label in ordered:
                rep = report_by[label]
                if rep is None:
                    # format was forced, so the loop above skipped the
                    # analysis -- the model still needs features
                    rep = structure.analyze(permuted_by[label],
                                            sample_rows=sample_rows)
                    report_by[label] = rep
                feats.append(features_for(rep, threads, l2_bytes=l2b,
                                          llc_bytes=llcb, machine=machine))
            scores = model.predict(_np.stack(feats))
            for label, yhat in zip(ordered, scores):
                predicted[label] = {"predictor": "model",
                                    "gflops": float(2.0 ** yhat)}
        else:
            for label in ordered:
                predicted[label] = _predict(permuted_by[label], threads,
                                            machine, parallel_spec,
                                            predictor)
        chosen = ordered[0]
        for label in ordered[1:]:       # strict >: ties keep sorted order
            if predicted[label]["gflops"] > predicted[chosen]["gflops"]:
                chosen = label
        if chosen != "none" and "none" in predicted:
            # reordered winners must clear the transport margin
            bar = predicted["none"]["gflops"] * (1.0 + REORDER_MARGIN)
            if predicted[chosen]["gflops"] <= bar:
                chosen = "none"
        stats["scoring"] = predictor
    stats["predict_s"] = time.perf_counter() - t0

    reordering, permuted = cands[chosen], permuted_by[chosen]
    report, format_name = report_by[chosen], fmt_by[chosen]

    if mesh is not None:
        return _compile_sharded(fp, permuted, reordering, report, mesh,
                                partition, bm=bm, threads=threads,
                                predicted=predicted, chosen=chosen,
                                interpret=interpret, stats=stats,
                                keep_csr=keep_csr)

    t0 = time.perf_counter()
    container = convert(permuted, format_name, fill=pad_value)
    stats["convert_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    prep = _prepare(container, format_name, bn=bn, bm=bm,
                    n_stripes=n_stripes, seg_len=seg_len,
                    pad_value=pad_value) if use_pallas else None
    stats["prepare_s"] = time.perf_counter() - t0

    return SpmvPlan(
        fingerprint=fp, format_name=format_name, container=container,
        prep=prep, reordering=reordering, report=report,
        csr=permuted if keep_csr else None, threads=threads,
        use_pallas=use_pallas, interpret=interpret, semiring=semiring,
        predicted=predicted, chosen=chosen, compile_stats=stats)


def _compile_sharded(fp, permuted, reordering, report, mesh, partition, *,
                     bm, threads, predicted, chosen, interpret, stats,
                     keep_csr) -> SpmvPlan:
    """Row-sharded plan: `prepare_ell_shards` is the plan-build step, the
    `shard_map` Pallas ELL kernel is the executor."""
    from repro.distributed.spmv import default_row_partition

    t0 = time.perf_counter()
    if partition is None:
        partition = default_row_partition(permuted, mesh)
    prep = kl.prepare_ell_shards(permuted, partition, bm=bm)
    stats["prepare_s"] = time.perf_counter() - t0
    return SpmvPlan(
        fingerprint=fp, format_name="ell-sharded", container=None,
        prep=prep, reordering=reordering, report=report,
        csr=permuted if keep_csr else None, threads=threads,
        use_pallas=True, interpret=interpret, predicted=predicted,
        chosen=chosen, compile_stats=stats, mesh=mesh)


def plan_for_container(matrix, interpret: Optional[bool] = None) -> SpmvPlan:
    """Minimal plan for an ALREADY-CONVERTED container (no analysis, no
    reordering decision — the caller chose the format): just the one-time
    kernel layout prep.  This is what `core.spmv.spmv` caches so repeated
    per-call dispatch stops re-padding the matrix."""
    names = {DIA: "dia", BELL: "bell", ELL: "ell", CSR: "csr", HYB: "hyb"}
    format_name = names[type(matrix)]
    prep = _prepare(matrix, format_name, bn=512, bm=128, n_stripes=1)
    return SpmvPlan(
        fingerprint=matrix_fingerprint(matrix), format_name=format_name,
        container=matrix, prep=prep,
        csr=matrix if isinstance(matrix, CSR) else None,
        interpret=interpret, chosen="container")
