"""Plan serialization through `repro.checkpoint` — a planned matrix
survives restart.

A plan becomes one checkpointable pytree: every array (container fields,
permutations, shard slabs, retained CSR) is a leaf, and the static
decision record (format, knobs, structure report, predictor scores) is
msgpack'd into a single uint8 leaf.  `CheckpointManager` then gives the
usual guarantees for free: crash-safe commit marker, codec fallback,
shard files.  Restore is schema-free (`CheckpointManager.restore_any`),
so a fresh process can load a plan without knowing its format up front.

The pre-padded kernel layout is NOT stored: it is a deterministic
function of the container plus its knobs (`bn`/`bm`/`n_stripes`, which
are recorded), so `load_plan` rebuilds it once — identical bits, half
the checkpoint size.  Device meshes are never serialized; pass `mesh=`
to `load_plan` to rebind a row-sharded plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import msgpack
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.formats import BELL, CSR, DIA, ELL, HYB
from repro.core.structure import StructureReport
from repro.kernels import _layout as kl

from .compiler import _prepare
from .plan import SpmvPlan

_VERSION = 1


def _plain(v):
    """Coerce a metadata value to something msgpack can round-trip."""
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, dict):
        return {str(k): _plain(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_plain(x) for x in v]
    if v is None or isinstance(v, (bool, int, float, str, bytes)):
        return v
    return str(v)


def _prep_knobs(plan: SpmvPlan) -> Dict:
    """Recover the layout knobs from the built prep (enough to rebuild it
    deterministically at load time)."""
    p = plan.prep
    if p is None:
        return {}
    if isinstance(p, kl.PreparedDIA):
        return {"bn": p.bn}
    if isinstance(p, kl.PreparedELL):
        return {"bm": int(p.data.shape[1])}
    if isinstance(p, kl.PaddedCSR):
        return {"bm": p.bm, "n_stripes": int(p.vals.shape[0])}
    if isinstance(p, kl.PreparedSegCSR):
        return {"seg_len": p.seg_len}
    if isinstance(p, kl.PreparedHYB):
        return {"seg_len": p.heavy.seg_len,
                "bm": int(p.light.data.shape[1])}
    return {}


def plan_state(plan: SpmvPlan) -> Dict:
    """The plan as one checkpointable pytree (nested string-keyed dicts)."""
    meta = {
        "version": _VERSION,
        "fingerprint": plan.fingerprint,
        "format_name": plan.format_name,
        "threads": plan.threads,
        "use_pallas": plan.use_pallas,
        "interpret": plan.interpret,
        "semiring": plan.semiring,
        "chosen": plan.chosen,
        "predicted": _plain(plan.predicted),
        "compile_stats": _plain(plan.compile_stats),
        "prep_knobs": _prep_knobs(plan),
        "has_csr": plan.csr is not None,
        "report": (_plain(dataclasses.asdict(plan.report))
                   if plan.report is not None else None),
    }
    state: Dict = {}

    c = plan.container
    if isinstance(c, DIA):
        meta["container"] = {"type": "dia", "n_rows": c.n_rows,
                             "n_cols": c.n_cols}
        state["container"] = {"data": c.data, "offsets": c.offsets}
    elif isinstance(c, BELL):
        meta["container"] = {"type": "bell", "n_rows": c.n_rows,
                             "n_cols": c.n_cols, "bm": c.bm, "bn": c.bn,
                             "blocks_per_row": c.blocks_per_row}
        state["container"] = {"data": c.data, "block_cols": c.block_cols}
    elif isinstance(c, ELL):
        meta["container"] = {"type": "ell", "n_rows": c.n_rows,
                             "n_cols": c.n_cols, "max_nnz": c.max_nnz}
        state["container"] = {"data": c.data, "indices": c.indices}
    elif isinstance(c, HYB):
        meta["container"] = {"type": "hyb", "n_rows": c.n_rows,
                             "n_cols": c.n_cols, "threshold": c.threshold,
                             "light_width": c.light_width}
        state["container"] = {"data": c.data, "indices": c.indices,
                              "hvals": c.hvals, "hrows": c.hrows,
                              "hcols": c.hcols}
    elif isinstance(c, CSR) or c is None:
        # CSR containers are stored once, under "csr" (below)
        meta["container"] = {"type": "csr" if isinstance(c, CSR) else None}
        if isinstance(c, CSR) and plan.csr is None:
            state["csr"] = {"data": c.data, "indices": c.indices,
                            "indptr": c.indptr}
            meta["csr_shape"] = [c.n_rows, c.n_cols]
    else:
        raise TypeError(f"unserializable container: {type(c)}")

    if plan.format_name == "ell-sharded":
        p = plan.prep
        meta["sharded"] = {"n_rows": p.n_rows, "n_cols": p.n_cols,
                           "bm": p.bm}
        state["sharded"] = {"data": p.data, "idx": p.idx,
                            "starts": np.asarray(p.starts)}

    if plan.reordering is not None:
        r = plan.reordering
        meta["reorder"] = {"strategy": r.strategy,
                           "params": _plain(r.params),
                           "stats": _plain(r.stats)}
        state["reorder"] = {"row_perm": np.asarray(r.row_perm),
                            "col_perm": np.asarray(r.col_perm)}

    if plan.csr is not None:
        meta["csr_shape"] = [plan.csr.n_rows, plan.csr.n_cols]
        state["csr"] = {"data": plan.csr.data, "indices": plan.csr.indices,
                        "indptr": plan.csr.indptr}

    state["meta"] = np.frombuffer(msgpack.packb(meta), dtype=np.uint8).copy()
    return state


def plan_from_state(state: Dict, mesh=None) -> SpmvPlan:
    """Rebuild a `SpmvPlan` from `plan_state` output (as restored by
    `CheckpointManager.restore_any`)."""
    meta = msgpack.unpackb(np.asarray(state["meta"]).tobytes(),
                           strict_map_key=False)
    if meta["version"] != _VERSION:
        raise ValueError(f"unknown plan state version {meta['version']}")

    csr = None
    if "csr" in state:
        n_rows, n_cols = meta["csr_shape"]
        g = state["csr"]
        csr = CSR(data=g["data"], indices=g["indices"], indptr=g["indptr"],
                  n_rows=int(n_rows), n_cols=int(n_cols))

    cmeta = meta["container"]
    ctype = cmeta["type"] if cmeta else None
    if ctype == "dia":
        g = state["container"]
        container = DIA(data=g["data"], offsets=g["offsets"],
                        n_rows=int(cmeta["n_rows"]),
                        n_cols=int(cmeta["n_cols"]))
    elif ctype == "bell":
        g = state["container"]
        container = BELL(data=g["data"], block_cols=g["block_cols"],
                         n_rows=int(cmeta["n_rows"]),
                         n_cols=int(cmeta["n_cols"]), bm=int(cmeta["bm"]),
                         bn=int(cmeta["bn"]),
                         blocks_per_row=int(cmeta["blocks_per_row"]))
    elif ctype == "ell":
        g = state["container"]
        container = ELL(data=g["data"], indices=g["indices"],
                        n_rows=int(cmeta["n_rows"]),
                        n_cols=int(cmeta["n_cols"]),
                        max_nnz=int(cmeta["max_nnz"]))
    elif ctype == "hyb":
        g = state["container"]
        container = HYB(data=g["data"], indices=g["indices"],
                        hvals=g["hvals"], hrows=g["hrows"],
                        hcols=g["hcols"], n_rows=int(cmeta["n_rows"]),
                        n_cols=int(cmeta["n_cols"]),
                        threshold=int(cmeta["threshold"]),
                        light_width=int(cmeta["light_width"]))
    elif ctype == "csr":
        container = csr
    else:
        container = None

    reordering = None
    if "reorder" in state:
        from repro.reorder import Reordering

        rmeta = meta["reorder"]
        reordering = Reordering(
            row_perm=np.asarray(state["reorder"]["row_perm"]),
            col_perm=np.asarray(state["reorder"]["col_perm"]),
            strategy=rmeta["strategy"], params=rmeta.get("params", {}),
            stats=rmeta.get("stats", {}))

    format_name = meta["format_name"]
    if format_name == "ell-sharded":
        g = state["sharded"]
        smeta = meta["sharded"]
        prep = kl.ShardedELL(
            data=g["data"], idx=g["idx"], n_rows=int(smeta["n_rows"]),
            n_cols=int(smeta["n_cols"]),
            starts=np.asarray(g["starts"], dtype=np.int64),
            bm=int(smeta["bm"]))
    elif meta["use_pallas"] and container is not None:
        knobs = meta.get("prep_knobs", {})
        semiring = meta.get("semiring", "plus_times")
        pad_value = 0.0
        if semiring != "plus_times":
            from repro.graph.semiring import resolve
            pad_value = resolve(semiring).pad_value
        prep = _prepare(container, format_name,
                        bn=int(knobs.get("bn", 512)),
                        bm=int(knobs.get("bm", 128)),
                        n_stripes=int(knobs.get("n_stripes", 1)),
                        seg_len=int(knobs.get("seg_len", 512)),
                        pad_value=pad_value)
    else:
        prep = None

    report = (StructureReport(**meta["report"])
              if meta.get("report") is not None else None)

    return SpmvPlan(
        fingerprint=meta["fingerprint"], format_name=format_name,
        container=container, prep=prep, reordering=reordering,
        report=report, csr=csr, threads=int(meta["threads"]),
        use_pallas=bool(meta["use_pallas"]), interpret=meta["interpret"],
        semiring=meta.get("semiring", "plus_times"),
        predicted=meta.get("predicted", {}), chosen=meta.get("chosen", "none"),
        compile_stats=meta.get("compile_stats", {}), mesh=mesh)


def _f64_leaf(arr: np.ndarray) -> np.ndarray:
    """float64 array as a uint8 leaf.  Checkpoint restore funnels every
    leaf through `jnp.asarray`, which truncates float64 to float32 under
    the default x64-off config -- split thresholds and leaf values must
    survive bit-exactly (the CI byte-compare depends on it), so they ride
    as raw bytes like the msgpack'd meta leaf does."""
    return np.frombuffer(np.ascontiguousarray(arr, np.float64).tobytes(),
                         dtype=np.uint8).copy()


def _f64_from_leaf(leaf) -> np.ndarray:
    return np.frombuffer(np.asarray(leaf, np.uint8).tobytes(),
                         dtype=np.float64).copy()


def model_state(model) -> Dict:
    """A `costmodel.CostModel` as one checkpointable pytree: the trees'
    node arrays concatenated (CSR-style `offsets` delimit trees), the
    scalar/record fields msgpack'd into the usual uint8 `meta` leaf."""
    meta = {
        "version": _VERSION,
        "kind": "costmodel",
        "base": float(model.base),
        "learning_rate": float(model.learning_rate),
        "feature_names": list(model.feature_names),
        "config": _plain(dict(model.config)),
        "meta": _plain(dict(model.meta)),
    }
    trees = model.trees
    offsets = np.zeros(len(trees) + 1, dtype=np.int32)
    for i, t in enumerate(trees):
        offsets[i + 1] = offsets[i] + t.feat.shape[0]

    def cat(name, dtype):
        if not trees:
            return np.zeros(0, dtype)
        return np.concatenate([np.asarray(getattr(t, name), dtype)
                               for t in trees])

    return {
        "meta": np.frombuffer(msgpack.packb(meta), dtype=np.uint8).copy(),
        "offsets": offsets,
        "feat": cat("feat", np.int32),
        "left": cat("left", np.int32),
        "right": cat("right", np.int32),
        "thresh": _f64_leaf(cat("thresh", np.float64)),
        "value": _f64_leaf(cat("value", np.float64)),
    }


def model_from_state(state: Dict):
    """Rebuild a `costmodel.CostModel` from `model_state` output."""
    from .costmodel import CostModel, _Tree

    meta = msgpack.unpackb(np.asarray(state["meta"]).tobytes(),
                           strict_map_key=False)
    if meta["version"] != _VERSION or meta.get("kind") != "costmodel":
        raise ValueError(f"not a cost-model state: {meta.get('kind')!r} "
                         f"v{meta.get('version')!r}")
    offsets = np.asarray(state["offsets"], dtype=np.int64)
    thresh = _f64_from_leaf(state["thresh"])
    value = _f64_from_leaf(state["value"])
    trees = []
    for i in range(offsets.shape[0] - 1):
        lo, hi = int(offsets[i]), int(offsets[i + 1])
        trees.append(_Tree(
            feat=np.asarray(state["feat"][lo:hi], np.int32),
            thresh=thresh[lo:hi].copy(),
            left=np.asarray(state["left"][lo:hi], np.int32),
            right=np.asarray(state["right"][lo:hi], np.int32),
            value=value[lo:hi].copy()))
    return CostModel(base=float(meta["base"]),
                     learning_rate=float(meta["learning_rate"]),
                     trees=tuple(trees),
                     feature_names=tuple(meta["feature_names"]),
                     config=meta.get("config", {}),
                     meta=meta.get("meta", {}))


def save_model(model, ckpt_dir: str, step: int = 0,
               manager: Optional[CheckpointManager] = None) -> str:
    """Write a cost model as a committed checkpoint step.  The codec is
    pinned to zlib (not the zstd-preferring default), so the shipped
    in-repo artifact restores in environments without optional
    compressors installed."""
    mgr = manager if manager is not None else CheckpointManager(
        ckpt_dir, codec="zlib")
    return mgr.save(step, model_state(model), blocking=True)


def load_model(ckpt_dir: str, step: Optional[int] = None):
    """Load (model, step) from a checkpoint written by `save_model`."""
    mgr = CheckpointManager(ckpt_dir)
    state, step = mgr.restore_any(step)
    return model_from_state(state), step


def save_plan(plan: SpmvPlan, ckpt_dir: str, step: int = 0,
              manager: Optional[CheckpointManager] = None) -> str:
    """Write the plan as a committed checkpoint step.  Returns the step dir."""
    mgr = manager if manager is not None else CheckpointManager(ckpt_dir)
    return mgr.save(step, plan_state(plan), blocking=True)


def load_plan(ckpt_dir: str, step: Optional[int] = None, mesh=None
              ) -> Tuple[SpmvPlan, int]:
    """Load (plan, step) from a plan checkpoint written by `save_plan`.

    `mesh=` rebinds a row-sharded plan to this process's devices (meshes
    are never serialized).
    """
    mgr = CheckpointManager(ckpt_dir)
    state, step = mgr.restore_any(step)
    return plan_from_state(state, mesh=mesh), step
