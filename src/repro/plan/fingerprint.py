"""Content fingerprints for sparse-matrix containers.

A plan is only reusable while the matrix it froze is byte-identical, so
the cache key is a digest of the container's actual contents (shape,
dtype, and raw array bytes), not its object identity: two CSRs built
from the same COO stream fingerprint equal, and flipping one stored
value changes the digest (content-addressed invalidation -- no epoch or
dirty-bit protocol needed).

Fingerprinting is host-side and requires concrete (non-tracer) arrays;
`is_concrete` is the guard callers use before touching plan machinery
from inside a jitted region.
"""
from __future__ import annotations

import hashlib
import weakref

import jax
import numpy as np


def is_concrete(container) -> bool:
    """True when every array leaf is a concrete (host-readable) array."""
    return not any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree_util.tree_leaves(container))


def fingerprint_arrays(*arrays, extra: str = "") -> str:
    """blake2b digest over array shapes, dtypes, and raw bytes."""
    h = hashlib.blake2b(digest_size=16)
    h.update(extra.encode())
    for a in arrays:
        a = np.asarray(a)
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


# id -> (weakref, digest).  Hashing is O(container bytes), so the hot
# paths (spmv's per-call cache lookup) must not redo it per multiply:
# the digest is memoized per *object*, with a weakref callback evicting
# the entry on collection so a recycled id can never serve a stale
# digest.  Containers are frozen pytrees of immutable arrays; mutating
# one's underlying buffer in place is outside the content-addressing
# contract.
#
# The callback keeps the memo bounded by *live* containers; `_MEMO_CAP`
# is the backstop for the pathological case of that many containers
# held alive at once (a long-running serve fleet pinning every graph it
# ever saw) -- past it, the oldest entries are dropped FIFO and simply
# re-hash on next use.
_FP_MEMO: dict = {}
_DELTA_MEMO: dict = {}       # same discipline, for EdgeDelta digests
_MEMO_CAP = 4096


def _memo_put(memo: dict, key: int, obj, fp: str) -> None:
    try:
        ref = weakref.ref(obj, lambda _, k=key, m=memo: m.pop(k, None))
    except TypeError:
        return                          # not weakref-able: skip the memo
    memo[key] = (ref, fp)
    while len(memo) > _MEMO_CAP:
        memo.pop(next(iter(memo)))


def forget_fingerprint(matrix) -> str | None:
    """Drop `matrix`'s memoized digest, returning the stale digest if one
    was memoized for this exact object.

    Mutating a container's underlying buffers in place is outside the
    content-addressing contract (the per-object memo would keep serving
    the pre-mutation digest); callers that do it anyway use this to evict
    the memo -- `PlanCache.invalidate(matrix)` wraps it so both the stale
    and the re-hashed entries are dropped in one call.
    """
    entry = _FP_MEMO.pop(id(matrix), None)
    if entry is not None and entry[0]() is matrix:
        return entry[1]
    return None


def matrix_fingerprint(matrix) -> str:
    """Digest of any supported container (CSR/ELL/BELL/DIA or dense).

    The container type participates in the digest, so a CSR and the DIA
    converted from it do not collide even when they encode the same
    values.  Memoized per container object (O(1) after the first call).
    """
    key = id(matrix)
    entry = _FP_MEMO.get(key)
    if entry is not None and entry[0]() is matrix:
        return entry[1]
    leaves = jax.tree_util.tree_leaves(matrix)
    fp = fingerprint_arrays(*leaves, extra=type(matrix).__name__)
    _memo_put(_FP_MEMO, key, matrix, fp)
    return fp


def delta_fingerprint(delta) -> str:
    """Digest of an `EdgeDelta`'s contents (coordinates, values, delete
    flags, shape).  Memoized per delta object with the same weakref
    discipline as `matrix_fingerprint` -- a delta hashes once no matter
    how many overlay generations carry it."""
    key = id(delta)
    entry = _DELTA_MEMO.get(key)
    if entry is not None and entry[0]() is delta:
        return entry[1]
    fp = fingerprint_arrays(
        delta.rows, delta.cols, delta.vals, delta.deletes,
        extra=f"EdgeDelta:{delta.n_rows}x{delta.n_cols}")
    _memo_put(_DELTA_MEMO, key, delta, fp)
    return fp


def chain_fingerprint(base_fp: str, delta_fp: str) -> str:
    """Fingerprint of base + delta, derived from the two digests alone.

    This is what makes the streaming plan lifecycle O(delta) instead of
    O(matrix): the base matrix is NEVER re-hashed when a delta arrives
    -- its frozen digest is chained with the delta's digest, and chains
    compose (overlay generation k hashes only batch k).  Two different
    batch histories reaching the same net matrix get different chained
    digests; that is deliberately conservative -- both keys still map to
    correct plans for the matrix they describe."""
    h = hashlib.blake2b(digest_size=16)
    h.update(b"chain:")
    h.update(base_fp.encode())
    h.update(b"+")
    h.update(delta_fp.encode())
    return h.hexdigest()
