"""`SpmvPlan` — the frozen decision chain for one matrix.

A plan captures everything the per-call stack used to redo on every
`spmv()` invocation: the structure report, the chosen reordering, the
converted storage format, and the pre-padded Pallas kernel layout.
`execute()` is the amortized hot path: it performs zero structure
analysis, zero reordering, zero format conversion, and zero matrix-side
layout padding — only the x gather/scatter transport (when reordered),
the per-call x pad, and the kernel itself.

Repeated-traffic surfaces built on a plan:

  * `execute(x)`        one multiply, bit-identical to the per-call
                        `core.spmv.spmv(fmt, x, use_pallas=True)` path
                        (same prepared layout, same kernel);
  * `execute_many(X)`   batched multi-vector SpMV (SpMM): the vectorized
                        jnp format kernel vmapped over the leading axis
                        of X, jitted once per plan;
  * `power_iteration`   iterative driver (paper §I: repeated SpMV drives
                        eigensolvers) that amortizes one plan across all
                        iterations;
  * `address_trace`     the cached telemetry demand trace, so sweeps
                        replay one plan across the whole axis grid.

Plans serialize through `repro.plan.serial` (backed by
`repro.checkpoint`), so a planned matrix survives restart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.formats import BELL, CSR, DIA, ELL, HYB
from repro.kernels import _layout as kl


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _jnp_kernels():
    """Container type -> vectorized jnp reference kernel (late import:
    `core.spmv` is a thin client of this package)."""
    from repro.core.spmv import (spmv_bell_jnp, spmv_csr_jnp, spmv_dia_jnp,
                                 spmv_ell_jnp, spmv_hyb_jnp)

    return {CSR: spmv_csr_jnp, ELL: spmv_ell_jnp,
            BELL: spmv_bell_jnp, DIA: spmv_dia_jnp, HYB: spmv_hyb_jnp}


@dataclasses.dataclass
class SpmvPlan:
    """Compiled, reusable execution plan for one matrix.

    Obtain via `repro.plan.compile` (or `PlanCache.get_or_compile`); the
    constructor is an implementation detail shared with `serial.load_plan`.
    """

    fingerprint: str                 # digest of the ORIGINAL matrix
    format_name: str                 # 'dia'|'bell'|'ell'|'csr'|'csr-seg'|'hyb'|'ell-sharded'
    container: Any                   # converted format container (post-reorder)
    prep: Any                        # Prepared* / PaddedCSR / ShardedELL layout
    reordering: Any = None           # repro.reorder.Reordering or None
    report: Any = None               # StructureReport of the (permuted) matrix
    csr: Any = None                  # post-reorder CSR (trace / SpMM source)
    threads: int = 1
    use_pallas: bool = True
    interpret: Optional[bool] = None
    semiring: str = "plus_times"     # (⊕, ⊗) pair the kernels run under
    predicted: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    chosen: str = "none"             # winning (reordering) candidate label
    compile_stats: Dict[str, float] = dataclasses.field(default_factory=dict)
    mesh: Any = None                 # sharded plans only; never serialized
    _many_fn: Any = dataclasses.field(default=None, repr=False, compare=False)
    _traces: Dict = dataclasses.field(default_factory=dict, repr=False,
                                      compare=False)

    # -- geometry -----------------------------------------------------------

    @property
    def n_rows(self) -> int:
        src = self.container if self.container is not None else self.prep
        return int(src.n_rows)

    @property
    def n_cols(self) -> int:
        src = self.container if self.container is not None else self.prep
        return int(src.n_cols)

    def _semiring(self):
        """Resolved `Semiring` object, or None for plus-times (the
        historical bit-exact kernel paths take the None branch)."""
        if self.semiring == "plus_times":
            return None
        from repro.graph.semiring import resolve
        return resolve(self.semiring)

    # -- execution ----------------------------------------------------------

    def execute(self, x: jax.Array, interpret: Optional[bool] = None
                ) -> jax.Array:
        """y = A @ x through the frozen plan (original row/col order)."""
        x = jnp.asarray(x)
        if self.reordering is not None:
            y = self._run(self.reordering.permute_x(x), interpret)
            return self.reordering.restore_y(y)
        return self._run(x, interpret)

    __call__ = execute

    def _run(self, x: jax.Array, interpret: Optional[bool]) -> jax.Array:
        if not self.use_pallas:
            return self._jnp_kernel()(x)
        interpret = _resolve_interpret(
            self.interpret if interpret is None else interpret)
        sr = self._semiring()
        if self.format_name == "ell-sharded":
            from repro.distributed.spmv import spmv_row_sharded_prepared
            if sr is not None:
                raise ValueError("sharded plans are plus-times only")
            if self.mesh is None:
                raise ValueError("sharded plan has no mesh bound; pass "
                                 "mesh= to load_plan or set plan.mesh")
            return spmv_row_sharded_prepared(self.prep, x, self.mesh,
                                             interpret=interpret)
        if sr is not None:
            if self.format_name not in ("ell", "csr", "csr-seg", "hyb"):
                raise ValueError(
                    f"semiring {self.semiring!r} plans support "
                    f"ell/csr/csr-seg/hyb, not {self.format_name!r}")
            runners = {"ell": kl.spmv_ell_prepared,
                       "csr": kl.spmv_csr_prepared,
                       "csr-seg": kl.spmv_csr_seg_prepared,
                       "hyb": kl.spmv_hyb_prepared}
            return runners[self.format_name](self.prep, x,
                                             interpret=interpret, semiring=sr)
        runners = {
            "dia": kl.spmv_dia_prepared,
            "bell": kl.spmv_bell_prepared,
            "ell": kl.spmv_ell_prepared,
            "csr": kl.spmv_csr_prepared,
            "csr-seg": kl.spmv_csr_seg_prepared,
            "hyb": kl.spmv_hyb_prepared,
        }
        return runners[self.format_name](self.prep, x, interpret=interpret)

    def _source_container(self):
        container = self.container if self.container is not None else self.csr
        if container is None:
            raise ValueError(
                "plan retains no container or CSR (compiled with "
                "keep_csr=False); recompile with keep_csr=True to use the "
                "jnp/SpMM paths")
        return container

    def _jnp_kernel(self):
        container = self._source_container()
        sr = self._semiring()
        if sr is not None:
            from repro.graph.semiring import spmv_semiring_jnp
            return lambda xv: spmv_semiring_jnp(container, xv, sr)
        kern = _jnp_kernels()[type(container)]
        return lambda xv: kern(container, xv)

    # -- repeated-traffic surfaces ------------------------------------------

    def execute_many(self, X: jax.Array) -> jax.Array:
        """Batched multi-vector SpMV (SpMM path): Y[k] = A @ X[k].

        Uses the vectorized jnp format kernel vmapped over the leading
        axis (one fused SpMM, not a Python loop of Pallas launches),
        jitted once per plan and reused across calls.  Matches
        `execute` per vector up to float summation-order tolerance.
        """
        X = jnp.asarray(X)
        if X.ndim != 2:
            raise ValueError(f"execute_many expects (k, n_cols), got {X.shape}")
        if self._many_fn is None:
            self._many_fn = self._build_many()
        return self._many_fn(X)

    def _build_many(self):
        base = self._jnp_kernel()       # semiring-aware one-vector body
        if self.reordering is not None:
            cp = jnp.asarray(self.reordering.col_perm)
            irp = jnp.asarray(self.reordering.inv_row_perm)

            def one(xv):
                return jnp.take(base(jnp.take(xv, cp, axis=0)),
                                irp, axis=0)
        else:
            one = base
        return jax.jit(jax.vmap(one))

    def power_iteration(self, x0: jax.Array, n_iters: int = 16):
        """Dominant-eigenpair driver over the cached plan (paper §I's
        repeated-SpMV analytics).  Returns (eigenvalue estimate, vector)."""
        x = jnp.asarray(x0)
        lam = jnp.array(0.0, x.dtype)
        for _ in range(n_iters):
            y = self.execute(x)
            lam = jnp.linalg.norm(y)
            x = y / jnp.maximum(lam, 1e-30)
        return lam, x

    # -- telemetry ----------------------------------------------------------

    def address_trace(self, machine):
        """The SpMV demand-address trace of the planned (permuted) matrix,
        computed once per machine and cached — telemetry sweeps replay this
        one trace across the whole mechanism/thread grid.

        The trace is FORMAT-AWARE: a 'hyb' plan's trace interleaves the
        light row-major stream with the column-sorted heavy stream (the
        locality the hybrid split buys), a 'csr-seg' plan reuses the flat
        CSR stream (its win is thread balance, not stream shape)."""
        if self.csr is None:
            raise ValueError("plan was compiled with keep_csr=False; "
                             "no CSR retained for trace replay")
        if machine not in self._traces:
            from repro.telemetry.hierarchy import format_address_trace
            self._traces[machine] = format_address_trace(
                self.csr, self.format_name, machine,
                container=self.container)
        return self._traces[machine]

    # -- reporting ----------------------------------------------------------

    def summary(self) -> str:
        r = self.reordering.strategy if self.reordering is not None else "none"
        pred = self.predicted.get(self.chosen, {})
        gf = pred.get("gflops")
        gf_s = f" pred={gf:.2f}GF" if gf is not None else ""
        sr_s = "" if self.semiring == "plus_times" else f" sr={self.semiring}"
        return (f"SpmvPlan[{self.fingerprint[:8]}] fmt={self.format_name}"
                f"{sr_s} reorder={r} threads={self.threads}{gf_s}")
