"""Per-iteration cache behavior of a whole analytic, from one plan.

An iterative analytic is the same SpMV demand stream replayed once per
iteration, so its memory behavior falls out of the plan's memoized
`address_trace` with zero extra tracing: instantiate one hierarchy and
replay the trace n_iters times against *warm* state, keeping one
`EventCounters` per iteration.  Iteration 1 is the cold pass; later
iterations show what survives in cache between SpMVs (x and the hot
front of the matrix arrays) -- the compounding the paper's single-SpMV
tables cannot show, and what `telemetry.sweep.graph_sweep` tabulates
across the FD / R-MAT structure axis.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.cache_model import SANDY_BRIDGE, MachineModel
from repro.telemetry.events import EventCounters
from repro.telemetry.hierarchy import HierarchySpec
from repro.telemetry.topdown import TopdownSummary, topdown_summary


def iteration_counters(plan, n_iters: int,
                       machine: MachineModel = SANDY_BRIDGE,
                       spec: Optional[HierarchySpec] = None
                       ) -> List[EventCounters]:
    """One `EventCounters` per iteration of an analytic run over `plan`.

    The hierarchy stays warm across iterations (that is the point);
    the plan must have been compiled with `keep_csr=True` (drivers do).
    """
    spec = spec if spec is not None else HierarchySpec()
    hier = spec.instantiate(machine)
    trace = plan.address_trace(machine).tolist()
    return [hier.replay(trace) for _ in range(max(int(n_iters), 1))]


def iteration_summaries(plan, n_iters: int,
                        machine: MachineModel = SANDY_BRIDGE,
                        spec: Optional[HierarchySpec] = None
                        ) -> List[TopdownSummary]:
    """`iteration_counters` flattened to topdown report rows."""
    nnz = plan.csr.nnz if plan.csr is not None else plan.n_rows
    return [topdown_summary(c, machine, max(nnz, 1))
            for c in iteration_counters(plan, n_iters, machine, spec)]


def iteration_bounds(plan, n_iters: int,
                     machine: MachineModel = SANDY_BRIDGE,
                     spec: Optional[HierarchySpec] = None) -> List[str]:
    """Per-iteration dominant bound category (staged topdown label, e.g.
    'retiring' or 'backend_dram') -- the serving path's one-word answer
    to *why* a plan's iterations cost what they cost.  Iteration 1 is
    cold; a label that changes across the list is a working set settling
    into cache."""
    return [s.bound() for s in iteration_summaries(plan, n_iters,
                                                   machine, spec)]


__all__ = ["iteration_counters", "iteration_summaries", "iteration_bounds"]
