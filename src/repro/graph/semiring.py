"""Semirings: the algebra that turns SpMV into a graph-analytics engine.

The paper's opening claim is that SpMV is "the core operation in many
common network and graph analytics".  Those analytics are iterated
*semiring* SpMVs: replace (+, *) in y[i] = SUM_j A[i,j] * x[j] with a
pluggable (add ⊕, mul ⊗) pair and the same kernel computes

    plus_times   y[i] = Σ_j   A[i,j] * x[j]     linear algebra / PageRank
    min_plus     y[i] = min_j A[i,j] + x[j]     shortest paths (SSSP)
    or_and       y[i] = OR_j  A[i,j] & x[j]     BFS reachability/frontier
    max_times    y[i] = max_j A[i,j] * x[j]     widest/most-reliable path

The access *stream* -- the thing the paper measures -- is identical for
every semiring: same gathers of x, same streaming of the matrix arrays.
Only the two scalar ops in the inner loop change, which is why the whole
`repro.plan` pipeline (structure analysis, reordering decisions, cache
prediction, telemetry traces) carries over unchanged.

A `Semiring` is shape-compatible with the Pallas kernel inner loops: the
kernels call `mul` elementwise and `reduce` along the slot axis, so an
instance must be hashable (all fields are module-level jnp functions or
floats) to ride through `jax.jit` static arguments.

Padding contract: sparse layouts pad rows/cells to fixed width, and a
padding slot must be *absorbing*: `mul(pad_value, x) == identity` for
every x the analytic can produce, so padded slots vanish under `reduce`.
plus_times pads 0.0 (0 * x = 0), min_plus pads +inf (inf + x = inf).
This is also why the dense-footprint formats (DIA bands, BELL tiles) are
plus-times-only: they materialize absent entries as stored 0.0, which is
only absorbing when ⊗ is multiplication.

Booleans are embedded in f32 {0.0, 1.0} (or_and is max_times restricted
to indicator values), so every semiring reuses the float kernels and the
float address traces unchanged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.formats import CSR, ELL, HYB


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (⊕, ⊗) pair with the identities the kernels and layouts need.

    add / mul      elementwise jnp binary ops (⊕ / ⊗)
    reduce         the jnp reduction matching `add` (sum / min / max)
    segment        the jax.ops segment reduction matching `add`
    identity       ⊕-identity: the value of an empty reduction (what an
                   all-padding row -- e.g. a vertex with no in-edges --
                   produces)
    pad_value      stored-slot fill: mul(pad_value, x) == identity
    """

    name: str
    add: Callable
    mul: Callable
    reduce: Callable
    segment: Callable
    identity: float
    pad_value: float

    def __repr__(self) -> str:          # stable across runs: cache-key safe
        return f"Semiring({self.name})"


PLUS_TIMES = Semiring("plus_times", jnp.add, jnp.multiply, jnp.sum,
                      jax.ops.segment_sum, 0.0, 0.0)
MIN_PLUS = Semiring("min_plus", jnp.minimum, jnp.add, jnp.min,
                    jax.ops.segment_min, math.inf, math.inf)
# or_and over {0.0, 1.0} indicators: AND is *, OR is max.
OR_AND = Semiring("or_and", jnp.maximum, jnp.multiply, jnp.max,
                  jax.ops.segment_max, 0.0, 0.0)
# max_times is only a semiring over nonnegative values (max's identity is
# then 0, which is also the absorbing pad).
MAX_TIMES = Semiring("max_times", jnp.maximum, jnp.multiply, jnp.max,
                     jax.ops.segment_max, 0.0, 0.0)

SEMIRINGS = {s.name: s for s in (PLUS_TIMES, MIN_PLUS, OR_AND, MAX_TIMES)}


def resolve(semiring: Union[str, Semiring, None]) -> Semiring:
    """Name | instance | None (-> plus_times) to a registry `Semiring`."""
    if semiring is None:
        return PLUS_TIMES
    if isinstance(semiring, Semiring):
        return semiring
    return SEMIRINGS[semiring]


# ---------------------------------------------------------------------------
# Semiring jnp reference kernels (the oracles for the generalized Pallas
# paths, and the vmappable bodies behind `SpmvPlan.execute_many`)
# ---------------------------------------------------------------------------

def spmv_ell_semiring_jnp(ell: ELL, x: jax.Array, sr: Semiring) -> jax.Array:
    """y[i] = ⊕_slots  data[i, s] ⊗ x[idx[i, s]].

    The ELL container must have been built with `fill=sr.pad_value`
    (`ELL.from_csr(..., fill=...)`) so its padding slots are absorbing.
    Zero-width containers (nnz=0 matrices) reduce to the ⊕-identity.
    """
    if ell.data.shape[1] == 0:
        return jnp.full((ell.n_rows,), sr.identity, ell.data.dtype)
    return sr.reduce(sr.mul(ell.data, jnp.take(x, ell.indices, axis=0)),
                     axis=1)


def spmv_csr_semiring_jnp(csr: CSR, x: jax.Array, sr: Semiring) -> jax.Array:
    """Gather + segment-⊕ over row ids; empty rows get the ⊕-identity
    (jax's segment_min/max fill empty segments with +/-inf, which is only
    right for min_plus -- the where() fixes the rest)."""
    nnz = csr.data.shape[0]
    lengths = jnp.diff(csr.indptr)
    if nnz == 0:
        return jnp.full((csr.n_rows,), sr.identity, csr.data.dtype)
    row_ids = jnp.repeat(jnp.arange(csr.n_rows), lengths,
                         total_repeat_length=nnz)
    prods = sr.mul(csr.data, jnp.take(x, csr.indices, axis=0))
    y = sr.segment(prods, row_ids, num_segments=csr.n_rows)
    return jnp.where(lengths > 0, y,
                     jnp.asarray(sr.identity, y.dtype))


def spmv_hyb_semiring_jnp(hyb: HYB, x: jax.Array, sr: Semiring) -> jax.Array:
    """Light ELL partial ⊕ heavy segment-⊕.  Heavy rows are all-padding
    in the light slab (absorbing fill -> ⊕-identity there) and light rows
    are absent from the heavy stream (masked to the ⊕-identity here), so
    the join is exact.  Requires `HYB.from_csr(..., fill=sr.pad_value)`.
    """
    light = ELL(data=hyb.data, indices=hyb.indices, n_rows=hyb.n_rows,
                n_cols=hyb.n_cols, max_nnz=hyb.light_width)
    y = spmv_ell_semiring_jnp(light, x, sr)
    if hyb.hvals.shape[0] == 0:
        return y
    prods = sr.mul(hyb.hvals, jnp.take(x, hyb.hcols, axis=0))
    h = sr.segment(prods, hyb.hrows, num_segments=hyb.n_rows)
    counts = jax.ops.segment_sum(jnp.ones_like(prods), hyb.hrows,
                                 num_segments=hyb.n_rows)
    h = jnp.where(counts > 0, h, jnp.asarray(sr.identity, h.dtype))
    return sr.add(y, h)


def spmv_semiring_jnp(container, x: jax.Array, sr: Semiring) -> jax.Array:
    """Dispatch on container type (ELL, CSR and HYB only -- see the
    padding contract in the module docstring for why DIA/BELL are
    excluded)."""
    if isinstance(container, HYB):
        return spmv_hyb_semiring_jnp(container, x, sr)
    if isinstance(container, ELL):
        return spmv_ell_semiring_jnp(container, x, sr)
    if isinstance(container, CSR):
        return spmv_csr_semiring_jnp(container, x, sr)
    raise TypeError(
        f"semiring SpMV supports ELL, CSR and HYB, got "
        f"{type(container).__name__}"
        " (dense-footprint formats store absent entries as 0.0, which is "
        "only absorbing under plus_times)")


__all__ = ["Semiring", "PLUS_TIMES", "MIN_PLUS", "OR_AND", "MAX_TIMES",
           "SEMIRINGS", "resolve", "spmv_ell_semiring_jnp",
           "spmv_csr_semiring_jnp", "spmv_hyb_semiring_jnp",
           "spmv_semiring_jnp"]
