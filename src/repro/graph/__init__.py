"""Semiring graph analytics on the compile-once SpMV pipeline.

The paper's motivating workloads ("network and graph analytics", §I)
as iterated semiring SpMVs over `repro.plan`:

  * `semiring`  -- the (⊕, ⊗) algebra: plus_times / min_plus / or_and /
                   max_times, with the absorbing-padding contract the
                   generalized Pallas kernels rely on
  * `drivers`   -- pagerank, bfs, sssp, connected_components: compile a
                   plan once, iterate `execute`/`execute_many` with
                   host-side convergence checks; each analytic is
                   factored into an operand builder + a per-iteration
                   stepper (`ANALYTICS` / `make_stepper`), the
                   step-function API `repro.serve_graph` batches across
                   concurrent requests
  * `telemetry` -- per-iteration cache counters from the plan's memoized
                   address trace (feeds `telemetry.sweep.graph_sweep`)
"""
from .drivers import (ANALYTICS, DRIVERS, AnalyticDef, GraphResult,
                      analytic_operand, bfs, check_sources,
                      connected_components, make_stepper, pagerank,
                      plan_options, sssp, transpose_csr,
                      warm_start_params)
from .semiring import (MAX_TIMES, MIN_PLUS, OR_AND, PLUS_TIMES, SEMIRINGS,
                       Semiring, resolve, spmv_csr_semiring_jnp,
                       spmv_ell_semiring_jnp, spmv_semiring_jnp)
from .telemetry import iteration_counters, iteration_summaries

__all__ = [
    "Semiring", "SEMIRINGS", "PLUS_TIMES", "MIN_PLUS", "OR_AND", "MAX_TIMES",
    "resolve", "spmv_ell_semiring_jnp", "spmv_csr_semiring_jnp",
    "spmv_semiring_jnp",
    "GraphResult", "DRIVERS", "pagerank", "bfs", "sssp",
    "connected_components", "transpose_csr",
    "AnalyticDef", "ANALYTICS", "analytic_operand", "make_stepper",
    "check_sources", "plan_options", "warm_start_params",
    "iteration_counters", "iteration_summaries",
]
