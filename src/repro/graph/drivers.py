"""Iterative graph-analytics drivers on compiled `SpmvPlan`s.

The paper motivates SpMV as "the core operation in many common network
and graph analytics" -- these drivers are those analytics, each one an
iterated semiring SpMV over a plan compiled ONCE:

    pagerank              plus_times  on the column-stochastic transpose
    bfs                   or_and      frontier propagation (hop depths)
    sssp                  min_plus    Bellman-Ford relaxation
    connected_components  min_plus    label propagation (zero weights)

Every analytic is factored into three pieces so both the blocking
drivers here and the `repro.serve_graph` engine can run it:

  * an **operand builder** (`analytic_operand`) -- host-side derivation
    of the matrix the iteration multiplies (stochastic transpose,
    pattern transpose, symmetrized zero-weight adjacency) plus any
    auxiliary vectors (PageRank's dangling mask);
  * a **stepper** (`make_stepper`) -- the per-iteration state machine:
    `frontier()` yields the (k, n) batch the next SpMV consumes,
    `advance(y)` folds the product back in, updates per-lane
    convergence, and returns the iteration's progress scalar;
  * the **SpMV itself**, which the *caller* owns: the drivers below loop
    `plan.execute` / `plan.execute_many`, while the serving engine
    coalesces frontiers from many concurrent requests over the same
    graph into one batched `execute_many` per step.

The per-iteration cost is therefore exactly the paper's object of study:
one SpMV's worth of memory traffic, nothing else -- which is what lets
`telemetry.sweep.graph_sweep` replay a whole analytic from the plan's
memoized address trace.

Graph convention: the input is a square CSR adjacency with A[i, j] != 0
meaning an edge i -> j (weight = stored value).  SpMV computes
y[i] = ⊕_j A[i,j] ⊗ x[j] -- a *pull* along rows -- so push-style
traversals (BFS/SSSP from a source) run on the transpose, built once at
plan-compile time.  Undirected graphs should be stored symmetrically
(generators' FD/R-MAT matrices are fine as-is).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSR

from .semiring import MIN_PLUS, OR_AND, PLUS_TIMES, Semiring


@dataclasses.dataclass
class GraphResult:
    """Outcome of one analytic run.

    values    the analytic's vector: PageRank scores, hop depths,
              distances, or component labels ((k, n) for multi-source)
    n_iters   SpMV iterations executed
    converged True when the fixpoint/tolerance was reached before
              `max_iters`
    history   one scalar per iteration (residual / frontier size /
              labels changed) -- the convergence trajectory
    plan      the compiled `SpmvPlan` the iterations executed through
              (its memoized `address_trace` is what telemetry replays)
    """

    values: np.ndarray
    n_iters: int
    converged: bool
    history: List[float]
    plan: object

    def summary(self) -> str:
        tail = f"{self.history[-1]:.3g}" if self.history else "-"
        return (f"{self.plan.summary()} iters={self.n_iters} "
                f"converged={self.converged} last={tail}")


def transpose_csr(csr: CSR) -> CSR:
    """A^T as a canonically sorted CSR (host-side, plan-compile time)."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    return CSR.from_coo(np.asarray(csr.indices, dtype=np.int64), rows,
                        np.asarray(csr.data), csr.n_cols, csr.n_rows,
                        dtype=np.asarray(csr.data).dtype)


def _require_square(adj: CSR, who: str) -> int:
    if adj.n_rows != adj.n_cols:
        raise ValueError(f"{who} needs a square adjacency, "
                         f"got {adj.n_rows}x{adj.n_cols}")
    return adj.n_rows


def check_sources(source, n: int, who: str = "analytic") -> np.ndarray:
    """Validate and normalize a source spec to an int64 array.

    Empty and duplicate sources are well-defined (zero lanes / equal
    lanes); out-of-range indices are refused up front with a clear error
    instead of surfacing as an IndexError deep in the frontier setup.
    """
    sources = np.atleast_1d(np.asarray(source, dtype=np.int64))
    if sources.ndim != 1:
        raise ValueError(f"{who} sources must be a scalar or 1-D sequence, "
                         f"got shape {sources.shape}")
    if sources.size and (sources.min() < 0 or sources.max() >= n):
        bad = sources[(sources < 0) | (sources >= n)]
        raise ValueError(f"{who} sources out of range for n={n}: "
                         f"{bad.tolist()}")
    return sources


def _graph_plan(matrix: CSR, semiring, *, reorder, plan_cache, format=None,
                use_pallas=True, interpret=None):
    """Compile-once entry shared by every driver: plans land in the
    process-wide `plan.DEFAULT_CACHE` (or a caller-supplied `PlanCache`),
    so re-running an analytic -- or a different analytic over the same
    derived matrix -- recompiles nothing."""
    from repro import plan as _plan

    cache = plan_cache if plan_cache is not None else _plan.DEFAULT_CACHE
    return cache.get_or_compile(matrix, **plan_options(
        semiring, reorder=reorder, format=format, use_pallas=use_pallas,
        interpret=interpret))


def plan_options(semiring, *, reorder="none", predictor="none", format=None,
                 use_pallas=True, interpret=None) -> Dict:
    """The exact compile-option dict the drivers use -- shared with
    `serve_graph` admission so its warm-pool check (`PlanCache.key_for`)
    and its compiles produce the same cache keys the drivers would.

    `predictor` defaults to 'none' (no candidate scoring), preserving the
    historical cache keys; pass 'model'/'oracle' together with
    `reorder='auto'` when the engine should pick reorderings per graph.
    """
    name = semiring.name if isinstance(semiring, Semiring) else str(semiring)
    opts = dict(reorder=reorder, predictor=predictor, semiring=name,
                use_pallas=use_pallas, interpret=interpret, keep_csr=True)
    if format is not None:
        opts["format"] = format
    return opts


# ---------------------------------------------------------------------------
# Operand builders: adjacency -> the matrix the iteration multiplies
# ---------------------------------------------------------------------------

def pagerank_operand(adj: CSR) -> Tuple[CSR, Dict]:
    """Column-stochastic transpose P[j, i] = 1/out_deg[i] per edge i -> j,
    plus the dangling-vertex mask the iteration redistributes."""
    n = _require_square(adj, "pagerank")
    indptr = np.asarray(adj.indptr, dtype=np.int64)
    cols = np.asarray(adj.indices, dtype=np.int64)
    out_deg = np.diff(indptr).astype(np.float32)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    stoch = CSR.from_coo(cols, rows,
                         1.0 / np.maximum(out_deg[rows], 1.0), n, n)
    return stoch, {"dangling": (out_deg == 0).astype(np.float32)}


def bfs_operand(adj: CSR) -> Tuple[CSR, Dict]:
    """0/1 pattern of A^T: or_and propagation pulls each vertex's
    frontier membership from its in-neighbors along original edges."""
    n = _require_square(adj, "bfs")
    at = transpose_csr(adj)
    pattern = CSR(data=jnp.ones_like(at.data), indices=at.indices,
                  indptr=at.indptr, n_rows=n, n_cols=n)
    return pattern, {}


def sssp_operand(adj: CSR) -> Tuple[CSR, Dict]:
    _require_square(adj, "sssp")
    return transpose_csr(adj), {}


def cc_operand(adj: CSR) -> Tuple[CSR, Dict]:
    """Symmetrized zero-weight pattern: min_plus SpMV then computes each
    vertex's minimum neighbor label."""
    n = _require_square(adj, "connected_components")
    if n > (1 << 24):
        raise ValueError(
            f"connected_components labels are f32 vertex ids, which are "
            f"only injective up to 2^24; got n={n}")
    indptr = np.asarray(adj.indptr, dtype=np.int64)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    cols = np.asarray(adj.indices, dtype=np.int64)
    # deduplicate coordinates (an edge stored in both directions would
    # symmetrize to a doubled entry): the min_plus reduction is
    # unaffected, and canonical duplicate-free operands are what the
    # streaming lifecycle's csr_diff requires
    keys = np.unique(np.concatenate([rows * n + cols, cols * n + rows]))
    sym = CSR.from_coo(keys // n, keys % n,
                       np.zeros(keys.size, dtype=np.float32), n, n)
    return sym, {}


# ---------------------------------------------------------------------------
# Steppers: per-iteration state machines (frontier -> SpMV -> advance)
# ---------------------------------------------------------------------------

class PageRankStepper:
    """Power iteration on the stochastic transpose, k lanes.

    Without sources each lane teleports uniformly (classic PageRank, the
    historical driver semantics); a source lane teleports to its seed
    vertex instead -- personalized PageRank, which is what makes
    multi-source serving requests produce genuinely distinct lanes.
    """

    analytic = "pagerank"

    def __init__(self, plan, aux: Dict, sources=(), damping: float = 0.85,
                 tol: float = 1e-8, r0=None):
        n = plan.n_cols
        sources = check_sources(sources, n, "pagerank") if len(
            np.atleast_1d(sources)) else np.array([], dtype=np.int64)
        self.plan, self.damping, self.tol = plan, float(damping), float(tol)
        self.dangling = jnp.asarray(aux["dangling"])
        if sources.size:
            t = np.zeros((len(sources), n), np.float32)
            t[np.arange(len(sources)), sources] = 1.0
        else:
            t = np.full((1, n), 1.0 / max(n, 1), np.float32)
        self.teleport = jnp.asarray(t)
        if r0 is not None:
            r = jnp.asarray(r0, jnp.float32)
            r = r.reshape(1, n) if r.ndim == 1 else r
            if r.shape != self.teleport.shape:
                raise ValueError(
                    f"r0 shape {tuple(r.shape)} does not match the "
                    f"{tuple(self.teleport.shape)} lane layout")
            r = r / jnp.maximum(r.sum(axis=1, keepdims=True), 1e-30)
        else:
            r = self.teleport
        self.r = r
        self.k = int(r.shape[0])
        self.lane_done = np.zeros(self.k, bool)
        self.done = self.k == 0

    def frontier(self):
        return self.r

    def advance(self, y) -> float:
        y = jnp.asarray(y)
        leaked = self.r @ self.dangling                       # (k,)
        r_new = (self.damping * (y + leaked[:, None] * self.teleport)
                 + (1.0 - self.damping) * self.teleport)
        resid = np.asarray(jnp.abs(r_new - self.r).sum(axis=1))
        self.r = r_new
        self.lane_done = resid < self.tol
        self.done = bool(self.lane_done.all())
        return float(resid.max()) if resid.size else 0.0

    def values(self) -> np.ndarray:
        return np.asarray(self.r)


class BfsStepper:
    """or_and frontier propagation; `values()[l, v]` is v's hop depth
    from lane l's source (+inf if unreachable).  Duplicate sources are
    fine (equal lanes); zero sources is a zero-lane no-op run.

    No warm-start: depths are assigned level-synchronously (a vertex's
    depth is the global `level` counter the step its frontier bit first
    rises), so even an insert-only delta can LOWER finite depths --
    resuming from old depths would keep the stale values.  Any delta
    re-seeds BFS (`warm_start_params` returns None)."""

    analytic = "bfs"

    def __init__(self, plan, aux: Dict, sources=(), **_):
        n = plan.n_cols
        sources = check_sources(sources, n, "bfs")
        k = len(sources)
        self.plan, self.k, self.level = plan, k, 0
        self.depth = np.full((k, n), np.inf, dtype=np.float32)
        self.depth[np.arange(k), sources] = 0.0
        self.front = np.zeros((k, n), dtype=np.float32)
        self.front[np.arange(k), sources] = 1.0
        self.done = not self.front.any()
        self.lane_done = ~self.front.any(axis=1)

    def frontier(self):
        return self.front

    def advance(self, y) -> float:
        y = np.asarray(y)
        self.level += 1
        reached = (y > 0.0) & np.isinf(self.depth)
        self.depth[reached] = self.level
        self.front = reached.astype(np.float32)
        self.lane_done = ~self.front.any(axis=1)
        self.done = not self.front.any()
        return float(reached.sum())

    def values(self) -> np.ndarray:
        return self.depth


class SsspStepper:
    """min_plus Bellman-Ford relaxation, k source lanes.

    `d0` warm-starts from prior distances (shape (k, n) matching the
    sources): after insert-only edge deltas the old converged distances
    are valid upper bounds, so relaxation resumes from them and only
    re-settles the vertices the new edges improved.  Deletes can RAISE
    true distances, which monotone relaxation can never do -- callers
    must re-seed then (`warm_start_params` encodes the rule)."""

    analytic = "sssp"

    def __init__(self, plan, aux: Dict, sources=(), d0=None, **_):
        n = plan.n_cols
        sources = check_sources(sources, n, "sssp")
        k = len(sources)
        self.plan, self.k = plan, k
        self.dist = np.full((k, n), np.inf, dtype=np.float32)
        self.dist[np.arange(k), sources] = 0.0
        if d0 is not None:
            self.dist = np.minimum(
                np.asarray(d0, np.float32).reshape(k, n), self.dist)
        self.lane_done = np.zeros(k, bool)
        self.done = k == 0

    def frontier(self):
        return self.dist

    def advance(self, y) -> float:
        nd = np.minimum(self.dist, np.asarray(y))
        changed = (nd < self.dist).sum(axis=1)
        self.dist = nd
        self.lane_done = changed == 0
        self.done = bool(self.lane_done.all())
        return float(changed.sum())

    def values(self) -> np.ndarray:
        return self.dist


class CcStepper:
    """min-label propagation to the component-wise minimum vertex id.
    Always one lane; sources are ignored.

    `l0` warm-starts from prior labels: after insert-only deltas each
    vertex's old label (the min id of its old component) is a reachable
    upper bound in the new graph, so propagation resumes and only the
    merged components re-settle.  Edge deletes can split components --
    labels would have to rise -- so deletes force a re-seed
    (`warm_start_params`)."""

    analytic = "connected_components"

    def __init__(self, plan, aux: Dict, sources=(), l0=None, **_):
        n = plan.n_cols
        self.plan, self.k = plan, 1
        self.labels = np.arange(n, dtype=np.float32)[None]
        if l0 is not None:
            self.labels = np.minimum(
                np.asarray(l0, np.float32).reshape(1, n), self.labels)
        self.lane_done = np.zeros(1, bool)
        self.done = False

    def frontier(self):
        return self.labels

    def advance(self, y) -> float:
        nl = np.minimum(self.labels, np.asarray(y))
        changed = int((nl < self.labels).sum())
        self.labels = nl
        self.lane_done[:] = changed == 0
        self.done = changed == 0
        return float(changed)

    def values(self) -> np.ndarray:
        return self.labels


@dataclasses.dataclass(frozen=True)
class AnalyticDef:
    """One analytic, decomposed for engine-driven execution."""

    name: str
    semiring: Semiring
    operand: Callable[[CSR], Tuple[CSR, Dict]]
    stepper: Callable
    source_based: bool          # lanes = sources (vs one state vector)


ANALYTICS: Dict[str, AnalyticDef] = {
    "pagerank": AnalyticDef("pagerank", PLUS_TIMES, pagerank_operand,
                            PageRankStepper, source_based=False),
    "bfs": AnalyticDef("bfs", OR_AND, bfs_operand, BfsStepper,
                       source_based=True),
    "sssp": AnalyticDef("sssp", MIN_PLUS, sssp_operand, SsspStepper,
                        source_based=True),
    "connected_components": AnalyticDef(
        "connected_components", MIN_PLUS, cc_operand, CcStepper,
        source_based=False),
}


def analytic_operand(analytic: str, adj: CSR) -> Tuple[CSR, str, Dict]:
    """(operand matrix, semiring name, aux) for one analytic -- the
    host-side derivation `serve_graph` admission performs once per
    (graph, analytic) before consulting the plan cache."""
    d = ANALYTICS.get(analytic)
    if d is None:
        raise ValueError(f"unknown analytic {analytic!r}; "
                         f"have {sorted(ANALYTICS)}")
    matrix, aux = d.operand(adj)
    return matrix, d.semiring.name, aux


def make_stepper(analytic: str, plan, aux: Dict, sources=(), params=None):
    """Instantiate the per-iteration state machine for one request."""
    d = ANALYTICS.get(analytic)
    if d is None:
        raise ValueError(f"unknown analytic {analytic!r}; "
                         f"have {sorted(ANALYTICS)}")
    return d.stepper(plan, aux, sources=sources, **(params or {}))


#: Stepper kwarg each analytic consumes to resume from prior values.
WARM_START_PARAM = {"pagerank": "r0", "sssp": "d0",
                    "connected_components": "l0"}


def warm_start_params(analytic: str, values, delta=None) -> Optional[Dict]:
    """Stepper params resuming `analytic` from converged `values` after
    edge delta `delta`, or None when correctness demands a re-seed.

    The rules (each argued in the steppers' docstrings):

      pagerank   always warm -- power iteration converges to its unique
                 fixpoint from any start; old scores are just a better
                 start than teleport;
      sssp / cc  warm after insert-only deltas (old values are valid
                 upper bounds the monotone iteration drives down to the
                 new fixpoint); deletes can raise true values, which
                 min-reductions cannot, so they re-seed;
      bfs        never warm -- level-synchronous depth assignment goes
                 stale under any delta.

    `delta` may be the adjacency delta or the derived operand delta
    (inserts map to inserts either way); None means "unknown mutation",
    treated as delete-bearing.
    """
    kw = WARM_START_PARAM.get(analytic)
    if kw is None:
        return None
    if analytic != "pagerank" and (delta is None or delta.has_deletes):
        return None
    return {kw: np.asarray(values, dtype=np.float32)}


def _drive(stepper, plan, max_iters: int, multi: bool) -> GraphResult:
    """The blocking driver loop: pull `frontier()`, run the plan, feed
    `advance()` -- single-source stays on the 1-D Pallas `execute` path
    (bit-compatible with the historical drivers), multi-source batches
    through `execute_many`."""
    history: List[float] = []
    it = 0
    while it < max_iters and not stepper.done:
        it += 1
        F = stepper.frontier()
        if multi:
            y = np.asarray(plan.execute_many(jnp.asarray(F)))
        else:
            y = np.asarray(plan.execute(jnp.asarray(F)[0]))[None]
        history.append(stepper.advance(y))
    vals = stepper.values()
    return GraphResult(values=vals if multi else vals[0], n_iters=it,
                       converged=bool(stepper.done), history=history,
                       plan=plan)


# ---------------------------------------------------------------------------
# Blocking drivers (compile one plan, iterate to convergence)
# ---------------------------------------------------------------------------

def pagerank(adj: CSR, damping: float = 0.85, tol: float = 1e-8,
             max_iters: int = 100, *, r0=None, reorder="none",
             format: Optional[str] = None, plan_cache=None,
             use_pallas: bool = True,
             interpret: Optional[bool] = None) -> GraphResult:
    """PageRank by power iteration on P = A^T D_out^{-1} (plus_times).

    Dangling vertices (zero out-degree) redistribute their mass
    uniformly, so r stays a probability distribution.  Converges when
    the L1 step residual drops below `tol`.  `r0` overrides the uniform
    start (it is normalized to sum 1) -- on near-regular graphs (FD
    grids) the uniform vector is already the fixpoint, so a perturbed
    start is what makes the iteration count meaningful there.
    """
    matrix, _, aux = analytic_operand("pagerank", adj)
    p = _graph_plan(matrix, PLUS_TIMES, reorder=reorder, format=format,
                    plan_cache=plan_cache, use_pallas=use_pallas,
                    interpret=interpret)
    st = PageRankStepper(p, aux, damping=damping, tol=tol, r0=r0)
    return _drive(st, p, max_iters, multi=False)


def bfs(adj: CSR, source: Union[int, Sequence[int]],
        max_iters: Optional[int] = None, *, reorder="none",
        format: Optional[str] = None, plan_cache=None,
        use_pallas: bool = True, interpret: Optional[bool] = None
        ) -> GraphResult:
    """Hop depths from `source` by or_and frontier propagation on A^T.

    `values[v]` is the BFS depth of v (0 at the source, +inf if
    unreachable).  A sequence of sources runs them all concurrently:
    single source iterates `plan.execute`, multi-source batches the
    frontiers through `plan.execute_many` (values then (k, n), one row
    per source -- duplicates produce equal rows, an empty sequence a
    (0, n) result).  The loop terminates on the first empty frontier --
    the normal end state, reached immediately on an edgeless (nnz=0)
    graph.
    """
    n = _require_square(adj, "bfs")
    multi = np.ndim(source) > 0
    matrix, _, aux = analytic_operand("bfs", adj)
    p = _graph_plan(matrix, OR_AND, reorder=reorder, format=format,
                    plan_cache=plan_cache, use_pallas=use_pallas,
                    interpret=interpret)
    st = BfsStepper(p, aux, sources=np.atleast_1d(
        np.asarray(source, dtype=np.int64)))
    return _drive(st, p, n if max_iters is None else max_iters, multi=multi)


def sssp(adj: CSR, source: int, max_iters: Optional[int] = None, *,
         d0=None, reorder="none", format: Optional[str] = None,
         plan_cache=None, use_pallas: bool = True,
         interpret: Optional[bool] = None) -> GraphResult:
    """Single-source shortest paths by Bellman-Ford relaxation:
    d' = d ⊕ (A^T (⊕=min, ⊗=+) d), iterated to fixpoint.

    Edge weights are the stored values (nonnegative for the shortest-path
    interpretation); unreachable vertices keep +inf.  Converges in at
    most n-1 relaxations; typically far fewer (`history` counts the
    distances lowered per iteration).  `d0` warm-starts from prior
    distances (valid after insert-only graph deltas; see `SsspStepper`).
    """
    n = _require_square(adj, "sssp")
    matrix, _, aux = analytic_operand("sssp", adj)
    p = _graph_plan(matrix, MIN_PLUS, reorder=reorder, format=format,
                    plan_cache=plan_cache, use_pallas=use_pallas,
                    interpret=interpret)
    st = SsspStepper(p, aux, sources=[source], d0=d0)
    return _drive(st, p, n if max_iters is None else max_iters, multi=False)


def connected_components(adj: CSR, max_iters: Optional[int] = None, *,
                         l0=None, reorder="none",
                         format: Optional[str] = None,
                         plan_cache=None, use_pallas: bool = True,
                         interpret: Optional[bool] = None) -> GraphResult:
    """Component labels by min-label propagation over the symmetrized
    pattern: with zero edge weights, min_plus SpMV computes each vertex's
    minimum neighbor label, and l' = l ⊕ (S (min,+) l) converges to the
    component-wise minimum vertex id.  `values[v]` is v's component label;
    isolated vertices keep their own id (empty rows reduce to +inf, which
    the ⊕ with the current label absorbs).

    Labels ride through the f32 kernels, so vertex ids must be exactly
    representable: graphs beyond 2^24 rows are refused rather than
    silently merging components whose seed ids collide in f32."""
    n = _require_square(adj, "connected_components")
    matrix, _, aux = analytic_operand("connected_components", adj)
    p = _graph_plan(matrix, MIN_PLUS, reorder=reorder, format=format,
                    plan_cache=plan_cache, use_pallas=use_pallas,
                    interpret=interpret)
    st = CcStepper(p, aux, l0=l0)
    return _drive(st, p, n if max_iters is None else max_iters, multi=False)


DRIVERS = {"pagerank": pagerank, "bfs": bfs, "sssp": sssp,
           "connected_components": connected_components}

__all__ = ["GraphResult", "transpose_csr", "pagerank", "bfs", "sssp",
           "connected_components", "DRIVERS",
           "AnalyticDef", "ANALYTICS", "analytic_operand", "make_stepper",
           "check_sources", "plan_options",
           "warm_start_params", "WARM_START_PARAM",
           "PageRankStepper", "BfsStepper", "SsspStepper", "CcStepper"]
