"""Iterative graph-analytics drivers on compiled `SpmvPlan`s.

The paper motivates SpMV as "the core operation in many common network
and graph analytics" -- these drivers are those analytics, each one an
iterated semiring SpMV over a plan compiled ONCE:

    pagerank              plus_times  on the column-stochastic transpose
    bfs                   or_and      frontier propagation (hop depths)
    sssp                  min_plus    Bellman-Ford relaxation
    connected_components  min_plus    label propagation (zero weights)

Every driver follows the same shape: build the analytic's operand matrix
host-side, `plan.get_or_compile` it (structure analysis, optional
reordering, absorbing-padded kernel layout -- all amortized across every
iteration AND across repeated driver calls on the same graph), then loop
`plan.execute` / `plan.execute_many` with a host-side convergence check.
The per-iteration cost is therefore exactly the paper's object of study:
one SpMV's worth of memory traffic, nothing else -- which is what lets
`telemetry.sweep.graph_sweep` replay a whole analytic from the plan's
memoized address trace.

Graph convention: the input is a square CSR adjacency with A[i, j] != 0
meaning an edge i -> j (weight = stored value).  SpMV computes
y[i] = ⊕_j A[i,j] ⊗ x[j] -- a *pull* along rows -- so push-style
traversals (BFS/SSSP from a source) run on the transpose, built once at
plan-compile time.  Undirected graphs should be stored symmetrically
(generators' FD/R-MAT matrices are fine as-is).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSR

from .semiring import MIN_PLUS, OR_AND, PLUS_TIMES


@dataclasses.dataclass
class GraphResult:
    """Outcome of one analytic run.

    values    the analytic's vector: PageRank scores, hop depths,
              distances, or component labels ((k, n) for multi-source)
    n_iters   SpMV iterations executed
    converged True when the fixpoint/tolerance was reached before
              `max_iters`
    history   one scalar per iteration (residual / frontier size /
              labels changed) -- the convergence trajectory
    plan      the compiled `SpmvPlan` the iterations executed through
              (its memoized `address_trace` is what telemetry replays)
    """

    values: np.ndarray
    n_iters: int
    converged: bool
    history: List[float]
    plan: object

    def summary(self) -> str:
        tail = f"{self.history[-1]:.3g}" if self.history else "-"
        return (f"{self.plan.summary()} iters={self.n_iters} "
                f"converged={self.converged} last={tail}")


def transpose_csr(csr: CSR) -> CSR:
    """A^T as a canonically sorted CSR (host-side, plan-compile time)."""
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(indptr))
    return CSR.from_coo(np.asarray(csr.indices, dtype=np.int64), rows,
                        np.asarray(csr.data), csr.n_cols, csr.n_rows,
                        dtype=np.asarray(csr.data).dtype)


def _require_square(adj: CSR, who: str) -> int:
    if adj.n_rows != adj.n_cols:
        raise ValueError(f"{who} needs a square adjacency, "
                         f"got {adj.n_rows}x{adj.n_cols}")
    return adj.n_rows


def _graph_plan(matrix: CSR, semiring, *, reorder, plan_cache, format=None,
                use_pallas=True, interpret=None):
    """Compile-once entry shared by every driver: plans land in the
    process-wide `plan.DEFAULT_CACHE` (or a caller-supplied `PlanCache`),
    so re-running an analytic -- or a different analytic over the same
    derived matrix -- recompiles nothing."""
    from repro import plan as _plan

    cache = plan_cache if plan_cache is not None else _plan.DEFAULT_CACHE
    opts = dict(reorder=reorder, predictor="none", semiring=semiring.name,
                use_pallas=use_pallas, interpret=interpret, keep_csr=True)
    if format is not None:
        opts["format"] = format
    return cache.get_or_compile(matrix, **opts)


# ---------------------------------------------------------------------------
# PageRank (plus_times)
# ---------------------------------------------------------------------------

def pagerank(adj: CSR, damping: float = 0.85, tol: float = 1e-8,
             max_iters: int = 100, *, r0=None, reorder="none",
             plan_cache=None, use_pallas: bool = True,
             interpret: Optional[bool] = None) -> GraphResult:
    """PageRank by power iteration on P = A^T D_out^{-1} (plus_times).

    Dangling vertices (zero out-degree) redistribute their mass
    uniformly, so r stays a probability distribution.  Converges when
    the L1 step residual drops below `tol`.  `r0` overrides the uniform
    start (it is normalized to sum 1) -- on near-regular graphs (FD
    grids) the uniform vector is already the fixpoint, so a perturbed
    start is what makes the iteration count meaningful there.
    """
    n = _require_square(adj, "pagerank")
    indptr = np.asarray(adj.indptr, dtype=np.int64)
    cols = np.asarray(adj.indices, dtype=np.int64)
    out_deg = np.diff(indptr).astype(np.float32)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    # P[j, i] = 1/out_deg[i] for every edge i -> j (column-stochastic)
    stoch = CSR.from_coo(cols, rows,
                         1.0 / np.maximum(out_deg[rows], 1.0), n, n)
    p = _graph_plan(stoch, PLUS_TIMES, reorder=reorder,
                    plan_cache=plan_cache, use_pallas=use_pallas,
                    interpret=interpret)
    dangling = jnp.asarray((out_deg == 0).astype(np.float32))

    if r0 is None:
        r = jnp.full((n,), 1.0 / max(n, 1), jnp.float32)
    else:
        r = jnp.asarray(r0, jnp.float32)
        r = r / jnp.maximum(r.sum(), 1e-30)
    history: List[float] = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        leaked = jnp.dot(dangling, r)
        r_new = (damping * (p.execute(r) + leaked / n)
                 + (1.0 - damping) / n)
        resid = float(jnp.abs(r_new - r).sum())
        history.append(resid)
        r = r_new
        if resid < tol:
            converged = True
            break
    return GraphResult(values=np.asarray(r), n_iters=it,
                       converged=converged, history=history, plan=p)


# ---------------------------------------------------------------------------
# BFS (or_and)
# ---------------------------------------------------------------------------

def bfs(adj: CSR, source: Union[int, Sequence[int]],
        max_iters: Optional[int] = None, *, reorder="none", plan_cache=None,
        use_pallas: bool = True, interpret: Optional[bool] = None
        ) -> GraphResult:
    """Hop depths from `source` by or_and frontier propagation on A^T.

    `values[v]` is the BFS depth of v (0 at the source, +inf if
    unreachable).  A sequence of sources runs them all concurrently:
    single source iterates `plan.execute`, multi-source batches the
    frontiers through `plan.execute_many` (values then (k, n)).  The
    loop terminates on the first empty frontier -- the normal end state,
    reached immediately on an edgeless (nnz=0) graph.
    """
    n = _require_square(adj, "bfs")
    sources = np.atleast_1d(np.asarray(source, dtype=np.int64))
    multi = np.ndim(source) > 0
    k = len(sources)
    at = transpose_csr(adj)
    pattern = CSR(data=jnp.ones_like(at.data), indices=at.indices,
                  indptr=at.indptr, n_rows=n, n_cols=n)
    p = _graph_plan(pattern, OR_AND, reorder=reorder, plan_cache=plan_cache,
                    use_pallas=use_pallas, interpret=interpret)

    depth = np.full((k, n), np.inf, dtype=np.float32)
    depth[np.arange(k), sources] = 0.0
    frontier = np.zeros((k, n), dtype=np.float32)
    frontier[np.arange(k), sources] = 1.0
    max_iters = n if max_iters is None else max_iters

    history: List[float] = []
    level = 0
    converged = False
    while level < max_iters:
        if not frontier.any():
            converged = True
            break
        level += 1
        if multi:
            y = np.asarray(p.execute_many(jnp.asarray(frontier)))
        else:
            y = np.asarray(p.execute(jnp.asarray(frontier[0])))[None]
        reached = (y > 0.0) & np.isinf(depth)
        depth[reached] = level
        frontier = reached.astype(np.float32)
        history.append(float(reached.sum()))
    else:
        converged = not frontier.any()
    return GraphResult(values=depth if multi else depth[0], n_iters=level,
                       converged=converged, history=history, plan=p)


# ---------------------------------------------------------------------------
# SSSP (min_plus)
# ---------------------------------------------------------------------------

def sssp(adj: CSR, source: int, max_iters: Optional[int] = None, *,
         reorder="none", plan_cache=None, use_pallas: bool = True,
         interpret: Optional[bool] = None) -> GraphResult:
    """Single-source shortest paths by Bellman-Ford relaxation:
    d' = d ⊕ (A^T (⊕=min, ⊗=+) d), iterated to fixpoint.

    Edge weights are the stored values (nonnegative for the shortest-path
    interpretation); unreachable vertices keep +inf.  Converges in at
    most n-1 relaxations; typically far fewer (`history` counts the
    distances lowered per iteration).
    """
    n = _require_square(adj, "sssp")
    at = transpose_csr(adj)
    p = _graph_plan(at, MIN_PLUS, reorder=reorder, plan_cache=plan_cache,
                    use_pallas=use_pallas, interpret=interpret)

    dist = np.full((n,), np.inf, dtype=np.float32)
    dist[source] = 0.0
    max_iters = n if max_iters is None else max_iters
    history: List[float] = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        relaxed = np.asarray(p.execute(jnp.asarray(dist)))
        nd = np.minimum(dist, relaxed)
        changed = int((nd < dist).sum())
        history.append(float(changed))
        dist = nd
        if changed == 0:
            converged = True
            break
    return GraphResult(values=dist, n_iters=it, converged=converged,
                       history=history, plan=p)


# ---------------------------------------------------------------------------
# Connected components (min_plus label propagation)
# ---------------------------------------------------------------------------

def connected_components(adj: CSR, max_iters: Optional[int] = None, *,
                         reorder="none", plan_cache=None,
                         use_pallas: bool = True,
                         interpret: Optional[bool] = None) -> GraphResult:
    """Component labels by min-label propagation over the symmetrized
    pattern: with zero edge weights, min_plus SpMV computes each vertex's
    minimum neighbor label, and l' = l ⊕ (S (min,+) l) converges to the
    component-wise minimum vertex id.  `values[v]` is v's component label;
    isolated vertices keep their own id (empty rows reduce to +inf, which
    the ⊕ with the current label absorbs).

    Labels ride through the f32 kernels, so vertex ids must be exactly
    representable: graphs beyond 2^24 rows are refused rather than
    silently merging components whose seed ids collide in f32."""
    n = _require_square(adj, "connected_components")
    if n > (1 << 24):
        raise ValueError(
            f"connected_components labels are f32 vertex ids, which are "
            f"only injective up to 2^24; got n={n}")
    indptr = np.asarray(adj.indptr, dtype=np.int64)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    cols = np.asarray(adj.indices, dtype=np.int64)
    sym = CSR.from_coo(np.concatenate([rows, cols]),
                       np.concatenate([cols, rows]),
                       np.zeros(2 * len(rows), dtype=np.float32), n, n)
    p = _graph_plan(sym, MIN_PLUS, reorder=reorder, plan_cache=plan_cache,
                    use_pallas=use_pallas, interpret=interpret)

    labels = np.arange(n, dtype=np.float32)
    max_iters = n if max_iters is None else max_iters
    history: List[float] = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        nl = np.minimum(labels, np.asarray(p.execute(jnp.asarray(labels))))
        changed = int((nl < labels).sum())
        history.append(float(changed))
        labels = nl
        if changed == 0:
            converged = True
            break
    return GraphResult(values=labels, n_iters=it, converged=converged,
                       history=history, plan=p)


DRIVERS = {"pagerank": pagerank, "bfs": bfs, "sssp": sssp,
           "connected_components": connected_components}

__all__ = ["GraphResult", "transpose_csr", "pagerank", "bfs", "sssp",
           "connected_components", "DRIVERS"]
