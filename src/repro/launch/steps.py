"""Step builders: the jittable (train / prefill / decode) step for one
(arch x shape), plus its in/out shardings.  Shared by the dry-run, the
training launcher and the serving engine.

Every builder returns a `LoweredPlan`:
    fn            -- the pure step function
    in_specs      -- ShapeDtypeStruct tree for .lower()
    in_shardings  -- NamedSharding tree matching in_specs
    out_shardings -- NamedSharding tree (or None leaves = compiler choice)
    donate        -- argnums donated (params/opt-state/cache buffers)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shard_rules
from repro.distributed.api import use_mesh
from repro.models import registry
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.loop import TrainConfig, make_train_step

Params = Any

# Arch -> optimizer: AdamW's 8 B/param fp32 moments do not fit for the
# >= 200B-param configs on 256 x 16 GiB chips; they use factored Adafactor
# (DESIGN.md §8 "giant-model memory honesty").
ADAFACTOR_THRESHOLD = 2.0e11


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    name = "adafactor" if cfg.param_count() > ADAFACTOR_THRESHOLD else "adamw"
    return OptimizerConfig(name=name)


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    kind: str
    fn: Callable
    in_specs: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...]

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self, mesh: Mesh):
        with use_mesh(mesh):
            return self.jitted().lower(*self.in_specs)


def _named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))


def params_and_shardings(cfg: ModelConfig, mesh: Mesh):
    api = registry.get_model(cfg)
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    pspecs = shard_rules.param_specs(params_shape, cfg, mesh)
    return api, params_shape, pspecs


# ---------------------------------------------------------------------------
# Train step plan
# ---------------------------------------------------------------------------

def build_train_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     tc: Optional[TrainConfig] = None) -> LoweredPlan:
    tc = tc or TrainConfig(optimizer=optimizer_for(cfg))
    api, params_shape, pspecs = params_and_shardings(cfg, mesh)
    opt_init, _ = make_optimizer(tc.optimizer)
    opt_shape = jax.eval_shape(opt_init, params_shape)
    ospecs = shard_rules.opt_state_specs(opt_shape, params_shape, cfg, mesh)

    batch_specs_sds = registry.input_specs(cfg, shape)
    bspecs = shard_rules.batch_specs(batch_specs_sds, mesh)

    step = make_train_step(api, tc)

    metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
    return LoweredPlan(
        kind="train",
        fn=step,
        in_specs=(params_shape, opt_shape, batch_specs_sds),
        in_shardings=(_named(pspecs, mesh), _named(ospecs, mesh),
                      _named(bspecs, mesh)),
        out_shardings=(_named(pspecs, mesh), _named(ospecs, mesh),
                       _named(metrics_spec, mesh)),
        donate=(0, 1),
    )


# ---------------------------------------------------------------------------
# Prefill plan
# ---------------------------------------------------------------------------

def build_prefill_plan(cfg: ModelConfig, shape: ShapeConfig,
                       mesh: Mesh) -> LoweredPlan:
    api, params_shape, pspecs = params_and_shardings(cfg, mesh)
    batch_sds = registry.input_specs(cfg, shape)
    bspecs = shard_rules.batch_specs(batch_sds, mesh)

    def prefill_step(params, batch):
        return api.prefill(params, batch, shape.seq_len)

    logits_cache_shape = jax.eval_shape(prefill_step, params_shape, batch_sds)
    _, cache_shape = logits_cache_shape
    cspecs = shard_rules.cache_specs(cache_shape, cfg, mesh)
    out_shardings = (None, _named(cspecs, mesh))

    return LoweredPlan(
        kind="prefill",
        fn=prefill_step,
        in_specs=(params_shape, batch_sds),
        in_shardings=(_named(pspecs, mesh), _named(bspecs, mesh)),
        out_shardings=out_shardings,
        donate=(),
    )


# ---------------------------------------------------------------------------
# Decode (serve_step) plan: one new token against a seq_len-deep cache
# ---------------------------------------------------------------------------

def build_decode_plan(cfg: ModelConfig, shape: ShapeConfig,
                      mesh: Mesh) -> LoweredPlan:
    api, params_shape, pspecs = params_and_shardings(cfg, mesh)
    sds = registry.input_specs(cfg, shape)   # {'cache', 'tokens'}
    cache_shape, tok_shape = sds["cache"], sds["tokens"]
    cspecs = shard_rules.cache_specs(cache_shape, cfg, mesh)
    tspecs = shard_rules.batch_specs(tok_shape, mesh)

    def serve_step(params, cache, tokens):
        return api.decode_step(params, cache, tokens)

    return LoweredPlan(
        kind="decode",
        fn=serve_step,
        in_specs=(params_shape, cache_shape, tok_shape),
        in_shardings=(_named(pspecs, mesh), _named(cspecs, mesh),
                      _named(tspecs, mesh)),
        out_shardings=(None, _named(cspecs, mesh)),
        donate=(1,),
    )


def build_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               **kw) -> LoweredPlan:
    if shape.kind == "train":
        return build_train_plan(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_plan(cfg, shape, mesh)
    if shape.kind == "decode":
        return build_decode_plan(cfg, shape, mesh)
    raise ValueError(shape.kind)
