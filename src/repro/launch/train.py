"""Training launcher: end-to-end driver with checkpoint/restart, heartbeat,
straggler watch and deterministic data replay.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container the mesh is whatever the host offers (1 device);
the same driver drives the production mesh on a real cluster -- everything
mesh-specific flows through launch.steps/distributed.sharding.  Fault
tolerance is exercised for real: `--fail-at-step N` kills the step loop
once at step N and the Supervisor restores from the last committed
checkpoint and replays data deterministically.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.api import use_mesh
from repro.distributed.fault import (HeartbeatMonitor, StragglerDetector,
                                     Supervisor)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import optimizer_for
from repro.models import registry
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.loop import TrainConfig, make_train_step


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = registry.get_model(cfg)
    opt = optimizer_for(cfg)
    if args.lr:
        opt = OptimizerConfig(name=opt.name, lr=args.lr,
                              warmup_steps=min(100, args.steps // 10 + 1),
                              total_steps=args.steps)
    tc = TrainConfig(optimizer=opt, remat=args.remat,
                     accum_steps=args.accum, n_steps=args.steps,
                     checkpoint_every=args.ckpt_every)
    return cfg, api, tc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject one crash at this step (fault-tolerance "
                         "demo); Supervisor restarts from the checkpoint")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg, api, tc = build(args)
    mesh = make_local_mesh(model=args.model_parallel)
    mgr = CheckpointManager(args.ckpt_dir)
    hb = HeartbeatMonitor(n_workers=1, timeout_s=300.0)
    straggler = StragglerDetector(k=3.0)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)

    step_fn_holder = {}
    failed_once = {"done": False}

    def make_state():
        """Fresh or checkpoint-restored (params, opt, step)."""
        with use_mesh(mesh):
            params = api.init(jax.random.PRNGKey(args.seed))
            opt_init, _ = make_optimizer(tc.optimizer)
            opt_state = opt_init(params)
            if "fn" not in step_fn_holder:
                step_fn_holder["fn"] = jax.jit(make_train_step(api, tc),
                                               donate_argnums=(0, 1))
            start = 0
            latest = mgr.latest_step()
            if latest is not None:
                (params, opt_state), start = mgr.restore(
                    latest, (params, opt_state))
                start += 1
                print(f"[train] restored step {latest} from {args.ckpt_dir}")
        return {"params": params, "opt": opt_state, "step": start}

    pipe = make_pipeline(data_cfg)
    losses = []

    def step_fn(state, step):
        if args.fail_at_step == step and not failed_once["done"]:
            failed_once["done"] = True
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.time()
        batch = pipe.batch_at(step)
        with use_mesh(mesh):
            params, opt_state, metrics = step_fn_holder["fn"](
                state["params"], state["opt"], batch)
        dt = time.time() - t0
        hb.beat(0, step)
        straggler.record(0, dt)
        loss = float(metrics["loss"])
        losses.append((step, loss))
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if step > 0 and step % tc.checkpoint_every == 0:
            mgr.save(step, (params, opt_state))
        return {"params": params, "opt": opt_state, "step": step + 1}

    sup = Supervisor(max_restarts=3)
    state = sup.run(make_state, step_fn, n_steps=args.steps)
    mgr.save(int(state["step"]) - 1, (state["params"], state["opt"]),
             blocking=True)
    if sup.restarts:
        print(f"[train] survived {sup.restarts} restart(s): {sup.failures}")
    print(f"[train] done at step {state['step']-1}; "
          f"final loss {losses[-1][1]:.4f}; straggler medians "
          f"{straggler.medians()}")
    return losses


if __name__ == "__main__":
    main()
