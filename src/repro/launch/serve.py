"""Serving launcher: batched-request demo over the decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --requests 16 --max-new 12

Drives the continuous-batching engine (serve/engine.py) with a synthetic
request trace: mixed prompt lengths, Poisson-ish arrivals, per-request
token budgets.  Prints per-request outputs and scheduler statistics
(pool utilization, preemptions, steps).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.serve import EngineConfig, Request, make_engine


def synthetic_requests(n: int, vocab: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(4, 48))
        prompt = rng.integers(1, vocab, size=plen).tolist()
        out.append(Request(req_id=i, prompt=prompt,
                           max_new_tokens=int(rng.integers(4, max_new + 1))))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-context", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encdec:
        raise SystemExit("serve launcher drives decoder-only archs")

    eng = make_engine(cfg, ecfg=EngineConfig(
        max_batch=args.max_batch, max_context=args.max_context,
        block_size=args.block_size, temperature=args.temperature,
        seed=args.seed))
    reqs = synthetic_requests(args.requests, cfg.vocab, args.max_new,
                              args.seed)
    t0 = time.time()
    out = eng.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"[serve] req {rid:3d}: {out[rid]}")
    stats = eng.sched.stats()
    print(f"[serve] {len(out)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/max(dt,1e-9):.1f} tok/s); stats={stats}")
    return out, stats


if __name__ == "__main__":
    main()
