import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun.jsonl]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The XLA_FLAGS line above MUST run before any jax import: it gives this
CPU-only container 512 placeholder devices so `jax.make_mesh` can build the
2 x 16 x 16 production mesh.  Nothing is ever allocated at full size -- the
inputs are ShapeDtypeStructs and only `.lower().compile()` runs.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import CONFIGS, SHAPES, applicable_shapes, get_config
from repro.launch.mesh import make_production_mesh, mesh_dict
from repro.launch.steps import build_plan, optimizer_for
from repro.roofline import analysis as roofline


def _cost_dict(compiled):
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return dict(cost)
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            if hasattr(ma, field):
                out[field] = int(getattr(ma, field))
        return out
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def tokens_per_step(shape) -> float:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch * 1.0        # decode: one token per sequence


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    toks = tokens_per_step(shape)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * toks


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hlo_dir: str | None = None, save_hlo: bool = False,
             profile: str = "baseline") -> dict:
    from repro.models import tuning
    tuning.set_profile(profile)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": mesh_dict(mesh), "n_chips": n_chips,
        "kind": shape.kind, "optimizer": optimizer_for(cfg).name,
        "profile": profile, "knobs": tuning.snapshot(),
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    t0 = time.time()
    try:
        plan = build_plan(cfg, shape, mesh)
        lowered = plan.lower(mesh)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        cost = _cost_dict(compiled)
        mem = _memory_dict(compiled)
        hlo = compiled.as_text()
        mf = model_flops(cfg, shape)
        rl = roofline.analyze(cost, hlo, n_chips=n_chips, model_flops=mf)

        rec.update(
            status="ok",
            cost={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))},
            memory=mem,
            flops_per_chip=rl.flops,
            hbm_bytes_per_chip=rl.hbm_bytes,
            collective_bytes_per_chip=rl.collective_bytes,
            collectives=rl.collectives,
            collective_counts=rl.collective_counts,
            compute_s=rl.compute_s, memory_s=rl.memory_s,
            collective_s=rl.collective_s, bottleneck=rl.bottleneck,
            model_flops=mf, useful_flops_frac=rl.useful_flops_frac,
        )
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}-pod: "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s")
        if mem and "error" not in mem:
            print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"  roofline: {rl.summary()}")
        if save_hlo and hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            tag = (f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}"
                   + ("" if profile == "baseline" else f"_{profile}"))
            with open(os.path.join(hlo_dir, tag + ".hlo.txt"), "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} x {shape_name} FAILED: {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(CONFIGS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--hlo-dir", default="experiments/hlo")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, cfg in sorted(CONFIGS.items()):
            for shape_name in applicable_shapes(cfg):
                cells.append((arch, shape_name, False))
                cells.append((arch, shape_name, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = ([False, True] if args.both_meshes else [args.multi_pod])
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = 0
    with open(args.out, "a") as f:
        for arch, shape_name, mp in cells:
            rec = run_cell(arch, shape_name, mp, hlo_dir=args.hlo_dir,
                           save_hlo=args.save_hlo, profile=args.profile)
            f.write(json.dumps(rec) + "\n")
            f.flush()
            n_ok += rec["status"] == "ok"
    print(f"[dryrun] {n_ok}/{len(cells)} cells OK -> {args.out}")
    if n_ok < len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
