"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
is the slow DCN/ICI boundary -- only (compressed) gradients cross it.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """`jax.make_mesh` across jax versions: newer jax wants explicit
    `axis_types`; on older jax (no `jax.sharding.AxisType`) meshes are
    Auto-typed already and the kwarg does not exist."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))


def mesh_dict(mesh) -> dict:
    return {name: size for name, size in
            zip(mesh.axis_names, mesh.devices.shape)}
