"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
is the slow DCN/ICI boundary -- only (compressed) gradients cross it.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh(model: int = 1):
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=_auto(2))


def mesh_dict(mesh) -> dict:
    return {name: size for name, size in
            zip(mesh.axis_names, mesh.devices.shape)}
