"""Deterministic, resumable, sharded data pipeline.

Training at 1000+ nodes needs three properties the paper's harness never
worried about but a framework must provide:

  * determinism  -- batch `i` is a pure function of (seed, i); restart at
                    step N reproduces exactly the batches N, N+1, ...
  * sharding     -- host h of H draws only its 1/H slice of the global
                    batch (no coordination, no duplicate samples);
  * resumability -- pipeline state is one integer (the step), checkpointed
                    next to the params.

Two sources: `SyntheticLM` (counter-based random tokens; used everywhere in
this container) and `PackedFileDataset` (memory-mapped token file with the
same interface, for real corpora).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    host_id: int = 0
    n_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """Counter-based RNG -> O(1) state; batch i is pure f(seed, i)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def restore(self, state: Dict[str, int]):
        self.step = int(state["step"])

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        c = self.cfg
        # independent stream per (step, host): fold both into the key
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(c.seed), step), c.host_id)
        toks = jax.random.randint(
            key, (c.host_batch, c.seq_len + 1), 0, c.vocab, dtype=jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b


class PackedFileDataset:
    """Memory-mapped uint16/uint32 token file, deterministic strided reads.

    File layout: flat token ids.  Sample j for step i is the window starting
    at ((i * global_batch + host_offset + j) * seq_len) mod usable length --
    sequential disk access, no shuffle buffer state to checkpoint.
    """

    def __init__(self, cfg: DataConfig, path: str, dtype=np.uint16):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.usable = (len(self.tokens) - 1) // cfg.seq_len
        if self.usable <= 0:
            raise ValueError(f"{path}: too few tokens for seq_len")
        self.step = 0

    def state(self):
        return {"step": self.step}

    def restore(self, state):
        self.step = int(state["step"])

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        c = self.cfg
        base = step * c.global_batch + c.host_id * c.host_batch
        rows = []
        for j in range(c.host_batch):
            w = (base + j) % self.usable
            seg = np.asarray(
                self.tokens[w * c.seq_len: w * c.seq_len + c.seq_len + 1],
                dtype=np.int32)
            rows.append(seg)
        arr = jnp.asarray(np.stack(rows))
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self):
        return self

    def __next__(self):
        b = self.batch_at(self.step)
        self.step += 1
        return b


def write_token_file(path: str, tokens: np.ndarray):
    tokens.astype(np.uint16).tofile(path)


def make_pipeline(cfg: DataConfig, path: Optional[str] = None):
    if path and os.path.exists(path):
        return PackedFileDataset(cfg, path)
    return SyntheticLM(cfg)
