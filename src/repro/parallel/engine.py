"""Deterministic multithreaded trace replay: private caches, shared LLC.

One thread per `RowPartition` part.  Each thread owns a private cache
stack (optional L1, then L2 with the §V mechanisms) and its own
sequential prefetcher; all threads assigned to a socket share one LLC
`CacheLevel` instance, so capacity contention between the threads'
streaming matrix data and the shared x working set is simulated, not
assumed.  Accesses are interleaved round-robin (one access per live
thread per round), which makes the replay deterministic: the same
partition and matrix produce bit-identical per-thread counters.

With one thread, no L1, and machine geometry the replay degenerates to
`telemetry.hierarchy.Hierarchy.default` on the full trace —
`repro.core.cache_model.simulate_exact` parity is pinned by
`tests/test_parallel.py`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.telemetry.events import EventCounters
from repro.telemetry.hierarchy import (CacheLevel, Hierarchy, MissCache,
                                       SequentialPrefetcher, StreamBuffers,
                                       VictimCache, spmv_address_trace)


@dataclasses.dataclass(frozen=True)
class ParallelSpec:
    """Declarative description of the simulated multicore.

    Private-side geometry mirrors `HierarchySpec` (None -> machine
    default / fully associative); `llc_*` describes the per-socket
    shared last level.  `l1_bytes` adds an optional private first level
    in front of the L2 (the machine-geometry default omits it so the
    1-thread replay stays bit-compatible with the single-stream path).
    """

    l1_bytes: Optional[int] = None       # private L1; None -> no L1 level
    l1_ways: Optional[int] = None
    l2_bytes: Optional[int] = None       # private L2; None -> machine default
    ways: Optional[int] = None           # L2 associativity; None -> full
    llc_bytes: Optional[int] = None      # shared per-socket LLC
    llc_ways: Optional[int] = None
    prefetcher: bool = True              # per-thread next-line prefetcher
    pf_shutoff: bool = True              # model the paper's §IV-C shutoff
    queueing: bool = True                # DRAM queueing delay near saturation
    # §V mechanisms on the private L2 miss path (composable with the
    # telemetry mechanism axis)
    victim_entries: int = 0
    miss_entries: int = 0
    stream_buffers: int = 0
    stream_depth: int = 4

    def label(self) -> str:
        parts = []
        if self.l1_bytes:
            parts.append(f"l1-{self.l1_bytes // 1024}k")
        if self.l2_bytes:
            parts.append(f"l2-{self.l2_bytes // 1024}k")
        if self.llc_bytes:
            parts.append(f"llc-{self.llc_bytes // 1024}k")
        if self.victim_entries:
            parts.append(f"victim{self.victim_entries}")
        if self.stream_buffers:
            parts.append(f"stream{self.stream_buffers}x{self.stream_depth}")
        if not self.prefetcher:
            parts.append("nopf")
        return "+".join(parts) if parts else "machine"

    def _l2_mechanisms(self) -> List:
        mechs: List = []
        if self.victim_entries:
            mechs.append(VictimCache(self.victim_entries))
        if self.miss_entries:
            mechs.append(MissCache(self.miss_entries))
        if self.stream_buffers:
            mechs.append(StreamBuffers(self.stream_buffers,
                                       self.stream_depth))
        return mechs


@dataclasses.dataclass(frozen=True)
class ParallelRun:
    """Raw result of one interleaved replay (final warm sweep)."""

    counters: List[EventCounters]        # one per thread
    accesses: np.ndarray                 # per-thread trace lengths
    sockets: np.ndarray                  # thread -> socket id
    pf_enabled: np.ndarray               # per-thread prefetcher state (bool)

    @property
    def n_threads(self) -> int:
        return len(self.counters)


def partitioned_traces(csr, partition, machine,
                       trace: Optional[np.ndarray] = None) -> List[np.ndarray]:
    """Per-thread slices of the *global* SpMV address trace.

    All threads address one shared layout (same x/val/idx/ptr/y bases as
    `spmv_address_trace`), so val/idx/ptr/y regions of different threads
    are disjoint while every thread gathers from the same x region —
    the sharing pattern that makes the LLC contended.  Concatenating the
    slices in part order reproduces the single-stream trace exactly.

    `trace` overrides the freshly-computed global trace so one trace can
    be sliced under many partitions (e.g. a cached
    `SpmvPlan.address_trace` replayed across a whole thread axis).
    """
    if trace is None:
        trace = spmv_address_trace(csr, machine)
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    starts = np.asarray(partition.starts, dtype=np.int64)
    # row r starts at trace position 2*r + 3*indptr[r]
    cuts = 2 * starts + 3 * indptr[starts]
    return [trace[cuts[t]:cuts[t + 1]] for t in range(len(starts) - 1)]


def nnz_partitioned_traces(csr, partition, machine,
                           trace: Optional[np.ndarray] = None
                           ) -> List[np.ndarray]:
    """Per-thread slices of the global SpMV trace at *nonzero* cuts
    (`core.partition.NnzPartition`, the merge-CSR execution).

    A cut at nonzero c inside row r starts the slice at c's own trace
    position 2*(r+1) + 3*c (the carry-out merge reconciles the shared
    row); a cut on a row boundary starts at that row's header 2*r + 3*c,
    so trailing empty rows stay with the preceding thread.  Concatenating
    the slices in part order reproduces the single-stream trace exactly.
    """
    if trace is None:
        trace = spmv_address_trace(csr, machine)
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cuts = np.asarray(partition.cuts, dtype=np.int64)
    # row containing each cut: last r with indptr[r] <= cut
    r = np.searchsorted(indptr, cuts, side="right") - 1
    on_boundary = indptr[r] == cuts
    pos = np.where(on_boundary, 2 * r + 3 * cuts, 2 * (r + 1) + 3 * cuts)
    # leading empty rows sit before the first cut's row: thread 0 owns them
    pos[0] = 0
    return [trace[pos[t]:pos[t + 1]] for t in range(len(cuts) - 1)]


def _socket_of(thread: int, machine) -> int:
    """Compact affinity with SMT-style wraparound: threads fill socket 0's
    cores first, then socket 1's, then oversubscribe from socket 0 again."""
    return (thread // machine.cores_per_socket) % max(machine.sockets, 1)


def replay_parallel(traces: Sequence, machine, spec: ParallelSpec,
                    sweeps: int = 2,
                    pf_enabled: Optional[Sequence[bool]] = None
                    ) -> ParallelRun:
    """Interleave the per-thread traces through private stacks + shared LLCs.

    `pf_enabled` masks individual threads' prefetchers (used by the
    §IV-C shutoff fixed point in `scaling.simulate_parallel`); `sweeps`
    repeats the whole interleaved replay against warm cache state and
    returns the counters of the final sweep, like `Hierarchy.run_trace`.
    """
    n_threads = len(traces)
    lb = machine.line_bytes
    if pf_enabled is None:
        pf_enabled = [spec.prefetcher] * n_threads

    sockets = np.array([_socket_of(t, machine) for t in range(n_threads)])
    llc_lines = (spec.llc_bytes or machine.l3_bytes) // lb
    shared_llc = {s: CacheLevel("L3", llc_lines, spec.llc_ways)
                  for s in sorted(set(sockets.tolist()))}

    hiers: List[Hierarchy] = []
    for t in range(n_threads):
        levels: List[CacheLevel] = []
        if spec.l1_bytes:
            levels.append(CacheLevel("L1", spec.l1_bytes // lb, spec.l1_ways))
        pf_level = len(levels)           # the prefetcher serves the L2
        levels.append(CacheLevel("L2", (spec.l2_bytes or machine.l2_bytes)
                                 // lb, spec.ways,
                                 mechanisms=spec._l2_mechanisms()))
        levels.append(shared_llc[int(sockets[t])])
        pf = (SequentialPrefetcher(machine.prefetch_streams)
              if pf_enabled[t] else None)
        hiers.append(Hierarchy(levels, pf, pf_level=pf_level))

    lists = [t.tolist() if isinstance(t, np.ndarray) else list(t)
             for t in traces]
    lens = [len(t) for t in lists]
    for _ in range(max(sweeps, 1)):
        counters = [EventCounters() for _ in range(n_threads)]
        accessors = [h.access for h in hiers]
        pos = [0] * n_threads
        left = sum(lens)
        while left:
            for t in range(n_threads):
                p = pos[t]
                if p < lens[t]:
                    accessors[t](lists[t][p], counters[t])
                    pos[t] = p + 1
                    left -= 1
    return ParallelRun(counters=counters,
                       accesses=np.array(lens, dtype=np.int64),
                       sockets=sockets,
                       pf_enabled=np.array(pf_enabled, dtype=bool))
