"""repro.parallel — multithreaded SpMV scaling engine.

The title axis of the paper ("Multithreaded Performance") made
executable: N threads, each replaying its `RowPartition` slice of the
SpMV demand stream through **private** L1/L2 caches while all threads on
a socket contend for one **shared** last-level cache and one DRAM link.
Replay is round-robin interleaved and fully deterministic, so
per-thread event counters are bit-identical across runs.

  engine    ParallelSpec, partitioned traces, the interleaved replay
  scaling   cycle/bandwidth/queueing time model, prefetcher-shutoff
            fixed point, speedup curves

The sweep harness with the thread axis lives in `repro.telemetry.sweep`
(`scaling_sweep`) and its reports in `repro.telemetry.report`
(`scaling_report`, `scaling_gap_report`); the hardware-side sharded
execution path is `repro.distributed.spmv`.
"""
from .engine import (ParallelRun, ParallelSpec, nnz_partitioned_traces,
                     partitioned_traces, replay_parallel)
from .scaling import (ParallelMetrics, parallel_metrics, simulate_parallel,
                      thread_cycles)

__all__ = [
    "ParallelRun", "ParallelSpec", "partitioned_traces",
    "nnz_partitioned_traces", "replay_parallel",
    "ParallelMetrics", "parallel_metrics", "simulate_parallel",
    "thread_cycles",
]
