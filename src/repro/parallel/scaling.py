"""Time model over a `ParallelRun`: latency, bandwidth, queueing, shutoff.

Turns per-thread event counters into one wall-time estimate per thread
count so speedup curves can be drawn.  The model reuses the single-core
constants (`telemetry.topdown.COMPUTE_CPN`, `MECH_HIT_CYCLES`,
`MachineModel.l3_hit_cycles/dram_cycles/mlp`) and adds the two
multithreaded effects the paper measures:

  * a per-socket DRAM **bandwidth floor** — all threads on a socket share
    one memory link, so execution time is at least the socket's DRAM
    line traffic divided by `dram_bw_gbs`; near saturation a queueing
    term inflates miss latency (same form as
    `cache_model.analytic_metrics_from_profile`);
  * the §IV-C **prefetcher shutoff** — when a socket's *demand* DRAM
    utilization exceeds `machine.pf_shutoff_util`, its threads' stream
    prefetchers turn off and the replay is repeated once with them
    disabled (a deterministic one-step fixed point: R-MAT's gather
    misses congest the link and kill the prefetcher; FD's don't).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from repro.telemetry import events as ev
# The single-core topdown model owns the calibration constants; sharing
# them (rather than re-stating the literals) keeps single-stream and
# multithreaded report rows comparable when either is re-tuned.
from repro.telemetry.topdown import COMPUTE_CPN, MECH_HIT_CYCLES

from .engine import ParallelRun, ParallelSpec, partitioned_traces, replay_parallel

# DRAM utilization above which queueing delay inflates miss latency, and
# the inflation cap (mirrors cache_model's saturated-DRAM stall term).
QUEUE_UTIL_KNEE = 0.8
QUEUE_UTIL_CAP = 1.0


def thread_cycles(c, machine, nnz: int) -> Tuple[float, float]:
    """(compute_cycles, stall_cycles) for one thread's counters."""
    mech_hits = c[ev.VICTIM_HIT] + c[ev.MISS_CACHE_HIT] + c[ev.STREAM_HIT]
    stall = (c[ev.L3_DEMAND_HIT] * machine.l3_hit_cycles
             + c[ev.L3_DEMAND_MISS] * machine.dram_cycles
             + mech_hits * MECH_HIT_CYCLES) / machine.mlp
    return nnz * COMPUTE_CPN, stall


@dataclasses.dataclass(frozen=True)
class ParallelMetrics:
    """Headline numbers for one (matrix, partition, spec) replay."""

    threads: int
    time_s: float                 # max(latency, bandwidth) after queueing
    lat_time_s: float             # slowest thread's cycle estimate
    bw_time_s: float              # slowest socket's DRAM-traffic floor
    dram_util: float              # bw_time / time (pre-queueing)
    demand_util: float            # demand-only DRAM utilization (max socket)
    dram_bytes: int               # total DRAM line traffic, all sockets
    pf_on_frac: float             # threads whose prefetcher stayed on
    nnz_per_thread: Tuple[int, ...]
    cycles_per_thread: Tuple[float, ...]
    l2_mpki: Tuple[float, ...]    # per-thread private-L2 demand MPKI
    llc_mpki: Tuple[float, ...]   # per-thread shared-LLC demand MPKI

    @property
    def l2_mpki_mean(self) -> float:
        return float(np.mean(self.l2_mpki)) if self.l2_mpki else 0.0

    @property
    def l2_mpki_max(self) -> float:
        return float(np.max(self.l2_mpki)) if self.l2_mpki else 0.0

    def gflops_est(self) -> float:
        nnz = sum(self.nnz_per_thread)
        return 2.0 * nnz / max(self.time_s, 1e-30) / 1e9


def parallel_metrics(run: ParallelRun, machine,
                     nnz_per_thread) -> ParallelMetrics:
    """Roll a replay into the time model (deterministic, pure function)."""
    lb = machine.line_bytes
    nnz_per_thread = tuple(int(v) for v in nnz_per_thread)
    freq = machine.freq_ghz * 1e9
    bw = machine.dram_bw_gbs * 1e9

    # SMT oversubscription: more threads than cores on a socket share issue
    # ports, multiplying compute cycles (stalls still overlap across SMT).
    socket_threads = {s: int(np.sum(run.sockets == s))
                      for s in set(run.sockets.tolist())}
    compute = np.empty(run.n_threads)
    stall = np.empty(run.n_threads)
    for t, c in enumerate(run.counters):
        compute[t], stall[t] = thread_cycles(c, machine, nnz_per_thread[t])
        compute[t] *= max(1.0, socket_threads[int(run.sockets[t])]
                          / machine.cores_per_socket)

    # DRAM line traffic per socket: demand fills + prefetcher fills (the
    # prefetcher pulls from memory; lines already LLC-resident are a small
    # minority for these streams, so all fills are charged to the link).
    sockets = sorted(set(run.sockets.tolist()))
    demand_b = {s: 0 for s in sockets}
    total_b = {s: 0 for s in sockets}
    for t, c in enumerate(run.counters):
        s = int(run.sockets[t])
        demand_b[s] += c[ev.L3_DEMAND_MISS] * lb
        total_b[s] += (c[ev.L3_DEMAND_MISS] + c[ev.L2_PREFETCH_FILL]) * lb

    lat_time = float(np.max(compute + stall)) / freq
    bw_time = max(total_b[s] / bw for s in sockets)
    time0 = max(lat_time, bw_time)
    dram_util = bw_time / max(time0, 1e-30)

    # queueing delay: near saturation, misses wait on the memory controller.
    # Normalized so the factor is 1.0 at the knee and grows continuously
    # (same 1/sqrt(headroom) shape as cache_model's saturated-DRAM term).
    if dram_util > QUEUE_UTIL_KNEE:
        u = min(dram_util, QUEUE_UTIL_CAP)
        stall = stall * math.sqrt((1.05 - QUEUE_UTIL_KNEE) / (1.05 - u))
        lat_time = float(np.max(compute + stall)) / freq
    time_s = max(lat_time, bw_time)
    demand_util = max(demand_b[s] / bw for s in sockets) / max(time_s, 1e-30)

    kinst = np.maximum(np.array(nnz_per_thread, dtype=np.float64)
                       * machine.instr_per_nnz / 1e3, 1e-12)
    l2_mpki = tuple(c[ev.L2_DEMAND_MISS] / k
                    for c, k in zip(run.counters, kinst))
    llc_mpki = tuple(c[ev.L3_DEMAND_MISS] / k
                     for c, k in zip(run.counters, kinst))
    return ParallelMetrics(
        threads=run.n_threads,
        time_s=time_s, lat_time_s=lat_time, bw_time_s=bw_time,
        dram_util=dram_util, demand_util=min(demand_util, 1.0),
        dram_bytes=int(sum(total_b.values())),
        pf_on_frac=float(np.mean(run.pf_enabled)) if run.n_threads else 0.0,
        nnz_per_thread=nnz_per_thread,
        cycles_per_thread=tuple(float(v) for v in compute + stall),
        l2_mpki=l2_mpki, llc_mpki=llc_mpki,
    )


def simulate_parallel(csr, partition, machine, spec: ParallelSpec,
                      sweeps: int = 2,
                      traces: Optional[list] = None,
                      trace=None) -> Tuple[ParallelRun, ParallelMetrics]:
    """Replay a partitioned matrix and apply the prefetcher-shutoff
    fixed point.  Returns the final (run, metrics) pair.

    `traces` overrides the partition-derived traces (prebuilt ones can be
    shared across specs, like `sweep.run_point` does for mechanisms);
    `trace` is the lighter variant: one prebuilt *global* trace, sliced
    here per partition (what `scaling_sweep` passes from the matrix's
    cached plan so the thread axis replays one trace).
    """
    if traces is None:
        traces = partitioned_traces(csr, partition, machine, trace=trace)
    nnz = np.asarray(partition.nnz_per_part, dtype=np.int64)
    run = replay_parallel(traces, machine, spec, sweeps=sweeps)
    metrics = parallel_metrics(run, machine, nnz)

    if spec.prefetcher and spec.pf_shutoff:
        # per-socket demand utilization decides which sockets lose their
        # prefetchers; one extra deterministic pass applies the decision
        lb, bw = machine.line_bytes, machine.dram_bw_gbs * 1e9
        shut = set()
        for s in sorted(set(run.sockets.tolist())):
            demand = sum(run.counters[t][ev.L3_DEMAND_MISS] * lb
                         for t in range(run.n_threads)
                         if int(run.sockets[t]) == s)
            if demand / bw / max(metrics.time_s, 1e-30) \
                    > machine.pf_shutoff_util:
                shut.add(s)
        if shut:
            mask = [int(run.sockets[t]) not in shut
                    for t in range(run.n_threads)]
            run = replay_parallel(traces, machine, spec, sweeps=sweeps,
                                  pf_enabled=mask)
            metrics = parallel_metrics(run, machine, nnz)
    return run, metrics
