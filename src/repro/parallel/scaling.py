"""Time model over a `ParallelRun`: latency, bandwidth, queueing, shutoff.

Turns per-thread event counters into one wall-time estimate per thread
count so speedup curves can be drawn.  The model is built on the staged
topdown attribution (`telemetry.topdown.stage_cycles`): every thread's
counters become a `TopdownStages` record, the machine-level roll-up
(`machine_stages`) adds the per-socket DRAM **bandwidth floor** as its
own stage, and the run's total cycle count is *defined* as the staged
sum — so stage cycles always sum bit-exactly to the reported total
(the contract `tests/test_topdown_invariants.py` pins).

The two multithreaded effects the paper measures:

  * a per-socket DRAM **bandwidth floor** — all threads on a socket share
    one memory link, so execution time is at least the socket's DRAM
    line traffic divided by `dram_bw_gbs`; near saturation a queueing
    term inflates miss latency (same form as
    `cache_model.analytic_metrics_from_profile`) and lands in the
    `backend_contention` stage;
  * the §IV-C **prefetcher shutoff** — when a socket's *demand* DRAM
    utilization exceeds `machine.pf_shutoff_util`, its threads' stream
    prefetchers turn off and the replay is repeated once with them
    disabled (a deterministic one-step fixed point: R-MAT's gather
    misses congest the link and kill the prefetcher; FD's don't).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from repro.telemetry import events as ev
# The single-core topdown model owns the calibration constants; sharing
# them (rather than re-stating the literals) keeps single-stream and
# multithreaded report rows comparable when either is re-tuned.
from repro.telemetry.topdown import (COMPUTE_CPN, MECH_HIT_CYCLES,
                                     TopdownStages, machine_stages,
                                     stage_cycles)

from .engine import ParallelRun, ParallelSpec, partitioned_traces, replay_parallel

# DRAM utilization above which queueing delay inflates miss latency, and
# the inflation cap (mirrors cache_model's saturated-DRAM stall term).
QUEUE_UTIL_KNEE = 0.8
QUEUE_UTIL_CAP = 1.0


def thread_cycles(c, machine, nnz: int) -> Tuple[float, float]:
    """(compute_cycles, stall_cycles) for one thread's counters.

    Compatibility wrapper over `stage_cycles`; the staged record is the
    primary representation."""
    s = stage_cycles(c, machine, nnz)
    return s.retiring, s.backend_l2 + s.backend_llc + s.backend_dram


@dataclasses.dataclass(frozen=True)
class ParallelMetrics:
    """Headline numbers for one (matrix, partition, spec) replay."""

    threads: int
    time_s: float                 # total_cycles / freq (staged sum)
    lat_time_s: float             # slowest thread's cycle estimate
    bw_time_s: float              # slowest socket's DRAM-traffic floor
    dram_util: float              # bw_time / time (pre-queueing)
    demand_util: float            # demand-only DRAM utilization (max socket)
    dram_bytes: int               # total DRAM line traffic, all sockets
    pf_on_frac: float             # threads whose prefetcher stayed on
    nnz_per_thread: Tuple[int, ...]
    cycles_per_thread: Tuple[float, ...]
    l2_mpki: Tuple[float, ...]    # per-thread private-L2 demand MPKI
    llc_mpki: Tuple[float, ...]   # per-thread shared-LLC demand MPKI
    # staged attribution: machine-level roll-up (critical thread +
    # bandwidth-floor stage) and the per-thread records behind it.
    # total_cycles == stages.total_cycles() bit-exactly, and
    # time_s == total_cycles / (freq_ghz * 1e9).
    stages: TopdownStages = dataclasses.field(default_factory=TopdownStages)
    thread_stages: Tuple[TopdownStages, ...] = ()
    total_cycles: float = 0.0

    @property
    def l2_mpki_mean(self) -> float:
        return float(np.mean(self.l2_mpki)) if self.l2_mpki else 0.0

    @property
    def l2_mpki_max(self) -> float:
        return float(np.max(self.l2_mpki)) if self.l2_mpki else 0.0

    def gflops_est(self) -> float:
        nnz = sum(self.nnz_per_thread)
        return 2.0 * nnz / max(self.time_s, 1e-30) / 1e9

    def bound(self) -> str:
        """Dominant machine-level stage name (e.g. 'backend_dram')."""
        return self.stages.bound()


def parallel_metrics(run: ParallelRun, machine, nnz_per_thread,
                     queueing: bool = True) -> ParallelMetrics:
    """Roll a replay into the time model (deterministic, pure function).

    `queueing=False` drops the saturation queueing term (the
    `backend_contention` stage stays 0); `simulate_parallel` forwards
    `ParallelSpec.queueing` here.
    """
    lb = machine.line_bytes
    nnz_per_thread = tuple(int(v) for v in nnz_per_thread)
    freq = machine.freq_ghz * 1e9
    bw = machine.dram_bw_gbs * 1e9

    # SMT oversubscription: more threads than cores on a socket share issue
    # ports; the excess lands in the frontend stage (stalls still overlap
    # across SMT).
    socket_threads = {s: int(np.sum(run.sockets == s))
                      for s in set(run.sockets.tolist())}
    smt = [max(1.0, socket_threads[int(run.sockets[t])]
               / machine.cores_per_socket) for t in range(run.n_threads)]
    base = [stage_cycles(c, machine, nnz_per_thread[t], smt_factor=smt[t])
            for t, c in enumerate(run.counters)]

    # DRAM line traffic per socket: demand fills + prefetcher fills (the
    # prefetcher pulls from memory; lines already LLC-resident are a small
    # minority for these streams, so all fills are charged to the link).
    sockets = sorted(set(run.sockets.tolist()))
    demand_b = {s: 0 for s in sockets}
    total_b = {s: 0 for s in sockets}
    for t, c in enumerate(run.counters):
        s = int(run.sockets[t])
        demand_b[s] += c[ev.L3_DEMAND_MISS] * lb
        total_b[s] += (c[ev.L3_DEMAND_MISS] + c[ev.L2_PREFETCH_FILL]) * lb

    totals = [s.total_cycles() for s in base]
    lat_time = max(totals) / freq if totals else 0.0
    bw_time = max(total_b[s] / bw for s in sockets)
    time0 = max(lat_time, bw_time)
    dram_util = bw_time / max(time0, 1e-30)

    # queueing delay: near saturation, misses wait on the memory controller.
    # Normalized so the factor is 1.0 at the knee and grows continuously
    # (same 1/sqrt(headroom) shape as cache_model's saturated-DRAM term);
    # the inflation is attributed to the backend_contention stage.
    per_thread = base
    if queueing and dram_util > QUEUE_UTIL_KNEE:
        u = min(dram_util, QUEUE_UTIL_CAP)
        q = math.sqrt((1.05 - QUEUE_UTIL_KNEE) / (1.05 - u))
        per_thread = [stage_cycles(c, machine, nnz_per_thread[t],
                                   smt_factor=smt[t], queue_factor=q)
                      for t, c in enumerate(run.counters)]
        totals = [s.total_cycles() for s in per_thread]
        lat_time = max(totals) / freq if totals else 0.0

    # machine roll-up: critical thread + bandwidth-floor excess.  The
    # staged sum IS the total — time_s is derived from it, never the
    # other way around, which is what makes the accounting bit-exact.
    stages = machine_stages(per_thread, bw_time * freq)
    total_cycles = stages.total_cycles()
    time_s = total_cycles / freq
    demand_util = max(demand_b[s] / bw for s in sockets) / max(time_s, 1e-30)

    kinst = np.maximum(np.array(nnz_per_thread, dtype=np.float64)
                       * machine.instr_per_nnz / 1e3, 1e-12)
    l2_mpki = tuple(c[ev.L2_DEMAND_MISS] / k
                    for c, k in zip(run.counters, kinst))
    llc_mpki = tuple(c[ev.L3_DEMAND_MISS] / k
                     for c, k in zip(run.counters, kinst))
    return ParallelMetrics(
        threads=run.n_threads,
        time_s=time_s, lat_time_s=lat_time, bw_time_s=bw_time,
        dram_util=dram_util, demand_util=min(demand_util, 1.0),
        dram_bytes=int(sum(total_b.values())),
        pf_on_frac=float(np.mean(run.pf_enabled)) if run.n_threads else 0.0,
        nnz_per_thread=nnz_per_thread,
        cycles_per_thread=tuple(totals),
        l2_mpki=l2_mpki, llc_mpki=llc_mpki,
        stages=stages, thread_stages=tuple(per_thread),
        total_cycles=total_cycles,
    )


def simulate_parallel(csr, partition, machine, spec: ParallelSpec,
                      sweeps: int = 2,
                      traces: Optional[list] = None,
                      trace=None) -> Tuple[ParallelRun, ParallelMetrics]:
    """Replay a partitioned matrix and apply the prefetcher-shutoff
    fixed point.  Returns the final (run, metrics) pair.

    `traces` overrides the partition-derived traces (prebuilt ones can be
    shared across specs, like `sweep.run_point` does for mechanisms);
    `trace` is the lighter variant: one prebuilt *global* trace, sliced
    here per partition (what `scaling_sweep` passes from the matrix's
    cached plan so the thread axis replays one trace).
    """
    if traces is None:
        traces = partitioned_traces(csr, partition, machine, trace=trace)
    nnz = np.asarray(partition.nnz_per_part, dtype=np.int64)
    run = replay_parallel(traces, machine, spec, sweeps=sweeps)
    metrics = parallel_metrics(run, machine, nnz, queueing=spec.queueing)

    if spec.prefetcher and spec.pf_shutoff:
        # per-socket demand utilization decides which sockets lose their
        # prefetchers; one extra deterministic pass applies the decision
        lb, bw = machine.line_bytes, machine.dram_bw_gbs * 1e9
        shut = set()
        for s in sorted(set(run.sockets.tolist())):
            demand = sum(run.counters[t][ev.L3_DEMAND_MISS] * lb
                         for t in range(run.n_threads)
                         if int(run.sockets[t]) == s)
            if demand / bw / max(metrics.time_s, 1e-30) \
                    > machine.pf_shutoff_util:
                shut.add(s)
        if shut:
            mask = [int(run.sockets[t]) not in shut
                    for t in range(run.n_threads)]
            run = replay_parallel(traces, machine, spec, sweeps=sweeps,
                                  pf_enabled=mask)
            metrics = parallel_metrics(run, machine, nnz,
                                       queueing=spec.queueing)
    return run, metrics
