"""Kimi K2 -- trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=112,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert_ff=2048,
                  n_shared_experts=1, first_moe_layer=1),
    source="arXiv:2501.kimi2 (paper-table); first layer dense, 1 shared expert",
)
