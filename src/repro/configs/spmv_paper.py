"""The paper's own experiment config: FD + R-MAT sweeps on Sandy Bridge."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SpMVExperimentConfig:
    min_log2_rows: int = 11
    max_log2_rows: int = 26
    thread_counts: tuple = (1, 2, 4, 8, 16)
    fd_nnz_per_row: int = 9
    rmat_nnz_per_row: int = 8
    constant_work: int = 2 ** 33     # runs = 2^33 / nnz (paper §III-A)


CONFIG = SpMVExperimentConfig()
