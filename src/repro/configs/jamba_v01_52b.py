"""Jamba v0.1 -- Mamba+attention 1:7 interleave with 16-expert MoE [arXiv:2403.19887]."""
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    # 1 attention layer per 8 (1:7 attn:mamba), MoE every 2 layers
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert_ff=14336,
                  first_moe_layer=1, moe_every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    subquadratic=True,
    source="arXiv:2403.19887; 4 attn layers of 32, KV tiny at 500k",
)
