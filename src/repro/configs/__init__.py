"""Architecture registry: --arch <id> resolves here."""
from . import (arctic_480b, chameleon_34b, granite_8b, jamba_v01_52b,
               kimi_k2_1t_a32b, qwen2_72b, rwkv6_3b, stablelm_1_6b,
               starcoder2_15b, whisper_large_v3)
from .base import SHAPES, ModelConfig, ShapeConfig, applicable_shapes

_MODULES = [kimi_k2_1t_a32b, arctic_480b, whisper_large_v3, rwkv6_3b,
            jamba_v01_52b, granite_8b, stablelm_1_6b, starcoder2_15b,
            qwen2_72b, chameleon_34b]

CONFIGS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_IDS = sorted(CONFIGS)


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch '{name}'; have {ARCH_IDS}")
    return CONFIGS[name]
